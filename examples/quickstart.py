"""Quickstart: NVCache as a plug-and-play I/O booster.

Runs in seconds on CPU:
  1. open a file through NVCache and write — durable at NVMM speed;
  2. read it back (read-your-writes while the slow tier is stale);
  3. pull the power mid-flight, run the paper's recovery, verify no
     committed byte was lost;
  4. train a tiny LM with NVCache-backed checkpoints and resume it.

Usage:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.registry import get_smoke
from repro.core import NVCache, Policy, recover
from repro.data.pipeline import SyntheticTokens
from repro.models.registry import build
from repro.optim.adamw import AdamW
from repro.storage.fsapi import NVCacheFS
from repro.storage.tiers import DRAM, SSD_SATA, Tier
from repro.train import loop as train_loop

POL = Policy(entry_size=4096, log_entries=4096, read_cache_pages=64,
             batch_min=16, batch_max=256)


def io_booster_demo():
    print("== 1-3: write / read / crash / recover ==")
    tier = Tier(SSD_SATA, sync=False)          # the slow tier ("SSD")
    nv = NVCache(POL, tier, track_crashes=True)
    fd = nv.open("/demo.dat")
    nv.pwrite(fd, b"synchronously durable!" * 100, 0)
    assert nv.pread(fd, 22, 0) == b"synchronously durable!"
    print("   write returned -> bytes are durable in the NVMM log")
    print(f"   log entries in flight: {nv.log.used_entries}")

    nvmm = nv.crash()                          # power loss, nothing drained
    print("   power loss! recovering from the NVMM log...")
    tier2 = Tier(SSD_SATA, sync=False)
    stats = recover(nvmm, POL, tier2.open)
    got = tier2.open("/demo.dat").snapshot()
    assert got[:22] == b"synchronously durable!"
    print(f"   recovered {stats.entries_replayed} entries, "
          f"{stats.bytes_replayed} bytes — no committed write lost\n")


def training_demo():
    print("== 4: training with NVCache-backed checkpoints ==")
    cfg = get_smoke("llama3.2-1b")
    model = build(cfg)
    nv = NVCache(POL, Tier(DRAM))
    fs = NVCacheFS(nv)
    pipe = SyntheticTokens(cfg.vocab, 2, 32, seed=0)
    _, hist = train_loop.train(model, AdamW(lr=1e-3), pipe, fs,
                               total_steps=20, ckpt_every=10)
    print(f"   trained 20 steps: loss {hist[0]['loss']:.3f} -> "
          f"{hist[-1]['loss']:.3f}")
    # resume: a fresh loop picks up at the last durable checkpoint
    pipe2 = SyntheticTokens(cfg.vocab, 2, 32, seed=0)
    _, hist2 = train_loop.train(model, AdamW(lr=1e-3), pipe2, fs,
                                total_steps=25, ckpt_every=10)
    print(f"   resumed at step 20, ran {len(hist2)} more steps")
    nv.shutdown()


if __name__ == "__main__":
    io_booster_demo()
    training_demo()
    print("quickstart OK")
