"""Crash-recovery torture demo: a kvlite database over NVCache is killed at
a random point under write load; after the paper's recovery procedure the
database replays its (NVCache-boosted) data log and every acknowledged
write is present.

Usage:  PYTHONPATH=src python examples/crash_recovery_demo.py [seed]
"""
import sys

import numpy as np

from repro.core import NVCache, Policy, recover
from repro.storage.fsapi import NVCacheFS, TierFS
from repro.storage.kvlite import KVLite
from repro.storage.tiers import DRAM, Tier

POL = Policy(entry_size=1024, log_entries=512, page_size=1024,
             read_cache_pages=32, batch_min=8, batch_max=64)


def main(seed: int = 0):
    rng = np.random.default_rng(seed)
    tier = Tier(DRAM)
    nv = NVCache(POL, tier, track_crashes=True)
    db = KVLite(NVCacheFS(nv), "/db", sync=True)

    crash_at = int(rng.integers(50, 400))
    acknowledged = {}
    for i in range(crash_at):
        k = f"key{int(rng.integers(0, 64)):03d}".encode()
        v = rng.bytes(int(rng.integers(10, 200)))
        db.put(k, v)
        acknowledged[k] = v                  # put returned => durable

    print(f"power loss after {crash_at} acknowledged puts "
          f"({nv.log.used_entries} entries still in the NVMM log)")
    nvmm = nv.crash(choose_evicted=lambda lines: [
        l for l in lines if rng.random() < 0.5])   # adversarial eviction

    tier2 = Tier(DRAM)
    for path in tier.paths():
        snap = tier.open(path).snapshot()
        if snap:
            tier2.open(path).pwrite(snap, 0)
    stats = recover(nvmm, POL, tier2.open)
    print(f"recovery replayed {stats.entries_replayed} entries")

    db2 = KVLite(TierFS(tier2), "/db", sync=True)
    missing = sum(1 for k, v in acknowledged.items() if db2.get(k) != v)
    print(f"verified {len(acknowledged)} acknowledged keys: {missing} missing")
    assert missing == 0, "DURABILITY VIOLATION"
    print("OK — every acknowledged write survived the crash")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 0)
