"""End-to-end training driver.

Presets:
  tiny   ~0.1M params,  fast CPU demo (default here)
  small  ~10M params,   minutes on CPU
  100m   ~100M params,  the deliverable scale — a few hundred steps
                        (hours on this 1-core container; sized for a real host)

Every preset trains with the NVCache persistence stack: synchronous-
durability checkpoints, resumable data pipeline, metrics JSONL.

Usage:  PYTHONPATH=src python examples/train_e2e.py --preset tiny --steps 30
"""
import argparse
import dataclasses

from repro.configs.registry import get_smoke
from repro.core import NVCache, Policy
from repro.data.pipeline import SyntheticTokens
from repro.models.common import ModelConfig
from repro.models.registry import build
from repro.optim.adamw import AdamW
from repro.optim.schedules import warmup_cosine
from repro.storage.fsapi import NVCacheFS
from repro.storage.tiers import BLOB, Tier
from repro.train import loop as train_loop

PRESETS = {
    "tiny": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                 vocab=512, head_dim=16, batch=4, seq=64),
    "small": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                  vocab=8192, head_dim=32, batch=4, seq=128),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                 vocab=32768, head_dim=64, batch=8, seq=512),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args(argv)

    p = dict(PRESETS[args.preset])
    batch, seq = p.pop("batch"), p.pop("seq")
    cfg = ModelConfig(arch=f"e2e-{args.preset}", family="dense",
                      tie_embeddings=True, attn_block=256, **p)
    model = build(cfg)
    print(f"model: {cfg.param_count() / 1e6:.1f}M params")

    nv = NVCache(Policy(entry_size=65536, log_entries=4096,
                        read_cache_pages=256, batch_min=16, batch_max=1024,
                        verify_crc=False), Tier(BLOB))
    pipe = SyntheticTokens(cfg.vocab, batch, seq, seed=0)
    _, hist = train_loop.train(model, AdamW(lr=3e-4,
                                            schedule=warmup_cosine(20, args.steps)),
                               pipe, NVCacheFS(nv), total_steps=args.steps,
                               ckpt_every=args.ckpt_every)
    print(f"steps: {len(hist)}  loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    print(f"avg step time: {sum(h['step_time'] for h in hist) / len(hist):.3f}s")
    print("nvcache:", nv.stats())
    nv.shutdown()


if __name__ == "__main__":
    main()
