"""Crash-consistency properties of the NVMM log protocol (paper §II-B/§III).

The simulated NVMM tracks durability at cacheline granularity; ``crash()``
lets hypothesis choose *which* un-flushed dirty lines happened to reach the
persistence domain.  The properties:

  P1 (synchronous durability): every write whose call returned before the
     crash is fully recovered, for EVERY adversarial eviction choice.
  P2 (atomicity): a write interrupted before its group-head commit is
     recovered either fully or not at all — never partially.
  P3 (order): recovery applies surviving writes in application order, so
     the final byte state equals replaying the completed prefix in order.
"""
import os

import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:                      # container without hypothesis
    from _propcheck import HealthCheck, given, settings, strategies as st

from repro.core import NVCache, NVMM, Policy, recover
from repro.core.log import NVLog
from repro.storage.tiers import DRAM, Tier

POL = Policy(entry_size=192, log_entries=32, page_size=256,
             read_cache_pages=4, batch_min=2, batch_max=8)

writes_st = st.lists(
    st.tuples(st.integers(0, 2000),                   # offset
              st.binary(min_size=1, max_size=700)),   # data (multi-entry ok)
    min_size=1, max_size=12)


def apply_all(writes):
    img = bytearray()
    for off, data in writes:
        if off + len(data) > len(img):
            img.extend(b"\x00" * (off + len(data) - len(img)))
        img[off:off + len(data)] = data
    return bytes(img)


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(writes=writes_st, evict=st.data())
def test_p1_completed_writes_survive_any_crash(writes, evict):
    tier = Tier(DRAM)
    nv = NVCache(POL, tier, track_crashes=True)
    fd = nv.open("/f")
    for off, data in writes:
        nv.pwrite(fd, data, off)
    # power loss with adversarial eviction of un-flushed lines
    nvmm = nv.crash(choose_evicted=lambda lines: evict.draw(
        st.sets(st.sampled_from(sorted(lines)) if lines else st.nothing(),
                max_size=len(lines))) if lines else [])
    tier2 = Tier(DRAM)
    # pre-drained bytes live in the old tier; copy them over (the slow tier
    # itself is durable storage)
    for path in tier.paths():
        snap = tier.open(path).snapshot()
        if snap:
            tier2.open(path).pwrite(snap, 0)
    recover(nvmm, POL, tier2.open)
    got = tier2.open("/f").snapshot()
    exp = apply_all(writes)
    assert got[:len(exp)] == exp
    assert all(b == 0 for b in got[len(exp):])


@settings(max_examples=40, deadline=None)
@given(presize=st.integers(0, 500),
       torn_off=st.integers(0, 500),
       torn=st.binary(min_size=POL.entry_size, max_size=POL.entry_size * 3))
def test_p2_uncommitted_group_never_partially_recovered(presize, torn_off, torn):
    """Fill a multi-entry group but crash before the head commit."""
    tier = Tier(DRAM)
    nvmm = NVMM(POL.nvmm_bytes, track=True)
    log = NVLog(nvmm, POL, format=True)
    log.fd_table_set(0, "/f")
    if presize:
        log.append(0, 0, b"\x11" * presize)           # committed baseline
    # torn write: followers + head filled and flushed, but NO commit flag
    sh = log.shards[0]
    ed = POL.entry_data
    k = log.entries_needed(len(torn))
    head, seq = sh.alloc(k, seq_source=log.next_seq)
    for j in range(1, k):
        sh.fill_entry(head + j, 0, torn_off + j * ed, torn[j * ed:(j + 1) * ed],
                      cg=head + 2, seq=seq)
    sh.fill_entry(head, 0, torn_off, torn[:ed], cg=0, seq=seq)
    nvmm.pfence()
    nvmm.crash()                                       # nothing else evicted
    stats = recover(nvmm, POL, tier.open)
    got = tier.open("/f").snapshot()
    exp = b"\x11" * presize
    assert got[:presize] == exp
    # no byte of the torn write may appear beyond the committed baseline
    if len(got) > presize:
        assert all(b == 0 for b in got[presize:])
    assert stats.entries_replayed == (1 if presize and presize <= ed
                                      else log.entries_needed(presize) if presize else 0)


@settings(max_examples=30, deadline=None)
@given(writes=writes_st)
def test_p3_order_preserved_through_wraparound(writes):
    """Many overlapping writes >> log capacity: final state == in-order replay."""
    tier = Tier(DRAM)
    nv = NVCache(POL, tier, track_crashes=True)
    fd = nv.open("/f")
    for rep in range(4):                               # force wraparound
        for off, data in writes:
            nv.pwrite(fd, data, off)
    nvmm = nv.crash()                                  # nothing evicted
    tier2 = Tier(DRAM)
    for path in tier.paths():
        snap = tier.open(path).snapshot()
        if snap:
            tier2.open(path).pwrite(snap, 0)
    recover(nvmm, POL, tier2.open)
    exp = apply_all(writes * 4)
    got = tier2.open("/f").snapshot()
    assert got[:len(exp)] == exp


def test_commit_flag_alone_is_not_enough_without_data_flush():
    """Sanity check of the crash model itself: if the protocol forgot the
    pfence before the commit, adversarial eviction could surface a committed
    entry with lost data — our CRC would catch it.  Here we verify the fence
    ordering the protocol does perform: data lines are durable whenever the
    commit line is."""
    nvmm = NVMM(POL.nvmm_bytes, track=True)
    log = NVLog(nvmm, POL, format=True)
    log.fd_table_set(0, "/f")
    log.append(0, 0, b"\xabcd".ljust(64, b"\x99"))
    nvmm.crash()                                       # drop all un-flushed
    tier = Tier(DRAM)
    stats = recover(nvmm, POL, tier.open)
    assert stats.entries_replayed == 1
    assert stats.crc_failures == 0
    assert tier.open("/f").snapshot()[:64] == b"\xabcd".ljust(64, b"\x99")


def test_recovery_resets_log_and_fd_table():
    nvmm = NVMM(POL.nvmm_bytes, track=True)
    log = NVLog(nvmm, POL, format=True)
    log.fd_table_set(3, "/x")
    log.append(3, 10, b"hello")
    nvmm.crash()
    tier = Tier(DRAM)
    recover(nvmm, POL, tier.open)
    log2 = NVLog(nvmm, POL, format=False)
    assert log2.persistent_tail == 0
    assert log2.fd_table_get(3) is None
    assert tier.open("/x").snapshot()[10:15] == b"hello"
