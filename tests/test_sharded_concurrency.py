"""Many-writer stress over the sharded log (K ∈ {1, 2, 4}).

Asserts (a) durable linearizability — a reader never observes a value that
no writer has started committing, and after a crash every value any reader
*did* observe is durable; (b) tail discipline — per shard,
``volatile_tail <= persistent_tail <= head`` and ``head - volatile_tail``
never exceeds the shard size, i.e. undrained entries are never recycled.
"""
import struct
import threading

import pytest

from repro.core import NVCache, Policy
from repro.storage.tiers import DRAM, Tier

N_WRITERS = 8
PAGES_PER_WRITER = 4
OPS_PER_WRITER = 25


def make_policy(k: int) -> Policy:
    return Policy(entry_size=1024, log_entries=64 * k, page_size=1024,
                  read_cache_pages=8, batch_min=8, batch_max=32,
                  shards=k, shard_route="stripe", stripe_pages=1)


def page_bytes(counter: int, ps: int) -> bytes:
    return struct.pack("<I", counter) * (ps // 4)


def decode_page(page: bytes):
    """Returns the uniform 4-byte counter, or None if the page is torn."""
    word = page[:4]
    if word * (len(page) // 4) != page:
        return None
    return struct.unpack("<I", word)[0]


class InvariantSampler(threading.Thread):
    """Polls every shard's tails while writers hammer the log."""

    def __init__(self, nv):
        super().__init__(daemon=True)
        self.nv = nv
        self.stop = threading.Event()
        self.violations = []
        self.samples = 0

    def run(self):
        while not self.stop.is_set():
            for sh in self.nv.log.shards:
                # read order makes each comparison race-free: ptail is
                # monotone and always written before the matching vtail
                ptail_before = sh.persistent_tail
                with sh._lock:
                    vtail, head = sh.volatile_tail, sh.head
                ptail_after = sh.persistent_tail
                self.samples += 1
                if vtail > ptail_after:
                    self.violations.append(
                        f"shard {sh.sid}: vtail={vtail} recycled past "
                        f"ptail={ptail_after} (undrained entries reused)")
                if ptail_before > head:
                    self.violations.append(
                        f"shard {sh.sid}: ptail={ptail_before} beyond head={head}")
                if head - vtail > sh.n:
                    self.violations.append(
                        f"shard {sh.sid}: overbooked head={head} vtail={vtail}")


def run_stress(nv, started, observed, n_reads=300):
    """Writers own disjoint pages; readers check atomicity + admissibility."""
    ps = nv.policy.page_size
    fd = nv.open("/f")
    errors = []

    def writer(w):
        try:
            for i in range(OPS_PER_WRITER):
                p = w * PAGES_PER_WRITER + i % PAGES_PER_WRITER
                c = (w << 16) | (i + 1)
                started[p] = c                  # published BEFORE any byte lands
                nv.pwrite(fd, page_bytes(c, ps), p * ps)
        except Exception as exc:                # pragma: no cover - surfaced below
            errors.append(exc)

    def reader():
        try:
            npages = N_WRITERS * PAGES_PER_WRITER
            for i in range(n_reads):
                p = i % npages
                page = nv.pread(fd, ps, p * ps)
                if not page.strip(b"\x00"):
                    continue                    # not written yet
                c = decode_page(page)
                assert c is not None, f"torn page {p}"
                assert c <= started[p], \
                    f"page {p}: observed {c:#x} before any writer started it"
                observed[p] = max(observed[p], c)
        except Exception as exc:
            errors.append(exc)

    ws = [threading.Thread(target=writer, args=(w,)) for w in range(N_WRITERS)]
    rs = [threading.Thread(target=reader) for _ in range(2)]
    for t in ws + rs:
        t.start()
    for t in ws + rs:
        t.join(timeout=120)
    if errors:
        raise errors[0]
    return fd


@pytest.mark.parametrize("k", [1, 2, 4])
def test_many_writers_tails_never_recycle_undrained(k):
    nv = NVCache(make_policy(k), Tier(DRAM))
    npages = N_WRITERS * PAGES_PER_WRITER
    started, observed = [0] * npages, [0] * npages
    sampler = InvariantSampler(nv)
    sampler.start()
    try:
        fd = run_stress(nv, started, observed)
    finally:
        sampler.stop.set()
        sampler.join(timeout=30)
    assert sampler.samples > 0
    assert not sampler.violations, sampler.violations[:3]
    nv.flush()
    assert nv.log.used_entries == 0
    # every page ends at its writer's final counter (no lost/stale drain)
    ps = nv.policy.page_size
    for w in range(N_WRITERS):
        for j in range(PAGES_PER_WRITER):
            p = w * PAGES_PER_WRITER + j
            last = max(i + 1 for i in range(OPS_PER_WRITER)
                       if i % PAGES_PER_WRITER == j)
            assert decode_page(nv.pread(fd, ps, p * ps)) == \
                ((w << 16) | last), f"page {p}"
    nv.shutdown()


@pytest.mark.parametrize("k", [1, 2, 4])
def test_crash_after_stress_every_observed_write_is_durable(k):
    """Durable linearizability under crash: drop every un-flushed line; any
    value a reader observed before the crash must still be recovered."""
    from repro.core import recover

    tier = Tier(DRAM)
    nv = NVCache(make_policy(k), tier, track_crashes=True)
    npages = N_WRITERS * PAGES_PER_WRITER
    started, observed = [0] * npages, [0] * npages
    run_stress(nv, started, observed)
    nvmm = nv.crash()                       # nothing evicted: worst case
    tier2 = Tier(DRAM)
    for path in tier.paths():
        snap = tier.open(path).snapshot()
        if snap:
            tier2.open(path).pwrite(snap, 0)
    recover(nvmm, nv.policy, tier2.open)
    got = tier2.open("/f").snapshot()
    ps = nv.policy.page_size
    for p in range(npages):
        page = got[p * ps:(p + 1) * ps]
        if len(page) < ps:
            page = page + b"\x00" * (ps - len(page))
        c = decode_page(page)
        assert c is not None, f"page {p} torn after recovery"
        assert c >= observed[p], \
            (f"page {p}: reader observed {observed[p]:#x} before the crash "
             f"but recovery produced {c:#x} — an observed write was lost")
