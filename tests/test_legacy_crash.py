"""Fuse-randomized crash consistency of the legacy workload models
(PR 5 acceptance): SQLite rollback-journal + WAL, RocksDB-style
WAL+MANIFEST, over NVCache with K ∈ {1, 2, 4} shards.

Each trial runs the unmodified application protocol over NVCacheFS with a
fuse wired into the NVMM that blows at a uniformly random persistence-
protocol point; the crash then adversarially evicts half the un-flushed
cachelines.  After NVCache recovery, the *application's own* recovery
runs over the recovered tier (TierFS — the app is legacy code, it runs on
anything), and the model's oracle must observe a legal state:

* every transaction acknowledged before the crash is present;
* the in-flight transaction is whole or absent — never torn;
* no resurrected journal/WAL (unlink is the rollback-journal commit
  point; a WAL that outlives its MANIFEST double-applies records);
* the read path stayed full-scan-free (``stats_full_scans == 0``).
"""
import random

import pytest

from repro.core import NVCache, Policy
from repro.storage.fsapi import NVCacheFS, TierFS
from repro.storage.legacy import RocksLite, SQLiteRollbackDB, SQLiteWALDB
from repro.storage.tiers import DRAM, Tier
from test_namespace import ThreadFusedNVMM, clone_tier
from test_sharded_recovery import PowerLoss


def make_policy(k: int) -> Policy:
    return Policy(entry_size=256, log_entries=256 * k, page_size=256,
                  read_cache_pages=16, batch_min=4, batch_max=32,
                  shards=k, shard_route="fdid")


def _run_sqlite_rj(fs, tracker):
    db = SQLiteRollbackDB(fs, page_size=256, npages=6)
    for t in range(1, 8):
        tracker["started"] = t
        db.commit(t)
        tracker["acked"] = t
    db.close()


def _run_sqlite_wal(fs, tracker):
    db = SQLiteWALDB(fs, page_size=256, npages=6)
    for t in range(1, 8):
        tracker["started"] = t
        db.commit(t)
        tracker["acked"] = t
        if t % 3 == 0:
            db.checkpoint()
    db.close()


def _run_rocks(fs, tracker):
    db = RocksLite(fs)
    for i in range(1, 15):
        tracker["started"] = i
        db.put(*RocksLite.kv(i))
        tracker["acked"] = i
        if i % 5 == 0:
            wal = db._wal_path(db.wal_num)
            db.flush()
            tracker["flushed_wals"].append(wal)
    db.close()


def _check_sqlite_rj(fs, tracker):
    db = SQLiteRollbackDB(fs, page_size=256, npages=6)  # app recovery
    t = db.check_consistent(tracker["acked"], tracker["started"])
    db.close()
    return t


def _check_sqlite_wal(fs, tracker):
    db = SQLiteWALDB(fs, page_size=256, npages=6)
    t = db.check_consistent(tracker["acked"], tracker["started"])
    db.close()
    return t


def _check_rocks(fs, tracker):
    db = RocksLite(fs)
    m = db.check_consistent(tracker["acked"], tracker["started"],
                            tracker["flushed_wals"])
    db.close()
    return m


MODELS = {
    "sqlite-rj": (_run_sqlite_rj, _check_sqlite_rj),
    "sqlite-wal": (_run_sqlite_wal, _check_sqlite_wal),
    "rocksdb": (_run_rocks, _check_rocks),
}


def _dry_total(model: str, pol: Policy) -> int:
    run, _ = MODELS[model]
    dry = ThreadFusedNVMM(pol.nvmm_bytes)
    nv = NVCache(pol, Tier(DRAM), nvmm=dry, recover=False)
    dry.ops = 0
    run(NVCacheFS(nv), {"acked": 0, "started": 0, "flushed_wals": []})
    total = dry.ops
    nv.cleanup.power_loss()
    return total


@pytest.mark.parametrize("model", sorted(MODELS))
@pytest.mark.parametrize("k", [1, 2, 4])
def test_fuse_randomized_crash_yields_legal_app_state(k, model):
    from repro.core import recover
    pol = make_policy(k)
    total = _dry_total(model, pol)
    run, check = MODELS[model]
    trials = 12
    for trial in range(trials):
        rng = random.Random(7000 * k + 31 * trial + hash(model) % 1000)
        nvmm = ThreadFusedNVMM(pol.nvmm_bytes, track=True)
        tier = Tier(DRAM)
        nv = NVCache(pol, tier, nvmm=nvmm, recover=False, track_crashes=True)
        tracker = {"acked": 0, "started": 0, "flushed_wals": []}
        nvmm.arm(rng.randrange(0, total + 1))
        completed = False
        try:
            run(NVCacheFS(nv), tracker)
            completed = True
        except PowerLoss:
            pass
        nvmm._fuse = None
        nv._crashed = True
        nv.cleanup.power_loss()
        nvmm.crash(choose_evicted=lambda lines: [
            l for l in lines if rng.random() < 0.5])
        tier2 = clone_tier(tier)
        recover(nvmm, pol, tier2)
        # the app's own recovery + oracle, over the recovered tier
        observed = check(TierFS(tier2), tracker)
        assert tracker["acked"] <= observed <= tracker["started"]
        if completed:
            assert observed == tracker["started"]


@pytest.mark.parametrize("model", sorted(MODELS))
def test_models_survive_clean_crash_and_reopen_over_nvcache(model):
    """No fuse: run to completion, power-cut, recover, reopen the app over
    a FRESH NVCache on the recovered tier (the restart path)."""
    from repro.core import recover
    pol = make_policy(2)
    run, check = MODELS[model]
    nvmm = ThreadFusedNVMM(pol.nvmm_bytes, track=True)
    tier = Tier(DRAM)
    nv = NVCache(pol, tier, nvmm=nvmm, recover=False, track_crashes=True)
    tracker = {"acked": 0, "started": 0, "flushed_wals": []}
    run(NVCacheFS(nv), tracker)
    nv._crashed = True
    nv.cleanup.power_loss()
    nvmm.crash()
    tier2 = clone_tier(tier)
    recover(nvmm, pol, tier2)
    nv2 = NVCache(pol, tier2)
    assert check(NVCacheFS(nv2), tracker) == tracker["started"]
    nv2.shutdown()
