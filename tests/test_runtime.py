"""Fault runtime (heartbeats/stragglers/failover) and elastic re-mesh."""
from repro.runtime.elastic import shard_rows, viable_mesh
from repro.runtime.fault import HeartbeatMonitor


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _fleet(mon, n=8, spares=2):
    for i in range(n):
        mon.register(f"w{i}")
    for i in range(spares):
        mon.register(f"spare{i}", spare=True)


def test_dead_worker_detection_and_failover():
    clk = FakeClock()
    mon = HeartbeatMonitor(dead_after_s=30, clock=clk)
    _fleet(mon)
    mon.note_checkpoint(100)
    for t in range(5):
        clk.t = t * 10.0
        for i in range(8):
            if i != 3:                      # w3 dies after t=0
                mon.beat(f"w{i}", t)
            elif t == 0:
                mon.beat("w3", 0)
    plan = mon.plan()
    assert plan is not None
    assert plan.dead == ["w3"]
    assert plan.replacements == {"w3": "spare0"}
    assert plan.restart_step == 100
    assert not plan.remesh
    mon.apply(plan)
    assert "w3" not in mon.workers
    assert "spare0" not in mon.spares


def test_straggler_detection():
    clk = FakeClock()
    mon = HeartbeatMonitor(dead_after_s=1e9, straggler_factor=2.0, clock=clk)
    _fleet(mon, n=6, spares=1)
    for step in range(10):
        for i in range(6):
            clk.t = step * 1.0 + (0.9 if i == 5 else 0.0)
            mon.beat(f"w{i}", step)
    # w5's per-step rate equals the others (same cadence) -> no straggler
    assert mon.stragglers() == []
    # now w5 slows to 4x per step
    for step in range(10, 16):
        for i in range(5):
            clk.t = step * 1.0
            mon.beat(f"w{i}", step)
    for step in range(10, 16):
        clk.t = 12 + (step - 10) * 4.0
        mon.beat("w5", step)
    assert mon.stragglers() == ["w5"]


def test_remesh_when_spares_exhausted():
    clk = FakeClock()
    mon = HeartbeatMonitor(dead_after_s=5, clock=clk)
    _fleet(mon, n=4, spares=1)
    for i in range(4):
        mon.beat(f"w{i}", 0)
    clk.t = 100.0
    mon.beat("w0", 1)
    plan = mon.plan()                      # w1..w3 dead, only one spare
    assert len(plan.dead) == 3
    assert plan.remesh


def test_viable_mesh_shapes():
    assert viable_mesh(512) == ((2, 16, 16), ("pod", "data", "model"))
    assert viable_mesh(256) == ((16, 16), ("data", "model"))
    assert viable_mesh(240) == ((15, 16), ("data", "model"))
    shape, axes = viable_mesh(200)          # 200 % 16 != 0 -> shrink TP
    assert shape[0] * shape[1] == 200


def test_shard_rows():
    assert shard_rows("w", (64, 8), shard_idx=1, n_shards=4) == (16, 32)
    assert shard_rows("w", (63, 8), shard_idx=1, n_shards=4) is None
    assert shard_rows("s", (), shard_idx=0, n_shards=4) is None
