"""kvlite (the 'legacy application'): correctness over both stacks and
replay-on-reopen."""
from repro.core import NVCache, Policy
from repro.storage.fsapi import NVCacheFS, TierFS
from repro.storage.kvlite import KVLite
from repro.storage.tiers import DRAM, Tier

POL = Policy(entry_size=4096, log_entries=256, page_size=4096,
             read_cache_pages=16, batch_min=4, batch_max=64, verify_crc=False)


def test_put_get_over_tier():
    db = KVLite(TierFS(Tier(DRAM)), sync=True)
    for i in range(50):
        db.put(f"k{i}".encode(), f"v{i}".encode() * 3)
    assert db.get(b"k7") == b"v7v7v7"
    assert db.get(b"missing") is None
    assert len(db) == 50


def test_put_get_over_nvcache_unmodified():
    """The same application code runs over NVCache — plug-and-play."""
    nv = NVCache(POL, Tier(DRAM))
    db = KVLite(NVCacheFS(nv), sync=True)
    for i in range(50):
        db.put(f"k{i}".encode(), f"v{i}".encode() * 3)
    assert db.get(b"k49") == b"v49v49v49"
    db.put(b"k7", b"updated")
    assert db.get(b"k7") == b"updated"
    nv.shutdown()


def test_replay_on_reopen():
    tier = Tier(DRAM)
    fs = TierFS(tier)
    db = KVLite(fs, "/db", sync=True)
    db.put(b"a", b"1")
    db.put(b"b", b"2")
    db.put(b"a", b"3")
    db2 = KVLite(TierFS(tier), "/db", sync=True)
    assert db2.get(b"a") == b"3"
    assert db2.get(b"b") == b"2"


def test_replay_stops_at_torn_tail_record():
    """A crash mid-append can leave a header whose klen/vlen extend past
    EOF; replay must stop at the last complete record instead of indexing
    garbage (failing before the PR-5 fix: the torn key was indexed with a
    value range past EOF, and the next put appended after the torn bytes)."""
    import struct
    tier = Tier(DRAM)
    fs = TierFS(tier)
    db = KVLite(fs, "/db", sync=True)
    db.put(b"whole", b"value-1")
    db.put(b"also", b"value-2")
    good_end = db._end
    db.close()
    # simulate the torn append: a header claiming bytes far past EOF, plus
    # a prefix of the key that never finished
    torn = struct.pack("<II", 9, 1 << 20) + b"torn-"
    raw = tier.open("/db")
    raw.pwrite(torn, good_end)
    db2 = KVLite(TierFS(tier), "/db", sync=True)
    assert db2.get(b"whole") == b"value-1"
    assert db2.get(b"also") == b"value-2"
    assert len(db2) == 2, "torn tail record was indexed"
    assert db2._end == good_end, "replay ran past the last complete record"
    # the next put overwrites the torn bytes and is readable after reopen
    db2.put(b"fresh", b"value-3")
    db3 = KVLite(TierFS(tier), "/db", sync=True)
    assert db3.get(b"fresh") == b"value-3"
    assert len(db3) == 3


def test_replay_stops_at_torn_header():
    """EOF in the middle of a header (not just the payload) is also a torn
    tail: replay must treat it as end-of-log."""
    tier = Tier(DRAM)
    db = KVLite(TierFS(tier), "/db", sync=True)
    db.put(b"k", b"v")
    good_end = db._end
    db.close()
    tier.open("/db").pwrite(b"\x05\x00", good_end)   # 2 bytes of a header
    db2 = KVLite(TierFS(tier), "/db", sync=True)
    assert db2.get(b"k") == b"v"
    assert len(db2) == 1 and db2._end == good_end
