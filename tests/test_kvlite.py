"""kvlite (the 'legacy application'): correctness over both stacks and
replay-on-reopen."""
from repro.core import NVCache, Policy
from repro.storage.fsapi import NVCacheFS, TierFS
from repro.storage.kvlite import KVLite
from repro.storage.tiers import DRAM, Tier

POL = Policy(entry_size=4096, log_entries=256, page_size=4096,
             read_cache_pages=16, batch_min=4, batch_max=64, verify_crc=False)


def test_put_get_over_tier():
    db = KVLite(TierFS(Tier(DRAM)), sync=True)
    for i in range(50):
        db.put(f"k{i}".encode(), f"v{i}".encode() * 3)
    assert db.get(b"k7") == b"v7v7v7"
    assert db.get(b"missing") is None
    assert len(db) == 50


def test_put_get_over_nvcache_unmodified():
    """The same application code runs over NVCache — plug-and-play."""
    nv = NVCache(POL, Tier(DRAM))
    db = KVLite(NVCacheFS(nv), sync=True)
    for i in range(50):
        db.put(f"k{i}".encode(), f"v{i}".encode() * 3)
    assert db.get(b"k49") == b"v49v49v49"
    db.put(b"k7", b"updated")
    assert db.get(b"k7") == b"updated"
    nv.shutdown()


def test_replay_on_reopen():
    tier = Tier(DRAM)
    fs = TierFS(tier)
    db = KVLite(fs, "/db", sync=True)
    db.put(b"a", b"1")
    db.put(b"b", b"2")
    db.put(b"a", b"3")
    db2 = KVLite(TierFS(tier), "/db", sync=True)
    assert db2.get(b"a") == b"3"
    assert db2.get(b"b") == b"2"
