"""The page-coalescing drain engine and the dirty-page index (PR 2).

Covers: O(entries-on-page) dirty-miss replay with zero whole-log scans,
per-page entry-ref retire accounting across K shards, dirty-miss reads
racing a concurrent drain (never torn, never stale), extent coalescing
reducing backend page writes, fsync epoch merging, and the two tier-model
satellite fixes (truncate page-state cleanup, DMWriteCacheTier re-wrap).
"""
import threading
import struct

import pytest

from repro.core import NVCache, Policy
from repro.core.drain import FsyncEpochScheduler
from repro.storage.tiers import (DMWriteCacheTier, DRAM, PAGE, SSD_SATA,
                                 Tier, TierFile)


def make_policy(k: int, **kw) -> Policy:
    defaults = dict(entry_size=256, log_entries=64 * k, page_size=256,
                    read_cache_pages=4, batch_min=4, batch_max=16,
                    shards=k, shard_route="stripe", stripe_pages=2)
    defaults.update(kw)
    return Policy(**defaults)


# ----------------------------------------------------------- dirty-page index
@pytest.mark.parametrize("k", [1, 2, 4])
def test_dirty_miss_inspects_only_the_pages_entries(k):
    """A dirty miss on a page with E live entries replays exactly E refs and
    never rescans the log (acceptance criterion: no scan_all_committed on
    the read path)."""
    # batch_min is clamped to entries_per_shard // 2 = 16: with <= 8 entries
    # per shard nothing drains, so every written entry stays live
    pol = make_policy(k, log_entries=64 * k, batch_min=10 ** 6,
                      read_cache_pages=2)
    nv = NVCache(pol, Tier(DRAM))
    fd = nv.open("/f")
    ps = pol.page_size
    E = 5
    for j in range(E):                       # E small writes, all on page 0
        nv.pwrite(fd, bytes([j + 1]) * 16, j * 16)
    nv.pwrite(fd, b"\xEE" * 32, 7 * ps)      # unrelated page
    # page 0 was updated in place while loaded; force it out of the cache
    for p in range(1, 6):
        nv.pread(fd, ps, p * ps)
    d0 = nv._files["/f"].radix.get(0)
    assert d0.content is None, "page 0 should have been evicted"
    assert d0.dirty_refs == E
    misses0 = nv.stats_dirty_misses
    replay0 = nv.stats_replay_entries
    got = nv.pread(fd, ps, 0)                # the dirty miss under test
    exp = bytearray(ps)
    for j in range(E):
        exp[j * 16:(j + 1) * 16] = bytes([j + 1]) * 16
    assert got == bytes(exp)
    assert nv.stats_dirty_misses == misses0 + 1
    assert nv.stats_replay_entries == replay0 + E   # exactly E, not O(log)
    nv.shutdown()


@pytest.mark.parametrize("k", [1, 2, 4])
def test_refs_are_seq_ordered_and_retired_on_drain(k):
    """Per-page index invariants: refs stay in commit order, and a full
    drain retires every ref on every page (pending accounting matches)."""
    import random
    pol = make_policy(k)
    nv = NVCache(pol, Tier(DRAM))
    fd = nv.open("/f")
    rng = random.Random(17 * k)
    for _ in range(60):
        off = rng.randrange(0, 6 * pol.page_size)
        n = rng.randint(1, 3 * pol.entry_data)
        nv.pwrite(fd, bytes([rng.randrange(1, 255)]) * n, off)
        # sample the invariant mid-stream on a few descriptors
        f = nv._files["/f"]
        for p in range(6):
            d = f.radix.get(p)
            if d is None:
                continue
            refs = d.snapshot_refs()
            seqs = [r.seq for r in refs]
            assert seqs == sorted(seqs), f"page {p} index out of commit order"
    nv.flush()
    f = nv._files["/f"]
    assert f.pending.get() == 0
    assert nv.log.used_entries == 0
    for p in range(12):                       # covers every touched page
        d = f.radix.get(p)
        if d is not None:
            assert d.dirty_refs == 0, f"page {p} kept refs after full drain"
    nv.shutdown()


@pytest.mark.parametrize("k", [1, 2, 4])
def test_dirty_miss_racing_drain_never_torn_or_stale(k):
    """Readers take dirty misses while drains are forced concurrently: a
    page image must never mix two writes (torn) nor lose the freshest
    committed one the reader could prove durable (stale)."""
    pol = Policy(entry_size=1024, log_entries=64 * k, page_size=1024,
                 read_cache_pages=2, batch_min=4, batch_max=16,
                 shards=k, shard_route="stripe", stripe_pages=1)
    nv = NVCache(pol, Tier(DRAM))
    fd = nv.open("/f")
    ps = pol.page_size
    NPAGES = 4
    OPS = 60
    started = [0] * NPAGES
    errors = []
    stop = threading.Event()

    def writer(w):
        try:
            for i in range(OPS):
                p = (w + i) % NPAGES
                c = (w << 16) | (i + 1)
                started[p] = max(started[p], c)
                nv.pwrite(fd, struct.pack("<I", c) * (ps // 4), p * ps)
        except Exception as exc:
            errors.append(exc)

    def reader():
        try:
            i = 0
            while not stop.is_set():
                p = i % NPAGES
                i += 1
                page = nv.pread(fd, ps, p * ps)
                if not page.strip(b"\x00"):
                    continue
                word = page[:4]
                if word * (ps // 4) != page:
                    errors.append(AssertionError(f"torn page {p}"))
                    stop.set()
        except Exception as exc:
            errors.append(exc)

    def flusher():
        try:
            while not stop.is_set():
                nv.flush(timeout=60)
        except Exception as exc:
            errors.append(exc)

    ws = [threading.Thread(target=writer, args=(w,)) for w in range(3)]
    rs = [threading.Thread(target=reader) for _ in range(2)]
    fl = threading.Thread(target=flusher)
    for t in ws + rs + [fl]:
        t.start()
    for t in ws:
        t.join(timeout=120)
    stop.set()
    for t in rs + [fl]:
        t.join(timeout=60)
    if errors:
        raise errors[0]
    nv.flush()
    # after a full drain a dirty miss degenerates to a clean backend read:
    # evict and re-read every page, values must be the freshest committed
    for p in range(NPAGES):
        page = nv.pread(fd, ps, p * ps)
        if page.strip(b"\x00"):
            word = page[:4]
            assert word * (ps // 4) == page, f"torn page {p} after drain"
    nv.shutdown()


# ------------------------------------------------------------- coalescing win
def test_sequential_small_writes_coalesce_into_few_backend_writes():
    """16 KiB of 1 KiB-sequential writes: the coalescing engine must touch
    each backend page about once, the entry-at-a-time baseline 4x+ that
    (acceptance: >= 2x fewer backend page writes per committed byte)."""
    results = {}
    for coalesce in (False, True):
        pol = Policy(entry_size=1024 + 48, log_entries=256, page_size=4096,
                     read_cache_pages=8, batch_min=4, batch_max=64,
                     drain_coalesce=coalesce, fsync_epoch=coalesce)
        tier = Tier(DRAM)
        nv = NVCache(pol, tier)
        fd = nv.open("/f")
        for i in range(16):
            nv.pwrite(fd, bytes([i + 1]) * 1024, i * 1024)
        nv.flush()
        f = tier.open("/f")
        results[coalesce] = {"pwrites": f.stats_writes,
                             "page_writes": f.stats_page_writes}
        # correctness of the coalesced image
        for i in range(16):
            assert nv.pread(fd, 1024, i * 1024) == bytes([i + 1]) * 1024
        assert f.snapshot()[:16 * 1024] == b"".join(
            bytes([i + 1]) * 1024 for i in range(16))
        nv.shutdown()
    assert results[False]["page_writes"] >= 2 * results[True]["page_writes"], \
        results
    assert results[False]["pwrites"] >= 2 * results[True]["pwrites"], results


def test_overlapping_writes_in_one_batch_drain_in_commit_order():
    """Same bytes overwritten repeatedly inside one batch: the materialized
    page must hold the LAST committed value, and the backend page is
    written once."""
    pol = Policy(entry_size=256, log_entries=64, page_size=256,
                 read_cache_pages=4, batch_min=10 ** 6, batch_max=10 ** 6)
    tier = Tier(DRAM)
    nv = NVCache(pol, tier)
    fd = nv.open("/f")
    for v in (1, 2, 3, 4, 5):
        nv.pwrite(fd, bytes([v]) * 100, 50)
    nv.pwrite(fd, b"\x77" * 60, 120)          # overlaps the tail of the above
    nv.flush()
    f = tier.open("/f")
    snap = f.snapshot()
    assert snap[50:120] == b"\x05" * 70
    assert snap[120:180] == b"\x77" * 60
    nv.shutdown()


# ---------------------------------------------------------------- fsync epoch
class _SlowSyncFile:
    def __init__(self):
        self.gate = threading.Event()
        self.entered = threading.Event()
        self.fsyncs = 0
        self._lock = threading.Lock()

    def fsync(self):
        with self._lock:
            self.fsyncs += 1
            first = self.fsyncs == 1
        if first:
            self.entered.set()
            assert self.gate.wait(timeout=30)


def test_fsync_epoch_scheduler_merges_concurrent_requests():
    """While one fsync is in flight, every caller that arrives shares the
    single next epoch: 1 + N concurrent requests -> exactly 2 device
    fsyncs, and each caller returns only after an fsync that started after
    its request."""
    sched = FsyncEpochScheduler(enabled=True)
    f = _SlowSyncFile()
    t0 = threading.Thread(target=sched.fsync, args=(f,))
    t0.start()
    assert f.entered.wait(timeout=30)         # epoch 1 is now in flight
    late = [threading.Thread(target=sched.fsync, args=(f,)) for _ in range(3)]
    for t in late:
        t.start()
    # the 3 latecomers must all be waiting, not issuing
    deadline = threading.Event()
    deadline.wait(0.05)
    assert f.fsyncs == 1
    f.gate.set()                              # release epoch 1
    t0.join(timeout=30)
    for t in late:
        t.join(timeout=30)
    assert not t0.is_alive() and not any(t.is_alive() for t in late)
    assert f.fsyncs == 2                      # 4 requests -> 2 epochs
    assert sched.stats_requests == 4
    assert sched.stats_issued == 2
    assert sched.stats_merged == 2


def test_fsync_epoch_failure_reaches_every_sharer():
    """A failed device fsync must surface to EVERY caller that shared the
    epoch — a merged drain thread must never retire log entries whose data
    never became durable."""
    class FailingSyncFile(_SlowSyncFile):
        def fsync(self):
            super().fsync()
            raise OSError("EIO")

    sched = FsyncEpochScheduler(enabled=True)
    f = FailingSyncFile()
    results = []

    def call():
        try:
            sched.fsync(f)
            results.append(None)
        except OSError as e:
            results.append(e)

    t0 = threading.Thread(target=call)
    t0.start()
    assert f.entered.wait(timeout=30)         # epoch 1 in flight (will fail)
    late = [threading.Thread(target=call) for _ in range(3)]
    for t in late:
        t.start()
    f.gate.set()
    for t in [t0] + late:
        t.join(timeout=30)
    assert len(results) == 4
    assert all(isinstance(r, OSError) for r in results), results
    assert f.fsyncs == 2                      # epoch 1 + the shared epoch 2


def test_fsync_epoch_disabled_passes_through():
    sched = FsyncEpochScheduler(enabled=False)
    f = _SlowSyncFile()
    f.gate.set()
    for _ in range(3):
        sched.fsync(f)
    assert f.fsyncs == 3
    assert sched.stats_merged == 0


# ---------------------------------------------------------- tier model fixes
def test_truncate_drops_page_state_beyond_new_size():
    """Satellite: fsync after truncate must not pay for pages that no
    longer exist."""
    tier = Tier(SSD_SATA)
    f = tier.open("/t")
    f.pwrite(b"x" * (10 * PAGE), 0)
    assert len(f._dirty_pages) == 10
    f.truncate(PAGE + 1)                      # keep pages 0 and 1 (partial)
    assert f._dirty_pages == {0, 1}
    assert f._cached_pages == {0, 1}
    cost_before = tier.gate.total_cost
    f.fsync()
    paid = tier.gate.total_cost - cost_before
    expect = (SSD_SATA.fsync_base_s + 2 * SSD_SATA.page_write_s
              + SSD_SATA.syscall_s)
    assert abs(paid - expect) < 1e-9, (paid, expect)
    f.truncate(0)
    assert not f._dirty_pages and not f._cached_pages


def test_dm_writecache_reopen_does_not_double_charge():
    """Satellite: re-opening the same path must not stack another pwrite
    wrapper (which double-charged the NVMM commit cost per reopen)."""
    tier = DMWriteCacheTier(scale=1.0)
    f1 = tier.open("/d")
    wrapped_once = f1.pwrite
    f2 = tier.open("/d")
    assert f2 is f1
    assert f2.pwrite is wrapped_once          # not re-wrapped
    cost0 = tier.gate.total_cost
    f2.pwrite(b"z" * PAGE, 0)
    single_open_cost = tier.gate.total_cost - cost0
    ref_tier = DMWriteCacheTier(scale=1.0)
    rf = ref_tier.open("/d")
    rc0 = ref_tier.gate.total_cost
    rf.pwrite(b"z" * PAGE, 0)
    assert abs((ref_tier.gate.total_cost - rc0) - single_open_cost) < 1e-9
    assert f1.stats_writes == 1               # counted once, not per wrapper


def test_pwritev_cost_and_stats_model():
    """The vectored write path: one syscall + per-segment overhead, page
    accounting deduplicated per call."""
    tier = Tier(SSD_SATA)                     # buffered: no page cost on write
    f = tier.open("/v")
    c0 = tier.gate.total_cost
    n = f.pwritev([(b"a" * 100, 0), (b"b" * 100, 100), (b"c" * 100, 200)])
    assert n == 300
    paid = tier.gate.total_cost - c0
    expect = SSD_SATA.syscall_s + 2 * SSD_SATA.iov_seg_s
    assert abs(paid - expect) < 1e-12
    assert f.stats_writes == 1
    assert f.stats_wvec_segments == 3
    assert f.stats_page_writes == 1           # all three segments on page 0
    assert f.snapshot()[:300] == b"a" * 100 + b"b" * 100 + b"c" * 100
    # sync tier: unique pages charged once per call even if hit twice
    stier = Tier(SSD_SATA, sync=True)
    sf = stier.open("/s")
    c0 = stier.gate.total_cost
    sf.pwritev([(b"x" * 10, 0), (b"y" * 10, 100)])   # same page twice
    paid = stier.gate.total_cost - c0
    expect = (SSD_SATA.syscall_s + SSD_SATA.iov_seg_s
              + 1 * SSD_SATA.page_write_s)
    assert abs(paid - expect) < 1e-12
