"""Dual persistence engine (PR 7 tentpole): paged NVMM regions absorbing
large / overwrite-heavy streams in place, next to the sharded log.

Three layers:

* classifier — the per-file stream detector proposes log→page for large or
  rewrite-heavy windows, page→log for small-write windows, and never flips
  on a flip-flopping stream (hysteresis);
* engine semantics — paged writes commit into frames (no log append),
  reads serve framed pages from NVMM without replay or full scans, flush /
  close / shutdown write frames back, truncate clips or drops them, the
  pool falls back to the log when exhausted;
* crash consistency — a fuse wired into the NVMM kills the run at every
  persistence-protocol point across a log→page→log migration script;
  after recovery every page must hold a committed prefix state: old or
  new, never torn, across BOTH modes (the frames' seq-fencing against the
  log and the metadata journal).

Also covers the satellites riding along: the fsync-free ``ftruncate(0)``
WAL-reset drain and the deferred backend apply for ``rename``.
"""
import os

import pytest

from repro.core import NVCache, Policy, recover
from repro.core.log import META_NO_FDID, MOP_RENAME
from repro.core.policy import StreamClassifier
from repro.storage.tiers import DRAM, Tier
from test_namespace import ThreadFusedNVMM, clone_tier
from test_sharded_recovery import PowerLoss

PS = 256


def make_policy(**kw):
    base = dict(entry_size=256, log_entries=128, page_size=PS,
                read_cache_pages=8, batch_min=4, batch_max=16,
                page_frames=16, classify_window=4)
    base.update(kw)
    return Policy(**base)


def the_file(nv):
    assert len(nv._by_fdid) == 1
    return next(iter(nv._by_fdid.values()))


# ------------------------------------------------------------- classifier
def test_classifier_small_writes_stay_log():
    clf = StreamClassifier(make_policy())
    for i in range(64):                      # small writes, distinct pages
        assert clf.note_write(i * PS, 16) is None
    assert clf.mode == "log"


def test_classifier_large_writes_propose_page():
    clf = StreamClassifier(make_policy())
    got = [clf.note_write(i * PS, PS) for i in range(8)]
    # window 1 votes page (no switch yet: hysteresis), window 2 confirms
    assert got[3] is None and got[7] == "page"
    clf.confirm("page")
    assert clf.mode == "page"
    # and the same stream never re-proposes the mode it is already in
    assert all(clf.note_write(i * PS, PS) is None for i in range(8))


def test_classifier_overwrites_propose_page():
    clf = StreamClassifier(make_policy())
    # half-page writes, all to the same page: small avg but pure rewrite
    got = [clf.note_write(0, PS // 2) for _ in range(8)]
    assert got[7] == "page"


def test_classifier_flip_flop_never_switches():
    clf = StreamClassifier(make_policy())
    switched = []
    for rnd in range(8):                     # alternate window votes
        size = PS if rnd % 2 == 0 else 16
        off = 0 if rnd % 2 == 0 else (100 + rnd) * PS
        for i in range(4):
            r = clf.note_write(off + i, size)
            if r is not None:
                switched.append(r)
    assert switched == [] and clf.mode == "log"


def test_classifier_page_mode_back_to_log():
    clf = StreamClassifier(make_policy())
    for i in range(8):
        r = clf.note_write(i * PS, PS)
    clf.confirm("page")
    got = [clf.note_write((1000 + i) * PS, 8) for i in range(8)]
    assert got[7] == "log"


# -------------------------------------------------------- engine semantics
def test_paged_write_read_flush_roundtrip():
    pol = make_policy()
    tier = Tier(DRAM)
    nv = NVCache(pol, tier)
    fd = nv.open("/f")
    blob = bytes(range(256))
    for rnd in range(12):                    # overwrite-heavy: 4 hot pages
        for p in range(4):
            nv.pwrite(fd, blob, p * PS)
    f = the_file(nv)
    assert f.pmode and set(f.frames) == {0, 1, 2, 3}
    st = nv.stats()
    assert st["mode_migrations"] == 1
    assert st["paged_frames_used"] == 4
    assert st["paged_frame_writes"] > 12     # overwrites landed in frames
    # reads serve framed pages from NVMM — fresh, replay-free, no scans
    assert nv.pread(fd, PS, 0) == blob
    assert nv.pread(fd, PS, 3 * PS) == blob
    nv.flush()                               # paged half of the barrier
    assert tier.open("/f").pread(PS, 2 * PS) == blob
    nv.close(fd)
    nv.shutdown()


def test_paged_mode_appends_nothing_to_the_log():
    pol = make_policy(batch_min=10 ** 6, batch_max=10 ** 6)  # no drain
    nv = NVCache(pol, Tier(DRAM))
    fd = nv.open("/f")
    for _ in range(8):                       # classifier flips to page mode
        nv.pwrite(fd, b"x" * PS, 0)
    assert the_file(nv).pmode
    used = nv.log.used_entries
    for _ in range(30):                      # framed overwrites: in place
        nv.pwrite(fd, b"y" * PS, 0)
    assert nv.log.used_entries == used
    assert nv.pread(fd, PS, 0) == b"y" * PS
    nv.cleanup.power_loss()                  # tear down without draining


def test_pool_exhaustion_falls_back_to_log_per_page():
    pol = make_policy(page_frames=2, batch_min=10 ** 6, batch_max=10 ** 6)
    nv = NVCache(pol, Tier(DRAM))
    fd = nv.open("/f")
    for rnd in range(4):                     # flip to page mode on 2 pages
        for p in range(2):
            nv.pwrite(fd, b"a" * PS, p * PS)
    for p in range(2):
        nv.pwrite(fd, b"b" * PS, p * PS)
    f = the_file(nv)
    assert f.pmode and len(f.frames) == 2    # pool is now full
    used = nv.log.used_entries
    nv.pwrite(fd, b"c" * PS, 5 * PS)         # no frame left: log fallback
    assert nv.log.used_entries > used
    assert 5 not in f.frames
    assert nv.stats()["paged_alloc_fallbacks"] >= 1
    assert nv.pread(fd, PS, 5 * PS) == b"c" * PS
    assert nv.pread(fd, PS, 0) == b"b" * PS
    nv.cleanup.power_loss()


def test_truncate_drops_and_clips_frames():
    pol = make_policy()
    tier = Tier(DRAM)
    nv = NVCache(pol, tier)
    fd = nv.open("/f")
    for rnd in range(4):
        for p in range(3):
            nv.pwrite(fd, bytes([rnd + p]) * PS, p * PS)
    f = the_file(nv)
    assert f.pmode and set(f.frames) == {0, 1, 2}
    nv.ftruncate(fd, PS + 100)               # cuts page 2, clips page 1
    assert set(f.frames) == {0, 1}
    assert nv.stat_size(fd) == PS + 100
    assert nv.pread(fd, PS, PS) == bytes([4]) * 100  # tail gone
    nv.ftruncate(fd, 0)                      # WAL reset drops everything
    assert f.frames == {}
    assert nv.stat_size(fd) == 0
    nv.close(fd)
    nv.shutdown()
    assert tier.open("/f").size() == 0


def test_unlinked_file_frames_die_without_writeback():
    pol = make_policy()
    tier = Tier(DRAM)
    nv = NVCache(pol, tier)
    fd = nv.open("/j")
    for _ in range(8):
        nv.pwrite(fd, b"J" * PS, 0)
    f = the_file(nv)
    assert f.pmode and f.frames
    tf = tier.open("/j")
    before = tf.stats_bytes
    nv.unlink("/j")
    nv.close(fd)                             # last close reaps the file
    nv.flush()
    assert tf.stats_bytes == before          # no frame writeback
    assert not tier.exists("/j")
    assert nv.stats()["paged_frames_used"] == 0   # pool reclaimed
    nv.shutdown()


def test_mode_migration_page_to_log_writes_back():
    pol = make_policy(batch_min=10 ** 6, batch_max=10 ** 6)
    tier = Tier(DRAM)
    nv = NVCache(pol, tier)
    fd = nv.open("/f")
    for _ in range(8):
        nv.pwrite(fd, b"P" * PS, 0)
    f = the_file(nv)
    assert f.pmode
    assert nv._migrate_mode(f, False)        # explicit page -> log
    assert not f.pmode and f.frames == {}
    assert tier.open("/f").pread(PS, 0) == b"P" * PS  # frame reached backend
    nv.pwrite(fd, b"L" * PS, 0)              # back to log appends
    assert nv.log.used_entries > 0
    assert nv.pread(fd, PS, 0) == b"L" * PS
    nv.cleanup.power_loss()


# ------------------------------------------------------- crash consistency
def _mode_script(nv):
    """log writes -> migrate to paged -> framed overwrites -> migrate back
    -> log write; every op is individually atomic and synchronously
    durable, so a crash may sit between any two."""
    fd = nv.open("/f")
    nv.pwrite(fd, b"A" * PS, 0)
    nv.pwrite(fd, b"a" * PS, PS)
    f = the_file(nv)
    assert nv._migrate_mode(f, True)
    nv.pwrite(fd, b"B" * PS, 0)              # framed
    nv.pwrite(fd, b"C" * PS, 0)              # framed overwrite (slot flip)
    nv.pwrite(fd, b"b" * PS, PS)             # framed
    assert nv._migrate_mode(f, False)        # writeback + free
    nv.pwrite(fd, b"D" * PS, 0)              # log again


def _mode_script_states():
    A, a = b"A" * PS, b"a" * PS
    return [
        {"/f": b""},
        {"/f": A},
        {"/f": A + a},
        {"/f": b"B" * PS + a},
        {"/f": b"C" * PS + a},
        {"/f": b"C" * PS + b"b" * PS},
        {"/f": b"D" * PS + b"b" * PS},
    ]


def _legal(observed, states):
    return any(observed == s for s in states)


@pytest.mark.parametrize("k", [1, 2, 4])
def test_mode_migration_crash_sweep_old_or_new(k):
    """Crash at every 3rd NVMM persistence op across the full
    log→page→log script, K ∈ {1, 2, 4}: recovery must land a committed
    prefix state — no torn frames, no lost committed writes, across both
    modes and the migrations between them."""
    pol = make_policy(shards=k, log_entries=128 * k,
                      batch_min=10 ** 6, batch_max=10 ** 6)
    dry = ThreadFusedNVMM(pol.nvmm_bytes)
    nv = NVCache(pol, Tier(DRAM), nvmm=dry, recover=False)
    dry.ops = 0
    _mode_script(nv)
    total = dry.ops
    nv.cleanup.power_loss()
    states = [{}] + _mode_script_states()

    checked = 0
    for fuse in range(0, total + 1, 3):
        nvmm = ThreadFusedNVMM(pol.nvmm_bytes, track=True)
        tier = Tier(DRAM)
        nv = NVCache(pol, tier, nvmm=nvmm, recover=False, track_crashes=True)
        nvmm.arm(fuse)
        done = False
        try:
            _mode_script(nv)
            done = True
        except PowerLoss:
            pass
        nvmm._fuse = None
        nv._crashed = True
        nv.cleanup.power_loss()
        nvmm.crash()                         # nothing un-flushed survives
        tier2 = clone_tier(tier)
        stats = recover(nvmm, pol, tier2)
        observed = {p: tier2.open(p).snapshot() for p in tier2.paths()}
        assert _legal(observed, states), \
            f"k={k} fuse={fuse}: torn state {observed!r} ({stats})"
        if done:
            assert _legal(observed, [states[-1]]), \
                f"k={k} fuse={fuse}: completed script lost writes"
        checked += 1
    assert checked > 20


def test_paged_overwrite_crash_sweep_dense():
    """Every single fuse point across framed overwrites of one page: the
    header flip is the commit — the page is always one of the committed
    images, never a mix."""
    pol = make_policy(batch_min=10 ** 6, batch_max=10 ** 6)

    def script(nv):
        fd = nv.open("/p")
        f = the_file(nv)
        nv.pwrite(fd, b"0" * PS, 0)
        assert nv._migrate_mode(f, True)
        for ch in b"123":
            nv.pwrite(fd, bytes([ch]) * PS, 0)

    dry = ThreadFusedNVMM(pol.nvmm_bytes)
    nv = NVCache(pol, Tier(DRAM), nvmm=dry, recover=False)
    dry.ops = 0
    script(nv)
    total = dry.ops
    nv.cleanup.power_loss()
    legal = [{}, {"/p": b""}] + [{"/p": bytes([c]) * PS} for c in b"0123"]

    for fuse in range(total + 1):
        nvmm = ThreadFusedNVMM(pol.nvmm_bytes, track=True)
        tier = Tier(DRAM)
        nv = NVCache(pol, tier, nvmm=nvmm, recover=False, track_crashes=True)
        nvmm.arm(fuse)
        try:
            script(nv)
        except PowerLoss:
            pass
        nvmm._fuse = None
        nv._crashed = True
        nv.cleanup.power_loss()
        nvmm.crash()
        tier2 = clone_tier(tier)
        stats = recover(nvmm, pol, tier2)
        observed = {p: tier2.open(p).snapshot() for p in tier2.paths()}
        assert _legal(observed, legal), \
            f"fuse={fuse}: torn frame {observed!r} ({stats})"


# ------------------------------------------- satellite: fsync-free WAL reset
def test_ftruncate_zero_drains_without_backend_fsync():
    pol = make_policy(page_frames=0, batch_min=10 ** 6, batch_max=10 ** 6)
    tier = Tier(DRAM)
    nv = NVCache(pol, tier)
    fd = nv.open("/wal")
    for i in range(6):
        nv.pwrite(fd, bytes([i]) * 200, i * 200)
    tf = tier.open("/wal")
    fsyncs = tf.stats_fsyncs
    nv.ftruncate(fd, 0)                      # barrier drains all 6 entries
    assert the_file(nv).pending.get() == 0   # ...but the discarded bytes
    assert tf.stats_fsyncs == fsyncs         # never paid a device fsync
    assert not the_file(nv).skip_drain_fsync  # window closed
    assert nv.stat_size(fd) == 0
    # a normal shrink (length > 0) still fsyncs its surviving bytes
    nv.pwrite(fd, b"k" * 300, 0)
    nv.ftruncate(fd, 100)
    assert tf.stats_fsyncs > fsyncs
    assert nv.pread(fd, 300, 0) == b"k" * 100
    nv.close(fd)
    nv.shutdown()


def test_ftruncate_zero_crash_sweep_old_or_new():
    pol = make_policy(page_frames=0, batch_min=10 ** 6, batch_max=10 ** 6)

    def script(nv):
        fd = nv.open("/w")
        nv.pwrite(fd, b"W" * 300, 0)
        nv.ftruncate(fd, 0)
        nv.pwrite(fd, b"X" * 100, 0)

    dry = ThreadFusedNVMM(pol.nvmm_bytes)
    nv = NVCache(pol, Tier(DRAM), nvmm=dry, recover=False)
    dry.ops = 0
    script(nv)
    total = dry.ops
    nv.cleanup.power_loss()
    legal = [{}, {"/w": b""}, {"/w": b"W" * 300}, {"/w": b""},
             {"/w": b"X" * 100}]
    for fuse in range(0, total + 1, 3):
        nvmm = ThreadFusedNVMM(pol.nvmm_bytes, track=True)
        tier = Tier(DRAM)
        nv = NVCache(pol, tier, nvmm=nvmm, recover=False, track_crashes=True)
        nvmm.arm(fuse)
        try:
            script(nv)
        except PowerLoss:
            pass
        nvmm._fuse = None
        nv._crashed = True
        nv.cleanup.power_loss()
        nvmm.crash()
        tier2 = clone_tier(tier)
        stats = recover(nvmm, pol, tier2)
        observed = {p: tier2.open(p).snapshot() for p in tier2.paths()}
        assert _legal(observed, legal), \
            f"fuse={fuse}: torn WAL reset {observed!r} ({stats})"


# --------------------------------------- satellite: deferred rename apply
def test_rename_apply_is_queued_and_runs_before_return():
    tier = Tier(DRAM)
    nv = NVCache(make_policy(), tier)
    fd = nv.open("/a")
    nv.pwrite(fd, b"payload", 0)
    nv.close(fd)
    nv.rename("/a", "/b")
    # the apply went through the deferred queue, not synchronously under
    # the namespace lock — but it IS done by the time rename returns
    assert nv.ns.stats_deferred_applies >= 1
    assert tier.exists("/b") and not tier.exists("/a")
    fd = nv.open("/b", os.O_RDONLY)
    assert nv.pread(fd, 16, 0) == b"payload"
    nv.close(fd)
    nv.shutdown()


def test_drain_applies_deferred_record_when_caller_does_not():
    """The drain's meta-apply path: a queued apply whose originating
    thread never ran it must not wedge the drain — the drain thread runs
    the queue itself before consuming the record."""
    pol = make_policy(page_frames=0)
    tier = Tier(DRAM)
    nv = NVCache(pol, tier)
    fd = nv.open("/a")
    nv.pwrite(fd, b"data", 0)
    nv.close(fd)
    applied = []
    with nv._meta:
        marks, mseq = nv.ns.journal_locked(MOP_RENAME, META_NO_FDID, 0, "/a", "/b")
        nv.ns.queue_apply(
            mseq, lambda: (tier.rename("/a", "/b"), applied.append(1)), marks)
    # note: apply_deferred() deliberately NOT called here
    nv.flush()      # flush waits for the record to be consumed — which
    #                 requires a drain thread to have applied it first
    assert applied == [1]
    assert tier.exists("/b") and not tier.exists("/a")
    assert not nv.ns.has_unapplied()
    nv.shutdown()


# ------------------------------------------------------ recovery stats
def test_recovery_reports_frames():
    pol = make_policy(batch_min=10 ** 6, batch_max=10 ** 6)
    nvmm = ThreadFusedNVMM(pol.nvmm_bytes, track=True)
    tier = Tier(DRAM)
    nv = NVCache(pol, tier, nvmm=nvmm, recover=False, track_crashes=True)
    fd = nv.open("/f")
    for _ in range(8):
        nv.pwrite(fd, b"F" * PS, 0)
    assert the_file(nv).pmode
    nv.crash()
    tier2 = clone_tier(tier)
    stats = recover(nvmm, pol, tier2)
    assert stats.frames_seen == 1 and stats.frames_replayed == 1
    assert stats.frames_dropped == 0
    assert tier2.open("/f").snapshot() == b"F" * PS
