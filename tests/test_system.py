"""End-to-end behaviour: train -> crash -> recover -> resume, and the
paper's Table-I property matrix on our stacks."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke
from repro.core import NVCache, Policy, recover
from repro.data.pipeline import SyntheticTokens
from repro.models.registry import build
from repro.optim.adamw import AdamW
from repro.storage.fsapi import NVCacheFS, TierFS
from repro.storage.tiers import DRAM, Tier
from repro.train import loop as train_loop

POL = Policy(entry_size=16384, log_entries=8192, page_size=4096,
             read_cache_pages=64, batch_min=8, batch_max=512, verify_crc=False)


def _setup(tier=None):
    tier = tier or Tier(DRAM)
    nv = NVCache(POL, tier)
    cfg = get_smoke("llama3.2-1b")
    model = build(cfg)
    opt = AdamW(lr=1e-3)
    pipe = SyntheticTokens(cfg.vocab, batch=2, seq=32, seed=9)
    return tier, nv, model, opt, pipe


def test_train_loss_decreases():
    tier, nv, model, opt, pipe = _setup()
    _state, hist = train_loop.train(model, opt, pipe, NVCacheFS(nv),
                                    total_steps=30, ckpt_every=10)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first, f"loss did not decrease: {first} -> {last}"
    nv.shutdown()


def test_crash_restart_resumes_exactly():
    """Run 17 steps (ckpt@10), 'crash', recover the NVMM log, restart: the
    loop resumes from step 10 with identical data batches, and finishes."""
    tier = Tier(DRAM)
    nv = NVCache(POL, tier, track_crashes=True)
    cfg = get_smoke("llama3.2-1b")
    model = build(cfg)
    opt = AdamW(lr=1e-3)
    pipe = SyntheticTokens(cfg.vocab, batch=2, seq=32, seed=9)
    _, hist1 = train_loop.train(model, opt, pipe, NVCacheFS(nv),
                                total_steps=17, ckpt_every=10)
    # power loss right after the step-17 checkpoint: its bytes are durable
    # ONLY in the NVMM log (cleanup may not have drained) — recovery must
    # replay them into the slow tier for the restart to see step 17.
    nvmm = nv.crash()
    recover(nvmm, POL, tier.open)          # the paper's recovery procedure

    nv2 = NVCache(POL, tier)
    pipe2 = SyntheticTokens(cfg.vocab, batch=2, seq=32, seed=9)
    state2, hist2 = train_loop.train(model, opt, pipe2, NVCacheFS(nv2),
                                     total_steps=20, ckpt_every=10)
    # restarted at step 17 => 3 more steps run, data pipeline in lockstep
    assert len(hist2) == 3
    assert pipe2.step == 20
    nv2.shutdown()


def test_table1_property_matrix():
    """Paper Table I, as executable assertions."""
    # NVCache: synchronous durability (write durable before return) and
    # durable linearizability (visible => durable)
    tier = Tier(DRAM)
    nv = NVCache(POL, tier, track_crashes=True)
    fd = nv.open("/t")
    nv.pwrite(fd, b"D" * 100, 0)
    nvmm = nv.crash()                      # adversarial: nothing evicted
    tier2 = Tier(DRAM)
    recover(nvmm, POL, tier2.open)
    assert tier2.open("/t").snapshot()[:100] == b"D" * 100   # durable

    # large storage space: data >> NVMM log flows through to the slow tier
    tier = Tier(DRAM)
    small = Policy(entry_size=256, log_entries=16, page_size=256,
                   read_cache_pages=4, batch_min=2, batch_max=8)
    nv = NVCache(small, tier)
    fd = nv.open("/big")
    blob = bytes(range(256)) * 64          # 16 KiB >> 4 KiB log
    nv.pwrite(fd, blob, 0)
    assert nv.pread(fd, len(blob), 0) == blob
    nv.flush()
    assert tier.open("/big").snapshot()[:len(blob)] == blob
    nv.shutdown()

    # tmpfs: no durability (volatile) — fsync buys nothing
    vol = Tier(DRAM, volatile=True)
    f = vol.open("/v")
    f.pwrite(b"x", 0)
    f.fsync()
    assert vol.volatile                    # documented: no durability

    # fsync is a no-op on NVCache (Table III)
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    fd = nv.open("/noop")
    nv.write(fd, b"abc")
    before = nv.cleanup.stats_fsyncs
    nv.fsync(fd)
    assert nv.cleanup.stats_fsyncs == before
    nv.shutdown()
