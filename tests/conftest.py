"""Shared pytest configuration: the `slow` marker, the full-scan guard,
and the `--sanitize` mode.

Slow tests (multi-minute pjit / pipeline runs) are skipped by default and
enabled with ``--runslow``; CI runs the default (fast) selection.

The **full-scan guard** is always on: every :class:`repro.core.log.NVLog`
built during the session is registered, and any test across which the
total ``stats_full_scans`` grew fails — the read/drain paths must never
regress to whole-log scans (``scan_all_committed`` is recovery/diagnostic
only).  This replaces the ``assert nv.log.stats_full_scans == 0`` lines
that used to be scattered through the test files.  A test that scans on
purpose opts out with ``@pytest.mark.full_scan_ok``.

``--sanitize`` additionally arms the runtime checkers in
:mod:`repro.analysis` before any engine object is constructed: every NVMM
gets a persistence-ordering shadow (pmcheck) and every registered lock a
hierarchy tracer (lockcheck).  The autouse fixture below fails any test
that accumulated a violation — the checkers record instead of raise,
because raising inside a drain thread would hang the pool.
"""
import weakref

import pytest

_nvlog_refs = []


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="also run tests marked @pytest.mark.slow")
    parser.addoption("--sanitize", action="store_true", default=False,
                     help="run under the persistence-ordering and "
                          "lock-hierarchy sanitizers (repro.analysis)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test, excluded by default (use --runslow)")
    config.addinivalue_line(
        "markers", "full_scan_ok: test intentionally performs a full log "
                   "scan (exempt from the full-scan guard)")
    if config.getoption("--sanitize"):
        from repro.analysis import sanitize
        sanitize.install()
    # always-on full-scan guard bookkeeping (composes with the sanitize
    # patch of NVLog.__init__: this wraps whatever is currently installed)
    from repro.core.log import NVLog
    if not getattr(NVLog.__init__, "_full_scan_guard", False):
        orig_init = NVLog.__init__

        def init(self, *a, **kw):
            orig_init(self, *a, **kw)
            _nvlog_refs.append(weakref.ref(self))

        init._full_scan_guard = True
        NVLog.__init__ = init


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


def _total_full_scans() -> int:
    alive = [r() for r in _nvlog_refs]
    if len(alive) > 64 and None in alive:       # prune dead refs
        _nvlog_refs[:] = [r for r in _nvlog_refs if r() is not None]
    return sum(log.stats_full_scans for log in alive if log is not None)


@pytest.fixture(autouse=True)
def _sanitize_guard(request):
    """Fail any test that performed a full log scan (always), plus any
    test that accumulated a sanitizer violation (under --sanitize)."""
    base_scans = _total_full_scans()
    st = None
    if request.config.getoption("--sanitize"):
        from repro.analysis import sanitize
        st = sanitize.state_or_none()
        st.begin_test()
    yield
    # the global delta below owns FS001 reporting in-process
    errors = [] if st is None else st.end_test(allow_full_scan=True)
    if "full_scan_ok" not in request.keywords:
        delta = _total_full_scans() - base_scans
        if delta > 0:
            errors.append(
                f"FS001: {delta} full log scan(s) during this test "
                f"(scan_all_committed is recovery/diagnostic-only; mark "
                f"the test full_scan_ok if intentional)")
    if errors:
        pytest.fail("sanitizer violations:\n  " + "\n  ".join(errors),
                    pytrace=False)
