"""Shared pytest configuration: the `slow` marker and its opt-in flag.

Slow tests (multi-minute pjit / pipeline runs) are skipped by default and
enabled with ``--runslow``; CI runs the default (fast) selection.
"""
import pytest


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="also run tests marked @pytest.mark.slow")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test, excluded by default (use --runslow)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
