"""Extent-granular read path with readahead (PR 3 tentpole, read side).

A cache miss loads an aligned extent of ``Policy.readahead_pages`` in one
backend operation (``TierFile.preadv``); every covered page still goes
through the dirty-page-index replay, so readahead can never bypass
durable-linearizability (``NVLog.stats_full_scans`` stays 0 and the replay
stays O(entries-on-page)).
"""
import struct
import threading

import pytest

from repro.core import NVCache, Policy
from repro.storage.tiers import DRAM, PAGE, SSD_SATA, Tier


def make_policy(**kw) -> Policy:
    # readahead_ramp=False: these tests pin the PR-3 full-window arithmetic
    # exactly; the ramp (PR 5) has its own tests below
    defaults = dict(entry_size=256, log_entries=256, page_size=256,
                    read_cache_pages=64, batch_min=4, batch_max=16,
                    readahead_ramp=False)
    defaults.update(kw)
    return Policy(**defaults)


# ------------------------------------------------------------ op reduction
def test_cold_sequential_read_uses_fewer_backend_ops():
    """The acceptance shape: readahead=8 must issue >= 2x fewer backend
    read syscalls than readahead=1 on a cold sequential scan (~8x: the
    first miss is a single-page probe, the second opens the window)."""
    NP = 64
    ops = {}
    for ra in (1, 8):
        pol = make_policy(readahead_pages=ra, read_cache_pages=128)
        tier = Tier(DRAM)
        tier.open("/f").pwrite(bytes(range(256)) * NP, 0)
        nv = NVCache(pol, tier)
        fd = nv.open("/f")
        for p in range(NP):
            assert nv.pread(fd, 256, p * 256) == bytes(range(256))
        ops[ra] = tier.open("/f").stats_preads
        s = nv.stats()
        assert s["log_full_scans"] == 0
        if ra == 8:
            # miss 0 probes one page; miss 1 is sequential and loads the
            # rest of window [0, 8); then one extent load per window
            assert s["lru_misses"] == 2 + (NP - 8) // 8
            assert s["readahead_loads"] == 1 + (NP - 8) // 8
            assert s["readahead_pages"] == NP - s["lru_misses"]
            assert s["readahead_hits"] == s["readahead_pages"]  # all used
        nv.shutdown()
    assert ops[1] == 64
    assert ops[8] == 9, f"extent loads not batched: {ops}"


def test_random_misses_do_not_open_the_readahead_window():
    """A non-sequential miss loads only its own page — random workloads
    must not pay device cost for prefetches they will evict unused."""
    pol = make_policy(readahead_pages=8, read_cache_pages=128)
    tier = Tier(DRAM)
    tier.open("/f").pwrite(b"r" * (64 * 256), 0)
    nv = NVCache(pol, tier)
    fd = nv.open("/f")
    for p in (40, 3, 17, 60, 9, 33):          # no two sequential
        assert nv.pread(fd, 256, p * 256) == b"r" * 256
    tf = tier.open("/f")
    assert tf.stats_preads == 6
    assert tf.stats_page_reads == 0           # DRAM tier: cached by prefill
    assert nv.stats()["readahead_loads"] == 0
    assert nv.stats()["lru_misses"] == 6
    nv.shutdown()


def test_readahead_skips_already_cached_pages():
    """Pages already loaded inside the extent window are not re-read: the
    iovec segments cover only the uncached runs."""
    pol = make_policy(readahead_pages=8, read_cache_pages=128)
    tier = Tier(DRAM)
    tier.open("/f").pwrite(b"q" * (8 * 256), 0)
    nv = NVCache(pol, tier)
    fd = nv.open("/f")
    nv.pread(fd, 1, 0)              # probe: loads page 0 alone
    nv.pread(fd, 1, 256)            # sequential miss: loads window [0, 8)
    f = nv._files["/f"]
    assert all(f.radix.get(p).content is not None for p in range(8))
    tf = tier.open("/f")
    assert tf.stats_preads == 2
    # probe = 1 single-page segment; window = ONE run covering pages 1..7
    # (page 0 is cached and skipped, not re-read)
    assert tf.stats_rvec_segments == 2
    assert tf.stats_page_reads == 0           # DRAM prefill cached everything
    # re-read everything: pure hits, no new backend ops
    for p in range(8):
        assert nv.pread(fd, 256, p * 256) == b"q" * 256
    assert tf.stats_preads == 2
    nv.shutdown()


# ------------------------------------------------- dirty replay is never lost
def test_readahead_never_bypasses_dirty_index_replay():
    """Prefetched pages with live log entries must replay them — the
    backend bytes alone are stale until the drain runs."""
    pol = make_policy(readahead_pages=4, batch_min=10 ** 6, batch_max=10 ** 6,
                      read_cache_pages=64)
    tier = Tier(DRAM)
    nv = NVCache(pol, tier)
    fd = nv.open("/f")
    E = 3
    for p in range(8):                     # E live entries on every page
        for j in range(E):
            nv.pwrite(fd, bytes([16 * p + j + 1]) * 64, p * 256 + j * 64)
    assert nv.log.used_entries == 8 * E + 1   # nothing drained (+1: the
    #                                           journaled create of "/f")
    # force every page out of the cache so the next reads are extent misses
    nv.lru.drop_all()
    replay0 = nv.stats_replay_entries
    nv.pread(fd, 1, 0)                     # probe miss: page 0, replay E
    got = nv.pread(fd, 256, 256)           # sequential miss: window [0, 4)
    exp = bytearray(256)
    for j in range(E):
        exp[j * 64:(j + 1) * 64] = bytes([16 + j + 1]) * 64
    assert got[:E * 64] == bytes(exp[:E * 64])
    # pages 0..3 all replayed their index — exactly E entries each
    assert nv.stats_replay_entries - replay0 == 4 * E
    assert nv.stats_readahead_pages == 2   # pages 2, 3 prefetched
    # the prefetched pages serve the replayed (fresh) bytes on their hit
    for p in (2, 3):
        got = nv.pread(fd, 64, p * 256)
        assert got == bytes([16 * p + 1]) * 64, f"stale prefetched page {p}"
    assert nv.stats_readahead_hits == 2
    nv.shutdown()


def test_readahead_clamped_to_half_the_cache():
    """A tiny read cache degrades readahead to the per-page baseline
    instead of flushing itself on every miss."""
    pol = make_policy(readahead_pages=8, read_cache_pages=2)
    tier = Tier(DRAM)
    tier.open("/f").pwrite(b"z" * (16 * 256), 0)
    nv = NVCache(pol, tier)
    fd = nv.open("/f")
    for p in range(16):
        assert nv.pread(fd, 256, p * 256) == b"z" * 256
    assert nv.stats_readahead_loads == 0   # effective readahead == 1
    assert tier.open("/f").stats_preads == 16
    nv.shutdown()


def test_extent_clipped_to_file_size():
    pol = make_policy(readahead_pages=8, read_cache_pages=64)
    tier = Tier(DRAM)
    nv = NVCache(pol, tier)
    fd = nv.open("/f")
    nv.pwrite(fd, b"ab" * 300, 0)          # 600 bytes: pages 0..2
    nv.flush()
    nv.lru.drop_all()
    assert nv.pread(fd, 600, 0) == b"ab" * 300
    f = nv._files["/f"]
    assert f.radix.get(3) is None or f.radix.get(3).content is None, \
        "loaded a page past EOF"
    nv.shutdown()


# --------------------------------------------------- concurrency / lock order
def test_readahead_under_eviction_pressure_and_writers():
    """Extent loads take [atomic locks asc] then [cleanup locks asc] while
    writers take atomic locks asc and the drain takes cleanup locks asc —
    hammer all three with a cache smaller than the extent window and check
    nothing deadlocks or tears."""
    pol = Policy(entry_size=1024, log_entries=128, page_size=1024,
                 read_cache_pages=8, batch_min=4, batch_max=16,
                 readahead_pages=4)
    nv = NVCache(pol, Tier(DRAM))
    fd = nv.open("/f")
    ps = 1024
    NPAGES = 16                            # 2x the cache, 4x the extent
    OPS = 40
    errors = []
    stop = threading.Event()

    def writer(w):
        try:
            for i in range(OPS):
                p = (w + i) % NPAGES
                c = (w << 16) | (i + 1)
                nv.pwrite(fd, struct.pack("<I", c) * (ps // 4), p * ps)
        except Exception as exc:
            errors.append(exc)

    def reader():
        try:
            i = 0
            while not stop.is_set():
                p = i % NPAGES            # sequential: extent loads trigger
                i += 1
                page = nv.pread(fd, ps, p * ps)
                if not page.strip(b"\x00"):
                    continue
                if page[:4] * (ps // 4) != page:
                    errors.append(AssertionError(f"torn page {p}"))
                    stop.set()
        except Exception as exc:
            errors.append(exc)

    def flusher():
        try:
            while not stop.is_set():
                nv.flush(timeout=60)
        except Exception as exc:
            errors.append(exc)

    ws = [threading.Thread(target=writer, args=(w,)) for w in range(3)]
    rs = [threading.Thread(target=reader) for _ in range(2)]
    fl = threading.Thread(target=flusher)
    for t in ws + rs + [fl]:
        t.start()
    for t in ws:
        t.join(timeout=120)
    stop.set()
    for t in rs + [fl]:
        t.join(timeout=60)
    assert all(not t.is_alive() for t in ws + rs + [fl]), "deadlocked"
    if errors:
        raise errors[0]
    nv.shutdown()


# ----------------------------------------------------------- tier cost model
def test_preadv_cost_and_stats_model():
    tier = Tier(SSD_SATA)
    f = tier.open("/v")
    f.pwrite(b"x" * (4 * PAGE), 0)
    f.drop_page_cache()                    # writes populated the page cache
    assert f._dirty_pages == {0, 1, 2, 3}  # dirty pages cannot be dropped
    f.fsync()
    f.drop_page_cache()
    c0 = tier.gate.total_cost
    chunks = f.preadv([(PAGE, 0), (2 * PAGE, 2 * PAGE)])
    assert [len(c) for c in chunks] == [PAGE, 2 * PAGE]
    paid = tier.gate.total_cost - c0
    expect = SSD_SATA.syscall_s + SSD_SATA.iov_seg_s + 3 * SSD_SATA.page_read_s
    assert abs(paid - expect) < 1e-12, (paid, expect)
    assert f.stats_preads == 1
    assert f.stats_page_reads == 3
    assert f.stats_rvec_segments == 2
    # now cached: same call pays only syscall + segment overhead
    c0 = tier.gate.total_cost
    f.preadv([(PAGE, 0), (2 * PAGE, 2 * PAGE)])
    paid = tier.gate.total_cost - c0
    assert abs(paid - (SSD_SATA.syscall_s + SSD_SATA.iov_seg_s)) < 1e-12
    # short reads past EOF
    tail = f.preadv([(3 * PAGE, 3 * PAGE)])
    assert len(tail[0]) == PAGE


def test_pread_counts_read_stats():
    tier = Tier(SSD_SATA)
    f = tier.open("/r")
    f.pwrite(b"y" * PAGE, 0)
    f.drop_page_cache()
    f.fsync()
    f.drop_page_cache()
    f.pread(10, 0)
    assert f.stats_preads == 1 and f.stats_page_reads == 1
    f.pread(10, 0)                         # cached now
    assert f.stats_preads == 2 and f.stats_page_reads == 1


def test_lru_overflow_converges_back_to_capacity():
    """Overflow allocations (every victim pinned) must not ratchet the
    resident page count up forever: later acquires shrink back."""
    from repro.core.readcache import LRUCache, PageDesc
    lru = LRUCache(4, 64)
    descs = [PageDesc(i) for i in range(4)]
    for d in descs:
        lru.attach(d, lru.acquire_buffer())
    for d in descs:                           # pin everything
        d.atomic_lock.acquire()
    extra = lru.acquire_buffer()              # forced overflow
    assert lru._allocated == 5
    d5 = PageDesc(5)
    lru.attach(d5, extra)
    for d in descs:
        d.atomic_lock.release()
    for i in range(6, 14):                    # normal churn shrinks the pool
        d = PageDesc(i)
        lru.attach(d, lru.acquire_buffer())
    assert lru._allocated <= 4, "overflow ratcheted the cache size"


@pytest.mark.parametrize("bad", [dict(readahead_pages=0),
                                 dict(coalesce_deadline_ms=-1.0)])
def test_policy_validation(bad):
    with pytest.raises(ValueError):
        make_policy(**bad)


# ------------------------------------------------------- readahead ramp (PR 5)
def _ramp_nv(np=64, cap=8):
    pol = make_policy(readahead_pages=cap, read_cache_pages=128,
                      readahead_ramp=True)
    tier = Tier(DRAM)
    tier.open("/f").pwrite(bytes(range(256)) * np, 0)
    nv = NVCache(pol, tier)
    return nv, tier, nv.open("/f")


def test_ramp_grows_2_4_8_on_a_sequential_stream():
    """Kernel-style window growth: the first sequential miss after a reset
    loads 2 pages, the next 4, then 8 — the full window is only paid once
    the stream has proven itself."""
    nv, tier, fd = _ramp_nv()
    f = nv._of(fd).file
    loads = []                       # extent sizes, via the range helper
    p = 0
    nv.pread(fd, 256, 0)             # miss 0: probe (1 page)
    assert f.ra_window == 1
    for expect in (2, 4, 8, 8):
        p = f.ra_next
        e0, e1 = nv._extent_range(f, p)
        assert e0 == p and e1 - e0 == expect, (p, e0, e1)
        loads.append(e1 - e0)
        f.ra_next = e1               # pretend the extent loaded
    nv.shutdown()


def test_ramp_resets_on_a_random_miss():
    nv, tier, fd = _ramp_nv()
    f = nv._of(fd).file
    nv.pread(fd, 256, 0)                       # probe
    p = f.ra_next
    e0, e1 = nv._extent_range(f, p)            # ramp to 2
    assert (e0, e1) == (p, p + 2)
    assert f.ra_window == 2
    e0, e1 = nv._extent_range(f, 40)           # random miss: reset
    assert (e0, e1) == (40, 41)
    assert f.ra_window == 1
    e0, e1 = nv._extent_range(f, 41)           # sequential again: ramp anew
    assert e1 - e0 == 2
    nv.shutdown()


def test_ramp_short_burst_pays_less_than_full_window():
    """The satellite's point: a 4-page sequential burst must not load the
    full 8-page window (ramp: 1 + 2 + catches the rest), while a long
    stream converges to the same per-window cost as the static window."""
    # short burst: 4 pages
    nv, tier, fd = _ramp_nv(np=64)
    tf = tier.open("/f")
    tf.drop_page_cache()
    base = tf.stats_page_reads
    for p in range(4):
        nv.pread(fd, 256, p * 256)
    burst_pages = tf.stats_page_reads - base
    assert burst_pages <= 5, f"short burst overpaid: {burst_pages} pages"
    nv.shutdown()
    # long stream: total loads close to the static-window count
    nv, tier, fd = _ramp_nv(np=64)
    tf = tier.open("/f")
    tf.drop_page_cache()
    for p in range(64):
        assert nv.pread(fd, 256, p * 256) == bytes(range(256))
    s = nv.stats()
    assert s["log_full_scans"] == 0
    assert tf.stats_preads <= 12, f"long stream lost batching: {tf.stats_preads}"
    nv.shutdown()
