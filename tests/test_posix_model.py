"""Model-based POSIX conformance: random op sequences through NVCache must
behave exactly like an in-memory reference file (hypothesis-driven)."""
import os

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:                      # container without hypothesis
    from _propcheck import HealthCheck, given, settings, strategies as st

from repro.core import NVCache, Policy
from repro.storage.tiers import DRAM, Tier

POL = Policy(entry_size=128, log_entries=64, page_size=128,
             read_cache_pages=4, batch_min=4, batch_max=16)


class RefFile:
    """The oracle: plain POSIX semantics in memory."""

    def __init__(self):
        self.data = bytearray()
        self.cursor = 0

    def pwrite(self, data, off):
        end = off + len(data)
        if end > len(self.data):
            self.data.extend(b"\x00" * (end - len(self.data)))
        self.data[off:end] = data

    def pread(self, n, off):
        if off >= len(self.data):
            return b""
        return bytes(self.data[off:off + n])

    def write(self, data):
        self.pwrite(data, self.cursor)
        self.cursor += len(data)

    def read(self, n):
        out = self.pread(n, self.cursor)
        self.cursor += len(out)
        return out

    def seek(self, off, whence):
        if whence == os.SEEK_SET:
            target = off
        elif whence == os.SEEK_CUR:
            target = self.cursor + off
        else:
            target = len(self.data) + off
        if target < 0:
            raise OSError("negative seek (EINVAL)")   # cursor unchanged
        self.cursor = target
        return self.cursor


ops_st = st.lists(st.one_of(
    st.tuples(st.just("pwrite"), st.integers(0, 600),
              st.binary(min_size=1, max_size=300)),
    st.tuples(st.just("pread"), st.integers(0, 700), st.integers(1, 300)),
    st.tuples(st.just("write"), st.binary(min_size=1, max_size=200)),
    st.tuples(st.just("read"), st.integers(1, 200)),
    st.tuples(st.just("seek"), st.integers(-50, 700),
              st.sampled_from([os.SEEK_SET, os.SEEK_CUR, os.SEEK_END])),
    st.tuples(st.just("size"),),
    st.tuples(st.just("flush"),),
), min_size=1, max_size=30)


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=ops_st)
def test_nvcache_matches_posix_reference(ops):
    nv = NVCache(POL, Tier(DRAM))
    ref = RefFile()
    fd = nv.open("/f")
    try:
        for op in ops:
            if op[0] == "pwrite":
                _, off, data = op
                nv.pwrite(fd, data, off)
                ref.pwrite(data, off)
            elif op[0] == "pread":
                _, off, n = op
                assert nv.pread(fd, n, off) == ref.pread(n, off), op
            elif op[0] == "write":
                nv.write(fd, op[1])
                ref.write(op[1])
            elif op[0] == "read":
                assert nv.read(fd, op[1]) == ref.read(op[1]), op
            elif op[0] == "seek":
                _, off, whence = op
                try:
                    got = nv.lseek(fd, off, whence)
                except OSError:
                    got = "EINVAL"
                try:
                    want = ref.seek(off, whence)
                except OSError:
                    want = "EINVAL"
                assert got == want, op
            elif op[0] == "size":
                assert nv.stat_size(fd) == len(ref.data)
            elif op[0] == "flush":
                nv.flush()
        # final byte-for-byte equality
        assert nv.pread(fd, len(ref.data) + 10, 0) == bytes(ref.data)
    finally:
        nv.shutdown()


lifecycle_ops_st = st.lists(st.one_of(
    st.tuples(st.just("pwrite"), st.integers(0, 600),
              st.binary(min_size=1, max_size=300)),
    st.tuples(st.just("pread"), st.integers(0, 700), st.integers(1, 300)),
    st.tuples(st.just("append"), st.binary(min_size=1, max_size=200)),
    st.tuples(st.just("truncate"),),
    st.tuples(st.just("stat"),),
    st.tuples(st.just("stat_missing"),),
    st.tuples(st.just("flush"),),
    st.tuples(st.just("reopen"),),
), min_size=1, max_size=25)


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=lifecycle_ops_st)
def test_lifecycle_ops_match_posix_reference(ops):
    """The PR-3 lifecycle surface (O_TRUNC reopen, O_APPEND writes, stat of
    open/unopened/missing paths, close/reopen) under random interleavings
    against the in-memory oracle."""
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    ref = RefFile()
    fd = nv.open("/f")
    missing = 0
    try:
        for op in ops:
            if op[0] == "pwrite":
                _, off, data = op
                nv.pwrite(fd, data, off)
                ref.pwrite(data, off)
            elif op[0] == "pread":
                _, off, n = op
                assert nv.pread(fd, n, off) == ref.pread(n, off), op
            elif op[0] == "append":
                afd = nv.open("/f", os.O_RDWR | os.O_CREAT | os.O_APPEND)
                nv.write(afd, op[1])
                ref.pwrite(op[1], len(ref.data))
                nv.close(afd)
            elif op[0] == "truncate":
                tfd = nv.open("/f", os.O_RDWR | os.O_CREAT | os.O_TRUNC)
                ref.data = bytearray()
                nv.close(tfd)
            elif op[0] == "stat":
                assert nv.stat_size(fd) == len(ref.data)
                assert nv.stat_size("/f") == len(ref.data)
            elif op[0] == "stat_missing":
                missing += 1
                path = f"/missing-{missing}"
                try:
                    nv.stat_size(path)
                    raise AssertionError("stat of a missing path succeeded")
                except FileNotFoundError:
                    pass
                assert not tier.exists(path), "stat created a phantom file"
            elif op[0] == "flush":
                nv.flush()
            elif op[0] == "reopen":
                nv.close(fd)
                fd = nv.open("/f")
        assert nv.pread(fd, len(ref.data) + 10, 0) == bytes(ref.data)
        nv.flush()
        snap = tier.open("/f").snapshot()
        assert snap[:len(ref.data)] == bytes(ref.data)
        assert not any(snap[len(ref.data):]), "stale bytes past truncation"
    finally:
        nv.shutdown()


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=lifecycle_ops_st, crash_seed=st.integers(0, 2 ** 30))
def test_lifecycle_ops_crash_recovery(ops, crash_seed):
    """Same op mix, then power loss: recovery must reproduce the oracle —
    in particular it must never resurrect pre-truncate bytes."""
    import random
    from repro.core import recover
    tier = Tier(DRAM)
    nv = NVCache(POL, tier, track_crashes=True)
    ref = RefFile()
    fd = nv.open("/f")
    for op in ops:
        if op[0] == "pwrite":
            _, off, data = op
            nv.pwrite(fd, data, off)
            ref.pwrite(data, off)
        elif op[0] == "append":
            afd = nv.open("/f", os.O_RDWR | os.O_CREAT | os.O_APPEND)
            nv.write(afd, op[1])
            ref.pwrite(op[1], len(ref.data))
            nv.close(afd)
        elif op[0] == "truncate":
            tfd = nv.open("/f", os.O_RDWR | os.O_CREAT | os.O_TRUNC)
            ref.data = bytearray()
            nv.close(tfd)
        elif op[0] == "flush":
            nv.flush()
        # read-only/stat ops don't change the durable image: skip
    rng = random.Random(crash_seed)
    nvmm = nv.crash(choose_evicted=lambda lines: [
        l for l in lines if rng.random() < 0.5])
    tier2 = Tier(DRAM)
    for path in tier.paths():
        snap = tier.open(path).snapshot()
        if snap:
            tier2.open(path).pwrite(snap, 0)
    recover(nvmm, POL, tier2.open)
    got = tier2.open("/f").snapshot()
    assert got[:len(ref.data)] == bytes(ref.data)
    assert not any(got[len(ref.data):]), "recovery resurrected stale bytes"


def test_flock_unlock_flushes():
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    fd = nv.open("/f")
    nv.pwrite(fd, b"locked-write", 0)
    nv.flock(fd)                    # acquire: no flush needed
    nv.flock(fd, unlock=True)       # release: pending writes reach the tier
    assert tier.open("/f").snapshot()[:12] == b"locked-write"
    nv.shutdown()


# ------------------------------------------------ namespace ops (PR 5)
PATHS = ["/p0", "/p1", "/p2"]

namespace_ops_st = st.lists(st.one_of(
    st.tuples(st.just("pwrite"), st.integers(0, 2), st.integers(0, 500),
              st.binary(min_size=1, max_size=200)),
    st.tuples(st.just("pread"), st.integers(0, 2), st.integers(0, 600),
              st.integers(1, 200)),
    st.tuples(st.just("ftruncate"), st.integers(0, 2), st.integers(0, 450)),
    st.tuples(st.just("rename"), st.integers(0, 2), st.integers(0, 2)),
    st.tuples(st.just("unlink"), st.integers(0, 2)),
    st.tuples(st.just("stat"), st.integers(0, 2)),
    st.tuples(st.just("flush"),),
), min_size=1, max_size=25)


def _apply_namespace_ops(nv, ref, ops):
    """Drive NVCache and the multi-path oracle (path -> bytearray) through
    one op list; every access opens/closes so rename/unlink see refs==0."""
    for op in ops:
        kind = op[0]
        if kind == "pwrite":
            _, pi, off, data = op
            path = PATHS[pi]
            fd = nv.open(path)
            nv.pwrite(fd, data, off)
            nv.close(fd)
            img = ref.setdefault(path, bytearray())
            if off + len(data) > len(img):
                img.extend(b"\x00" * (off + len(data) - len(img)))
            img[off:off + len(data)] = data
        elif kind == "pread":
            _, pi, off, n = op
            path = PATHS[pi]
            if path not in ref:
                continue
            fd = nv.open(path)
            want = bytes(ref[path][off:off + n])
            assert nv.pread(fd, n, off) == want, op
            nv.close(fd)
        elif kind == "ftruncate":
            _, pi, ln = op
            path = PATHS[pi]
            fd = nv.open(path)
            nv.ftruncate(fd, ln)
            nv.close(fd)
            img = ref.setdefault(path, bytearray())
            if ln <= len(img):
                del img[ln:]
            else:
                img.extend(b"\x00" * (ln - len(img)))
        elif kind == "rename":
            _, si, di = op
            src, dst = PATHS[si], PATHS[di]
            if src not in ref:
                try:
                    nv.rename(src, dst)
                    raise AssertionError(f"rename of missing {src} passed")
                except FileNotFoundError:
                    continue
            nv.rename(src, dst)
            if src != dst:
                ref[dst] = ref.pop(src)
        elif kind == "unlink":
            _, pi = op
            path = PATHS[pi]
            if path not in ref:
                try:
                    nv.unlink(path)
                    raise AssertionError(f"unlink of missing {path} passed")
                except FileNotFoundError:
                    continue
            nv.unlink(path)
            del ref[path]
        elif kind == "stat":
            _, pi = op
            path = PATHS[pi]
            if path in ref:
                assert nv.stat_size(path) == len(ref[path]), op
            else:
                try:
                    nv.stat_size(path)
                    raise AssertionError(f"stat of missing {path} passed")
                except FileNotFoundError:
                    pass
        elif kind == "flush":
            nv.flush()


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=namespace_ops_st)
def test_namespace_ops_match_posix_reference(ops):
    """rename/unlink/ftruncate across three paths against a multi-path
    oracle: contents, sizes, ENOENT behavior and the final durable image
    must all match plain POSIX."""
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    ref = {}
    try:
        _apply_namespace_ops(nv, ref, ops)
        nv.flush()
        for path in PATHS:
            if path in ref:
                want = bytes(ref[path])
                fd = nv.open(path)
                assert nv.pread(fd, len(want) + 10, 0) == want
                nv.close(fd)
                snap = tier.open(path).snapshot()
                assert snap[:len(want)] == want
                assert not any(snap[len(want):]), "stale bytes past EOF"
            else:
                assert not tier.exists(path), f"{path} should not exist"
    finally:
        nv.shutdown()


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=namespace_ops_st, crash_seed=st.integers(0, 2 ** 30))
def test_namespace_ops_crash_recovery(ops, crash_seed):
    """Same op mix, then power loss with adversarial cacheline eviction:
    after recovery every surviving path holds exactly the oracle bytes,
    unlinked files never resurrect, renamed data lives under exactly the
    new name."""
    import random
    from repro.core import recover
    tier = Tier(DRAM)
    nv = NVCache(POL, tier, track_crashes=True)
    ref = {}
    _apply_namespace_ops(nv, ref, ops)
    rng = random.Random(crash_seed)
    nvmm = nv.crash(choose_evicted=lambda lines: [
        l for l in lines if rng.random() < 0.5])
    tier2 = Tier(DRAM)
    for path in tier.paths():
        snap = tier.open(path).snapshot()
        f2 = tier2.open(path)
        if snap:
            f2.pwrite(snap, 0)
    tier2.ns_seq = tier.ns_seq
    recover(nvmm, POL, tier2)
    for path in PATHS:
        if path in ref:
            want = bytes(ref[path])
            got = tier2.open(path).snapshot()
            assert got[:len(want)] == want, f"{path}: lost acknowledged bytes"
            assert not any(got[len(want):]), f"{path}: stale bytes past EOF"
        else:
            assert not tier2.exists(path), f"{path} resurrected by recovery"
