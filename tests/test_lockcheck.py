"""Unit tests for the lock-hierarchy tracer and the static lint pass
(repro.analysis.lockcheck / repro.analysis.lint) plus the hierarchy table
itself (repro.core.locking)."""
import textwrap
from pathlib import Path

import pytest

from repro.analysis import lint
from repro.analysis.lockcheck import LockTracer
from repro.core import locking
from repro.core.locking import HIERARCHY, LEAF_LEVEL, parse_hierarchy


# ------------------------------------------------------------- the hierarchy


def test_hierarchy_table_parses_and_is_sane():
    h = parse_hierarchy()
    assert h == HIERARCHY
    for name in ("meta", "route_gate", "page_atomic", "page_cleanup",
                 "shard", "pager_free"):
        assert name in h, name
    # ordered classes sit strictly below the leaf band
    ordered = {n: i for n, i in h.items() if not n.startswith("leaf:")}
    assert all(i["level"] < LEAF_LEVEL for i in ordered.values())
    assert all(i["level"] == LEAF_LEVEL for n, i in h.items()
               if n.startswith("leaf:"))
    # the write path holds page locks across log.append: shard ranks after
    assert h["page_atomic"]["level"] < h["shard"]["level"]
    assert h["page_atomic"]["multi"] and h["page_cleanup"]["multi"]


# ---------------------------------------------------------------- the tracer


def lk(tracer, name, **kw):
    return tracer.traced_lock(name, HIERARCHY[name], **kw)


def test_lc001_on_level_inversion():
    tr = LockTracer()
    meta, shard = lk(tr, "meta"), lk(tr, "shard")
    with shard:
        with meta:                      # 50 -> 10: inversion
            pass
    assert any(v.code == "LC001" for v in tr.violations)


def test_in_order_acquire_is_clean_and_recorded():
    tr = LockTracer()
    meta, shard = lk(tr, "meta"), lk(tr, "shard")
    with meta:
        with shard:
            pass
    assert tr.violations == []
    assert ("meta", "shard") in tr.edges


def test_lc002_on_descending_multi_keys():
    tr = LockTracer()
    p3 = lk(tr, "page_atomic", order_key=3)
    p1 = lk(tr, "page_atomic", order_key=1)
    with p3:
        with p1:                        # same class, key 1 after 3
            pass
    assert any(v.code == "LC002" for v in tr.violations)
    tr2 = LockTracer()
    a, b = lk(tr2, "page_atomic", order_key=1), lk(tr2, "page_atomic",
                                                   order_key=2)
    with a:
        with b:                         # ascending: fine
            pass
    assert tr2.violations == []


def test_trylock_is_exempt_from_ordering():
    tr = LockTracer()
    meta, shard = lk(tr, "meta"), lk(tr, "shard")
    with shard:
        assert meta.acquire(blocking=False)   # try-lock: cannot deadlock
        meta.release()
    assert tr.violations == []


def test_lc004_backend_io_under_shard_lock():
    tr = LockTracer()
    shard = lk(tr, "shard")
    with shard:
        tr.on_backend_io("pwritev", "/f")
    assert any(v.code == "LC004" for v in tr.violations)
    tr.violations.clear()
    tr.on_backend_io("fsync", "/f")           # not held: fine
    assert tr.violations == []


def test_lc003_cycle_detection():
    tr = LockTracer()
    tr.edges[("a", "b")] = "t1"
    tr.edges[("b", "c")] = "t1"
    tr.edges[("c", "a")] = "t2"
    assert tr.check_cycles()
    assert any(v.code == "LC003" for v in tr.violations)
    tr2 = LockTracer()
    tr2.edges[("a", "b")] = "t1"
    tr2.edges[("a", "c")] = "t1"
    assert tr2.check_cycles() == []


def test_traced_condition_notify_while_held():
    """Regression: TracedLock lacked ``_is_owned``, so Condition's fallback
    probe (``acquire(False)``) succeeded reentrantly on RLock-backed
    wrappers and ``notify`` raised "cannot notify on un-acquired lock"."""
    tr = LockTracer()
    prev = locking._tracer              # --sanitize arms a session tracer:
    locking.set_tracer(tr)              # restore IT, not None, or every
    try:                                # later test loses its lock edges
        cv = locking.make_condition("leaf:fsync_epoch")
        with cv:
            cv.notify_all()             # raised before the fix
            assert cv._lock._is_owned()
        # release/acquire cycles used by Condition.wait keep the owner sane
        shared = locking.make_lock("shard")
        cv2 = locking.make_condition("shard", shared)
        with cv2:
            state = shared._release_save()
            assert not shared._is_owned()
            shared._acquire_restore(state)
            assert shared._is_owned()
    finally:
        locking.set_tracer(prev)
    assert tr.violations == []


def test_untraced_factories_return_plain_locks():
    prev = locking._tracer
    locking.set_tracer(None)
    try:
        lock = locking.make_lock("shard")
        assert type(lock).__module__ == "_thread"   # zero overhead when off
    finally:
        locking.set_tracer(prev)


# ------------------------------------------------------------------ the lint


def test_lint_clean_on_core():
    import repro.core as core
    assert lint.run([Path(core.__file__).parent]) == []


def run_lint_snippet(tmp_path, src):
    f = tmp_path / "snippet.py"
    f.write_text(textwrap.dedent(src))
    return [(x.code, x.line) for x in lint.run([f])]


def test_lint_l001_direct_construction(tmp_path):
    out = run_lint_snippet(tmp_path, """\
        import threading
        lock = threading.Lock()
        """)
    assert ("L001", 2) in out


def test_lint_l001_unknown_class_and_non_literal(tmp_path):
    out = run_lint_snippet(tmp_path, """\
        from repro.core import locking
        a = locking.make_lock("no_such_class")
        name = "shard"
        b = locking.make_lock(name)
        """)
    assert ("L001", 2) in out and ("L001", 4) in out


def test_lint_l002_io_under_shard_lock(tmp_path):
    out = run_lint_snippet(tmp_path, """\
        from repro.core import locking
        import time

        class S:
            def __init__(self):
                self._lock = locking.make_lock("shard")

            def bad(self, backend, data):
                with self._lock:
                    time.sleep(0.1)
                    backend.pwritev(data, 0)

            def good(self, backend, data):
                with self._lock:
                    pass
                backend.pwritev(data, 0)
        """)
    codes = [c for c, _ in out]
    assert codes.count("L002") == 2
    assert ("L002", 10) in out and ("L002", 11) in out


def test_lint_l003_psync_without_pwb(tmp_path):
    out = run_lint_snippet(tmp_path, """\
        def bad(nvmm, off, data):
            nvmm.store(off, data)
            nvmm.psync()

        def good(nvmm, off, data):
            nvmm.store(off, data)
            nvmm.pwb(off, len(data))
            nvmm.psync()
        """)
    assert out == [("L003", 3)]


def test_lint_suppression_comment(tmp_path):
    out = run_lint_snippet(tmp_path, """\
        def odd(nvmm):
            nvmm.psync()  # lint: allow(L003)
        """)
    assert out == []
