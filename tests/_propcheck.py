"""Dependency-free stand-in for the subset of `hypothesis` these tests use.

The container may not ship `hypothesis`; rather than skip the crash-
consistency and POSIX-model property tests (they are the tier-1 safety
net), we fall back to this minimal clone: deterministic seeded random
generation, `max_examples` iterations, no shrinking.  Failures re-raise
with the falsifying example attached.  When the real hypothesis is
installed the test modules import it instead and none of this is used.
"""
from __future__ import annotations


import os
import random
import zlib


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"


class _Strategy:
    __slots__ = ("_draw",)

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


class _DataStrategy:
    """Marker for `st.data()`; `given` resolves it to a `_Data` object."""


class _Data:
    """Interactive draws inside the test body (`data.draw(strategy)`)."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy, label: str | None = None):
        return strategy.draw(self._rng)


class strategies:
    """Namespace mirroring `hypothesis.strategies` (the used subset)."""

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 1 << 30) -> _Strategy:
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def binary(*, min_size: int = 0, max_size: int = 64) -> _Strategy:
        return _Strategy(lambda r: r.randbytes(r.randint(min_size, max_size)))

    @staticmethod
    def just(value) -> _Strategy:
        return _Strategy(lambda r: value)

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda r: r.choice(seq))

    @staticmethod
    def one_of(*strats) -> _Strategy:
        if len(strats) == 1 and isinstance(strats[0], (list, tuple)):
            strats = tuple(strats[0])
        return _Strategy(lambda r: r.choice(strats).draw(r))

    @staticmethod
    def tuples(*strats) -> _Strategy:
        return _Strategy(lambda r: tuple(s.draw(r) for s in strats))

    @staticmethod
    def lists(elem: _Strategy, *, min_size: int = 0, max_size: int = 10) -> _Strategy:
        return _Strategy(
            lambda r: [elem.draw(r) for _ in range(r.randint(min_size, max_size))])

    @staticmethod
    def sets(elem: _Strategy, *, min_size: int = 0, max_size: int = 10) -> _Strategy:
        def draw(r: random.Random):
            want = r.randint(min_size, max_size)
            out: set = set()
            for _ in range(want * 4 + 4):
                if len(out) >= want:
                    break
                out.add(elem.draw(r))
            return out
        return _Strategy(draw)

    @staticmethod
    def nothing() -> _Strategy:
        def draw(_r):
            raise AssertionError("nothing() must never be drawn from")
        return _Strategy(draw)

    @staticmethod
    def data() -> _DataStrategy:
        return _DataStrategy()


def settings(max_examples: int = 100, deadline=None, suppress_health_check=()):
    def deco(fn):
        fn._pc_max_examples = max_examples
        return fn
    return deco


def given(**strategy_kwargs):
    def deco(fn):
        # NOTE: no functools.wraps — pytest would introspect __wrapped__ and
        # mistake the strategy parameters for fixtures.
        def run(*args, **kwargs):
            n = getattr(run, "_pc_max_examples", 100)
            base = zlib.crc32(fn.__qualname__.encode())
            base ^= int(os.environ.get("PROPCHECK_SEED", "0"))
            for i in range(n):
                rng = random.Random(base * 1_000_003 + i)
                drawn = {}
                for name, strat in strategy_kwargs.items():
                    if isinstance(strat, _DataStrategy):
                        drawn[name] = _Data(rng)
                    else:
                        drawn[name] = strat.draw(rng)
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as exc:
                    shown = {k: v for k, v in drawn.items()
                             if not isinstance(v, _Data)}
                    msg = repr(shown)
                    if len(msg) > 600:
                        msg = msg[:600] + "..."
                    raise AssertionError(
                        f"falsifying example #{i} of {fn.__qualname__}: {msg}"
                    ) from exc
        run.__name__ = fn.__name__
        run.__qualname__ = fn.__qualname__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        return run
    return deco
