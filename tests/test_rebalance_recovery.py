"""Crash + recovery property: no partial commit group is EVER observable
after ``recover()`` — under static routes and under adaptive routing with a
route-epoch flip mid-sequence.

The harness reuses the fuse model of ``test_sharded_recovery``: a fuse
wired into the simulated NVMM kills the run after an arbitrary number of
persistence-protocol operations (store/pwb/pfence/psync), then ``crash()``
adversarially evicts a random subset of the un-flushed cachelines.  The
fuse window covers the route-epoch install itself, so a crash can land
mid-``EpochRouter.install`` — the CRC'd route record must then parse as
either the old or the new epoch, never garbage, and recovery must still
replay every file to exactly the completed prefix (plus possibly the
in-flight write IN FULL).

Why a flip with no drain barrier is still recovery-safe (and hence what
this test actually proves): the barrier exists for the *drain* path — two
live shards holding overlapping entries would let two drain threads race.
Recovery has no such race: it merges ALL shards' committed groups by the
global commit seq and replays them in that one total order, so even the
barrier-less flip injected here (which deliberately leaves old-epoch
entries live in the old shard while new-epoch writes land elsewhere)
recovers every location in commit order.  K ∈ {1, 2, 4}, both static
routes, multi-entry groups included.
"""
import random

import pytest

from repro.core import Policy, recover
from repro.core.router import EpochRouter
from repro.storage.tiers import DRAM, Tier
from test_sharded_recovery import (FusedNVMM, NFILES, PowerLoss, apply_ops,
                                   fresh_log, gen_subops, split_stripes,
                                   state_matches)


def run_sequence(nvmm, pol, subops, flip_at, flip_key_op, arm=None):
    """Append ``subops`` in order, installing a route override for the file
    of ``subops[flip_key_op]`` just before subop ``flip_at``.  The op
    counter resets (and the fuse arms) AFTER the format, so the fuse window
    covers exactly the append sequence plus the epoch install.  Returns
    (completed, inflight)."""
    log = fresh_log(nvmm, pol)
    router = EpochRouter(nvmm, pol)
    log.router = router
    nvmm.ops = 0
    if arm is not None:
        nvmm.arm(arm)
    completed, inflight = [], None
    try:
        for i, op in enumerate(subops):
            if i == flip_at:
                fdid, off, _ = subops[flip_key_op]
                key = router.key_of(fdid, off)
                if key is not None:
                    cur = router.route(fdid, off)
                    inflight = None            # install writes no file data
                    router.install(key, (cur + 1) % pol.shards)
            inflight = op
            log.append(*op, timeout=10.0)
            completed.append(op)
            inflight = None
    except PowerLoss:
        pass
    return completed, inflight


@pytest.mark.parametrize("route", ["stripe", "fdid"])
@pytest.mark.parametrize("k", [1, 2, 4])
def test_no_partial_group_after_recovery_across_epoch_flip(k, route):
    pol = Policy(entry_size=256, log_entries=64 * k, page_size=256,
                 read_cache_pages=4, batch_min=2, batch_max=8,
                 shards=k, shard_route=route, stripe_pages=2,
                 shard_rebalance=True)
    for trial in range(25):
        rng = random.Random(7000 * k + 10 * trial + (route == "fdid"))
        subops = gen_subops(rng, pol)
        flip_at = rng.randrange(0, len(subops) + 1)
        flip_key_op = rng.randrange(0, len(subops))

        # dry run: total protocol ops of the full sequence incl. the install
        dry = FusedNVMM(pol.nvmm_bytes)
        run_sequence(dry, pol, subops, flip_at, flip_key_op)
        total_ops = dry.ops

        # real run: blow the fuse at a uniformly random protocol point
        nvmm = FusedNVMM(pol.nvmm_bytes, track=True)
        completed, inflight = run_sequence(
            nvmm, pol, subops, flip_at, flip_key_op,
            arm=rng.randrange(0, total_ops + 1))

        nvmm._fuse = None
        nvmm.crash(choose_evicted=lambda lines: [l for l in lines
                                                 if rng.random() < 0.5])
        tier = Tier(DRAM)
        stats = recover(nvmm, pol, tier.open)
        assert stats.crc_failures == 0
        assert stats.groups_dropped == 0

        exp = apply_ops(completed)
        exp_in = apply_ops(completed + [inflight]) if inflight else None
        for fdid in range(NFILES):
            got = tier.open(f"/f{fdid}").snapshot() \
                if tier.exists(f"/f{fdid}") else b""
            ok = state_matches(got, bytes(exp.get(fdid, b"")))
            if not ok and exp_in is not None and inflight[0] == fdid:
                # the in-flight group's commit line reached media: the write
                # must then appear in full, never torn
                ok = state_matches(got, bytes(exp_in.get(fdid, b"")))
            assert ok, (f"k={k} route={route} trial={trial} file=/f{fdid}: "
                        f"recovered bytes are neither the completed prefix "
                        f"nor prefix+inflight (torn or reordered group), "
                        f"route_epoch={stats.route_epoch}")


@pytest.mark.parametrize("k", [2, 4])
def test_crash_mid_install_leaves_record_old_or_new(k):
    """Fuse inside EpochRouter.install: after the crash the persisted route
    record must parse as epoch N or N+1, never as a torn record that maps
    keys to garbage shards."""
    from repro.core.router import load_route_record
    pol = Policy(entry_size=256, log_entries=64 * k, page_size=256,
                 read_cache_pages=4, batch_min=2, batch_max=8,
                 shards=k, shard_route="fdid", shard_rebalance=True)
    # an install costs a fixed number of protocol ops; probe every fuse point
    probe = FusedNVMM(pol.nvmm_bytes)
    fresh_log(probe, pol)
    router = EpochRouter(probe, pol)
    probe.ops = 0
    router.install(0, 1)
    install_ops = probe.ops
    assert install_ops > 0
    for fuse in range(install_ops + 1):
        nvmm = FusedNVMM(pol.nvmm_bytes, track=True)
        log = fresh_log(nvmm, pol)
        r = EpochRouter(nvmm, pol)
        log.router = r
        r.install(0, 1)                      # epoch 1, durable
        log.append(0, 0, b"x" * 100, timeout=10.0)
        nvmm.arm(fuse)
        try:
            r.install(0, 2 % k if 2 % k != r.static_route(0, 0) else 1)
        except PowerLoss:
            pass
        nvmm._fuse = None
        rng = random.Random(fuse)
        nvmm.crash(choose_evicted=lambda lines: [l for l in lines
                                                 if rng.random() < 0.5])
        epoch, table, _shifts = load_route_record(nvmm, pol)
        assert epoch in (0, 1, 2)
        for key, sid in table.items():
            assert 0 <= sid < k
        # and the data entry still recovers regardless of the record state
        tier = Tier(DRAM)
        recover(nvmm, pol, tier.open)
        assert tier.open("/f0").snapshot()[:100] == b"x" * 100
