"""POSIX-facade behaviour of NVCache (paper §II-A, §III, Table III)."""
import os

import pytest

from repro.core import NVCache, O_CREAT, O_RDONLY, O_RDWR, TEST_SMALL, Policy
from repro.storage.tiers import DRAM, Tier


def make_nv(policy: Policy = TEST_SMALL):
    tier = Tier(DRAM)
    return NVCache(policy, tier), tier


def test_write_read_roundtrip():
    nv, _ = make_nv()
    fd = nv.open("/f", O_RDWR | O_CREAT)
    assert nv.write(fd, b"hello world") == 11
    nv.lseek(fd, 0)
    assert nv.read(fd, 11) == b"hello world"
    nv.close(fd)
    nv.shutdown()


def test_read_your_own_write_before_drain():
    """Durable linearizability + read-after-write: the kernel page cache is
    stale while the entry is in the log; the read must still be fresh."""
    nv, tier = make_nv()
    fd = nv.open("/f")
    nv.pwrite(fd, b"A" * 1000, 0)
    # backend may not have the bytes yet; NVCache read must
    assert nv.pread(fd, 1000, 0) == b"A" * 1000
    nv.close(fd)
    assert tier.open("/f").snapshot()[:1000] == b"A" * 1000  # drained on close
    nv.shutdown()


def test_overwrite_and_partial_reads():
    nv, _ = make_nv()
    fd = nv.open("/f")
    nv.pwrite(fd, bytes(range(200)) * 10, 0)       # 2000 bytes
    nv.pwrite(fd, b"\xff" * 100, 500)
    got = nv.pread(fd, 2000, 0)
    exp = bytearray((bytes(range(200)) * 10))
    exp[500:600] = b"\xff" * 100
    assert got == bytes(exp)
    nv.shutdown()


def test_cursor_and_lseek_semantics():
    nv, _ = make_nv()
    fd = nv.open("/f")
    nv.write(fd, b"0123456789")
    assert nv.lseek(fd, 0, os.SEEK_CUR) == 10
    nv.lseek(fd, 2, os.SEEK_SET)
    assert nv.read(fd, 3) == b"234"
    assert nv.lseek(fd, -1, os.SEEK_END) == 9
    assert nv.read(fd, 5) == b"9"
    nv.shutdown()


def test_size_served_from_user_space():
    """stat/size must reflect in-flight writes (paper §II-C)."""
    nv, tier = make_nv()
    fd = nv.open("/f")
    nv.pwrite(fd, b"x" * 5000, 0)     # larger than the backend has seen
    assert nv.stat_size(fd) == 5000
    assert nv.stat_size("/f") == 5000
    nv.shutdown()


def test_fsync_is_noop_and_cheap():
    nv, _ = make_nv()
    fd = nv.open("/f")
    nv.write(fd, b"abc")
    nv.fsync(fd)      # must not raise, must not be needed for durability
    nv.shutdown()


def test_append_mode():
    nv, _ = make_nv()
    from repro.core import O_APPEND
    fd = nv.open("/f", O_RDWR | O_CREAT | O_APPEND)
    nv.write(fd, b"aaa")
    nv.write(fd, b"bbb")
    assert nv.pread(fd, 6, 0) == b"aaabbb"
    nv.shutdown()


def test_two_descriptors_independent_cursors():
    nv, _ = make_nv()
    fd1 = nv.open("/f")
    fd2 = nv.open("/f")
    nv.write(fd1, b"xyz")
    assert nv.read(fd2, 3) == b"xyz"     # fd2 cursor starts at 0
    nv.close(fd1)
    nv.close(fd2)
    nv.shutdown()


def test_read_only_bypass():
    nv, tier = make_nv()
    tier.open("/ro").pwrite(b"prefilled", 0)
    fd = nv.open("/ro", O_RDONLY)
    assert nv.read(fd, 9) == b"prefilled"
    assert nv._open and nv._files["/ro"].radix is None   # bypassed
    nv.close(fd)
    nv.shutdown()


def test_large_write_group_commit():
    """A write spanning many fixed-size entries commits atomically."""
    nv, _ = make_nv()
    fd = nv.open("/f")
    blob = os.urandom(TEST_SMALL.entry_data * 5 + 37)
    nv.pwrite(fd, blob, 13)
    assert nv.pread(fd, len(blob), 13) == blob
    nv.shutdown()


def test_write_larger_than_log_splits():
    nv, _ = make_nv()
    fd = nv.open("/f")
    blob = os.urandom(TEST_SMALL.entry_data * (TEST_SMALL.log_entries + 10))
    nv.pwrite(fd, blob, 0)
    assert nv.pread(fd, len(blob), 0) == blob
    nv.shutdown()


def test_flush_drains_everything():
    nv, tier = make_nv()
    fd = nv.open("/f")
    nv.pwrite(fd, b"z" * 3000, 100)
    nv.flush()
    assert nv.log.used_entries == 0
    assert tier.open("/f").snapshot()[100:3100] == b"z" * 3000
    nv.shutdown()


def test_stats_shape():
    nv, _ = make_nv()
    fd = nv.open("/f")
    nv.write(fd, b"q")
    s = nv.stats()
    assert {"log_used", "dirty_misses", "cleanup_batches"} <= set(s)
    nv.shutdown()


def test_multi_application_instances():
    """Paper §III Multi-application: two NVCache instances on separate
    NVMM regions (DAX files) coexist independently."""
    nv1, t1 = make_nv()
    nv2, t2 = make_nv()
    fd1 = nv1.open("/a")
    fd2 = nv2.open("/a")            # same path, different namespaces
    nv1.pwrite(fd1, b"one", 0)
    nv2.pwrite(fd2, b"two", 0)
    assert nv1.pread(fd1, 3, 0) == b"one"
    assert nv2.pread(fd2, 3, 0) == b"two"
    nv1.flush(); nv2.flush()
    assert t1.open("/a").snapshot()[:3] == b"one"
    assert t2.open("/a").snapshot()[:3] == b"two"
    nv1.shutdown(); nv2.shutdown()
