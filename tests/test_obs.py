"""Observability plane (PR 10): metrics registry, span profiler, flight
recorder.

Covers the four contracts the plane makes:

* histogram math — log2-ns bucket placement, percentile interpolation
  clamped to observed extremes, and zero-count safety (an empty window
  reads 0.0 everywhere, never NaN and never a count-less average);
* concurrency — per-thread cells merge to EXACT totals under an
  8-writer hammer with concurrent readers (runs racecheck-instrumented
  here, and pmcheck/lockcheck-shadowed under ``--sanitize``);
* flight ring — wraparound keeps exactly the last lap ordered by eseq,
  torn tail records are CRC-dropped rather than mis-decoded, and a fuse
  sweep over a crashing workload always recovers a seq-consistent
  forensic timeline;
* level gating — ``obs_level=0`` keeps the pwrite hot path free of any
  allocation inside ``repro.obs`` (the "a few ns per op" promise).
"""
import dataclasses
import os
import threading
import time
import tracemalloc

import pytest

from repro.analysis import racecheck
from repro.core import NVCache, Policy, recover
from repro.core.log import LogFullTimeout, NVLog
from repro.core.nvmm import NVMM
from repro.obs import metrics
from repro.obs.flight import (EV_COMMIT, EV_META_OP, EV_NAMES,
                              EV_ROUTE_EPOCH, FLIGHT_REC, FlightRecorder,
                              decode_ring)
from repro.obs.metrics import Counter, Histogram, Registry, check_name
from repro.storage.tiers import DRAM, Tier
from test_namespace import ThreadFusedNVMM, clone_tier
from test_sharded_recovery import PowerLoss

POL = Policy(entry_size=256, log_entries=128, page_size=256,
             read_cache_pages=8, batch_min=4, batch_max=16)
POL_NODRAIN = dataclasses.replace(POL, batch_min=10 ** 6, batch_max=10 ** 6)


# --------------------------------------------------------- histogram math
def test_histogram_log2_bucket_boundaries():
    """Bucket i holds [2^(i-1), 2^i); bucket 0 holds exactly the value 0."""
    h = Histogram("t.bucket_us")
    for v in (0, 1, 2, 3, 4, 7):
        h.record_ns(v)
    buckets, count, total, vmin, vmax = h._merged()
    assert (count, total, vmin, vmax) == (6, 17, 0, 7)
    assert buckets[0] == 1                   # the value 0
    assert buckets[1] == 1                   # [1, 2)
    assert buckets[2] == 2                   # [2, 4)
    assert buckets[3] == 2                   # [4, 8)
    assert sum(buckets) == 6


def test_percentiles_interpolate_and_clamp_to_extremes():
    h = Histogram("t.lat_us")
    for _ in range(99):
        h.record_ns(1000)
    h.record_ns(1_000_000)
    # p50 interpolates inside 1000's bucket [512, 1024) but can never
    # undercut the observed minimum
    assert 1000 <= h.percentile_ns(0.50) < 1024
    # p999 lands in the outlier's bucket [2^19, 2^20)
    assert 524288 <= h.percentile_ns(0.999) <= 1_000_000
    # q=1.0 clamps to the observed maximum exactly
    assert h.percentile_ns(1.0) == 1_000_000
    # a single-valued distribution is exact at every quantile
    h2 = Histogram("t.flat_us")
    for _ in range(10):
        h2.record_ns(300)
    for q in (0.0, 0.5, 0.95, 0.999, 1.0):
        assert h2.percentile_ns(q) == 300


def test_empty_histogram_reads_zero_not_nan():
    h = Histogram("t.empty_us")
    assert h.count == 0
    assert h.mean_ns() == 0.0
    assert h.percentile_ns(0.5) == 0.0
    snap = h.snapshot()
    assert snap["count"] == 0
    assert snap["mean_us"] == 0.0
    assert snap["p99_us"] == 0.0
    assert snap["sum_us"] == 0.0


def test_snapshot_units_follow_name_suffix():
    h_us = Histogram("t.a_us")
    h_us.record_ns(2000)
    s = h_us.snapshot()
    assert s["sum_us"] == pytest.approx(2.0)
    assert set(s) == {"count", "sum_us", "mean_us", "min_us", "max_us",
                      "p50_us", "p95_us", "p99_us", "p999_us"}
    h_s = Histogram("t.b_s")
    h_s.record_ns(2_000_000_000)
    assert h_s.snapshot()["sum_s"] == pytest.approx(2.0)


def test_merged_snapshot_pools_shard_histograms():
    a, b = Histogram("log.alloc_wait_us"), Histogram("log.alloc_wait_us")
    a.record_ns(1000)
    b.record_ns(3000)
    b.record_ns(500)
    pooled = Histogram.merged_snapshot("log.alloc_wait_us", [a, b])
    assert pooled["count"] == 3
    assert pooled["sum_us"] == pytest.approx(4.5)
    assert pooled["min_us"] == pytest.approx(0.5)
    assert pooled["max_us"] == pytest.approx(3.0)


# ------------------------------------------------------- naming + registry
def test_name_grammar_enforced():
    for bad in ("pwbs", "nvmm.pwbs", "Nvmm.pwb_total", "nvmm.pwb-total",
                "a.b_furlongs", "nvmm..pwb_total", "nvmm.pwb_total_"):
        with pytest.raises(ValueError):
            check_name(bad)
    for good in ("nvmm.pwb_total", "log.alloc_wait_us", "route.skew_ratio",
                 "page.frame_used_count", "nvmm.stored_bytes"):
        assert check_name(good) == good


def test_registry_rejects_duplicates_and_fans_out_groups():
    reg = Registry()
    reg.counter("x.a_total")
    with pytest.raises(ValueError):
        reg.counter("x.a_total")
    reg.bind_group({"y.hit_total": "hits", "y.miss_total": "misses"},
                   lambda: {"hits": 3})
    with pytest.raises(ValueError):
        reg.gauge("y.hit_total")             # group names are reserved too
    snap = reg.snapshot()
    assert snap["y.hit_total"] == 3
    assert snap["y.miss_total"] == 0         # missing dict key reads as 0
    assert "y.hit_total" in reg.names()


# ------------------------------------------------------ shard-merge hammer
def test_shard_merge_exact_under_8_writer_hammer():
    """8 threads hammer one Counter and one Histogram while the main
    thread snapshots concurrently: totals must come out EXACT (per-thread
    cells lose no increment) and racecheck must stay silent on the
    ``_cells`` list discipline."""
    racecheck.instrument(metrics._Sharded)
    racecheck.instrument(metrics.Registry)
    try:
        with racecheck.arm() as rc:
            reg = Registry()
            c = reg.counter("hammer.op_total")
            h = reg.histogram("hammer.op_us")
            n_threads, incs, recs = 8, 20000, 500
            start = threading.Barrier(n_threads)

            def work(tid):
                start.wait()
                for _ in range(incs):
                    c.inc()
                for _ in range(recs):
                    h.record_ns(1000 + tid)

            ts = [threading.Thread(target=work, args=(t,))
                  for t in range(n_threads)]
            for t in ts:
                t.start()
            # concurrent readers: merge while the writers are mid-flight
            while any(t.is_alive() for t in ts):
                assert c.value <= n_threads * incs
                assert h.snapshot()["count"] <= n_threads * recs
            for t in ts:
                t.join()
            assert c.value == n_threads * incs
            assert h.count == n_threads * recs
            assert h.sum_ns == sum(recs * (1000 + t)
                                   for t in range(n_threads))
            assert reg.snapshot()["hammer.op_total"] == n_threads * incs
        assert [v.code for v in rc.violations] == [], \
            [str(v) for v in rc.violations]
    finally:
        racecheck.deinstrument(metrics.Registry)
        racecheck.deinstrument(metrics._Sharded)


# ------------------------------------------------------------- flight ring
def test_flight_ring_wraparound_keeps_last_lap():
    pol = dataclasses.replace(POL, flight_records=8)
    nvmm = NVMM(pol.nvmm_bytes)
    fr = FlightRecorder(nvmm, pol)
    for i in range(20):
        fr.record(EV_COMMIT, i, i * 10)
    events, dropped = decode_ring(nvmm, pol)
    assert dropped == 0
    assert [e.eseq for e in events] == list(range(13, 21))
    assert [e.a for e in events] == list(range(12, 20))
    # adopting the ring without a reformat continues the eseq stream
    fr2 = FlightRecorder(nvmm, pol)
    fr2.record(EV_COMMIT, 99)
    events, _ = decode_ring(nvmm, pol)
    assert events[-1].eseq == 21 and events[-1].a == 99


def test_torn_tail_record_is_dropped_not_misdecoded():
    pol = dataclasses.replace(POL, flight_records=8)
    nvmm = NVMM(pol.nvmm_bytes)
    fr = FlightRecorder(nvmm, pol)
    for i in range(5):
        fr.record(EV_COMMIT, i)
    # tear the newest record: flip a payload byte, leave the CRC stale
    off = pol.flight_base + 4 * FLIGHT_REC
    raw = bytearray(bytes(nvmm.load(off, FLIGHT_REC)))
    raw[40] ^= 0xFF
    nvmm.store(off, bytes(raw))
    events, dropped = decode_ring(nvmm, pol)
    assert dropped == 1
    assert [e.eseq for e in events] == [1, 2, 3, 4]
    # never-written slots (5..7) are skipped silently, not counted torn
    assert all(e.type == EV_COMMIT for e in events)


def test_flight_payloads_clamp_none_and_negative_sentinels():
    """Width migrations pass ``new_sid=None`` / negative sentinels as
    payloads; record() must clamp them into u64 instead of raising
    struct.error mid-commit."""
    pol = dataclasses.replace(POL, flight_records=8)
    nvmm = NVMM(pol.nvmm_bytes)
    fr = FlightRecorder(nvmm, pol)
    fr.record(EV_ROUTE_EPOCH, 7, None, -1)
    events, dropped = decode_ring(nvmm, pol)
    assert dropped == 0 and len(events) == 1
    assert events[0].a == 7
    assert events[0].b == 0                  # None -> 0
    assert events[0].c == (1 << 64) - 1      # -1 -> two's-complement u64


@pytest.mark.parametrize("k", [1, 2, 4])
def test_crash_sweep_recovers_seq_consistent_flight_timeline(k):
    """Fuse the NVMM at protocol points across a write+rename+unlink
    workload: whatever survives the crash, recovery's decoded timeline
    must be strictly eseq-increasing with only known event types — and
    once the engine has fenced at least once, non-empty."""
    pol = Policy(entry_size=256, log_entries=128 * k, page_size=256,
                 read_cache_pages=8, batch_min=4, batch_max=16,
                 shards=k, shard_route="fdid", obs_level=1,
                 flight_records=64)

    def script(nv):
        fd = nv.open("/w")
        for i in range(12):
            nv.pwrite(fd, bytes([i + 1]) * 64, i * 64)
        nv.close(fd)
        nv.rename("/w", "/x")
        nv.unlink("/x")

    dry = ThreadFusedNVMM(pol.nvmm_bytes)
    nv = NVCache(pol, Tier(DRAM), nvmm=dry, recover=False)
    dry.ops = 0
    script(nv)
    total = dry.ops
    nv.cleanup.power_loss()

    checked = nonempty = 0
    seen_types = set()
    for fuse in range(1, total + 1, 7):
        nvmm = ThreadFusedNVMM(pol.nvmm_bytes, track=True)
        tier = Tier(DRAM)
        nv = NVCache(pol, tier, nvmm=nvmm, recover=False, track_crashes=True)
        nvmm.arm(fuse)
        try:
            script(nv)
        except PowerLoss:
            pass
        nvmm._fuse = None
        nv._crashed = True
        nv.cleanup.power_loss()
        nvmm.crash()
        stats = recover(nvmm, pol, clone_tier(tier))
        seqs = [e.eseq for e in stats.flight_events]
        assert all(b > a for a, b in zip(seqs, seqs[1:])), \
            f"k={k} fuse={fuse}: non-monotonic flight eseq {seqs}"
        for e in stats.flight_events:
            assert e.type in EV_NAMES, \
                f"k={k} fuse={fuse}: unknown event type {e.type}"
            seen_types.add(e.type)
        checked += 1
        if seqs:
            nonempty += 1
    assert checked > 5
    # flight lines piggyback on engine fences, so only crashes before the
    # FIRST fence may legally lose the whole ring — the bulk of the sweep
    # must come back with forensics
    assert nonempty >= checked // 2, (checked, nonempty)
    assert EV_META_OP in seen_types          # the create/rename/unlink trail
    assert EV_COMMIT in seen_types           # obs_level=1 commit records


# ------------------------------------------------------------ level gating
def test_obs_level0_pwrite_allocates_nothing_in_obs():
    """The off switch must actually be off: with ``obs_level=0`` the
    steady-state pwrite path may not allocate a single object inside
    ``repro.obs`` (no timer boxing, no cell creation, no record packing)."""
    nv = NVCache(POL_NODRAIN, Tier(DRAM))
    fd = nv.open("/quiet")
    nv.pwrite(fd, b"w" * 64, 0)              # warm every lazy path first
    obs_dir = os.path.dirname(metrics.__file__)
    tracemalloc.start()
    try:
        s1 = tracemalloc.take_snapshot()
        for i in range(32):
            nv.pwrite(fd, b"w" * 64, (i + 1) * 64)
        s2 = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    growth = [d for d in s2.compare_to(s1, "lineno")
              if d.size_diff > 0
              and d.traceback[0].filename.startswith(obs_dir)]
    assert not growth, [str(d) for d in growth]
    nv.cleanup.power_loss()


def test_profile_report_has_stage_rows_at_level2():
    pol = dataclasses.replace(POL, obs_level=2)
    nv = NVCache(pol, Tier(DRAM))
    fd = nv.open("/p")
    for i in range(20):
        nv.pwrite(fd, b"q" * 64, i * 64)
    nv.flush()
    m = nv.metrics()
    assert m["write.op_us"]["count"] == 20
    # commit spans cover every group append incl. the open()'s meta journal
    assert m["write.commit_us"]["count"] >= 20
    rep = nv.profile_report()
    assert "write.op_us" in rep and "drain." in rep
    nv.shutdown()


def test_profile_report_states_level_zero():
    nv = NVCache(POL, Tier(DRAM))
    fd = nv.open("/z")
    nv.pwrite(fd, b"z" * 64, 0)
    nv.flush()
    assert "no samples" in nv.profile_report()
    nv.shutdown()


# ----------------------------------------------------- alloc-wait contract
def test_alloc_wait_zero_count_reads_zero_not_nan():
    """The failing-before edge: a window with zero waits used to report a
    bare seconds sum that readers divided by an assumed count — now the
    count rides along and every derived stat reads 0, not NaN."""
    nv = NVCache(POL, Tier(DRAM))
    s = nv.stats()
    assert s["alloc_waits"] == 0
    assert s["alloc_wait_s"] == 0.0
    assert s["alloc_wait_mean_us"] == 0.0
    assert s["alloc_wait_p95_us"] == 0.0
    samp = nv.log.shards[0].load_sample()
    assert samp["alloc_waits"] == 0
    assert samp["alloc_wait_mean_us"] == 0.0
    nv.shutdown()


def test_alloc_wait_episode_carries_count_and_mean():
    pol = Policy(entry_size=256, log_entries=4, page_size=256,
                 read_cache_pages=4)
    nvmm = NVMM(pol.nvmm_bytes)
    log = NVLog(nvmm, pol, format=True)
    sh = log.shards[0]
    sh.alloc(3)
    sh.alloc(1)                              # shard now full

    def free_soon():
        time.sleep(0.02)
        with sh._space:                      # emulate a drain recycling slots
            sh.volatile_tail = 2
            sh._space.notify_all()

    t = threading.Thread(target=free_soon)
    t.start()
    sh.alloc(2, timeout=5.0)                 # one real log-full episode
    t.join()
    assert sh.alloc_wait.count == 1
    snap = sh.alloc_wait.snapshot()
    assert snap["count"] == 1
    assert snap["sum_us"] > 0
    assert snap["mean_us"] == pytest.approx(snap["sum_us"])
    assert sh.load_sample()["alloc_waits"] == 1
    assert sh.stats_alloc_wait_s == pytest.approx(snap["sum_us"] * 1e-6)


def test_zero_timeout_full_shard_records_no_phantom_wait():
    pol = Policy(entry_size=256, log_entries=4, page_size=256,
                 read_cache_pages=4)
    nvmm = NVMM(pol.nvmm_bytes)
    log = NVLog(nvmm, pol, format=True)
    sh = log.shards[0]
    sh.alloc(3)
    sh.alloc(1)
    with pytest.raises(LogFullTimeout):
        sh.alloc(1, timeout=0.0)
    assert sh.alloc_wait.count == 0          # never waited -> no episode


# --------------------------------------------------------- stats coherence
def test_stats_keeps_legacy_keys_and_matches_registry():
    nv = NVCache(POL, Tier(DRAM))
    fd = nv.open("/s")
    nv.pwrite(fd, b"z" * 300, 0)
    nv.flush()
    s = nv.stats()
    for key in ("shards", "log_used", "lru_hits", "cleanup_batches",
                "nvmm_psyncs", "nvmm_pwbs", "nvmm_fences", "alloc_wait_s",
                "route_epoch", "meta_ops", "mode_migrations",
                "paged_frames_used"):
        assert key in s, key
    m = nv.metrics()
    assert s["nvmm_psyncs"] == m["nvmm.psync_total"]
    assert s["cleanup_batches"] == m["drain.batch_total"]
    assert s["alloc_waits"] == m["log.alloc_wait_us"]["count"]
    assert m["flight.event_total"] > 0       # at least the attach record
    nv.shutdown()
