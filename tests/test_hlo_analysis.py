"""The trip-count-aware HLO analyzer against known-FLOPs programs."""
import sys
import os

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
import hlo_analysis  # noqa: E402


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_single_matmul_flops():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    res = hlo_analysis.analyze(_hlo(lambda x, y: x @ y, a, b))
    assert res["flops"] == 2 * 128 * 256 * 64


def test_scan_multiplies_by_trip_count():
    w = jnp.zeros((16, 64, 64), jnp.float32)   # 16 layers
    x = jnp.zeros((8, 64), jnp.float32)

    def f(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    res = hlo_analysis.analyze(_hlo(f, x, w))
    expected = 16 * 2 * 8 * 64 * 64
    assert abs(res["flops"] - expected) / expected < 0.01, res["flops"]


def test_batched_dot_contract_dims():
    a = jnp.zeros((4, 32, 16), jnp.float32)
    b = jnp.zeros((4, 16, 8), jnp.float32)
    res = hlo_analysis.analyze(_hlo(lambda x, y: jnp.einsum("bij,bjk->bik", x, y), a, b))
    assert res["flops"] == 2 * 4 * 32 * 16 * 8


def test_bytes_positive_and_collectives_absent_on_cpu_single():
    a = jnp.zeros((128, 128), jnp.float32)
    res = hlo_analysis.analyze(_hlo(lambda x: (x + 1.0).sum(), a))
    assert res["hbm_bytes"] > 128 * 128 * 4
    assert res["wire_bytes"] == 0
