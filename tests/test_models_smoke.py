"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + prefill + decode on CPU; shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import all_archs, get_config, get_smoke
from repro.configs.shapes import SHAPES, Shape, applicable, concrete_inputs
from repro.models.registry import build

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", all_archs())
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    model = build(cfg)
    params = model.init(KEY)
    batch = concrete_inputs(cfg, Shape("train_4k", "train", 64, 2))
    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gn > 0 and jnp.isfinite(gn), f"{arch}: bad grads"


@pytest.mark.parametrize("arch", all_archs())
def test_smoke_prefill_then_decode(arch):
    cfg = get_smoke(arch)
    model = build(cfg)
    params = model.init(KEY)
    batch = concrete_inputs(cfg, Shape("prefill_32k", "prefill", 32, 2))
    logits, cache = model.prefill(params, batch, 48)
    assert logits.shape == (2, 1, cfg.vocab)
    for _ in range(3):
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        logits, cache = model.decode_step(params, cache, tok)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite decode"
    prompt = 8 if cfg.family == "encdec" else 32   # whisper dec prompt is 8
    assert int(cache["pos"]) == prompt + 3


@pytest.mark.parametrize("arch", ["llama3.2-1b", "hymba-1.5b", "mamba2-780m",
                                  "minicpm3-4b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the train-path logits."""
    cfg = get_smoke(arch)
    model = build(cfg)
    params = model.init(KEY)
    S = 16
    toks = (jax.random.randint(KEY, (1, S), 1, cfg.vocab - 1)).astype(jnp.int32)
    full_logits, _ = model.forward(params, {"tokens": toks})
    _, cache = model.prefill(params, {"tokens": toks[:, :1]}, S + 2)
    outs = []
    for t in range(1, S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)                      # logits at positions 1..S-1
    ref = full_logits[:, 1:S]
    err = jnp.max(jnp.abs(dec - ref))
    assert float(err) < 2e-1, f"{arch}: decode/forward divergence {float(err)}"


@pytest.mark.parametrize("arch", all_archs())
def test_full_config_consistency(arch):
    """The FULL configs match the assignment table (never instantiated)."""
    cfg = get_config(arch)
    table = {
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "whisper-small": (24, 768, 12, 12, 3072, 51865),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 0, 151936),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == table
    if arch == "mamba2-780m":
        assert cfg.ssm_state == 128
    if arch == "hymba-1.5b":
        assert cfg.ssm_state == 16
    if arch.startswith("qwen3") or arch.startswith("arctic"):
        assert cfg.n_experts == 128
        assert cfg.top_k == (8 if arch.startswith("qwen3") else 2)


def test_param_counts_plausible():
    """Analytic param counts in the right ballpark for known models."""
    assert 1.1e9 < get_config("llama3.2-1b").param_count() < 1.4e9
    assert 0.7e9 < get_config("mamba2-780m").param_count() < 0.9e9
    assert 380e9 < get_config("arctic-480b").param_count() < 520e9
    a = get_config("qwen3-moe-30b-a3b")
    assert 25e9 < a.param_count() < 36e9
    assert 2e9 < a.active_param_count() < 5e9


def test_long_500k_applicability():
    long = SHAPES["long_500k"]
    runs = [a for a in all_archs() if applicable(get_config(a), long)[0]]
    assert sorted(runs) == ["hymba-1.5b", "mamba2-780m"]
