"""Multi-threading behaviour (paper §II-D): POSIX read/write atomicity,
parallel independent writes, cleanup-thread synchronization."""
import threading

from repro.core import NVCache, Policy
from repro.storage.tiers import DRAM, Tier

POL = Policy(entry_size=4096 + 32, log_entries=256, page_size=4096,
             read_cache_pages=4, batch_min=8, batch_max=64)


def test_parallel_disjoint_writers():
    nv = NVCache(POL, Tier(DRAM))
    fd = nv.open("/f")
    N, SZ = 8, 4096

    def worker(i):
        for rep in range(20):
            nv.pwrite(fd, bytes([i + 1]) * SZ, i * SZ)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(N)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    for i in range(N):
        assert nv.pread(fd, SZ, i * SZ) == bytes([i + 1]) * SZ
    nv.shutdown()


def test_same_page_write_atomicity():
    """Two threads hammer the same page with full-page patterns; a reader
    must never observe a torn page (per-page atomic locks, §II-D)."""
    nv = NVCache(POL, Tier(DRAM))
    fd = nv.open("/f")
    SZ = 4096
    nv.pwrite(fd, b"\x00" * SZ, 0)
    stop = threading.Event()
    torn = []

    def writer(pat):
        while not stop.is_set():
            nv.pwrite(fd, bytes([pat]) * SZ, 0)

    def reader():
        for _ in range(300):
            page = nv.pread(fd, SZ, 0)
            if len(set(page)) > 1:
                torn.append(bytes(sorted(set(page))))
                stop.set()
                return
        stop.set()

    ws = [threading.Thread(target=writer, args=(p,)) for p in (0xAA, 0xBB)]
    r = threading.Thread(target=reader)
    for t in ws + [r]:
        t.start()
    for t in ws + [r]:
        t.join(timeout=120)
    assert not torn, f"torn read observed: {torn[:1]}"
    nv.shutdown()


def test_log_backpressure_under_saturation():
    """Writers outrun the cleanup thread; the log fills and writers block
    until entries are recycled — nothing deadlocks, nothing is lost."""
    pol = Policy(entry_size=256, log_entries=16, page_size=256,
                 read_cache_pages=4, batch_min=2, batch_max=8)
    nv = NVCache(pol, Tier(DRAM))
    fd = nv.open("/f")
    data = b"Q" * (pol.entry_data * 3)   # 3-entry groups through a 16-entry log
    for i in range(50):
        nv.pwrite(fd, data, (i % 7) * 100)
    nv.flush()
    assert nv.log.used_entries == 0
    nv.shutdown()


def test_dirty_miss_vs_cleanup_race():
    """Reader takes a dirty miss while the cleanup thread is draining the
    same page: the cleanup lock must serialize them and the read must see
    the freshest committed data."""
    nv = NVCache(POL, Tier(DRAM))
    fd = nv.open("/f")
    SZ = 4096
    errors = []

    def writer():
        for i in range(100):
            nv.pwrite(fd, bytes([i % 251 + 1]) * SZ, 0)

    def reader():
        last = 0
        for _ in range(200):
            page = nv.pread(fd, SZ, 0)
            if not page:
                continue
            vals = set(page)
            if len(vals) > 1:
                errors.append("torn")
                return

    w = threading.Thread(target=writer)
    r = threading.Thread(target=reader)
    w.start(); r.start()
    w.join(); r.join()
    assert not errors
    nv.shutdown()


def test_eviction_pressure_with_tiny_read_cache():
    """read_cache_pages=4 with a 32-page working set: constant eviction and
    dirty misses must still return correct bytes."""
    pol = Policy(entry_size=1024, log_entries=128, page_size=1024,
                 read_cache_pages=4, batch_min=4, batch_max=32)
    nv = NVCache(pol, Tier(DRAM))
    fd = nv.open("/f")
    for p in range(32):
        nv.pwrite(fd, bytes([p + 1]) * 1024, p * 1024)
    for p in range(32):
        assert nv.pread(fd, 1024, p * 1024) == bytes([p + 1]) * 1024, f"page {p}"
    s = nv.stats()
    assert s["lru_evictions"] > 0
    nv.shutdown()
