"""Checkpoint manager: round-trip, crash-mid-save atomicity (the paper's
group-commit at application granularity), resharded restore, int8 mode."""
import jax
import numpy as np
import pytest

from repro.checkpoint import codec
from repro.checkpoint.manager import CheckpointManager
from repro.core import NVCache, Policy
from repro.runtime.elastic import reshard_restore
from repro.storage.fsapi import NVCacheFS, TierFS
from repro.storage.tiers import DRAM, Tier

POL = Policy(entry_size=4096, log_entries=4096, page_size=4096,
             read_cache_pages=64, batch_min=8, batch_max=256, verify_crc=False)


def _tree(seed=0, n=4000):
    rng = np.random.default_rng(seed)
    return {"params": {"w": rng.standard_normal((8, n)).astype(np.float32),
                       "b": rng.standard_normal((n,)).astype(np.float32)},
            "opt": {"m": rng.standard_normal((8, n)).astype(np.float32),
                    "step": np.int32(3)}}


def _eq(a, b, atol=0.0):
    flat_a, flat_b = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.allclose(x, y, atol=atol) for x, y in zip(flat_a, flat_b))


def test_roundtrip_tier():
    fs = TierFS(Tier(DRAM))
    mgr = CheckpointManager(fs)
    t = _tree()
    mgr.save(1, t)
    got = mgr.restore(t)
    assert _eq(t, got)


def test_roundtrip_nvcache_and_latest():
    nv = NVCache(POL, Tier(DRAM))
    mgr = CheckpointManager(NVCacheFS(nv))
    t1, t2 = _tree(1), _tree(2)
    mgr.save(1, t1)
    mgr.save(2, t2)
    assert mgr.latest_step() == 2
    assert _eq(t2, mgr.restore(t2))
    assert _eq(t1, mgr.restore(t1, step=1))
    mgr.close()
    nv.shutdown()


def test_crash_mid_save_restores_previous_step():
    """Kill power while step-2 data is written but its manifest is not:
    recovery must restore step 1 exactly, never a torn step 2."""
    tier = Tier(DRAM)
    nv = NVCache(POL, tier, track_crashes=True)
    fs = NVCacheFS(nv)
    mgr = CheckpointManager(fs)
    t1, t2 = _tree(1), _tree(2)
    mgr.save(1, t1)
    # write step-2 data WITHOUT committing the manifest (crash point)
    w = codec.Writer(fs, "/ckpt/step_00000002.ckpt", close_on_finish=False)
    for k, leaf in [("params/w", t2["params"]["w"])]:
        w.put_leaf(k, leaf)
    nvmm = nv.crash()
    # recovery into the surviving slow tier
    from repro.core import recover
    recover(nvmm, POL, tier.open)
    nv2 = NVCache(POL, tier)
    mgr2 = CheckpointManager(NVCacheFS(nv2))
    assert mgr2.latest_step() == 1
    assert _eq(t1, mgr2.restore(t1))
    nv2.shutdown()


def test_resharded_restore():
    """Save once, restore per-shard slices for a new shard count; the
    concatenation equals the original (elastic re-mesh path)."""
    fs = TierFS(Tier(DRAM))
    mgr = CheckpointManager(fs)
    t = _tree()
    mgr.save(5, t)
    parts = [reshard_restore(mgr, t, shard_idx=i, n_shards=4) for i in range(4)]
    w = np.concatenate([p["params"]["w"] for p in parts], axis=0)
    assert np.allclose(w, t["params"]["w"])
    # leaves not divisible by shards are replicated
    assert all(np.allclose(p["params"]["b"], t["params"]["b"]) for p in parts)


def test_int8_checkpoint_error_bounded():
    fs = TierFS(Tier(DRAM))
    mgr = CheckpointManager(fs, encoding=codec.ENC_INT8)
    t = _tree()
    info = mgr.save(1, t)
    got = mgr.restore(t)
    w, gw = t["params"]["w"], got["params"]["w"]
    denom = np.abs(w).max()
    assert np.abs(w - gw).max() <= denom / 127 + 1e-6
    # int (non-float) leaves stay exact
    assert got["opt"]["step"] == 3


requires_zstd = pytest.mark.skipif(
    codec.zstandard is None, reason="optional dependency `zstandard` not installed")


@requires_zstd
def test_zstd_payloads_roundtrip():
    """With zstandard installed, compressed records use it (not the zlib
    fallback) and round-trip exactly."""
    fs = TierFS(Tier(DRAM))
    w = codec.Writer(fs, "/z.ckpt", encoding=codec.ENC_ZSTD)
    arr = np.arange(8192, dtype=np.float32).reshape(64, 128)
    w.put_leaf("a", arr)
    w.finish()
    r = codec.Reader(fs, "/z.ckpt")
    assert all(e[0] == "a" for e in r.index)
    assert np.array_equal(r.read_leaf("a"), arr)


def test_zlib_fallback_roundtrip():
    """Force the zlib path (as on hosts without zstandard): records are
    tagged ENC_ZLIB / zc=1 and decode without zstd."""
    real = codec.zstandard
    codec.zstandard = None
    try:
        fs = TierFS(Tier(DRAM))
        w = codec.Writer(fs, "/zl.ckpt", encoding=codec.ENC_ZSTD)
        arr = np.arange(4096, dtype=np.float32)
        w.put_leaf("a", arr)
        w.finish()
        wq = codec.Writer(fs, "/q.ckpt", encoding=codec.ENC_INT8)
        wq.put_leaf("a", arr)
        wq.finish()
        assert np.array_equal(codec.Reader(fs, "/zl.ckpt").read_leaf("a"), arr)
        got = codec.Reader(fs, "/q.ckpt").read_leaf("a")
        assert np.abs(got - arr).max() <= np.abs(arr).max() / 127 + 1e-6
    finally:
        codec.zstandard = real


def test_gc_keeps_last_k():
    fs = TierFS(Tier(DRAM))
    mgr = CheckpointManager(fs, keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    m = mgr._read_manifest()
    assert m["steps"] == [3, 4]
