"""File-lifecycle bug-cluster regression tests (PR 3 satellites).

Each test here failed before its fix:

* ``open(O_TRUNC)`` truncated only the backend, leaving the file's
  undrained log entries, dirty-page-index refs and loaded page contents
  alive — a later drain resurrected pre-truncate bytes and cached reads
  served stale data (worse after a crash: recovery replayed them).
* ``stat_size(path)`` on an unopened path called ``Tier.open``, which
  *creates* an empty phantom file — stat of a nonexistent file mutated
  the namespace.
* ``write`` with ``O_APPEND`` reserved ``size = off + len(data)`` before
  the log append; a failed append left the size inflated forever, so
  readers got zero-filled bytes that were never written.
* ``close()`` raised on drain timeout *before* decrementing the refcount,
  permanently leaking the ``File``, its fdid slot and its NVMM fd-table
  entry.
"""
import os
import random

import pytest

from repro.core import NVCache, Policy, recover
from repro.core import api as api_mod
from repro.core.log import LogFullTimeout
from repro.storage.tiers import DRAM, Tier

POL = Policy(entry_size=256, log_entries=128, page_size=256,
             read_cache_pages=8, batch_min=4, batch_max=16)
# nothing drains on its own (batch_min clamps to entries_per_shard // 2),
# so undrained state survives until a barrier forces it — the worst case
# for the truncate bug
POL_NODRAIN = Policy(entry_size=256, log_entries=128, page_size=256,
                     read_cache_pages=8, batch_min=10 ** 6, batch_max=10 ** 6)

O_TRUNCW = os.O_RDWR | os.O_CREAT | os.O_TRUNC


# ------------------------------------------------------------------ O_TRUNC
def test_otrunc_does_not_resurrect_undrained_bytes():
    """write -> reopen with O_TRUNC -> drain -> read must yield zeros; the
    old code drained the pre-truncate entries *after* the backend truncate
    and brought the bytes back."""
    tier = Tier(DRAM)
    nv = NVCache(POL_NODRAIN, tier)
    fd = nv.open("/f")
    nv.pwrite(fd, b"\xAA" * 700, 0)          # sits undrained in the log
    assert nv.log.used_entries > 0
    fd2 = nv.open("/f", O_TRUNCW)
    assert nv.stat_size(fd2) == 0
    nv.flush()                               # the drain that used to resurrect
    assert nv.pread(fd2, 700, 0) == b""      # size is 0
    nv.pwrite(fd2, b"b", 650)                # extend: holes must read as zero
    assert nv.pread(fd2, 651, 0) == b"\x00" * 650 + b"b"
    snap = tier.open("/f").snapshot()
    assert not any(snap[:650]), "pre-truncate bytes resurrected in backend"
    nv.shutdown()


def test_otrunc_invalidates_cached_page_contents():
    """A page loaded in the read cache before O_TRUNC must not serve the
    pre-truncate bytes afterwards."""
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    fd = nv.open("/f")
    nv.pwrite(fd, b"\xBB" * 256, 0)
    nv.flush()
    assert nv.pread(fd, 256, 0) == b"\xBB" * 256   # page now loaded
    fd2 = nv.open("/f", O_TRUNCW)
    nv.pwrite(fd2, b"c", 200)                # same page, post-truncate
    assert nv.pread(fd2, 201, 0) == b"\x00" * 200 + b"c"
    nv.shutdown()


def test_otrunc_with_crash_and_recovery_yields_zeros():
    """Crash after the O_TRUNC open: recovery must NOT replay pre-truncate
    entries (they were durably consumed by the truncate's drain)."""
    tier = Tier(DRAM)
    nv = NVCache(POL_NODRAIN, tier, track_crashes=True)
    fd = nv.open("/f")
    nv.pwrite(fd, b"\xAA" * 700, 0)
    fd2 = nv.open("/f", O_TRUNCW)
    nv.pwrite(fd2, b"new", 10)               # post-truncate write, undrained
    nvmm = nv.crash()
    tier2 = Tier(DRAM)
    for path in tier.paths():
        snap = tier.open(path).snapshot()
        if snap:
            tier2.open(path).pwrite(snap, 0)
    recover(nvmm, POL_NODRAIN, tier2.open)
    got = tier2.open("/f").snapshot()
    assert got[10:13] == b"new"
    assert not any(got[:10]) and not any(got[13:]), \
        "recovery resurrected pre-truncate bytes"
    nv2 = NVCache(POL_NODRAIN, tier2)
    fd3 = nv2.open("/f")
    assert nv2.pread(fd3, 700, 0)[:13] == b"\x00" * 10 + b"new"
    nv2.shutdown()


def test_otrunc_readonly_open_does_not_truncate():
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    fd = nv.open("/f")
    nv.pwrite(fd, b"keep", 0)
    fd2 = nv.open("/f", os.O_RDONLY | os.O_TRUNC)   # POSIX: undefined, we keep
    assert nv.pread(fd2, 4, 0) == b"keep"
    nv.shutdown()


# ---------------------------------------------------------------- stat_size
def test_stat_of_nonexistent_path_raises_and_creates_nothing():
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    with pytest.raises(FileNotFoundError):
        nv.stat_size("/never-opened")
    assert not tier.exists("/never-opened"), "stat created a phantom file"
    assert tier.paths() == []
    # an existing-but-unopened backend file still stats fine
    tier.open("/on-disk").pwrite(b"12345", 0)
    assert nv.stat_size("/on-disk") == 5
    # and an open file stats from user space (in-flight writes included)
    fd = nv.open("/f")
    nv.pwrite(fd, b"x" * 999, 0)
    assert nv.stat_size("/f") == 999
    nv.shutdown()


def test_tier_size_of_is_non_creating():
    tier = Tier(DRAM)
    with pytest.raises(FileNotFoundError):
        tier.size_of("/nope")
    assert not tier.exists("/nope")
    tier.open("/yes").pwrite(b"abc", 0)
    assert tier.size_of("/yes") == 3


# ----------------------------------------------------------------- O_APPEND
def test_failed_append_rolls_back_size_reservation(monkeypatch):
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    fd = nv.open("/f", os.O_RDWR | os.O_CREAT | os.O_APPEND)
    nv.write(fd, b"base")
    nv.flush()

    def full(*a, **kw):
        raise LogFullTimeout("shard 0 full")
    monkeypatch.setattr(nv.log, "append", full)
    with pytest.raises(LogFullTimeout):
        nv.write(fd, b"lost-forever")
    monkeypatch.undo()
    # the reservation must be gone: size and reads unchanged...
    assert nv.stat_size(fd) == 4
    assert nv.pread(fd, 100, 0) == b"base"
    # ...and the next append lands at the pre-failure offset, not after a
    # zero-filled hole
    nv.write(fd, b"+tail")
    assert nv.pread(fd, 100, 0) == b"base+tail"
    nv.shutdown()


def test_failed_append_rollback_yields_to_concurrent_reservation(monkeypatch):
    """If another append reserved past ours before we rolled back, the
    rollback must not clobber that later reservation."""
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    fd = nv.open("/f", os.O_RDWR | os.O_CREAT | os.O_APPEND)

    def fail_then_sneak(*a, **kw):
        monkeypatch.undo()
        # a concurrent appender wins the race while our append is failing
        with nv._files["/f"].size_lock:
            nv._files["/f"].size += 7
        raise LogFullTimeout("shard 0 full")
    monkeypatch.setattr(nv.log, "append", fail_then_sneak)
    with pytest.raises(LogFullTimeout):
        nv.write(fd, b"xyz")
    assert nv.stat_size(fd) == 3 + 7, "rollback clobbered a later reservation"
    nv.shutdown()


def test_failed_append_rollback_respects_concurrent_pwrite(monkeypatch):
    """A pwrite that lands inside the failed append's reserved range leaves
    f.size untouched (it doesn't extend the file) — the rollback must not
    shrink the size below those durably committed bytes."""
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    fd = nv.open("/f", os.O_RDWR | os.O_CREAT | os.O_APPEND)
    nv.write(fd, b"base")
    fd2 = nv.open("/f")

    def fail_after_other_write(*a, **kw):
        monkeypatch.undo()                   # no page locks held here yet
        nv.pwrite(fd2, b"ZZZ", 4)            # commits exactly [4, 7)
        raise LogFullTimeout("shard 0 full")
    monkeypatch.setattr(nv, "_pwrite_split", fail_after_other_write)
    with pytest.raises(LogFullTimeout):
        nv.write(fd, b"xyz")                 # reserves [4, 7), then fails
    assert nv.stat_size(fd) == 7, "rollback hid a committed concurrent write"
    assert nv.pread(fd, 10, 0) == b"baseZZZ"
    nv.shutdown()


def test_partially_committed_append_keeps_committed_prefix_visible():
    """A split append (stripe-crossing) that fails midway must roll the
    size back only to the committed prefix: those bytes are durable in the
    log, and a smaller size would resurrect them as bytes-past-EOF after
    crash+recovery."""
    pol = Policy(entry_size=256, log_entries=256, page_size=256,
                 read_cache_pages=8, batch_min=10 ** 6, batch_max=10 ** 6,
                 shards=2, shard_route="stripe", stripe_pages=1)
    tier = Tier(DRAM)
    nv = NVCache(pol, tier, track_crashes=True)
    fd = nv.open("/f", os.O_RDWR | os.O_CREAT | os.O_APPEND)
    nv.write(fd, b"A" * 100)
    calls = [0]
    real_op = nv._pwrite_op

    def flaky(f, data, off):
        calls[0] += 1
        if calls[0] > 1:                     # first op commits, second fails
            raise LogFullTimeout("shard full")
        return real_op(f, data, off)
    nv._pwrite_op = flaky
    with pytest.raises(LogFullTimeout):
        nv.write(fd, b"B" * 300)             # [100,256) commits, [256,...) fails
    nv._pwrite_op = real_op
    assert calls[0] == 2
    # size reflects exactly the committed prefix, not 0 and not 400
    assert nv.stat_size(fd) == 256
    assert nv.pread(fd, 400, 0) == b"A" * 100 + b"B" * 156
    # crash+recovery agrees: nothing beyond the reported size
    nvmm = nv.crash()
    tier2 = Tier(DRAM)
    recover(nvmm, pol, tier2.open)
    got = tier2.open("/f").snapshot()
    assert got[:256] == b"A" * 100 + b"B" * 156
    assert not any(got[256:]), "durable bytes hidden past the rolled-back size"


# -------------------------------------------------------------------- close
def test_close_releases_descriptor_even_when_drain_times_out(monkeypatch):
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    fd = nv.open("/f")
    nv.pwrite(fd, b"d" * 100, 0)
    nv.flush()
    free_before = len(nv._fdid_free)
    monkeypatch.setattr(api_mod.File, "wait_drained",
                        lambda self, timeout=None: False)
    with pytest.raises(TimeoutError):
        nv.close(fd)
    monkeypatch.undo()
    # the barrier failed, but the descriptor must be fully torn down:
    assert fd not in nv._open
    assert "/f" not in nv._files, "File leaked after failed close"
    assert not nv._by_fdid, "fdid table entry leaked"
    assert len(nv._fdid_free) == free_before + 1, "fdid slot leaked"
    # the path is reusable and gets a fresh file table entry
    fd2 = nv.open("/f")
    assert nv.pread(fd2, 100, 0) == b"d" * 100
    nv.close(fd2)
    nv.shutdown()


def test_close_timeout_with_pending_entries_never_orphans_them(monkeypatch):
    """If the drain barrier times out while committed entries are still
    undrained, the fd closes but the File/fdid must stay registered and
    resolvable — retiring the fdid would make the drain drop the entries
    as orphans (or route them into whatever file reuses the fdid)."""
    tier = Tier(DRAM)
    nv = NVCache(POL_NODRAIN, tier)          # drains only on request
    fd = nv.open("/f")
    nv.pwrite(fd, b"A" * 500, 0)
    f = nv._files["/f"]
    fdid = f.fdid
    monkeypatch.setattr(api_mod.File, "wait_drained",
                        lambda self, timeout=None: False)
    with pytest.raises(TimeoutError):
        nv.close(fd)
    monkeypatch.undo()
    assert fd not in nv._open                # the descriptor is closed...
    assert nv._files.get("/f") is f          # ...but the File stays live
    assert nv._by_fdid.get(fdid) is f, "drain can no longer resolve fdid"
    assert fdid not in nv._fdid_free, "fdid freed with entries in flight"
    nv.flush()                               # the entries eventually land...
    assert f.pending.get() == 0
    assert tier.open("/f").snapshot()[:500] == b"A" * 500, "bytes orphaned"
    # ...and the flush sweep retires the drained orphan (no residual leak)
    assert "/f" not in nv._files
    assert fdid in nv._fdid_free
    fd2 = nv.open("/f")                      # the path works again
    assert nv.pread(fd2, 500, 0) == b"A" * 500
    nv.close(fd2)
    nv.shutdown()


def test_orphaned_file_is_adopted_by_reopen_before_any_flush(monkeypatch):
    tier = Tier(DRAM)
    nv = NVCache(POL_NODRAIN, tier)
    fd = nv.open("/f")
    nv.pwrite(fd, b"B" * 200, 0)
    f = nv._files["/f"]
    monkeypatch.setattr(api_mod.File, "wait_drained",
                        lambda self, timeout=None: False)
    with pytest.raises(TimeoutError):
        nv.close(fd)
    monkeypatch.undo()
    fd2 = nv.open("/f")                      # adopts the orphan, refs 0 -> 1
    assert nv._files["/f"] is f
    assert nv.pread(fd2, 200, 0) == b"B" * 200
    nv.close(fd2)                            # normal close retires it
    assert "/f" not in nv._files
    nv.shutdown()


def test_open_otrunc_unwinds_fd_on_drain_timeout(monkeypatch):
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    fd = nv.open("/f")
    nv.pwrite(fd, b"x" * 100, 0)
    nv.flush()
    nv.close(fd)
    monkeypatch.setattr(api_mod.File, "wait_drained",
                        lambda self, timeout=None: False)
    with pytest.raises(TimeoutError):
        nv.open("/f", O_TRUNCW)
    monkeypatch.undo()
    assert not nv._open, "O_TRUNC open leaked its fd on failure"
    assert "/f" not in nv._files
    fd2 = nv.open("/f")                      # the path still works afterwards
    assert nv.pread(fd2, 100, 0) == b"x" * 100   # truncate never happened
    nv.close(fd2)
    nv.shutdown()


def test_close_with_multiple_refs_keeps_file_on_timeout(monkeypatch):
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    fd1 = nv.open("/f")
    fd2 = nv.open("/f")
    monkeypatch.setattr(api_mod.File, "wait_drained",
                        lambda self, timeout=None: False)
    with pytest.raises(TimeoutError):
        nv.close(fd1)
    monkeypatch.undo()
    assert "/f" in nv._files and nv._files["/f"].refs == 1
    nv.pwrite(fd2, b"still-works", 0)
    assert nv.pread(fd2, 11, 0) == b"still-works"
    nv.close(fd2)
    assert "/f" not in nv._files
    nv.shutdown()


# ---------------------------------------------- randomized lifecycle + crash
def test_random_lifecycle_with_crash_recovers_exactly():
    """Random pwrite/append/truncate sequences, then a crash: surviving
    backend bytes + NVMM replay must equal the in-order application of the
    surviving (post-truncate) operations."""
    for trial in range(12):
        rng = random.Random(7100 + trial)
        pol = Policy(entry_size=256, log_entries=256, page_size=256,
                     read_cache_pages=8, batch_min=2, batch_max=8,
                     shards=1 + (trial % 2))
        tier = Tier(DRAM)
        nv = NVCache(pol, tier, track_crashes=True)
        fd = nv.open("/f")
        afd = nv.open("/f", os.O_RDWR | os.O_CREAT | os.O_APPEND)
        img = bytearray()
        for _ in range(rng.randint(5, 20)):
            op = rng.random()
            if op < 0.5:
                off = rng.randrange(0, 900)
                data = bytes([rng.randrange(1, 256)]) * rng.randint(1, 300)
                nv.pwrite(fd, data, off)
                if off + len(data) > len(img):
                    img.extend(b"\x00" * (off + len(data) - len(img)))
                img[off:off + len(data)] = data
            elif op < 0.8:
                data = bytes([rng.randrange(1, 256)]) * rng.randint(1, 200)
                nv.write(afd, data)
                img.extend(data)
            else:
                fdt = nv.open("/f", O_TRUNCW)
                nv.close(fdt)
                img = bytearray()
        nvmm = nv.crash()
        tier2 = Tier(DRAM)
        for path in tier.paths():
            snap = tier.open(path).snapshot()
            if snap:
                tier2.open(path).pwrite(snap, 0)
        stats = recover(nvmm, pol, tier2.open)
        assert stats.crc_failures == 0
        got = tier2.open("/f").snapshot()
        assert got[:len(img)] == bytes(img), f"trial {trial}: wrong bytes"
        assert not any(got[len(img):]), \
            f"trial {trial}: stale bytes past the truncated size"
