"""Partition rules + multi-device pjit equivalence (8 fake CPU devices in a
subprocess so the main test process keeps its single real device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs.registry import get_config, get_smoke
from repro.models.registry import build
from repro.parallel import sharding as shd

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class FakeMesh:
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        import numpy as np
        self.devices = np.empty(tuple(sizes.values()))


def test_param_specs_llama_shapes():
    cfg = get_smoke("llama3.2-1b")
    params = jax.eval_shape(lambda: build(cfg).init(jax.random.PRNGKey(0)))
    mesh = FakeMesh({"data": 2, "model": 2})
    specs = shd.param_specs(params, mesh, fsdp=True)
    wq = specs["layers"]["attn"]["wq"]
    assert wq == shd.P(None, "data", "model")
    wo = specs["layers"]["attn"]["wo"]
    assert wo == shd.P(None, "model", "data")
    assert specs["layers"]["norm1"] == shd.P()
    assert specs["embed"] == shd.P("model", "data")


def test_divisibility_fallback_to_replication():
    """granite kv=1: wk's head dim (1*128) divides 2 but a 256-way axis must
    fall back; odd dims never get sharded."""
    cfg = get_smoke("granite-20b")
    params = jax.eval_shape(lambda: build(cfg).init(jax.random.PRNGKey(0)))
    mesh = FakeMesh({"data": 3, "model": 7})   # nothing divides cleanly
    specs = shd.param_specs(params, mesh, fsdp=True)
    wk = specs["layers"]["attn"]["wk"]
    assert wk == shd.P(None, None, None)


def test_moe_expert_sharding():
    cfg = get_smoke("arctic-480b")
    params = jax.eval_shape(lambda: build(cfg).init(jax.random.PRNGKey(0)))
    mesh = FakeMesh({"data": 2, "model": 2})
    specs = shd.param_specs(params, mesh, fsdp=True)
    assert specs["layers"]["moe"]["wg"] == shd.P(None, "model", "data", None)
    assert specs["layers"]["moe"]["wd"] == shd.P(None, "model", None, "data")


def test_cache_specs_context_parallel_fallback():
    import jax.numpy as jnp
    cache = {"k": jax.ShapeDtypeStruct((4, 8, 64, 2, 16), jnp.bfloat16),
             "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    mesh = FakeMesh({"data": 2, "model": 4})
    specs = shd.cache_specs(cache, mesh)
    # KV=2 not divisible by model=4 -> shard sequence dim instead
    assert specs["k"] == shd.P(None, ("data",), "model", None, None)


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.registry import get_smoke
    from repro.configs.shapes import Shape, concrete_inputs
    from repro.models.registry import build
    from repro.optim.adamw import AdamW
    from repro.train import steps as tsteps
    from repro.launch.mesh import make_debug_mesh

    cfg = get_smoke("llama3.2-1b")
    model = build(cfg)
    opt = AdamW(lr=1e-3)
    batch = concrete_inputs(cfg, Shape("t", "train", 32, 4))
    state = tsteps.init_train_state(model, opt, jax.random.PRNGKey(0))
    step = tsteps.make_train_step(model, opt)

    # single-device reference
    s1, m1 = jax.jit(step)(jax.tree.map(jnp.copy, state), batch)

    mesh = make_debug_mesh(2, 4)
    with mesh:
        (in_sh, b_sh), (out_sh, _), _ = tsteps.train_shardings(
            model, opt, mesh, batch, fsdp=True)
        f = jax.jit(step, in_shardings=(in_sh, b_sh), out_shardings=(out_sh, None))
        s2, m2 = f(state, batch)

    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])))
    print(json.dumps({"loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
                      "param_delta": d}))
""")


@pytest.mark.slow
def test_pjit_8dev_matches_single_device():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # losses computed before the update: tight tolerance.  Param deltas are
    # dominated by Adam's step-1 sign sensitivity (update == ±lr exactly,
    # sign decided by fp reduction order), so the bound is 2*lr + eps.
    assert abs(res["loss1"] - res["loss2"]) < 2e-2, res
    assert res["param_delta"] <= 2.1e-3, res
