"""Sharded-log recovery: randomized crash injection over the multi-shard
commit sequence (fill followers -> pwb -> head commit -> psync).

A fuse wired into the simulated NVMM kills the process model after an
arbitrary number of persistence primitives; the crash then adversarially
evicts a random subset of the un-flushed cachelines.  After ``recover()``
the slow tier must hold, for every file, exactly the completed writes in
application order — plus, possibly, the in-flight write *in full* (its
commit flag may have reached media).  Never a torn group, never a reorder.

Runs for K ∈ {1, 2, 4} shards and for both routing modes.
"""
import random

import pytest

from repro.core import NVMM, Policy, recover
from repro.core.log import NVLog
from repro.core.policy import CACHELINE
from repro.storage.tiers import DRAM, Tier

NFILES = 3


class PowerLoss(Exception):
    pass


class FusedNVMM(NVMM):
    """NVMM that dies after a set number of persistence-protocol ops."""

    def __init__(self, size, *, track=False):
        super().__init__(size, track=track)
        self.ops = 0
        self._fuse = None

    def arm(self, n) -> None:
        self._fuse = n

    def _tick(self):
        self.ops += 1
        if self._fuse is not None:
            if self._fuse <= 0:
                raise PowerLoss()
            self._fuse -= 1

    def store(self, off, data):
        self._tick()
        super().store(off, data)

    def pwb(self, off, n=CACHELINE):
        self._tick()
        super().pwb(off, n)

    def pfence(self):
        self._tick()
        super().pfence()

    def psync(self):
        self._tick()
        super().psync()


def make_policy(k: int, route: str) -> Policy:
    return Policy(entry_size=256, log_entries=64 * k, page_size=256,
                  read_cache_pages=4, batch_min=2, batch_max=8,
                  shards=k, shard_route=route, stripe_pages=2)


def split_stripes(pol: Policy, off: int, data: bytes):
    """Mirror api.pwrite's stripe splitting: one log op never spans a stripe,
    so overlapping ops always route to the same shard."""
    if pol.shards == 1 or pol.shard_route != "stripe":
        yield off, data
        return
    sb = pol.stripe_bytes
    done = 0
    while done < len(data):
        lim = min(len(data) - done, sb - (off + done) % sb)
        yield off + done, data[done:done + lim]
        done += lim


def gen_subops(rng: random.Random, pol: Policy):
    """Random overlapping writes across NFILES files, stripe-split."""
    subops = []
    for _ in range(rng.randint(3, 10)):
        fdid = rng.randrange(NFILES)
        off = rng.randrange(0, 1400)
        data = bytes(rng.randrange(1, 256) for _ in range(rng.randint(1, 600)))
        subops.extend((fdid, o, d) for o, d in split_stripes(pol, off, data))
    return subops


def apply_ops(ops):
    imgs = {}
    for fdid, off, data in ops:
        img = imgs.setdefault(fdid, bytearray())
        if off + len(data) > len(img):
            img.extend(b"\x00" * (off + len(data) - len(img)))
        img[off:off + len(data)] = data
    return imgs


def fresh_log(nvmm, pol) -> NVLog:
    log = NVLog(nvmm, pol, format=True)
    for fdid in range(NFILES):
        log.fd_table_set(fdid, f"/f{fdid}")
    return log


def state_matches(got: bytes, want: bytes) -> bool:
    return got[:len(want)] == want and all(b == 0 for b in got[len(want):])


@pytest.mark.parametrize("route", ["stripe", "fdid"])
@pytest.mark.parametrize("k", [1, 2, 4])
def test_random_crash_points_never_tear_or_reorder(k, route):
    pol = make_policy(k, route)
    for trial in range(30):
        rng = random.Random(9000 * k + 10 * trial + (route == "fdid"))
        subops = gen_subops(rng, pol)

        # dry run: how many NVMM ops does the full sequence cost?
        dry = FusedNVMM(pol.nvmm_bytes)
        dry_log = fresh_log(dry, pol)
        dry.ops = 0
        for op in subops:
            dry_log.append(*op, timeout=10.0)
        total_ops = dry.ops

        # real run: blow the fuse at a uniformly random protocol point
        nvmm = FusedNVMM(pol.nvmm_bytes, track=True)
        log = fresh_log(nvmm, pol)
        nvmm.arm(rng.randrange(0, total_ops + 1))
        completed, inflight = [], None
        try:
            for op in subops:
                inflight = op
                log.append(*op, timeout=10.0)
                completed.append(op)
                inflight = None
        except PowerLoss:
            pass

        # power loss: a random subset of un-flushed lines reaches media
        nvmm._fuse = None
        nvmm.crash(choose_evicted=lambda lines: [l for l in lines
                                                 if rng.random() < 0.5])
        tier = Tier(DRAM)
        stats = recover(nvmm, pol, tier.open)
        assert stats.crc_failures == 0

        exp = apply_ops(completed)
        exp_in = apply_ops(completed + [inflight]) if inflight else None
        for fdid in range(NFILES):
            got = tier.open(f"/f{fdid}").snapshot() if tier.exists(f"/f{fdid}") \
                else b""
            ok = state_matches(got, bytes(exp.get(fdid, b"")))
            if not ok and exp_in is not None and inflight[0] == fdid:
                # the in-flight group's commit line happened to be evicted to
                # media: the write must then appear in full, never torn
                ok = state_matches(got, bytes(exp_in.get(fdid, b"")))
            assert ok, (f"k={k} route={route} trial={trial} file=/f{fdid}: "
                        f"recovered bytes are neither the completed prefix "
                        f"nor prefix+inflight (torn or reordered group)")


@pytest.mark.parametrize("k", [1, 2, 4])
def test_cross_shard_merge_preserves_per_file_order(k):
    """Overlapping writes that land in different shards (stripe routing on a
    hot file) must replay in commit order after a clean crash."""
    pol = make_policy(k, "stripe")
    nvmm = NVMM(pol.nvmm_bytes, track=True)
    log = fresh_log(nvmm, pol)
    rng = random.Random(k)
    ops = []
    for i in range(8):
        off = rng.randrange(0, 3 * pol.stripe_bytes)
        data = bytes([i + 1]) * rng.randint(1, pol.stripe_bytes)
        for o, d in split_stripes(pol, off, data):
            log.append(0, o, d, timeout=10.0)
            ops.append((0, o, d))
    nvmm.crash()                      # nothing evicted: all committed survive
    tier = Tier(DRAM)
    recover(nvmm, pol, tier.open)
    want = bytes(apply_ops(ops)[0])
    got = tier.open("/f0").snapshot()
    assert state_matches(got, want)


def test_recover_rejects_mismatched_shard_count():
    pol4 = make_policy(4, "stripe")
    nvmm = NVMM(pol4.nvmm_bytes, track=True)
    fresh_log(nvmm, pol4)
    nvmm.crash()
    pol2 = make_policy(2, "stripe")
    with pytest.raises(ValueError):
        recover(nvmm, pol2, Tier(DRAM).open)
