"""Epoch-based adaptive shard routing (core/router.py).

Covers: the static fallback (empty table == PR-3 routes, bit for bit), the
persisted route record (install/load roundtrip, torn-record CRC rejection),
the greedy planner (skew detection, hysteresis, placement-group
confinement, noise-key rejection), and the full migration protocol through
the api (freeze -> drain barrier -> install -> unfreeze) with data
integrity across the epoch flip.
"""
import threading

import pytest

from repro.core import NVMM, NVCache, Policy
from repro.core.log import NVLog
from repro.core.router import (EpochRouter, MIN_RATIO, load_route_record)
from repro.storage.tiers import DRAM, Tier


def make_policy(**kw):
    base = dict(entry_size=256, log_entries=256, page_size=256,
                read_cache_pages=8, batch_min=2, batch_max=16,
                shards=4, shard_route="fdid", shard_rebalance=True,
                rebalance_epoch_ms=10_000)   # ticks are driven manually
    base.update(kw)
    return Policy(**base)


def make_nv(pol):
    tier = Tier(DRAM)
    return NVCache(pol, tier), tier


# ------------------------------------------------------------------ routing
def test_empty_table_matches_static_routes():
    for route in ("fdid", "stripe"):
        pol = make_policy(shard_route=route, stripe_pages=2)
        nvmm = NVMM(pol.nvmm_bytes)
        log = NVLog(nvmm, pol, format=True)
        router = EpochRouter(nvmm, pol)
        for fdid in range(8):
            for off in range(0, 4 * pol.stripe_bytes, pol.stripe_bytes // 2):
                assert router.route(fdid, off) == log.route(fdid, off)


def test_shard_rebalance_false_keeps_router_off():
    pol = make_policy(shard_rebalance=False)
    nv, _ = make_nv(pol)
    try:
        assert nv.router is None
        assert nv.log.router is None
        assert nv.cleanup.rebalancer is None
    finally:
        nv.shutdown()


def test_override_reroutes_and_install_roundtrip():
    pol = make_policy()
    nvmm = NVMM(pol.nvmm_bytes)
    NVLog(nvmm, pol, format=True)
    router = EpochRouter(nvmm, pol)
    assert router.route(0, 0) == 0
    assert router.install(0, 3)
    assert router.epoch == 1
    assert router.route(0, 0) == 3
    # a second router on the same region adopts the persisted epoch
    router2 = EpochRouter(nvmm, pol)
    assert router2.epoch == 1
    assert router2.route(0, 0) == 3
    # installing the static route drops the override instead of growing
    assert router.install(0, 0)
    assert router.table == {}
    epoch, table, shifts = load_route_record(nvmm, pol)
    assert epoch == 2 and table == {} and shifts == {}


def test_torn_route_record_falls_back_to_static():
    pol = make_policy()
    nvmm = NVMM(pol.nvmm_bytes)
    NVLog(nvmm, pol, format=True)
    router = EpochRouter(nvmm, pol)
    router.install(5, 2)
    # corrupt one payload byte after the header: CRC must reject the record
    nvmm.store(pol.route_base + 16, b"\xff")
    epoch, table, shifts = load_route_record(nvmm, pol)
    assert (epoch, table, shifts) == (0, {}, {})
    assert EpochRouter(nvmm, pol).route(5, 0) == 5 % pol.shards


def test_route_table_cap_refuses_install():
    pol = make_policy(route_table_max=2)
    nvmm = NVMM(pol.nvmm_bytes)
    NVLog(nvmm, pol, format=True)
    router = EpochRouter(nvmm, pol)
    assert router.install(0, 1)
    assert router.install(1, 2)
    assert not router.install(2, 3)          # full: table untouched
    assert router.table == {0: 1, 1: 2}


def test_format_clears_route_record():
    pol = make_policy()
    nvmm = NVMM(pol.nvmm_bytes)
    NVLog(nvmm, pol, format=True)
    EpochRouter(nvmm, pol).install(0, 3)
    NVLog(nvmm, pol, format=True)            # reformat (recovery does this)
    assert load_route_record(nvmm, pol) == (0, {}, {})


# ----------------------------------------------------------------- planning
def feed(router, key_loads):
    """Simulate one epoch of appends: {fdid: entries}."""
    for fdid, n in key_loads.items():
        router.note_append(fdid, 0, n)


def test_plan_moves_colliding_hot_fdids_apart():
    pol = make_policy()
    nvmm = NVMM(pol.nvmm_bytes)
    NVLog(nvmm, pol, format=True)
    router = EpochRouter(nvmm, pol)
    # fdids 0 and 4 collide on shard 0; both hot
    feed(router, {0: 40, 4: 40, 1: 1, 2: 1, 3: 1})
    plan = router.plan()
    assert len(plan) == 1
    mig = plan[0]
    assert mig.key in (0, 4) and mig.old_sid == 0 and mig.new_sid != 0
    router.install(mig.key, mig.new_sid)
    # steady state afterwards: one hot key per shard, nothing to move
    feed(router, {0: 40, 4: 40, 1: 1, 2: 1, 3: 1})
    assert router.plan() == []


def test_plan_hysteresis_ignores_balanced_and_idle_epochs():
    pol = make_policy()
    nvmm = NVMM(pol.nvmm_bytes)
    NVLog(nvmm, pol, format=True)
    router = EpochRouter(nvmm, pol)
    feed(router, {0: 20, 1: 20, 2: 20, 3: 20})    # balanced
    assert router.plan() == []
    feed(router, {0: 3, 4: 3})                    # below MIN_EPOCH_ENTRIES
    assert router.plan() == []
    assert MIN_RATIO > 1.0                        # documented hysteresis


def test_plan_respects_placement_groups():
    # shards {0,1} and {2,3} are separate NUMA-style groups: a hot key on
    # shard 0 may only move to shard 1, even when shard 3 is idle
    pol = make_policy(placement_groups=2)
    nvmm = NVMM(pol.nvmm_bytes)
    NVLog(nvmm, pol, format=True)
    router = EpochRouter(nvmm, pol)
    feed(router, {0: 40, 4: 40, 1: 2})
    plan = router.plan()
    assert plan and all(m.new_sid in (0, 1) for m in plan)


def test_plan_skips_noise_keys():
    pol = make_policy()
    nvmm = NVMM(pol.nvmm_bytes)
    NVLog(nvmm, pol, format=True)
    router = EpochRouter(nvmm, pol)
    # one dominant key: moving it just relocates the hot spot; the tiny
    # cohabitant closes <10% of the gap — neither is worth a barrier
    feed(router, {0: 100, 4: 2})
    assert router.plan() == []


def test_plan_cost_model_vetoes_moves_behind_a_deep_backlog():
    """The migration cost model (PR 5): a justified move is skipped — and
    counted in ``stats_skipped_uneconomic`` — when the hot shard's drain
    backlog (what the migration's barrier must flush first) exceeds the
    load reduction recouped over the horizon; the same skew migrates once
    the backlog clears."""
    from repro.core.router import BARRIER_HORIZON_EPOCHS
    pol = make_policy()
    nvmm = NVMM(pol.nvmm_bytes)
    NVLog(nvmm, pol, format=True)
    router = EpochRouter(nvmm, pol)
    skew = {0: 40, 4: 40, 1: 1, 2: 1, 3: 1}
    feed(router, skew)
    # moving one 40-entry key gains 39 entries/epoch and the key owns half
    # the hot shard's load, so its barrier waits on ~half the backlog: a
    # backlog deeper than 2 * horizon * gain makes the move a net loss
    deep = [2 * BARRIER_HORIZON_EPOCHS * 39 + 4, 0, 0, 0]
    assert router.plan(queue_depths=deep) == []
    assert router.stats_skipped_uneconomic == 1
    assert router.table == {}                 # nothing installed
    # backlog drained: the same skew now migrates
    feed(router, skew)
    plan = router.plan(queue_depths=[0, 0, 0, 0])
    assert len(plan) == 1 and plan[0].old_sid == 0
    assert router.stats_skipped_uneconomic == 1


def test_plan_skips_moves_that_cannot_fit_the_table():
    """A migration whose install would be refused (table full) must not be
    planned at all — the freeze + drain barrier would be paid every epoch
    for nothing."""
    pol = make_policy(route_table_max=1)
    nvmm = NVMM(pol.nvmm_bytes)
    NVLog(nvmm, pol, format=True)
    router = EpochRouter(nvmm, pol)
    assert router.install(5, 2)              # occupies the only slot
    feed(router, {0: 40, 4: 40, 1: 1})       # skew that wants a migration
    assert router.plan() == []


def test_route_only_router_never_accumulates_counters():
    """The attach-adopted router (sampling=False) has no rebalance thread
    to drain its counters; note_append must be a no-op there."""
    pol = make_policy()
    nvmm = NVMM(pol.nvmm_bytes)
    log = NVLog(nvmm, pol, format=True)
    EpochRouter(nvmm, pol).install(0, 3)
    log.fd_table_set(0, "/f")
    log.append(0, 0, b"x" * 50)
    log2 = NVLog(nvmm, pol, format=False)    # auto-adopts, route-only
    assert log2.router is not None and not log2.router.sampling
    for i in range(50):
        log2.append(0, i * 100, b"y" * 50)
    assert log2.router._key_load == {}


def test_stripe_keys_pack_fdid_and_stripe():
    pol = make_policy(shard_route="stripe", stripe_pages=2)
    nvmm = NVMM(pol.nvmm_bytes)
    NVLog(nvmm, pol, format=True)
    router = EpochRouter(nvmm, pol)
    sb = pol.stripe_bytes
    k0 = router.key_of(3, 0)
    k4 = router.key_of(3, 4 * sb)
    assert k0 != k4
    assert EpochRouter.key_fdid(k0, pol) == EpochRouter.key_fdid(k4, pol) == 3
    router.install(k4, 2)
    assert router.route(3, 4 * sb) == 2
    assert router.route(3, 4 * sb + sb - 1) == 2     # same stripe
    assert router.route(3, 0) == router.static_route(3, 0)


# ------------------------------------------------------- api-level migration
def test_rebalance_end_to_end_migrates_and_keeps_data():
    pol = make_policy()
    nv, tier = make_nv(pol)
    try:
        fds = [nv.open(f"/f{i}") for i in range(8)]
        for rep in range(40):
            nv.pwrite(fds[0], bytes([1]) * 100, rep * 100)
            nv.pwrite(fds[4], bytes([2]) * 100, rep * 100)
        for i in (1, 2, 3, 5, 6, 7):
            nv.pwrite(fds[i], b"x" * 50, 0)
        assert nv.log.route(0, 0) == nv.log.route(4, 0) == 0   # collision
        nv.cleanup.rebalancer.tick()
        assert nv.router.epoch >= 1
        assert nv.cleanup.rebalancer.stats_migrations >= 1
        assert nv.log.route(0, 0) != nv.log.route(4, 0)        # spread out
        # post-flip writes land and read back through the new route
        for rep in range(10):
            nv.pwrite(fds[0], bytes([7]) * 100, rep * 100)
        assert nv.pread(fds[0], 100, 0) == bytes([7]) * 100
        assert nv.pread(fds[4], 100, 0) == bytes([2]) * 100
        nv.flush()
        st = nv.stats()
        assert st["route_epoch"] >= 1 and st["route_migrations"] >= 1
    finally:
        nv.shutdown()
    assert tier.open("/f0").snapshot()[:100] == bytes([7]) * 100
    assert tier.open("/f4").snapshot()[:100] == bytes([2]) * 100


def test_migration_blocks_until_inflight_writes_commit():
    """The freeze must wait for a writer that already pinned its route."""
    pol = make_policy()
    nv, _ = make_nv(pol)
    try:
        fd = nv.open("/f0")
        f = nv._of(fd).file
        f.route_enter()                      # simulate an in-flight write
        done = threading.Event()

        def freeze():
            assert f.route_freeze(timeout=5.0)
            done.set()

        t = threading.Thread(target=freeze)
        t.start()
        assert not done.wait(0.15)           # blocked on the in-flight write
        f.route_exit()
        assert done.wait(5.0)
        f.route_unfreeze()
        t.join()
        # a frozen gate blocks route_enter until unfreeze
        assert f.route_freeze(timeout=1.0)
        entered = threading.Event()
        t2 = threading.Thread(target=lambda: (f.route_enter(), entered.set()))
        t2.start()
        assert not entered.wait(0.15)
        f.route_unfreeze()
        assert entered.wait(5.0)
        f.route_exit()
        t2.join()
    finally:
        nv.shutdown()


def test_concurrent_writers_survive_live_rebalancing():
    """Writers hammer colliding hot files while the rebalance thread runs at
    a fast epoch; every acknowledged write must be durable and ordered."""
    pol = make_policy(log_entries=512, rebalance_epoch_ms=20)
    nv, tier = make_nv(pol)
    errors = []
    try:
        fds = [nv.open(f"/f{i}") for i in range(8)]

        def writer(w):
            try:
                fd = fds[4 * (w % 2)]        # files 0 and 4: shard collision
                for i in range(120):
                    nv.pwrite(fd, bytes([w + 1]) * 64, (w * 120 + i) * 64)
            except Exception as exc:         # pragma: no cover
                errors.append(exc)

        ts = [threading.Thread(target=writer, args=(w,)) for w in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errors
        nv.flush()
        for w in range(4):
            fd = fds[4 * (w % 2)]
            got = nv.pread(fd, 64, (w * 120 + 119) * 64)
            assert got == bytes([w + 1]) * 64
    finally:
        nv.shutdown()


def test_attach_restores_routes_for_live_entries():
    """NVLog(format=False) on a region with live entries + an installed
    epoch must route new writes like the pre-restart instance did — the
    whole point of persisting the table next to the superblock."""
    pol = make_policy()
    nvmm = NVMM(pol.nvmm_bytes)
    log = NVLog(nvmm, pol, format=True)
    router = EpochRouter(nvmm, pol)
    log.router = router
    log.fd_table_set(0, "/f")
    router.install(0, 3)
    log.append(0, 0, b"a" * 100)             # live entry now in shard 3
    assert log.shards[3].used_entries > 0
    # "restart": fresh objects on the same region.  The attach must honor
    # the persisted record on its own — even a shard_rebalance=False owner
    # that never installs a router must not fall back to static routes
    # while old-epoch entries are live.
    log2 = NVLog(nvmm, pol, format=False)
    assert log2.router is not None           # auto-adopted from the record
    assert log2.route(0, 0) == 3             # NOT the static shard 0
    router2 = EpochRouter(nvmm, pol)
    log2.router = router2
    assert log2.route(0, 0) == 3
    log2.append(0, 50, b"b" * 100)           # overlaps: must share shard 3
    assert log2.shards[3].used_entries >= log.shards[3].used_entries
    assert log2.shards[0].used_entries == 0


def test_retiring_a_file_drops_its_overrides():
    """A retired fdid's overrides must leave the table (else dead entries
    fill route_table_max forever and a reused fdid inherits dead routing)."""
    pol = make_policy()
    nv, _ = make_nv(pol)
    try:
        fd = nv.open("/hot")                 # fdid 0
        nv.pwrite(fd, b"x" * 100, 0)
        nv.router.install(0, 3)
        assert nv.log.route(0, 0) == 3
        epoch_before = nv.router.epoch
        nv.close(fd)                         # drains, retires fdid 0
        assert 0 not in nv.router.table
        assert nv.router.epoch > epoch_before
        # a new file reusing fdid 0 starts on its static route
        fd2 = nv.open("/other")
        assert nv._of(fd2).file.fdid == 0
        assert nv.log.route(0, 0) == 0
        nv.close(fd2)
    finally:
        nv.shutdown()


def test_stale_migration_plan_for_retired_fdid_is_skipped():
    """_migrate_route must not install an override for a fdid whose File is
    gone — the fdid may already name a brand-new file whose route gate was
    never frozen."""
    from repro.core.router import Migration
    pol = make_policy()
    nv, _ = make_nv(pol)
    try:
        fd = nv.open("/f0")                  # fdid 0
        nv.pwrite(fd, b"x" * 100, 0)
        nv.close(fd)                         # retire fdid 0
        assert not nv._migrate_route(Migration(0, 0, 0, 2, 40))
        assert nv.router.table == {}
        fd2 = nv.open("/reuse")              # reuses fdid 0
        assert nv._of(fd2).file.fdid == 0
        assert nv.log.route(0, 0) == 0       # untouched by the stale plan
        nv.close(fd2)
    finally:
        nv.shutdown()


# ----------------------------------------------------- stripe width tuning
def stripe_pol(**kw):
    base = dict(shard_route="stripe", stripe_pages=4)   # 1 KiB stripes
    base.update(kw)
    return make_policy(**base)


def feed_hot_stripes(router, sb, fdid=0, stripes=(0, 4), load=40):
    """One epoch: ``stripes`` of ``fdid`` all hot (and, with a stride-4
    pattern on 4 shards, all colliding on one shard), plus a light key per
    other shard so cold targets exist."""
    for s in stripes:
        router.note_append(fdid, s * sb, load)
    for other in (1, 2, 3):
        router.note_append(other, 0, 1)


def test_stripe_tuning_streak_emits_width_change():
    pol = stripe_pol()
    nvmm = NVMM(pol.nvmm_bytes)
    NVLog(nvmm, pol, format=True)
    router = EpochRouter(nvmm, pol)
    sb = pol.stripe_bytes
    # epochs 1 and 2: the planner proposes per-key moves (never installed,
    # so the skew repeats) — no width change yet
    for epoch in range(pol.stripe_tune_streak - 1):
        feed_hot_stripes(router, sb)
        plan = router.plan()
        assert plan and all(m.new_shift is None for m in plan)
        assert all(m.fdid == 0 for m in plan)
    # epoch 3: the streak trips — ONE width change replaces every per-key
    # move of the persistently hot fdid
    feed_hot_stripes(router, sb)
    plan = router.plan()
    assert len(plan) == 1
    mig = plan[0]
    assert mig.fdid == 0 and mig.new_shift == 1
    assert mig.old_sid == -1 and mig.new_sid == -1
    # a successful widening resets the streak: the NEXT skewed epoch is
    # back to per-key moves (at the new width)
    router.install_width(0, 1)
    feed_hot_stripes(router, sb)
    assert all(m.new_shift is None for m in router.plan())


def test_stripe_tuning_streak_resets_on_a_calm_epoch():
    pol = stripe_pol()
    nvmm = NVMM(pol.nvmm_bytes)
    NVLog(nvmm, pol, format=True)
    router = EpochRouter(nvmm, pol)
    sb = pol.stripe_bytes
    for epoch in range(pol.stripe_tune_streak - 1):
        feed_hot_stripes(router, sb)
        router.plan()
    feed(router, {0: 5, 1: 5, 2: 5, 3: 5})   # balanced epoch: no moves
    assert router.plan() == []
    # the streak restarted — two more hot epochs still only per-key moves
    for epoch in range(pol.stripe_tune_streak - 1):
        feed_hot_stripes(router, sb)
        assert all(m.new_shift is None for m in router.plan())


def test_stripe_tuning_never_narrows_below_a_page():
    pol = stripe_pol(stripe_pages=1)         # stripe == page: cannot halve
    nvmm = NVMM(pol.nvmm_bytes)
    NVLog(nvmm, pol, format=True)
    router = EpochRouter(nvmm, pol)
    sb = pol.stripe_bytes
    for epoch in range(pol.stripe_tune_streak + 2):
        feed_hot_stripes(router, sb)
        assert all(m.new_shift is None for m in router.plan())


def test_install_width_drops_overrides_and_persists():
    pol = stripe_pol()
    nvmm = NVMM(pol.nvmm_bytes)
    NVLog(nvmm, pol, format=True)
    router = EpochRouter(nvmm, pol)
    sb = pol.stripe_bytes
    k4 = router.key_of(0, 4 * sb)
    router.install(k4, 2)                    # per-key override for fdid 0
    k_other = router.key_of(7, 0)            # static route is shard 3:
    router.install(k_other, 1)               # override to 1 for a bystander
    assert router.install_width(0, 1)
    # fdid 0 keys are gone (stale at the new width); the bystander stays
    assert k4 not in router.table and k_other in router.table
    assert router.stripe_bytes_of(0) == sb // 2
    assert router.stripe_bytes_of(7) == sb
    # the formula now spreads fdid 0 at half-stripe granularity
    assert router.route(0, 0) != router.route(0, sb // 2)
    # persisted: a fresh attach adopts epoch, table, and widths
    epoch, table, shifts = load_route_record(nvmm, pol)
    assert shifts == {0: 1} and table == {k_other: 1}
    r2 = EpochRouter(nvmm, pol)
    assert r2.stripe_bytes_of(0) == sb // 2
    assert r2.route(0, sb // 2) == router.route(0, sb // 2)
    # width 0 removes the entry again
    assert router.install_width(0, 0)
    assert router.stripe_bytes_of(0) == sb
    assert load_route_record(nvmm, pol)[2] == {}


def test_install_width_requires_stripe_mode():
    pol = make_policy(shard_route="fdid")
    nvmm = NVMM(pol.nvmm_bytes)
    NVLog(nvmm, pol, format=True)
    router = EpochRouter(nvmm, pol)
    assert not router.install_width(0, 1)


def test_stripe_widening_end_to_end():
    """A persistently hot striped file gets its stripe width halved by the
    live rebalancer instead of being chased stripe-by-stripe, and every
    byte survives the width flip."""
    pol = stripe_pol(log_entries=1024)
    nv, tier = make_nv(pol)
    try:
        fds = [nv.open(f"/f{i}") for i in range(4)]
        sb = pol.stripe_bytes
        hot = [s * sb for s in range(0, 48, 4)]   # stride-4: all shard 0
        ticks = 0
        while nv.router.stats_stripe_widenings == 0 and ticks < 8:
            for off in hot:
                for rep in range(4):
                    nv.pwrite(fds[0], bytes([1 + rep]) * 100, off + rep * 100)
            for i in (1, 2, 3):
                nv.pwrite(fds[i], b"x" * 50, 0)
            nv.cleanup.rebalancer.tick()
            ticks += 1
        assert nv.router.stats_stripe_widenings >= 1
        assert nv.router.stripe_bytes_of(nv._of(fds[0]).file.fdid) < sb
        st = nv.stats()
        assert st["route_stripe_widenings"] >= 1
        # post-widening writes land and read back through the new formula
        for off in hot:
            nv.pwrite(fds[0], bytes([9]) * 100, off)
        for off in hot:
            assert nv.pread(fds[0], 100, off) == bytes([9]) * 100
            assert nv.pread(fds[0], 100, off + 300) == bytes([4]) * 100
        nv.flush()
    finally:
        nv.shutdown()
    snap = tier.open("/f0").snapshot()
    for off in hot:
        assert snap[off:off + 100] == bytes([9]) * 100
