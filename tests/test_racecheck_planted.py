"""Planted-bug suite for the guarded-by race detector (repro.analysis).

Two halves, one acceptance bar each (the pmcheck suite's structure):

* **zero false positives** — real engine paths (multi-writer traffic,
  stats aggregation, drain hand-offs, shutdown) run clean under an armed
  :class:`~repro.analysis.racecheck.RaceCheck`;
* **zero false negatives** — deterministic mutations (guard dropped,
  lock released early, unsynchronized publish) each trip exactly the
  expected RC code, and their correctly-synchronized mirrors run clean.

Interleavings are forced with *plain* ``threading.Semaphore`` hand-offs:
the detector never hooks raw semaphores, so they order execution in real
time without creating a happens-before edge — exactly the shape of a
"works on my machine" race.  Each racing pair ends on an (equally
untraced) ``threading.Barrier`` so both threads are alive until both
accesses are recorded — a thread that exits early can donate its OS
ident to the next one started, which would merge the two accesses into
one thread and hide the plant (the detector's documented ident-reuse
blind spot).  The static half plants L004/L005 snippets through
:func:`repro.analysis.lint.lint_file`.

Every toy class is instrumented inside the test and de-instrumented in a
``finally`` so nothing leaks into the session (under ``--sanitize`` the
planted races stay in the local detector — the ``arm()`` contract).
"""
import ast
import threading
from pathlib import Path

from repro.analysis import lint, racecheck
from repro.core import NVCache, Policy, locking
from repro.storage.tiers import DRAM, Tier


def codes(rc):
    return [v.code for v in rc.violations]


def run2(*fns):
    """Start the given thunks as threads and join them all."""
    ts = [threading.Thread(target=fn, name=f"planted-{i}")
          for i, fn in enumerate(fns)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()


# ------------------------------------------------------- planted: runtime


def test_planted_write_write_no_sync_rc001():
    """Two threads blindly store the same HB-only field: RC001."""
    class Toy:
        GUARDED_BY = {"x": None}

        def __init__(self):
            self.x = 0

    racecheck.instrument(Toy)
    try:
        with racecheck.arm() as rc:
            toy = Toy()
            gate = threading.Semaphore(0)
            end = threading.Barrier(2)

            def a():
                toy.x = 1
                gate.release()
                end.wait()

            def b():
                gate.acquire()
                toy.x = 2
                end.wait()

            run2(a, b)
        assert "RC001" in codes(rc), codes(rc)
    finally:
        racecheck.deinstrument(Toy)


def test_planted_publish_without_edge_rc002():
    """Writer publishes, reader consumes with no join/lock/event: RC002."""
    class Toy:
        GUARDED_BY = {"x": None}

        def __init__(self):
            self.x = 0

    racecheck.instrument(Toy)
    try:
        with racecheck.arm() as rc:
            toy = Toy()
            gate = threading.Semaphore(0)
            end = threading.Barrier(2)
            out = []

            def w():
                toy.x = 7
                gate.release()
                end.wait()

            def r():
                gate.acquire()
                out.append(toy.x)
                end.wait()

            run2(w, r)
        assert "RC002" in codes(rc), codes(rc)
        assert out == [7]
    finally:
        racecheck.deinstrument(Toy)


def test_planted_guard_dropped_rc003():
    """One reader honors the declared guard, the other skips it.  Reads
    carry no lock edge between the two threads, so the accesses are
    genuinely unordered — the contract violation RC003 exists for."""
    class Toy:
        GUARDED_BY = {"x": "lock"}

        def __init__(self):
            self.lock = locking.make_lock("leaf:lru")
            self.x = 0

    racecheck.instrument(Toy)
    try:
        with racecheck.arm() as rc:
            toy = Toy()
            gate = threading.Semaphore(0)
            end = threading.Barrier(2)

            def disciplined():
                with toy.lock:
                    _ = toy.x
                gate.release()
                end.wait()

            def sloppy():
                gate.acquire()
                _ = toy.x          # guard dropped — the planted bug
                end.wait()

            run2(disciplined, sloppy)
        assert "RC003" in codes(rc), codes(rc)
    finally:
        racecheck.deinstrument(Toy)


def test_planted_lock_released_early_rc003():
    """Double-checked read: a thread samples the field under the guard,
    releases, and re-reads it bare after a concurrent guarded write —
    the bare re-check is unordered against that write (the thread last
    saw the lock's clock *before* the writer held it)."""
    class Toy:
        GUARDED_BY = {"x": "lock"}

        def __init__(self):
            self.lock = locking.make_lock("leaf:lru")
            self.x = 0

    racecheck.instrument(Toy)
    try:
        with racecheck.arm() as rc:
            toy = Toy()
            wrote = threading.Semaphore(0)
            sampled = threading.Semaphore(0)
            end = threading.Barrier(2)

            def early():
                with toy.lock:
                    _ = toy.x       # disciplined first sample...
                sampled.release()
                wrote.acquire()
                _ = toy.x           # ...re-checked after letting go
                end.wait()

            def writer():
                sampled.acquire()
                with toy.lock:
                    toy.x = 1
                wrote.release()
                end.wait()

            run2(early, writer)
        assert "RC003" in codes(rc), codes(rc)
    finally:
        racecheck.deinstrument(Toy)


# ------------------------------------------------- mirrors: must run clean


def test_mirror_lock_discipline_clean():
    """Same write-write shape as the RC001 plant, but both writers hold
    the declared lock: common lockset + release/acquire edge — clean."""
    class Toy:
        GUARDED_BY = {"x": "lock"}

        def __init__(self):
            self.lock = locking.make_lock("leaf:lru")
            self.x = 0

    racecheck.instrument(Toy)
    try:
        with racecheck.arm() as rc:
            toy = Toy()

            def w(v):
                def fn():
                    for _ in range(50):
                        with toy.lock:
                            toy.x += v
                return fn

            run2(w(1), w(2))
            with toy.lock:
                assert toy.x == 150
        assert codes(rc) == [], codes(rc)
    finally:
        racecheck.deinstrument(Toy)


def test_mirror_lock_edge_orders_unguarded_read():
    """HB through a lock channel: the reader bounces through the writer's
    lock before its raw read, so release→acquire orders the accesses."""
    class Toy:
        GUARDED_BY = {"x": None}

        def __init__(self):
            self.lock = locking.make_lock("leaf:lru")
            self.x = 0

    racecheck.instrument(Toy)
    try:
        with racecheck.arm() as rc:
            toy = Toy()
            gate = threading.Semaphore(0)

            def w():
                with toy.lock:
                    toy.x = 3
                gate.release()

            def r():
                gate.acquire()
                with toy.lock:
                    pass            # pick up the writer's clock
                assert toy.x == 3
            run2(w, r)
        assert codes(rc) == [], codes(rc)
    finally:
        racecheck.deinstrument(Toy)


def test_mirror_event_handoff_clean():
    """set→wait is a publish edge: the classic flag-then-read pattern."""
    class Toy:
        GUARDED_BY = {"x": None}

        def __init__(self):
            self.x = 0

    racecheck.instrument(Toy)
    try:
        with racecheck.arm() as rc:
            toy = Toy()
            ev = threading.Event()
            out = []

            def w():
                toy.x = 9
                ev.set()

            def r():
                ev.wait()
                out.append(toy.x)

            run2(w, r)
        assert codes(rc) == [], codes(rc)
        assert out == [9]
    finally:
        racecheck.deinstrument(Toy)


def test_mirror_join_orders_teardown_read():
    """start/join edges: single-threaded setup, a worker's stores, and
    the parent's post-join read are all ordered — no lock needed."""
    class Toy:
        GUARDED_BY = {"x": "lock"}

        def __init__(self):
            self.lock = locking.make_lock("leaf:lru")
            self.x = 0

    racecheck.instrument(Toy)
    try:
        with racecheck.arm() as rc:
            toy = Toy()
            toy.x = 1                       # pre-start setup, no guard

            def w():
                with toy.lock:
                    toy.x += 1

            t = threading.Thread(target=w)
            t.start()
            t.join()
            assert toy.x == 2               # post-join stats read, no guard
        assert codes(rc) == [], codes(rc)
    finally:
        racecheck.deinstrument(Toy)


# ------------------------------------------------ real paths: no false pos


POL = Policy(entry_size=4096 + 32, log_entries=256, page_size=4096,
             read_cache_pages=8, batch_min=8, batch_max=64)


def test_real_multiwriter_engine_clean():
    """A compact slice of the 8-writer stress under an armed detector:
    disjoint writers, readers, stats() aggregation mid-flight, then the
    post-shutdown stats read — all against the annotated contract."""
    with racecheck.arm() as rc:
        nv = NVCache(POL, Tier(DRAM))
        fd = nv.open("/f")
        N, SZ = 4, 4096

        def worker(i):
            for _ in range(10):
                nv.pwrite(fd, bytes([i + 1]) * SZ, i * SZ)
                nv.pread(fd, SZ, i * SZ)

        def watcher():
            for _ in range(5):
                nv.stats()

        run2(*[lambda i=i: worker(i) for i in range(N)], watcher)
        for i in range(N):
            assert nv.pread(fd, SZ, i * SZ) == bytes([i + 1]) * SZ
        nv.fsync(fd)
        nv.close(fd)
        nv.shutdown()
        nv.stats()
    assert codes(rc) == [], "\n".join(str(v) for v in rc.violations)


def test_real_stats_snapshot_not_torn():
    """Satellite regression for the stats() race: hammer one byte range
    from two writers while a third thread aggregates stats().  The old
    unlocked `lru.stats_hits += 1` / bare-field aggregation pattern is
    planted as a mirror below; the real path must stay silent."""
    with racecheck.arm() as rc:
        nv = NVCache(POL, Tier(DRAM))
        fd = nv.open("/f")
        stop = threading.Event()

        def writer(pat):
            while not stop.is_set():
                nv.pwrite(fd, bytes([pat]) * 4096, 0)

        def aggregator():
            for _ in range(30):
                s = nv.stats()
                assert s["lru_hits"] >= 0
            stop.set()

        run2(lambda: writer(0xAA), lambda: writer(0xBB), aggregator)
        nv.close(fd)
        nv.shutdown()
    assert codes(rc) == [], "\n".join(str(v) for v in rc.violations)


def test_planted_unlocked_counter_aggregation_rc():
    """The failing-before shape of the stats() bug this PR fixes: two
    writer threads bump a shared counter under *different* page locks
    (mutual exclusion in neither pair), a reader aggregates it bare.
    The detector must call it — this is the lost-update torn read
    api.stats() used to be able to return."""
    class Stats:
        GUARDED_BY = {"hits": "lock"}

        def __init__(self):
            self.lock = locking.make_lock("leaf:lru")
            self.hits = 0

    racecheck.instrument(Stats)
    try:
        with racecheck.arm() as rc:
            st = Stats()
            page_a = locking.make_lock("page_atomic", order_key=0)
            page_b = locking.make_lock("page_atomic", order_key=1)
            gate = threading.Semaphore(0)
            end = threading.Barrier(2)

            def hit_a():
                with page_a:
                    st.hits += 1    # wrong lock: the old api.py pattern
                gate.release()
                end.wait()

            def hit_b():
                gate.acquire()
                with page_b:
                    st.hits += 1
                end.wait()

            run2(hit_a, hit_b)
        got = set(codes(rc))
        assert {"RC001", "RC003"} & got, codes(rc)
    finally:
        racecheck.deinstrument(Stats)


# ------------------------------------------------------- planted: static


HIERARCHY = lint.parse_hierarchy()


def lint_snippet(tmp_path: Path, src: str):
    p = tmp_path / "snippet.py"
    p.write_text(src)
    return lint.lint_file(p, ast.parse(src), HIERARCHY, set())


def test_lint_l004_guard_dropped(tmp_path):
    out = lint_snippet(tmp_path, (
        "class C:\n"
        "    GUARDED_BY = {'x': '_lock'}\n"
        "    def bump(self):\n"
        "        self.x += 1\n"
    ))
    assert [f.code for f in out] == ["L004"]


def test_lint_l004_write_spec_and_suppression(tmp_path):
    out = lint_snippet(tmp_path, (
        "class C:\n"
        "    GUARDED_BY = {'x': 'write:_lock'}\n"
        "    def read_ok(self):\n"
        "        return self.x\n"          # write: spec — reads are free
        "    def write_bad(self):\n"
        "        self.x = 1\n"
        "    def write_hushed(self):\n"
        "        self.x = 2  # lint: allow(L004)\n"
    ))
    assert [f.code for f in out] == ["L004"]
    assert "write_bad" not in out[0].msg   # message names class.field
    assert out[0].line == 6


def test_lint_l004_clean_mirrors(tmp_path):
    out = lint_snippet(tmp_path, (
        "class C:\n"
        "    GUARDED_BY = {'x': '_lock'}\n"
        "    def bump(self):\n"
        "        with self._lock:\n"
        "            self.x += 1\n"
        "    def bump_locked(self):\n"     # *_locked: caller holds it
        "        self.x += 1\n"
        "    def __init__(self):\n"
        "        self.x = 0\n"
    ))
    assert out == []


def test_lint_l005_undeclared_public_attr(tmp_path):
    out = lint_snippet(tmp_path, (
        "from repro.core import locking\n"
        "class D:\n"
        "    def __init__(self):\n"
        "        self.lock = locking.make_lock('leaf:lru')\n"
        "        self.n = 0\n"
        "    def bump(self):\n"
        "        self.n += 1\n"
    ))
    assert "L005" in [f.code for f in out]


def test_lint_l005_clean_when_declared(tmp_path):
    out = lint_snippet(tmp_path, (
        "from repro.core import locking\n"
        "class D:\n"
        "    GUARDED_BY = {'n': 'lock'}\n"
        "    def __init__(self):\n"
        "        self.lock = locking.make_lock('leaf:lru')\n"
        "        self.n = 0\n"
        "    def bump(self):\n"
        "        with self.lock:\n"
        "            self.n += 1\n"
    ))
    assert out == []


def test_lint_real_core_tree_clean():
    """0 FP on the real tree: the shipped annotations satisfy L004/L005."""
    import repro.core as core
    found = lint.run([Path(core.__file__).parent])
    assert [f for f in found if f.code in ("L004", "L005")] == []
