"""Batch-spanning drain coalescing (PR 3 tentpole, write side).

A drain batch may leave its contiguous tail extent (the still-filling tail
page) unconsumed so the next batch's contiguous entries merge into one
backend write.  Deferred entries keep every invariant: they stay committed
in the log (recovery replays them), their dirty-page-index refs stay live
(reads replay them), and nothing is consumed/retired before its bytes and
fsync land — the carry is just "not drained yet", bounded by
``Policy.coalesce_deadline_ms`` and closed by any drain barrier.

Most tests drive a detached (unstarted) CleanupThread so batch boundaries
are exact; the pool's own threads stay idle under a huge ``batch_min``.
"""
import time

import pytest

from repro.core import NVCache, Policy, recover
from repro.core.cleanup import CleanupThread
from repro.core.drain import choose_deferred_suffix
from repro.storage.tiers import DRAM, Tier

PS = 1024


def make_nv(**kw):
    defaults = dict(entry_size=256, log_entries=256, page_size=PS,
                    read_cache_pages=16, batch_min=10 ** 6, batch_max=10 ** 6,
                    coalesce_deadline_ms=10_000.0)   # nothing flushes by time
    defaults.update(kw)
    pol = Policy(**defaults)
    tier = Tier(DRAM)
    tier.open("/f")
    tier.open("/g")    # pre-exist the test paths: open() then journals no
    #                    create record, keeping the hand-stepped batch
    #                    arithmetic below exactly as authored
    nv = NVCache(pol, tier, track_crashes=True)
    # the detached drain thread below is stepped by hand; stop the pool's
    # own threads so batch boundaries are exactly the test's step() calls
    for th in nv.cleanup.threads:
        th.hard_stop.set()
        th.stop_event.set()
        th.shard.notify_committed()
    for th in nv.cleanup.threads:
        th.join(timeout=10)
    t = CleanupThread(nv.log, nv.log.shards[0], nv._resolve_fdid)
    return nv, tier, t


def step(nv, t):
    """One manual drain batch over everything committed in shard 0."""
    sh = nv.log.shards[0]
    run = sh.committed_run(sh.persistent_tail, nv.policy.batch_max)
    if run:
        t._consume_batch(run)
    return run


ED = 256 - 48   # entry_data


def test_tail_extent_is_carried_not_consumed():
    nv, tier, t = make_nv()
    fd = nv.open("/f")
    f = nv._files["/f"]
    nv.pwrite(fd, b"\x01" * ED, 0)           # entries 0..: page 0, open
    nv.pwrite(fd, b"\x02" * ED, ED)
    step(nv, t)
    # the whole batch fits the open tail page: carried, nothing written
    assert t._span_deferred == 2
    assert tier.open("/f").stats_writes == 0
    assert nv.log.used_entries == 2, "carried entries were consumed"
    assert f.pending.get() == 2, "pending retired before the deferred flush"
    assert f.radix.get(0).dirty_refs == 2, \
        "refs retired before the deferred flush"
    # reads replay the carried entries from the index (not the backend)
    assert nv.pread(fd, 2 * ED, 0) == b"\x01" * ED + b"\x02" * ED
    # a write entering the next page closes the carried extent: one merged
    # backend write covers both batches' page-0 bytes
    nv.pwrite(fd, b"\x03" * (PS - 2 * ED), 2 * ED)   # completes page 0
    nv.pwrite(fd, b"\x04" * 64, PS)                   # opens page 1
    step(nv, t)
    tf = tier.open("/f")
    assert tf.stats_writes == 1 and tf.stats_page_writes == 1
    assert t.stats_span_merges == 1
    assert t._span_deferred == 1                      # page-1 entry carried
    assert f.radix.get(0).dirty_refs == 0
    assert f.radix.get(1).dirty_refs == 1
    snap = tf.snapshot()
    assert snap[:PS] == b"\x01" * ED + b"\x02" * ED + b"\x03" * (PS - 2 * ED)
    nv.shutdown()


def test_deadline_closes_the_carried_extent():
    nv, tier, t = make_nv(coalesce_deadline_ms=10.0)
    fd = nv.open("/f")
    nv.pwrite(fd, b"\x05" * 100, 0)
    step(nv, t)
    assert t._span_deferred == 1
    assert tier.open("/f").stats_writes == 0
    time.sleep(0.02)                          # older than the deadline
    # the drain loop would wake on deadline_at; step the batch by hand
    step(nv, t)
    assert t._span_deferred == 0
    assert tier.open("/f").stats_writes == 1
    assert nv.log.used_entries == 0
    nv.shutdown()


def test_drain_barrier_flushes_the_carry():
    """close/flush/fsync set drain_event: the carried extent must be
    flushed — a drain barrier means 'durably on the slow tier', not
    'parked in the log'."""
    nv, tier, t = make_nv()
    fd = nv.open("/f")
    nv.pwrite(fd, b"\x06" * 200, 0)
    step(nv, t)
    assert t._span_deferred == 1
    t.drain_event.set()
    step(nv, t)
    assert t._span_deferred == 0
    assert tier.open("/f").snapshot()[:200] == b"\x06" * 200
    assert nv.log.used_entries == 0
    nv.shutdown()


def test_noncontiguous_next_batch_flushes_and_recarries():
    nv, tier, t = make_nv()
    fd = nv.open("/f")
    nv.pwrite(fd, b"\x07" * 100, 0)
    step(nv, t)
    assert t._span_deferred == 1
    nv.pwrite(fd, b"\x08" * 100, 5 * PS)      # far away: new open extent
    step(nv, t)
    # the old carry was written; the new tail entry is carried instead
    assert t._span_deferred == 1
    tf = tier.open("/f")
    assert tf.snapshot()[:100] == b"\x07" * 100
    assert len(tf.snapshot()) <= 5 * PS       # the new tail is NOT written yet
    nv.shutdown()


def test_carried_entries_survive_power_loss():
    """Deferred != lost: carried entries are still committed in the log, so
    recovery replays them."""
    nv, tier, t = make_nv()
    fd = nv.open("/f")
    nv.pwrite(fd, b"\x09" * 300, 0)
    step(nv, t)
    assert t._span_deferred >= 1
    assert tier.open("/f").stats_writes == 0  # nothing on the slow tier yet
    nvmm = nv.crash()
    tier2 = Tier(DRAM)
    recover(nvmm, nv.policy, tier2.open)
    assert tier2.open("/f").snapshot()[:300] == b"\x09" * 300
    nv.shutdown() if not nv._crashed else None


def test_choose_deferred_suffix_rules():
    nv, tier, t = make_nv()
    fd = nv.open("/f")
    sh = nv.log.shards[0]
    pol = nv.policy
    # one entry, inside one page -> carried
    nv.pwrite(fd, b"a" * 100, 0)
    assert choose_deferred_suffix(sh, sh.persistent_tail, 1, pol) == 1
    # second entry contiguous, still inside page 0 -> both carried
    nv.pwrite(fd, b"b" * 100, 100)
    assert choose_deferred_suffix(sh, sh.persistent_tail, 2, pol) == 2
    # an entry crossing into page 1 cuts the carry at the crossing group
    nv.pwrite(fd, b"c" * (PS - 100), 200)     # multi-entry group, crosses
    run = sh.committed_run(sh.persistent_tail, pol.batch_max)
    assert choose_deferred_suffix(sh, sh.persistent_tail, run, pol) == 0
    # a fresh entry cleanly inside page 1 is carried again
    nv.pwrite(fd, b"d" * 50, PS + 100)
    run = sh.committed_run(sh.persistent_tail, pol.batch_max)
    assert choose_deferred_suffix(sh, sh.persistent_tail, run, pol) == 1
    # a different file's entry breaks the suffix walk
    fd2 = nv.open("/g")
    nv.pwrite(fd2, b"e" * 50, PS + 150)       # contiguous bytes, other file
    run = sh.committed_run(sh.persistent_tail, pol.batch_max)
    assert choose_deferred_suffix(sh, sh.persistent_tail, run, pol) == 1
    nv.shutdown()


def test_span_disabled_never_defers():
    nv, tier, t = make_nv(coalesce_span_batches=False)
    fd = nv.open("/f")
    nv.pwrite(fd, b"\x0A" * 100, 0)
    step(nv, t)
    assert t._span_deferred == 0
    assert tier.open("/f").stats_writes == 1
    nv.shutdown()


def test_space_pressure_disables_the_carry():
    nv, tier, t = make_nv(log_entries=8)      # tiny shard
    fd = nv.open("/f")
    nv.pwrite(fd, b"\x0B" * 800, 0)           # 4 entries: shard half full
    step(nv, t)
    assert t._span_deferred == 0, "carried while writers may be blocked"
    assert nv.log.used_entries == 0
    nv.shutdown()


@pytest.mark.parametrize("k", [1, 2])
def test_trickle_workload_end_to_end(k):
    """Real pool threads, trickling contiguous 1 KiB writes: with the carry
    each backend page is written ~once; without it, ~once per batch."""
    writes, bs = 24, 256
    results = {}
    for span in (False, True):
        pol = Policy(entry_size=bs + 48, log_entries=256 * k, page_size=PS,
                     read_cache_pages=16, batch_min=1, batch_max=64,
                     shards=k, shard_route="fdid",
                     coalesce_span_batches=span, coalesce_deadline_ms=500.0)
        tier = Tier(DRAM)
        nv = NVCache(pol, tier)
        fd = nv.open("/t")
        for i in range(writes):
            nv.pwrite(fd, bytes([i + 1]) * bs, i * bs)
            time.sleep(0.003)                 # drain sees tiny batches
        nv.flush()
        tf = tier.open("/t")
        assert tf.snapshot()[:writes * bs] == b"".join(
            bytes([i + 1]) * bs for i in range(writes))
        assert nv.log.used_entries == 0
        results[span] = tf.stats_page_writes
        if span:
            assert nv.stats()["drain_deferred"] > 0
        nv.shutdown()
    pages = writes * bs // PS
    assert results[True] <= pages + 3, results
    assert results[False] >= 2 * results[True], results
