"""GPipe pipeline over a stage axis == sequential layer application
(forward AND gradients), on 4 fake devices in a subprocess."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    from repro.parallel.pipeline import pipeline_apply, split_stages

    L, D, M, MB = 8, 16, 6, 4           # 8 layers -> 4 stages of 2
    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (L, D, D)) / jnp.sqrt(D)
    bs = jax.random.normal(jax.random.PRNGKey(1), (L, D)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(2), (M, MB, D))

    def layer(w, b, h):
        return jnp.tanh(h @ w + b)

    def seq(params, x):
        Ws, bs = params
        def body(h, wb):
            return layer(wb[0], wb[1], h), None
        h, _ = jax.lax.scan(body, x, (Ws, bs))
        return h

    ref = jax.vmap(lambda mb: seq((Ws, bs), mb))(x)

    mesh = jax.make_mesh((4,), ("stage",))
    stage_params = split_stages((Ws, bs), 4)

    def stage_fn(params, h):
        sW, sb = params
        def body(hh, wb):
            return layer(wb[0], wb[1], hh), None
        hh, _ = jax.lax.scan(body, h, (sW, sb))
        return hh

    with mesh:
        out = jax.jit(lambda p, x: pipeline_apply(mesh, "stage", stage_fn, p, x))(
            stage_params, x)
        # gradients flow through the schedule
        def loss(p, x):
            return jnp.sum(pipeline_apply(mesh, "stage", stage_fn, p, x) ** 2)
        g = jax.jit(jax.grad(loss))(stage_params, x)
        gref = jax.grad(lambda p, x: jnp.sum(
            jax.vmap(lambda mb: seq(p, mb))(x) ** 2))((Ws, bs), x)

    fwd_err = float(jnp.max(jnp.abs(out - ref)))
    gW = g[0].reshape(Ws.shape)
    g_err = float(jnp.max(jnp.abs(gW - gref[0])))
    print(json.dumps({"fwd_err": fwd_err, "grad_err": g_err}))
""")


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["fwd_err"] < 1e-5, res
    assert res["grad_err"] < 1e-4, res
