"""Planted-bug suite for the persistence-ordering sanitizer (repro.analysis).

Two halves, one acceptance bar each:

* **zero false positives** — every REAL commit protocol in the engine
  (log group commit, frame flip, route record, consume/retire) runs clean
  under an attached :class:`~repro.analysis.pmcheck.PMCheck`;
* **zero false negatives** — deterministic mutations of those protocols
  (one pwb dropped, a fence reordered, a store slipped into the commit
  window, the commit flush omitted) each trip exactly the expected error
  code.

The planted sequences mirror ``LogShard.append`` / ``PagedRegion
.frame_write`` / ``EpochRouter._persist_locked`` byte-for-byte, minus the
one mutation under test, so a future protocol change that breaks the
mirror shows up as a planted test failing to plant (asserting the code
fired catches that too).
"""
import struct
import threading
import zlib

import pytest

from repro.analysis import pmcheck
from repro.core import Policy
from repro.core.log import CG_FREE, CG_HEAD, HDR_SIZE, NVLog, _HDR
from repro.core.nvmm import NVMM
from repro.core.pager import FR_MAPPED, PagedRegion, _FR
from repro.core.policy import CACHELINE, FRAME_HDR, ROUTE_HDR
from repro.core.router import EpochRouter, _RT_ENT, _RT_HDR


def mk(frames: int = 0):
    pol = Policy(entry_size=256, log_entries=64, page_size=256,
                 read_cache_pages=4, batch_min=1, batch_max=8,
                 page_frames=frames)
    nvmm = NVMM(pol.nvmm_bytes, track=True)
    log = NVLog(nvmm, pol)                    # formats the region
    pm = pmcheck.attach(nvmm, pol)            # shadow starts all-durable
    return nvmm, pol, log, pm


def codes(pm):
    return [v.code for v in pm.violations]


# ---------------------------------------------------------------- real paths


def test_real_log_append_single_and_group_clean():
    nvmm, pol, log, pm = mk()
    log.append(1, 0, b"x" * 16)                      # single entry
    log.append(1, 0, b"y" * (pol.entry_data * 3))    # head + 2 followers
    assert codes(pm) == []
    assert pm.stats_commits == 2


def test_real_consume_clean():
    nvmm, pol, log, pm = mk()
    for i in range(4):
        log.append(1, i * 8, bytes([i]) * 8)
    log.shards[0].consume(0, 4)
    assert codes(pm) == []


def test_real_frame_flip_clean():
    nvmm, pol, log, pm = mk(frames=4)
    pager = PagedRegion(nvmm, pol, log.next_seq)
    idx = pager.alloc(1, 0)
    pager.frame_write(idx, 1, 0, 0, 64, b"a" * 64, b"", 0)    # fresh frame
    pager.frame_write(idx, 1, 0, 32, 96, b"b" * 64, None, 0)  # slot flip
    pager.truncate_frame(idx, 48)
    pager.invalidate([idx])
    assert codes(pm) == []
    assert pm.stats_commits == 3          # truncate reseals, invalidate frees


def test_real_route_record_clean():
    nvmm, pol, log, pm = mk()
    router = EpochRouter(nvmm, pol, sampling=False)
    assert router.install(3, 0)
    assert codes(pm) == []
    assert pm.stats_commits == 1


# ------------------------------------------------------------- planted: log


def plant_group(nvmm, pol, *, skip_follower_pwb=False, skip_fence=False,
                pwb_after_fence=False, skip_commit_pwb=False,
                store_mid=False, double_pwb=False):
    """Mirror of ``LogShard.append`` for a 2-entry group at slots 0/1 with
    exactly one mutation enabled."""
    base = pol.shard_base(0)
    data0, data1 = b"h" * 32, b"f" * 32

    def fill(slot, cg, data):
        eoff = base + slot * pol.entry_size
        crc = zlib.crc32(data)
        nvmm.store(eoff, _HDR.pack(cg, 7, slot * 32, 1, len(data), 0, crc))
        nvmm.store(eoff + HDR_SIZE, data)
        return eoff

    e1 = fill(1, 2, data1)                      # follower (cg = head + 2)
    if not skip_follower_pwb and not pwb_after_fence:
        nvmm.pwb(e1, HDR_SIZE + len(data1))
    e0 = fill(0, CG_FREE, data0)                # head, uncommitted
    nvmm.store(e0 + 32, struct.pack("<I", 1))   # patch nfollow
    nvmm.pwb(e0, HDR_SIZE + len(data0))
    if double_pwb:
        nvmm.pwb(e0, HDR_SIZE + len(data0))     # covers no new dirty line
    if not skip_fence:
        nvmm.pfence()
    if pwb_after_fence:
        nvmm.pwb(e1, HDR_SIZE + len(data1))     # too late: nothing fences it
    nvmm.store_u64(e0, CG_HEAD)                 # commit the group
    if store_mid:
        nvmm.store(e1 + HDR_SIZE, b"Z" * 8)     # rides the open commit
    if not skip_commit_pwb:
        nvmm.pwb(e0, 8)
    nvmm.psync()


def test_planted_log_control_is_clean():
    nvmm, pol, log, pm = mk()
    plant_group(nvmm, pol)
    assert codes(pm) == []
    assert pm.stats_commits == 1


def test_planted_missing_follower_pwb_is_pm001():
    nvmm, pol, log, pm = mk()
    plant_group(nvmm, pol, skip_follower_pwb=True)
    assert codes(pm) == ["PM001"]


def test_planted_missing_fence_is_pm001():
    nvmm, pol, log, pm = mk()
    plant_group(nvmm, pol, skip_fence=True)
    assert codes(pm) == ["PM001"]


def test_planted_pwb_reordered_after_fence_is_pm001():
    nvmm, pol, log, pm = mk()
    plant_group(nvmm, pol, pwb_after_fence=True)
    assert codes(pm) == ["PM001"]


def test_planted_store_inside_commit_window_is_pm002():
    nvmm, pol, log, pm = mk()
    plant_group(nvmm, pol, store_mid=True)
    assert codes(pm) == ["PM002"]


def test_planted_missing_commit_pwb_is_pm004():
    nvmm, pol, log, pm = mk()
    plant_group(nvmm, pol, skip_commit_pwb=True)
    assert codes(pm) == ["PM004"]


def test_planted_redundant_pwb_is_diagnostic_not_error():
    nvmm, pol, log, pm = mk()
    plant_group(nvmm, pol, double_pwb=True)
    assert codes(pm) == []
    assert pm.diag_redundant_pwb == 1
    nvmm.pfence()                               # nothing requested: empty
    assert pm.diag_empty_fence == 1


# ----------------------------------------------------------- planted: frame


def plant_frame(nvmm, pol, *, skip_image_pwb=False, skip_fence=False):
    fb = pol.frame_base(0)
    img = b"q" * 96
    doff = fb + FRAME_HDR
    nvmm.store(doff, img)
    if not skip_image_pwb:
        nvmm.pwb(doff, len(img))
    if not skip_fence:
        nvmm.pfence()
    nvmm.store(fb, _FR.pack(FR_MAPPED, 0, 5, 9, 1, len(img),
                            zlib.crc32(img)))
    nvmm.pwb(fb, _FR.size)
    nvmm.psync()


def test_planted_frame_control_is_clean():
    nvmm, pol, log, pm = mk(frames=4)
    plant_frame(nvmm, pol)
    assert codes(pm) == []
    assert pm.stats_commits == 1


def test_planted_frame_missing_image_pwb_is_pm001():
    nvmm, pol, log, pm = mk(frames=4)
    plant_frame(nvmm, pol, skip_image_pwb=True)
    assert codes(pm) == ["PM001"]


def test_planted_frame_missing_fence_is_pm001():
    nvmm, pol, log, pm = mk(frames=4)
    plant_frame(nvmm, pol, skip_fence=True)
    assert codes(pm) == ["PM001"]


# ----------------------------------------------------------- planted: route


def plant_route(nvmm, pol, *, skip_fence=False):
    base = pol.route_base
    payload = _RT_ENT.pack(3, 0)
    nvmm.store(base + ROUTE_HDR, payload)
    nvmm.pwb(base + ROUTE_HDR, len(payload))
    if not skip_fence:
        nvmm.pfence()
    crc = zlib.crc32(payload + struct.pack("<QI", 1, 1))
    nvmm.store(base, _RT_HDR.pack(1, 1, crc))
    nvmm.pwb(base, ROUTE_HDR)
    nvmm.psync()


def test_planted_route_control_is_clean():
    nvmm, pol, log, pm = mk()
    plant_route(nvmm, pol)
    assert codes(pm) == []
    assert pm.stats_commits == 1


def test_planted_route_missing_fence_is_pm001():
    nvmm, pol, log, pm = mk()
    plant_route(nvmm, pol, skip_fence=True)
    assert codes(pm) == ["PM001"]


# -------------------------------------------------------------- suppression


def test_allow_set_suppresses_code():
    pol = Policy(entry_size=256, log_entries=64, page_size=256,
                 read_cache_pages=4)
    nvmm = NVMM(pol.nvmm_bytes, track=True)
    NVLog(nvmm, pol)
    pm = pmcheck.attach(nvmm, pol, allow={"PM001"})
    plant_group(nvmm, pol, skip_fence=True)
    assert codes(pm) == []


def test_crash_discards_open_windows():
    nvmm, pol, log, pm = mk()
    base = pol.shard_base(0)
    nvmm.store(base + HDR_SIZE, b"p" * 16)       # dirty, unfenced payload
    nvmm.crash()                                  # power loss mid-protocol
    plant_group(nvmm, pol)                        # fresh protocol run: clean
    assert codes(pm) == []


# ------------------------------------------------- NVMM fence/pwb race (core)


def test_drain_requested_survives_concurrent_pwb():
    """Regression: ``NVMM._drain_requested`` iterated ``_requested`` while
    a concurrent ``pwb`` mutated it ("Set changed size during iteration"
    out of the crash-fuse sweeps under --sanitize).  A fence over a long
    requested set racing a store+pwb loop killed the old code within a
    handful of reps at a short switch interval."""
    import sys
    nvmm = NVMM(1024 * CACHELINE, track=True)
    stop = threading.Event()
    errors = []

    def writer():
        i = 0
        try:
            while not stop.is_set():
                off = (i % 1000) * CACHELINE
                nvmm.store(off, b"w" * 8)
                nvmm.pwb(off, 8)
                i += 1
        except RuntimeError as e:          # pragma: no cover - pre-fix path
            errors.append(e)
            stop.set()

    prev = sys.getswitchinterval()
    sys.setswitchinterval(1e-6)
    t = threading.Thread(target=writer)
    t.start()
    try:
        for _ in range(60):
            if stop.is_set():
                break
            for j in range(200):
                nvmm.store(j * CACHELINE, b"m" * 8)
                nvmm.pwb(j * CACHELINE, 8)
            nvmm.psync()
    except RuntimeError as e:              # pragma: no cover - pre-fix path
        errors.append(e)
    finally:
        stop.set()
        t.join()
        sys.setswitchinterval(prev)
    assert not errors
