"""Data pipeline determinism/resume; optimizer behaviour; grad compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import FileBackedTokens, SyntheticTokens
from repro.optim import grad_compress
from repro.optim.adamw import AdamW, apply_updates, global_norm
from repro.optim.schedules import warmup_cosine
from repro.storage.fsapi import TierFS
from repro.storage.tiers import DRAM, Tier


def test_pipeline_deterministic_and_resumable():
    p1 = SyntheticTokens(1000, 2, 16, seed=5)
    a = [p1.next()["tokens"] for _ in range(4)]
    p2 = SyntheticTokens(1000, 2, 16, seed=5)
    for _ in range(2):
        p2.next()
    state = p2.state()
    p3 = SyntheticTokens(1000, 2, 16, seed=5)
    p3.load_state(state)
    np.testing.assert_array_equal(a[2], p3.next()["tokens"])


def test_pipeline_state_through_fs():
    fs = TierFS(Tier(DRAM))
    p = SyntheticTokens(1000, 2, 16, seed=1)
    p.next(); p.next()
    p.save_state(fs)
    q = SyntheticTokens(1000, 2, 16, seed=1)
    assert q.restore_state(fs)
    np.testing.assert_array_equal(p.next()["tokens"], q.next()["tokens"])


def test_file_backed_tokens():
    fs = TierFS(Tier(DRAM))
    tok = np.arange(100, dtype=np.int32)
    FileBackedTokens.write_shard(fs, "/shard0", tok[:60])
    FileBackedTokens.write_shard(fs, "/shard1", tok[60:])
    p = FileBackedTokens(fs, ["/shard0", "/shard1"], batch=2, seq=8)
    b = p.next()["tokens"]
    assert b.shape == (2, 8)
    assert set(b.reshape(-1)).issubset(set(tok.tolist()))


def test_adamw_converges_on_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        upd, state, _ = opt.update(grads, state, params)
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_grad_clip_and_norm():
    opt = AdamW(lr=0.1, clip_norm=1.0)
    params = {"x": jnp.zeros(3)}
    state = opt.init(params)
    upd, state, m = opt.update({"x": jnp.full(3, 100.0)}, state, params)
    assert float(m["grad_norm"]) > 100
    assert float(global_norm({"x": jnp.full(3, 100.0)})) == float(m["grad_norm"])


def test_schedule_shapes():
    f = warmup_cosine(10, 100)
    assert float(f(jnp.int32(0))) == 0.0
    assert abs(float(f(jnp.int32(10))) - 1.0) < 1e-6
    assert float(f(jnp.int32(100))) <= 0.11


def test_grad_compress_bounded_error():
    g = {"a": jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3,
         "b": jax.random.normal(jax.random.PRNGKey(1), (7, 13))}
    gc = grad_compress.compress_tree(g)
    for k in g:
        scale = jnp.abs(g[k]).max() / 127
        assert float(jnp.abs(gc[k] - g[k]).max()) <= float(scale) * 1.01 + 1e-6
