"""Durable namespace subsystem (PR 5 tentpole): logged metadata ops with
crash-consistent create/rename/unlink/ftruncate.

Covers the three layers of the protocol:

* API semantics — rename replaces, unlink removes, ftruncate cuts/grows,
  EBUSY on open files, ENOENT without O_CREAT, and the read path stays
  full-scan-free throughout;
* drain coordination — metadata entries are consumed (the log empties)
  only after their backend effect is applied, and the batch-spanning
  carry never holds one back;
* crash consistency — a fuse wired into the NVMM kills the run at EVERY
  persistence-protocol point of a metadata op sequence; after recovery
  the namespace must be *old-or-new, never torn*: unlinked files never
  resurrect, renamed data is attributed to exactly one name, a lost
  kernel create is restored from the log.
"""
import os
import threading

import pytest

from repro.core import NVCache, Policy, recover
from repro.storage.tiers import DRAM, Tier
from test_sharded_recovery import FusedNVMM, PowerLoss


class ThreadFusedNVMM(FusedNVMM):
    """Fuse that ticks (and blows) only on the constructing thread: the
    app-visible crash point is deterministic, while the drain threads —
    whose progress at that instant is inherently racy — keep running until
    the crash itself, exactly like real power loss."""

    def __init__(self, size, *, track=False):
        super().__init__(size, track=track)
        self._owner = threading.get_ident()

    def _tick(self):
        if threading.get_ident() != self._owner:
            return
        super()._tick()

POL = Policy(entry_size=256, log_entries=128, page_size=256,
             read_cache_pages=8, batch_min=4, batch_max=16)
POL_NODRAIN = Policy(entry_size=256, log_entries=128, page_size=256,
                     read_cache_pages=8, batch_min=10 ** 6, batch_max=10 ** 6)


def clone_tier(tier, *, drop=(), ns_seq=None):
    """The backend state an instant after the crash.  ``drop`` + ``ns_seq``
    simulate a kernel that lost a *suffix* of namespace updates (files
    created/renamed after the last directory sync): the dropped files
    disappear and the applied watermark rolls back with them — recovery
    must then rebuild exactly that suffix from the NVMM log."""
    t2 = Tier(DRAM)
    for p in tier.paths():
        if p in drop:
            continue
        snap = tier.open(p).snapshot()
        f2 = t2.open(p)
        if snap:
            f2.pwrite(snap, 0)
    t2.ns_seq = tier.ns_seq if ns_seq is None else ns_seq
    return t2


# ------------------------------------------------------------- API semantics
def test_rename_moves_data_and_replaces_destination():
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    fd = nv.open("/a")
    nv.pwrite(fd, b"payload-a", 0)
    nv.close(fd)
    fd = nv.open("/b")
    nv.pwrite(fd, b"old-b", 0)
    nv.close(fd)
    nv.rename("/a", "/b")
    assert not tier.exists("/a")
    fd = nv.open("/b", os.O_RDONLY)
    assert nv.pread(fd, 16, 0) == b"payload-a"
    nv.close(fd)
    with pytest.raises(FileNotFoundError):
        nv.stat_size("/a")
    nv.shutdown()


def test_unlink_removes_and_reopen_starts_fresh():
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    fd = nv.open("/f")
    nv.pwrite(fd, b"\xAA" * 600, 0)
    nv.close(fd)
    nv.unlink("/f")
    assert not tier.exists("/f")
    with pytest.raises(FileNotFoundError):
        nv.unlink("/f")
    fd = nv.open("/f")                       # re-create
    assert nv.stat_size(fd) == 0
    assert nv.pread(fd, 600, 0) == b""
    nv.pwrite(fd, b"new", 0)
    nv.flush()
    assert tier.open("/f").snapshot() == b"new"
    nv.shutdown()


def test_rename_refuses_open_files_unlink_goes_anonymous():
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    fd = nv.open("/f")
    nv.open("/g")
    nv.open("/x2")
    with pytest.raises(OSError, match="EBUSY"):
        nv.rename("/f", "/x")
    with pytest.raises(OSError, match="EBUSY"):
        nv.rename("/x2", "/g")               # busy destination
    # POSIX unlink-while-open: the NAME goes now, the file stays usable
    # through the open fd until its last close
    nv.pwrite(fd, b"still-mine", 0)
    nv.unlink("/f")
    assert not tier.exists("/f")
    with pytest.raises(FileNotFoundError):
        nv.stat_size("/f")
    assert nv.pread(fd, 10, 0) == b"still-mine"   # fd still works
    nv.pwrite(fd, b"!", 10)
    assert nv.pread(fd, 11, 0) == b"still-mine!"
    nv.close(fd)                             # last close reclaims it
    nv.flush()
    assert not tier.exists("/f")
    # the fdid was reclaimed: re-creating the path starts fresh
    fd2 = nv.open("/f")
    assert nv.stat_size(fd2) == 0
    nv.shutdown()


def test_unlink_while_open_dies_on_crash():
    """POSIX: an unlinked-but-open file is gone after a crash — including
    its post-unlink writes (no resurrection under the dead name)."""
    tier = Tier(DRAM)
    nv = NVCache(POL_NODRAIN, tier, track_crashes=True)
    fd = nv.open("/hot-journal")
    nv.pwrite(fd, b"j" * 400, 0)
    nv.unlink("/hot-journal")
    nv.pwrite(fd, b"after-unlink", 0)        # still-open fd keeps writing
    nvmm = nv.crash()
    tier2 = clone_tier(tier)
    stats = recover(nvmm, POL_NODRAIN, tier2)
    assert not tier2.exists("/hot-journal"), "unlinked file resurrected"
    assert stats.entries_replayed == 0, "orphan entries reached a backend"


def test_open_without_ocreat_raises_enoent():
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    with pytest.raises(FileNotFoundError):
        nv.open("/missing", os.O_RDONLY)
    with pytest.raises(FileNotFoundError):
        nv.open("/missing", os.O_RDWR)
    assert not tier.exists("/missing"), "failed open created a phantom"
    nv.shutdown()


def test_ftruncate_shrinks_purges_and_grows():
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    fd = nv.open("/f")
    nv.pwrite(fd, bytes(range(1, 255)) * 3, 0)        # 762 bytes, 3 pages
    assert nv.pread(fd, 762, 0) == bytes(range(1, 255)) * 3   # cache pages
    nv.ftruncate(fd, 300)
    assert nv.stat_size(fd) == 300
    assert nv.pread(fd, 1000, 0) == (bytes(range(1, 255)) * 3)[:300]
    # grow: zero-filled hole, cut bytes must NOT reappear
    nv.ftruncate(fd, 700)
    assert nv.stat_size(fd) == 700
    got = nv.pread(fd, 1000, 0)
    assert got[:300] == (bytes(range(1, 255)) * 3)[:300]
    assert not any(got[300:]), "cut bytes resurrected after grow"
    nv.flush()
    snap = tier.open("/f").snapshot()
    assert snap[:300] == (bytes(range(1, 255)) * 3)[:300]
    assert not any(snap[300:])
    nv.shutdown()


def test_ftruncate_readonly_and_negative():
    nv = NVCache(POL, Tier(DRAM))
    fd = nv.open("/f")
    nv.pwrite(fd, b"x", 0)
    nv.close(fd)
    ro = nv.open("/f", os.O_RDONLY)
    with pytest.raises(OSError):
        nv.ftruncate(ro, 0)
    rw = nv.open("/f")
    with pytest.raises(OSError):
        nv.ftruncate(rw, -1)
    nv.shutdown()


def test_rename_same_name_and_missing_source():
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    with pytest.raises(FileNotFoundError):
        nv.rename("/nope", "/x")
    fd = nv.open("/a")
    nv.close(fd)
    nv.rename("/a", "/a")                    # no-op, must not deadlock
    assert tier.exists("/a")
    nv.shutdown()


# --------------------------------------------------------- drain coordination
def test_meta_entries_drain_and_log_empties():
    tier = Tier(DRAM)
    nv = NVCache(POL, tier)
    for i in range(6):
        fd = nv.open(f"/f{i}")
        nv.pwrite(fd, b"d" * 100, 0)
        nv.close(fd)
    nv.rename("/f0", "/g0")
    nv.unlink("/f1")
    fd = nv.open("/f2")
    nv.ftruncate(fd, 10)
    nv.close(fd)
    nv.flush()
    assert nv.log.used_entries == 0, "metadata entries were not consumed"
    s = nv.stats()
    assert s["meta_ops"]["create"] == 6
    assert s["meta_ops"]["rename"] == 1
    assert s["meta_ops"]["unlink"] == 1
    assert s["meta_ops"]["ftruncate"] == 1
    nv.shutdown()


def test_unlink_after_undrained_writes_never_resurrects():
    """Undrained data + unlink: the barrier inside unlink drains first, so
    neither the drain nor crash recovery can bring the bytes back."""
    tier = Tier(DRAM)
    nv = NVCache(POL_NODRAIN, tier, track_crashes=True)
    fd = nv.open("/f")
    nv.pwrite(fd, b"\xBB" * 700, 0)
    nv.close(fd)
    assert tier.open("/f").snapshot()[:700] == b"\xBB" * 700  # close drained
    nv.unlink("/f")
    assert not tier.exists("/f")
    nvmm = nv.crash()
    tier2 = clone_tier(tier)
    recover(nvmm, POL_NODRAIN, tier2)
    assert not tier2.exists("/f"), "recovery resurrected an unlinked file"


def test_lost_create_is_restored_from_the_log():
    """The load-bearing case for journaled creates: the kernel loses the
    directory entry of a just-created (never-fsynced) file; recovery must
    restore it from the metadata record — with its data."""
    tier = Tier(DRAM)
    nv = NVCache(POL_NODRAIN, tier, track_crashes=True)
    fd = nv.open("/new-empty")
    nv.close(fd)
    fd = nv.open("/new-data")
    nv.pwrite(fd, b"must-survive", 0)
    nvmm = nv.crash()
    # the kernel lost both creates: files gone, watermark rolled back
    tier2 = clone_tier(tier, drop={"/new-empty", "/new-data"}, ns_seq=0)
    recover(nvmm, POL_NODRAIN, tier2)
    assert tier2.exists("/new-empty"), "lost create not replayed"
    assert tier2.open("/new-data").snapshot()[:12] == b"must-survive"


def test_recovery_attributes_renamed_data_to_one_name_only():
    tier = Tier(DRAM)
    nv = NVCache(POL_NODRAIN, tier, track_crashes=True)
    fd = nv.open("/a")
    nv.pwrite(fd, b"A" * 300, 0)
    nv.close(fd)
    pre_rename_seq = tier.ns_seq             # watermark before the rename
    nv.rename("/a", "/b")
    fd = nv.open("/b")
    nv.pwrite(fd, b"Z", 0)                   # post-rename write, undrained
    nvmm = nv.crash()
    import copy
    nvmm2 = copy.deepcopy(nvmm)              # recover() reformats the log
    # adversarial: the kernel lost the rename (directory never synced) —
    # the old name survives, the new one is gone, the watermark rolled
    # back.  Recovery must rebuild the rename from the log.
    tier2 = clone_tier(tier, drop={"/b"}, ns_seq=pre_rename_seq)
    tier2.open("/a").pwrite(b"A" * 300, 0)   # pre-rename directory state
    recover(nvmm, POL_NODRAIN, tier2)
    assert not tier2.exists("/a"), "data attributed to the old name"
    snap = tier2.open("/b").snapshot()
    assert snap[:1] == b"Z" and snap[1:300] == b"A" * 299
    # the surviving-kernel-state variant: nothing lost, same outcome
    tier3 = clone_tier(tier)
    recover(nvmm2, POL_NODRAIN, tier3)
    assert not tier3.exists("/a")
    snap = tier3.open("/b").snapshot()
    assert snap[:1] == b"Z" and snap[1:300] == b"A" * 299


# --------------------------------------------------- every-fuse-point crashes
def _meta_script(nv):
    """A metadata-heavy op sequence; yields (event, state) checkpoints.

    Returns the list of *acknowledged* logical states, each a dict
    path -> bytes of the expected durable image."""
    states = []
    fd = nv.open("/j")                       # create
    nv.pwrite(fd, b"J" * 300, 0)
    nv.close(fd)
    states.append({"/j": b"J" * 300})
    nv.rename("/j", "/k")                    # rename over nothing
    states.append({"/k": b"J" * 300})
    fd = nv.open("/j")                       # re-create old name
    nv.pwrite(fd, b"2" * 100, 0)
    nv.close(fd)
    states.append({"/k": b"J" * 300, "/j": b"2" * 100})
    fd = nv.open("/k")
    nv.ftruncate(fd, 50)                     # cut
    nv.close(fd)
    states.append({"/k": b"J" * 50, "/j": b"2" * 100})
    nv.rename("/j", "/k")                    # rename over existing
    states.append({"/k": b"2" * 100})
    nv.unlink("/k")                          # unlink
    states.append({})
    return states


def _count_script_ops(pol):
    dry = ThreadFusedNVMM(pol.nvmm_bytes)
    nv = NVCache(pol, Tier(DRAM), nvmm=dry, recover=False)
    dry.ops = 0
    _meta_script(nv)
    total = dry.ops
    nv.cleanup.power_loss()
    return total


def _legal(observed, states):
    for st in states:
        ok = set(observed) == set(st)
        if ok:
            for p, want in st.items():
                got = observed[p]
                if not (got[:len(want)] == want and not any(got[len(want):])):
                    ok = False
                    break
        if ok:
            return True
    return False


@pytest.mark.parametrize("k", [1, 2, 4])
def test_every_fuse_point_leaves_namespace_old_or_new(k):
    """Crash at EVERY NVMM persistence-protocol point of the metadata
    script: recovery must observe one of the acknowledged states (the
    in-flight op applied whole or not at all) — never a torn namespace."""
    pol = Policy(entry_size=256, log_entries=128 * k, page_size=256,
                 read_cache_pages=8, batch_min=10 ** 6, batch_max=10 ** 6,
                 shards=k, shard_route="fdid")
    total = _count_script_ops(pol)
    checked = 0
    for fuse in range(0, total + 1, 3):      # every 3rd point: full protocol
        #                                      coverage at tolerable runtime
        nvmm = ThreadFusedNVMM(pol.nvmm_bytes, track=True)
        tier = Tier(DRAM)
        nv = NVCache(pol, tier, nvmm=nvmm, recover=False, track_crashes=True)
        nvmm.arm(fuse)
        states = None
        try:
            states = _meta_script(nv)
        except PowerLoss:
            pass
        nvmm._fuse = None
        nv._crashed = True
        nv.cleanup.power_loss()
        nvmm.crash()                         # nothing un-flushed survives
        tier2 = clone_tier(tier)
        recover(nvmm, pol, tier2)
        observed = {p: tier2.open(p).snapshot() for p in tier2.paths()}
        # legal = any prefix state: ops are acknowledged one at a time, and
        # the crash may sit before or after the in-flight op's commit point
        all_states = [{}]
        full = _meta_script_states()
        all_states.extend(full)
        assert _legal(observed, all_states), \
            (f"k={k} fuse={fuse}: torn namespace {observed!r}")
        if states is not None:
            # script completed: the final state must be the observed one
            assert _legal(observed, [full[-1]])
        checked += 1
    assert checked > 10


def _meta_script_states():
    """Every state an op boundary can leave behind — each create/pwrite/
    rename/ftruncate/unlink is individually atomic and synchronously
    durable, so the crash may sit between ANY two of them (a created-but-
    not-yet-written file is legally empty)."""
    return [
        {"/j": b""},                              # created
        {"/j": b"J" * 300},                       # written
        {"/k": b"J" * 300},                       # renamed
        {"/k": b"J" * 300, "/j": b""},            # old name re-created
        {"/k": b"J" * 300, "/j": b"2" * 100},
        {"/k": b"J" * 50, "/j": b"2" * 100},      # ftruncate 50
        {"/k": b"2" * 100},                       # rename over existing
        {},                                       # unlinked
    ]


def test_fuse_mid_meta_commit_is_old_or_new_dense():
    """Dense (every single fuse point) sweep over a short rename+unlink
    script, K=2: the commit flag of the metadata group is the atomic
    switch."""
    pol = Policy(entry_size=256, log_entries=256, page_size=256,
                 read_cache_pages=8, batch_min=10 ** 6, batch_max=10 ** 6,
                 shards=2, shard_route="fdid")

    def script(nv):
        fd = nv.open("/m")
        nv.pwrite(fd, b"M" * 100, 0)
        nv.close(fd)
        nv.rename("/m", "/n")
        nv.unlink("/n")

    dry = ThreadFusedNVMM(pol.nvmm_bytes)
    nv = NVCache(pol, Tier(DRAM), nvmm=dry, recover=False)
    dry.ops = 0
    script(nv)
    total = dry.ops
    nv.cleanup.power_loss()

    legal = [{}, {"/m": b""}, {"/m": b"M" * 100}, {"/n": b"M" * 100}]
    for fuse in range(total + 1):
        nvmm = ThreadFusedNVMM(pol.nvmm_bytes, track=True)
        tier = Tier(DRAM)
        nv = NVCache(pol, tier, nvmm=nvmm, recover=False, track_crashes=True)
        nvmm.arm(fuse)
        try:
            script(nv)
        except PowerLoss:
            pass
        nvmm._fuse = None
        nv._crashed = True
        nv.cleanup.power_loss()
        nvmm.crash()
        tier2 = clone_tier(tier)
        stats = recover(nvmm, pol, tier2)
        observed = {p: tier2.open(p).snapshot() for p in tier2.paths()}
        assert _legal(observed, legal), \
            f"fuse={fuse}: torn namespace {observed!r} ({stats})"


def test_write_racing_unlink_commit_cannot_resurrect_the_path():
    """Crash in the window between the MOP_UNLINK record committing and
    the fd-table slot clearing, with a writer racing the unlink: the
    post-unlink data group must NOT re-create the dead path holding only
    the racing write's bytes (recovery's dead-fdid barrier).  Reproduced
    deterministically by journaling the unlink record without the
    slot-clear (the crash lands exactly there)."""
    from repro.core.log import MOP_UNLINK
    tier = Tier(DRAM)
    nv = NVCache(POL_NODRAIN, tier, track_crashes=True)
    fd = nv.open("/f")
    nv.pwrite(fd, b"pre" * 20, 0)
    # the unlink record commits (durable), but the crash preempts both the
    # fd-table clear and the backend apply...
    marks, _seq = nv.ns.journal_locked(MOP_UNLINK, nv._of(fd).file.fdid, 0, "/f")
    nv.ns.mark_applied(marks)
    # ...while a racing writer's group commits at a higher seq
    nv.pwrite(fd, b"RACE", 0)
    nvmm = nv.crash()
    tier2 = clone_tier(tier)
    stats = recover(nvmm, POL_NODRAIN, tier2)
    assert not tier2.exists("/f"), \
        "racing write resurrected the unlinked path"
    assert stats.unlinked_dropped >= 1


def test_fdid_reuse_after_unlink_is_not_dropped_by_the_barrier():
    """The dead-fdid barrier must lift when the fdid is re-bound: data of
    a file that legitimately reuses the unlinked file's fdid (same path,
    via a journaled re-create) survives recovery even while the old unlink
    record is still in the log."""
    tier = Tier(DRAM)
    nv = NVCache(POL_NODRAIN, tier, track_crashes=True)
    fd = nv.open("/f")
    nv.pwrite(fd, b"old", 0)
    nv.close(fd)                            # drains: fdid reclaimable
    nv.unlink("/f")                         # record stays in the log
    fd2 = nv.open("/f")                     # re-create: reuses the fdid
    assert nv._of(fd2).file.fdid == 0       # same (first) fdid slot
    nv.pwrite(fd2, b"NEW", 0)
    nvmm = nv.crash()
    tier2 = clone_tier(tier)
    stats = recover(nvmm, POL_NODRAIN, tier2)
    assert tier2.exists("/f"), "re-created file lost"
    assert tier2.open("/f").snapshot()[:3] == b"NEW"
    assert stats.unlinked_dropped == 0
