"""Crash injection over the plan/apply drain engine (PR 2).

Every application write in these tests *returned* before the crash, so it
is synchronously durable in the NVMM log.  A power loss at ANY plan/apply
checkpoint — mid-plan, between extent writes, after extents but before the
index retire, before the fsync, before the log consume — must therefore be
fully repaired by recovery: the slow tier ends up exactly equal to the
in-order application of all writes.  Torn extents or reordered batches
would surface as a byte mismatch.

The fuse counts drain-engine checkpoints (the ``fault_hook`` of
:class:`~repro.core.cleanup.CleanupThread`) across all K shards and flips
``hard_stop`` — the same switch real power loss uses — at an arbitrary one.
"""
import random
import threading

import pytest

from repro.core import NVCache, Policy, recover
from repro.core import drain as drain_mod
from repro.storage.tiers import DRAM, Tier


def make_policy(k: int, route: str = "stripe") -> Policy:
    # log big enough that writers never need a (possibly fused-dead) drain
    # thread to recycle entries: every write in these tests must return
    return Policy(entry_size=256, log_entries=256 * k, page_size=256,
                  read_cache_pages=4, batch_min=2, batch_max=8,
                  shards=k, shard_route=route, stripe_pages=2)


def apply_ops(ops):
    img = bytearray()
    for off, data in ops:
        if off + len(data) > len(img):
            img.extend(b"\x00" * (off + len(data) - len(img)))
        img[off:off + len(data)] = data
    return bytes(img)


class Fuse:
    """Counts drain checkpoints across every shard thread; at the armed
    count, simulates power loss by hard-stopping the whole pool."""

    def __init__(self, nv, at: int):
        self.nv = nv
        self.at = at
        self.count = 0
        self.tags = []
        self._lock = threading.Lock()

    def __call__(self, tag: str) -> None:
        with self._lock:
            self.count += 1
            self.tags.append(tag)
            fire = self.count == self.at
        if fire:
            for t in self.nv.cleanup.threads:
                t.hard_stop.set()
                t.stop_event.set()


@pytest.mark.parametrize("k", [1, 2, 4])
def test_power_loss_at_any_plan_apply_point_loses_nothing(k):
    seen_tags = set()
    for trial in range(25):
        rng = random.Random(5000 * k + trial)
        pol = make_policy(k, "stripe" if trial % 2 else "fdid")
        tier = Tier(DRAM)
        nv = NVCache(pol, tier, track_crashes=True)
        fuse = Fuse(nv, at=rng.randrange(1, 120))
        for t in nv.cleanup.threads:
            t.fault_hook = fuse
        fd = nv.open("/f")
        ops = []
        for _ in range(rng.randint(10, 25)):
            off = rng.randrange(0, 1200)
            data = bytes(rng.randrange(1, 256)
                         for _ in range(rng.randint(1, 500)))
            nv.pwrite(fd, data, off)          # returns => durable
            ops.append((off, data))
        # poke the drain so the fuse has work to bite on, then crash
        nv.cleanup.request_drain()
        for t in nv.cleanup.threads:
            t.join(timeout=0.05)
        nvmm = nv.crash()                     # drop every un-flushed line
        seen_tags.update(fuse.tags)
        # surviving slow-tier bytes + NVMM replay must equal ALL the writes
        tier2 = Tier(DRAM)
        for path in tier.paths():
            snap = tier.open(path).snapshot()
            if snap:
                tier2.open(path).pwrite(snap, 0)
        stats = recover(nvmm, pol, tier2.open)
        assert stats.crc_failures == 0
        got = tier2.open("/f").snapshot()
        exp = apply_ops(ops)
        assert got[:len(exp)] == exp, \
            f"k={k} trial={trial} fuse@{fuse.at}: torn/reordered/lost bytes"
        assert all(b == 0 for b in got[len(exp):])
    # the fuse must actually have exercised both phases across the trials
    assert drain_mod.PLAN_ENTRY in seen_tags
    assert {drain_mod.APPLY_EXTENT, drain_mod.APPLY_RETIRE} & seen_tags
    assert {drain_mod.FSYNC, drain_mod.CONSUME} & seen_tags


@pytest.mark.parametrize("tag", [drain_mod.PLAN_ENTRY, drain_mod.APPLY_FILE,
                                 drain_mod.APPLY_EXTENT,
                                 drain_mod.APPLY_RETIRE, drain_mod.FSYNC,
                                 drain_mod.CONSUME])
def test_power_loss_pinned_at_each_checkpoint(tag):
    """Deterministic variant: die at the FIRST occurrence of one specific
    checkpoint, for every checkpoint the engine defines."""
    pol = make_policy(2, "stripe")
    tier = Tier(DRAM)
    nv = NVCache(pol, tier, track_crashes=True)
    hit = threading.Event()

    def hook(t):
        if t == tag:
            hit.set()
            for th in nv.cleanup.threads:
                th.hard_stop.set()
                th.stop_event.set()

    for t in nv.cleanup.threads:
        t.fault_hook = hook
    fd = nv.open("/f")
    ops = []
    rng = random.Random(42)
    for _ in range(12):
        off = rng.randrange(0, 900)
        data = bytes([rng.randrange(1, 256)]) * rng.randint(1, 400)
        nv.pwrite(fd, data, off)
        ops.append((off, data))
    nv.cleanup.request_drain()
    assert hit.wait(timeout=30), f"checkpoint {tag} never reached"
    nvmm = nv.crash()
    tier2 = Tier(DRAM)
    for path in tier.paths():
        snap = tier.open(path).snapshot()
        if snap:
            tier2.open(path).pwrite(snap, 0)
    recover(nvmm, pol, tier2.open)
    got = tier2.open("/f").snapshot()
    exp = apply_ops(ops)
    assert got[:len(exp)] == exp
    assert all(b == 0 for b in got[len(exp):])


def test_graceful_stop_is_not_a_crash():
    """stop_event (shutdown) finishes the in-flight batch; only hard_stop
    abandons it — flush-then-shutdown must drain everything."""
    pol = make_policy(2)
    tier = Tier(DRAM)
    nv = NVCache(pol, tier)
    fd = nv.open("/f")
    for i in range(20):
        nv.pwrite(fd, bytes([i + 1]) * 100, i * 60)
    nv.flush()
    assert nv.log.used_entries == 0
    nv.shutdown()
    exp = apply_ops([(i * 60, bytes([i + 1]) * 100) for i in range(20)])
    assert tier.open("/f").snapshot()[:len(exp)] == exp
