"""Pallas kernels vs jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quantize import quantize_pallas
from repro.kernels.ssd_scan import ssd_pallas

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("B,Sq,Skv,H,KV,D", [
    (1, 32, 32, 2, 2, 16),
    (2, 64, 64, 4, 2, 32),
    (1, 48, 96, 4, 1, 64),      # MQA + cross-length
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None), (True, 24)])
def test_flash_attention_matches_oracle(B, Sq, Skv, H, KV, D, dtype, causal, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, D), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 blk_q=16, blk_k=16, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window or 0)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (1, 32, 2, 8, 1, 8, 8),
    (2, 64, 4, 16, 2, 16, 16),
    (1, 128, 4, 32, 1, 32, 32),
])
def test_ssd_matches_oracle(b, s, h, p, g, n, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    y1, st1 = ssd_pallas(x, dt, A, B, C, chunk=chunk, interpret=True)
    y2, st2 = ref.ssd_ref(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), rtol=2e-3, atol=2e-3)


def test_ssd_chunked_equals_sequential_recurrence():
    """The chunked SSD algorithm is exactly the sequential SSM recurrence."""
    b, s, h, p, g, n = 1, 24, 2, 4, 1, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    y, fin = ref.ssd_ref(x, dt, A, B, C, chunk=8)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        yt, state = ref.ssd_decode_ref(x[:, t], dt[:, t], A, B[:, t], C[:, t], state)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.stack(ys, 1)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(state), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape,group", [((64, 512), 256), ((3, 5, 256), 128),
                                         ((1024,), 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_matches_oracle(shape, group, dtype):
    x = jax.random.normal(KEY, shape, dtype) * 3
    q1, s1 = quantize_pallas(x, group=group, blk_r=16, interpret=True)
    q2, s2 = ref.quantize_ref(x, group=group)
    assert bool(jnp.all(q1 == q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(KEY, (128, 512)) * 5
    q, s = ref.quantize_ref(x, group=256)
    back = ref.dequantize_ref(q, s, group=256)
    err = jnp.abs(back - x)
    bound = jnp.abs(x).reshape(128, 2, 256).max(-1).repeat(256, -1).reshape(128, 512) / 127
    assert bool(jnp.all(err <= bound + 1e-6))
