"""Regression tests for the PR-4 recovery-correctness bug cluster.

1. Torn commit groups: a CRC failure on ANY entry of a committed group must
   drop the whole group — replaying the surviving entries would surface a
   partially applied multi-entry pwrite (exactly what the commit protocol
   promises can never happen).
2. Recovery fd leak: a raising ``open_backend``/``pwrite`` mid-replay must
   close every already-opened backend handle, fsync only files that fully
   replayed, leave the log intact (replay is idempotent, so a retry works)
   and re-raise.
3. ``LogShard.alloc`` timeout: the caller's ``timeout`` must be a total
   monotonic deadline, not a per-``Condition.wait`` budget — spurious
   wakeups / near-miss frees used to extend the wait unboundedly.

Each test fails on the pre-fix code.
"""
import threading
import time

import pytest

from repro.core import NVMM, Policy, recover
from repro.core import log as log_mod
from repro.core.log import HDR_SIZE, LogFullTimeout, NVLog
from repro.storage.tiers import DRAM, Tier

POL = Policy(entry_size=256, log_entries=64, page_size=256,
             read_cache_pages=4, batch_min=2, batch_max=8)
ED = POL.entry_data


def fresh_log(nvmm, pol=POL, nfiles=2):
    log = NVLog(nvmm, pol, format=True)
    for fdid in range(nfiles):
        log.fd_table_set(fdid, f"/f{fdid}")
    return log


# ------------------------------------------------------------ torn groups
def test_corrupt_follower_drops_whole_group():
    nvmm = NVMM(POL.nvmm_bytes, track=True)
    log = fresh_log(nvmm, nfiles=1)
    torn = bytes(range(1, 256)) * 2                  # 510 B -> 3 entries
    assert log.entries_needed(len(torn)) == 3
    log.append(0, 0, torn)                           # the group to corrupt
    log.append(0, 1000, b"B" * 100)                  # an innocent bystander
    nvmm.crash()
    # media corruption on the FOLLOWER (idx 1) payload: its head still says
    # committed, so pre-fix recovery replayed the head + second follower
    sh = log.shards[0]
    eoff = sh._eoff(1) + HDR_SIZE
    nvmm.store(eoff, bytes([nvmm.load(eoff, 1)[0] ^ 0xFF]))
    tier = Tier(DRAM)
    stats = recover(nvmm, POL, tier.open)
    got = tier.open("/f0").snapshot()
    # the bystander group replays; NO byte of the torn group may appear
    assert got[1000:1100] == b"B" * 100
    assert all(b == 0 for b in got[:len(torn)]), \
        "torn commit group partially applied"
    assert stats.crc_failures == 1
    assert stats.groups_dropped == 1
    assert stats.entries_replayed == 1               # just the bystander


def test_corrupt_head_drops_whole_group_too():
    nvmm = NVMM(POL.nvmm_bytes, track=True)
    log = fresh_log(nvmm, nfiles=1)
    torn = b"\x55" * (2 * ED)                        # exactly 2 entries
    log.append(0, 0, torn)
    nvmm.crash()
    sh = log.shards[0]
    eoff = sh._eoff(0) + HDR_SIZE
    nvmm.store(eoff, b"\xaa")                        # flip a head payload byte
    tier = Tier(DRAM)
    stats = recover(nvmm, POL, tier.open)
    got = tier.open("/f0").snapshot() if tier.exists("/f0") else b""
    assert all(b == 0 for b in got)
    assert stats.groups_dropped == 1 and stats.entries_replayed == 0


# ---------------------------------------------------------------- fd leak
class FlakyBackend:
    """In-memory backend that raises on the Nth pwrite (globally)."""

    budget = None        # class-level: remaining pwrites before the raise
    opened = []

    def __init__(self, path):
        self.path = path
        self.data = bytearray()
        self.pwrites = 0
        self.fsyncs = 0
        self.closed = 0
        FlakyBackend.opened.append(self)

    def pwrite(self, data, off):
        if FlakyBackend.budget is not None:
            if FlakyBackend.budget <= 0:
                raise OSError("injected pwrite failure")
            FlakyBackend.budget -= 1
        self.pwrites += 1
        if off + len(data) > len(self.data):
            self.data.extend(b"\x00" * (off + len(data) - len(self.data)))
        self.data[off:off + len(data)] = data
        return len(data)

    def fsync(self):
        self.fsyncs += 1

    def close(self):
        self.closed += 1


@pytest.fixture
def flaky():
    FlakyBackend.budget = None
    FlakyBackend.opened = []
    yield FlakyBackend
    FlakyBackend.budget = None
    FlakyBackend.opened = []


def crashed_two_file_log():
    nvmm = NVMM(POL.nvmm_bytes, track=True)
    log = fresh_log(nvmm)
    log.append(0, 0, b"a" * 50)      # /f0, group 0
    log.append(0, 100, b"a" * 50)    # /f0, group 1
    log.append(1, 0, b"b" * 50)      # /f1, group 2
    log.append(1, 100, b"b" * 50)    # /f1, group 3
    return nvmm


def test_midreplay_failure_closes_all_handles_and_fsyncs_completed(flaky):
    nvmm = crashed_two_file_log()
    nvmm.crash()
    flaky.budget = 2                 # /f0 replays fully; /f1's first pwrite dies
    with pytest.raises(OSError, match="injected"):
        recover(nvmm, POL, flaky)
    assert len(flaky.opened) == 2
    by_path = {b.path: b for b in flaky.opened}
    assert all(b.closed == 1 for b in flaky.opened), \
        "mid-replay failure leaked backend handles"
    assert by_path["/f0"].fsyncs == 1          # fully replayed before failure
    assert by_path["/f1"].fsyncs == 0          # incomplete: must NOT fsync


def test_failed_recovery_leaves_log_intact_and_retry_succeeds(flaky):
    nvmm = crashed_two_file_log()
    nvmm.crash()
    flaky.budget = 2
    with pytest.raises(OSError):
        recover(nvmm, POL, flaky)
    # the log was NOT reformatted: a retry replays everything (idempotent)
    flaky.budget = None
    tier = Tier(DRAM)
    stats = recover(nvmm, POL, tier.open)
    assert stats.entries_replayed == 4
    assert tier.open("/f0").snapshot()[100:150] == b"a" * 50
    assert tier.open("/f1").snapshot()[100:150] == b"b" * 50


def test_open_backend_failure_closes_earlier_handles(flaky):
    nvmm = crashed_two_file_log()
    nvmm.crash()

    def opener(path):
        if path == "/f1":
            raise PermissionError("injected open failure")
        return flaky(path)

    with pytest.raises(PermissionError):
        recover(nvmm, POL, opener)
    assert len(flaky.opened) == 1 and flaky.opened[0].closed == 1


# ------------------------------------------------------- alloc deadline
def test_alloc_timeout_is_a_total_deadline():
    """Spurious wakeups must not restart the timeout.  A stepped fake clock
    drives the deadline; a notifier keeps waking the waiter without freeing
    space.  Pre-fix, every wakeup re-armed the FULL timeout and the waiter
    outlived the budget by an unbounded factor."""
    pol = Policy(entry_size=256, log_entries=4, page_size=256,
                 read_cache_pages=4)
    nvmm = NVMM(pol.nvmm_bytes)
    log = NVLog(nvmm, pol, format=True)
    sh = log.shards[0]
    sh.alloc(3)                              # n=4 but k <= n-1 per alloc,
    sh.alloc(1)                              # so fill in two steps
    clock = {"t": 0.0}
    real_monotonic = time.monotonic
    log_mod.time.monotonic = lambda: clock["t"]
    result = {}
    try:
        def worker():
            try:
                sh.alloc(1, timeout=0.05)
            except LogFullTimeout:
                result["elapsed"] = clock["t"]
            except BaseException as exc:     # pragma: no cover
                result["err"] = exc

        t = threading.Thread(target=worker)
        t.start()
        # spurious wakeups every ~4 ms real time, 0.02 s fake time apiece;
        # stop the charade once fake time reaches 20x the timeout
        while "elapsed" not in result and "err" not in result \
                and clock["t"] < 1.0:
            time.sleep(0.004)
            with sh._space:
                clock["t"] += 0.02
                sh._space.notify_all()
        t.join(timeout=10.0)
    finally:
        log_mod.time.monotonic = real_monotonic
    assert not t.is_alive(), "alloc never timed out"
    assert "err" not in result, result.get("err")
    assert "elapsed" in result, "alloc neither returned nor timed out"
    # deadline semantics: raised within one wakeup-step of the 0.05 s budget
    assert result["elapsed"] <= 0.05 + 0.021, \
        f"timeout extended to {result['elapsed']:.3f}s by spurious wakeups"
    assert sh.stats_alloc_wait_s > 0.0


def test_alloc_zero_timeout_raises_immediately_when_full():
    pol = Policy(entry_size=256, log_entries=4, page_size=256,
                 read_cache_pages=4)
    nvmm = NVMM(pol.nvmm_bytes)
    log = NVLog(nvmm, pol, format=True)
    sh = log.shards[0]
    sh.alloc(3)
    sh.alloc(1)                              # shard now full
    t0 = time.monotonic()
    with pytest.raises(LogFullTimeout):
        sh.alloc(1, timeout=0.0)
    assert time.monotonic() - t0 < 1.0


def test_alloc_succeeds_when_space_frees_before_deadline():
    pol = Policy(entry_size=256, log_entries=4, page_size=256,
                 read_cache_pages=4)
    nvmm = NVMM(pol.nvmm_bytes)
    log = NVLog(nvmm, pol, format=True)
    sh = log.shards[0]
    head, _ = sh.alloc(3)
    sh.alloc(1)                              # shard now full
    assert head == 0

    def free_soon():
        time.sleep(0.05)
        with sh._space:                      # emulate a drain recycling slots
            sh.volatile_tail = 2
            sh._space.notify_all()

    t = threading.Thread(target=free_soon)
    t.start()
    idx, _ = sh.alloc(2, timeout=5.0)        # must ride out the wait
    t.join()
    assert idx == 4
