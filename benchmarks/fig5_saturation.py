"""Fig. 5 — log saturation: with a log smaller than the written data the
throughput starts at NVMM speed and collapses to the slow tier's drain
rate; smaller logs collapse earlier, all collapse to the same floor.

``run_shard_scaling`` is the beyond-paper experiment: once saturated, the
drain rate is the throughput, and K log shards drain through K independent
cleanup threads — committed-write throughput under multi-writer load should
scale with K until the device is the wall."""
from __future__ import annotations

from benchmarks.backends import make_stack
from benchmarks.fio_like import concurrent_random_write, random_write


def run(total_mib: float = 24, log_sizes_mib=(2, 6, 48)):
    rows = []
    for log_mib in log_sizes_mib:
        st = make_stack("nvcache+ssd", log_mib=log_mib, batch_min=200,
                        batch_max=2000)
        try:
            r = random_write(st.fs, total_mib=total_mib, file_mib=total_mib)
        finally:
            st.close()
        if len(r["samples"]) >= 2:
            half = len(r["samples"]) // 2
            early = sum(s["inst_mib_s"] for s in r["samples"][:half]) / half
            late = sum(s["inst_mib_s"] for s in r["samples"][half:]) / \
                (len(r["samples"]) - half)
        else:       # finished inside one interval: never saturated
            early = late = r["mib_per_s"]
        rows.append({"log_mib": log_mib, "mib_per_s": r["mib_per_s"],
                     "early_mib_s": early, "late_mib_s": late,
                     "seconds": r["seconds"]})
        print(f"fig5/log{log_mib}MiB,{r['avg_lat_us']:.1f},"
              f"early={early:.1f} late={late:.1f} MiB/s", flush=True)
    return rows


def run_shard_scaling(total_mib: float = 16, log_mib: float = 2,
                      threads: int = 4, shard_counts=(1, 2, 4)):
    """Committed-write throughput, ``threads`` concurrent writers, one file
    per writer, log much smaller than the data (saturated regime), K shards
    drained by K threads.  Routing is by fdid: unrelated files partition
    cleanly across shards (one drain + fsync stream per file); "stripe"
    routing trades some of that isolation for spreading a single hot file."""
    rows = []
    base = None
    for k in shard_counts:
        st = make_stack("nvcache+ssd", log_mib=log_mib, batch_min=50,
                        batch_max=500, shards=k, shard_route="fdid")
        try:
            r = concurrent_random_write(st.fs, threads=threads,
                                        total_mib=total_mib,
                                        file_mib=total_mib)
        finally:
            st.close()
        if base is None:
            base = r["mib_per_s"]
        speedup = r["mib_per_s"] / base
        rows.append({"shards": k, "threads": threads,
                     "mib_per_s": r["mib_per_s"], "speedup": speedup,
                     "avg_lat_us": r["avg_lat_us"], "seconds": r["seconds"]})
        print(f"fig5/shards{k}x{threads}w,{r['avg_lat_us']:.1f},"
              f"{r['mib_per_s']:.1f} MiB/s ({speedup:.2f}x vs K=1)", flush=True)
    return rows


if __name__ == "__main__":
    run()
    run_shard_scaling()
