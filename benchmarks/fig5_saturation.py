"""Fig. 5 — log saturation: with a log smaller than the written data the
throughput starts at NVMM speed and collapses to the slow tier's drain
rate; smaller logs collapse earlier, all collapse to the same floor."""
from __future__ import annotations

from benchmarks.backends import make_stack
from benchmarks.fio_like import random_write


def run(total_mib: float = 24, log_sizes_mib=(2, 6, 48)):
    rows = []
    for log_mib in log_sizes_mib:
        st = make_stack("nvcache+ssd", log_mib=log_mib, batch_min=200,
                        batch_max=2000)
        try:
            r = random_write(st.fs, total_mib=total_mib, file_mib=total_mib)
        finally:
            st.close()
        if len(r["samples"]) >= 2:
            half = len(r["samples"]) // 2
            early = sum(s["inst_mib_s"] for s in r["samples"][:half]) / half
            late = sum(s["inst_mib_s"] for s in r["samples"][half:]) / \
                (len(r["samples"]) - half)
        else:       # finished inside one interval: never saturated
            early = late = r["mib_per_s"]
        rows.append({"log_mib": log_mib, "mib_per_s": r["mib_per_s"],
                     "early_mib_s": early, "late_mib_s": late,
                     "seconds": r["seconds"]})
        print(f"fig5/log{log_mib}MiB,{r['avg_lat_us']:.1f},"
              f"early={early:.1f} late={late:.1f} MiB/s", flush=True)
    return rows


if __name__ == "__main__":
    run()
