"""Dual persistence engine (PR 7): paged NVMM frames vs the append log.

Two measurements:

* ``run_bytes_per_committed`` — an overwrite-heavy stream (several full
  passes over a file that fits the paged region) measured as TOTAL
  persisted bytes per committed byte: NVMM stored bytes plus backend
  bytes.  The log persists every overwrite twice — an entry appended to
  NVMM, then the drain's page write to the backend — so N passes cost
  ~2N page images.  A frame persists each overwrite once (in place, plus
  a 64-byte header flip) and pays the backend exactly one final image at
  writeback.  Acceptance: the paged engine persists >= 1.5x fewer bytes
  per committed byte.

* ``run_trickle_parity`` — the fig9 trickle workload (``batch_min=1``,
  small sequential writes with think-time gaps) run with the classifier
  armed: small-write streams must stay in log mode, keeping trickle
  throughput within 5% of the PR-5 tip.
"""
from __future__ import annotations

import time

from benchmarks.backends import make_stack
from repro.core.policy import CACHELINE

PAGE = 4096


def run_bytes_per_committed(n_pages: int = 32, passes: int = 8):
    """Overwrite ``n_pages`` full pages ``passes`` times (after one warmup
    pass that lets the classifier flip and the warmup entries drain);
    report steady-state persisted bytes (NVMM + backend) per committed
    byte for log vs paged mode."""
    rows = []
    for mode in ("log", "paged"):
        # eager-durability regime (the acceptance context): batch_min=1
        # drains per tiny batch, and batch_max < n_pages means a batch can
        # never span a full pass — so cross-pass overwrite coalescing
        # cannot mask the log's backend churn nondeterministically
        st = make_stack(
            "nvcache+ssd", log_mib=1, batch_min=1, batch_max=n_pages // 2,
            page_frames=2 * n_pages if mode == "paged" else 0,
            classify_window=8)
        try:
            fd = st.fs.open("/hot.dat")
            for p in range(n_pages):            # warmup pass: classifier
                st.fs.pwrite(fd, b"\x00" * PAGE, p * PAGE)
            st.nv.flush()                       # ...flips, refs drain
            tf = st.tier.open("/hot.dat")
            nvmm0 = st.nv.nvmm.stats_stored_bytes
            s0 = st.nv.stats()
            backend0 = tf.stats_bytes
            committed = 0
            t0 = time.perf_counter()
            for rnd in range(passes):
                buf = bytes([rnd + 1]) * PAGE
                for p in range(n_pages):
                    st.fs.pwrite(fd, buf, p * PAGE)
                    committed += PAGE
            st.nv.flush()
            dt = time.perf_counter() - t0
            s = st.nv.stats()
            nvmm_bytes = st.nv.nvmm.stats_stored_bytes - nvmm0
            backend_bytes = tf.stats_bytes - backend0
            persisted = nvmm_bytes + backend_bytes
            pwbs = s["nvmm_pwbs"] - s0["nvmm_pwbs"]
            flushed = CACHELINE * (s["nvmm_pwb_lines"] - s0["nvmm_pwb_lines"])
            psyncs = s["nvmm_psyncs"] - s0["nvmm_psyncs"]
            # reconcile the flush counters against the persisted-bytes
            # figure: every NVMM-stored byte must be covered by a pwb
            # (lower bound), and pwb traffic may exceed stores only by
            # per-call partial-line rounding (upper bound) — a redundant
            # or missing flush in a commit path moves one of these.
            assert nvmm_bytes <= flushed <= nvmm_bytes + 2 * CACHELINE * pwbs, \
                (mode, nvmm_bytes, flushed, pwbs)
            assert psyncs > 0, "no durability points recorded"
            rows.append({
                "mode": mode,
                "committed_bytes": committed,
                "nvmm_stored_bytes": nvmm_bytes,
                "backend_bytes": backend_bytes,
                "persisted_bytes": persisted,
                "persisted_per_committed_byte": persisted / committed,
                "nvmm_pwbs": pwbs,
                "flushed_bytes": flushed,
                "flushed_per_committed_byte": flushed / committed,
                "nvmm_psyncs": psyncs,
                "mode_migrations": s["mode_migrations"],
                "paged_frame_writes": s["paged_frame_writes"],
                "paged_writebacks": s["paged_writebacks"],
                "log_full_scans": s["log_full_scans"],
                "seconds": dt,
            })
        finally:
            st.close()
        print(f"fig_dualmode/{mode},persisted/committed="
              f"{rows[-1]['persisted_per_committed_byte']:.2f},"
              f"nvmm={rows[-1]['nvmm_stored_bytes']},"
              f"backend={rows[-1]['backend_bytes']}", flush=True)
    return rows


def run_trickle_parity(n_writes: int = 192, bs: int = 1024,
                       gap_s: float = 0.002):
    """fig9's trickle with the dual engine armed: the classifier must keep
    a small-write stream on the log, so throughput matches the PR-5 tip."""
    rows = []
    for mode in ("pr5-tip", "dual-engine"):
        st = make_stack(
            "nvcache+ssd", log_mib=2, batch_min=1, batch_max=500,
            span_batches=True, deadline_ms=100.0,
            page_frames=64 if mode == "dual-engine" else 0,
            classify_window=32)
        try:
            fd = st.fs.open("/trickle.dat")
            buf = b"t" * bs
            t0 = time.perf_counter()
            for i in range(n_writes):
                st.fs.pwrite(fd, buf, i * bs)
                if gap_s:
                    time.sleep(gap_s)
            st.nv.flush()
            dt = time.perf_counter() - t0
            s = st.nv.stats()
            rows.append({
                "mode": mode,
                "writes": n_writes, "bs": bs,
                "seconds": dt,
                "us_per_write": 1e6 * dt / n_writes,
                "mode_migrations": s["mode_migrations"],
                "paged_frame_writes": s["paged_frame_writes"],
            })
        finally:
            st.close()
        print(f"fig_dualmode/trickle_{mode},{1e6 * dt / n_writes:.1f}us/write,"
              f"migrations={rows[-1]['mode_migrations']}", flush=True)
    return rows


if __name__ == "__main__":
    run_bytes_per_committed()
    run_trickle_parity()
