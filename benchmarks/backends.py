"""The seven evaluated storage stacks (paper Table IV), as FS factories.

Device-time scale: simulated device costs are multiplied by SCALE so that
the Python interpreter overhead of the NVCache hot path (~tens of µs per
op, standing in for the paper's ~µs Optane path) keeps the same *ratio* to
the modeled SSD/NVMM costs as on the paper's hardware.  Ratios between
stacks are the experiment; absolute MiB/s are scaled.
"""
from __future__ import annotations

import dataclasses

from repro.core import NVCache, Policy
from repro.storage import tiers
from repro.storage.fsapi import NVCacheFS, TierFS

SCALE = 20.0

EXT4_DAX = dataclasses.replace(tiers.NVMM_OPTANE, name="ext4dax",
                               page_write_s=2.4e-6, page_read_s=1.5e-6,
                               syscall_s=3e-6)
NOVA = dataclasses.replace(tiers.NVMM_OPTANE, name="nova",
                           page_write_s=1.9e-6, page_read_s=1.3e-6,
                           syscall_s=2e-6)


def policy(log_mib: float, *, entry=4096, batch_min=1000, batch_max=10000,
           read_pages=1024, shards=1, shard_route="stripe",
           drain_coalesce=True, fsync_epoch=True, readahead=8,
           span_batches=True, deadline_ms=5.0, rebalance=False,
           rebalance_epoch_ms=50.0, placement_groups=1,
           page_frames=0, classify_window=32, obs_level=0) -> Policy:
    return Policy(entry_size=entry, log_entries=max(8 * shards, int(log_mib * 1024 * 1024 // entry)),
                  page_size=4096, read_cache_pages=read_pages,
                  batch_min=batch_min, batch_max=batch_max, verify_crc=False,
                  shards=shards, shard_route=shard_route,
                  drain_coalesce=drain_coalesce, fsync_epoch=fsync_epoch,
                  readahead_pages=readahead,
                  coalesce_span_batches=span_batches,
                  coalesce_deadline_ms=deadline_ms,
                  shard_rebalance=rebalance,
                  rebalance_epoch_ms=rebalance_epoch_ms,
                  placement_groups=placement_groups,
                  page_frames=page_frames, classify_window=classify_window,
                  obs_level=obs_level)


@dataclasses.dataclass
class Stack:
    name: str
    fs: object
    nv: object = None       # NVCache instance when applicable
    tier: object = None

    def close(self):
        if self.nv is not None:
            try:
                self.nv.shutdown()
            except Exception:
                pass


def make_stack(name: str, *, log_mib: float = 64, batch_min=1000,
               batch_max=10000, read_pages=1024, scale: float = SCALE,
               shards: int = 1, shard_route: str = "stripe",
               drain_coalesce: bool = True, fsync_epoch: bool = True,
               readahead: int = 8, span_batches: bool = True,
               deadline_ms: float = 5.0, rebalance: bool = False,
               rebalance_epoch_ms: float = 50.0,
               placement_groups: int = 1, page_frames: int = 0,
               classify_window: int = 32, obs_level: int = 0) -> Stack:
    if name == "nvcache+ssd":
        tier = tiers.Tier(tiers.SSD_SATA, sync=False, scale=scale)
        nv = NVCache(policy(log_mib, batch_min=batch_min, batch_max=batch_max,
                            read_pages=read_pages, shards=shards,
                            shard_route=shard_route,
                            drain_coalesce=drain_coalesce,
                            fsync_epoch=fsync_epoch, readahead=readahead,
                            span_batches=span_batches,
                            deadline_ms=deadline_ms, rebalance=rebalance,
                            rebalance_epoch_ms=rebalance_epoch_ms,
                            placement_groups=placement_groups,
                            page_frames=page_frames,
                            classify_window=classify_window,
                            obs_level=obs_level), tier)
        return Stack(name, NVCacheFS(nv), nv, tier)
    if name == "nvcache+nova":
        tier = tiers.Tier(NOVA, sync=False, scale=scale)
        nv = NVCache(policy(log_mib, batch_min=batch_min, batch_max=batch_max,
                            read_pages=read_pages, shards=shards,
                            shard_route=shard_route,
                            drain_coalesce=drain_coalesce,
                            fsync_epoch=fsync_epoch, readahead=readahead,
                            span_batches=span_batches,
                            deadline_ms=deadline_ms, rebalance=rebalance,
                            rebalance_epoch_ms=rebalance_epoch_ms,
                            placement_groups=placement_groups,
                            page_frames=page_frames,
                            classify_window=classify_window,
                            obs_level=obs_level), tier)
        return Stack(name, NVCacheFS(nv), nv, tier)
    if name == "dm-writecache":
        tier = tiers.DMWriteCacheTier(scale=scale)
        return Stack(name, TierFS(tier), tier=tier)
    if name == "ssd":
        tier = tiers.Tier(tiers.SSD_SATA, sync=True, scale=scale)
        return Stack(name, TierFS(tier), tier=tier)
    if name == "ext4-dax":
        tier = tiers.Tier(EXT4_DAX, sync=True, scale=scale)
        return Stack(name, TierFS(tier), tier=tier)
    if name == "nova":
        tier = tiers.Tier(NOVA, sync=True, scale=scale)
        return Stack(name, TierFS(tier), tier=tier)
    if name == "tmpfs":
        tier = tiers.Tier(tiers.DRAM, volatile=True, scale=scale)
        return Stack(name, TierFS(tier), tier=tier)
    raise KeyError(name)


ALL_STACKS = ["nvcache+ssd", "dm-writecache", "ext4-dax", "nova", "ssd",
              "tmpfs", "nvcache+nova"]
