"""Observability-plane figures (PR 10).

Two measurements over the nvcache+ssd stack:

* ``run_span_breakdown`` — fsync=1 random writes at ``obs_level=2``; the
  span profiler's per-stage histograms become the latency breakdown
  (p50/p95/p99 per stage), reconciled two ways: the foreground spans
  (op + drain-barrier stall) must add up to the workload wall-clock, and
  the commit-span totals are divided through the NVMM ``pwb``/fence
  counters into a fence-cost row (µs of commit time per fence, pwbs per
  committed group).
* ``run_obs_overhead`` — the same workload plain vs fully instrumented;
  CI fails the build when ``obs_level=2`` costs more than 10% on
  µs-per-op (and ``obs_level=0`` must be free — that guard is the
  tracemalloc test in ``tests/test_obs.py``).
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import backends, fio_like  # noqa: E402


def _stage_rows(m: dict) -> list:
    """Every span histogram in a metrics snapshot, as breakdown rows."""
    rows = []
    for name in sorted(m):
        v = m[name]
        if not (isinstance(v, dict) and name.endswith("_us") and v.get("count")):
            continue
        rows.append({"stage": name, "count": v["count"],
                     "sum_us": v["sum_us"], "p50_us": v["p50_us"],
                     "p95_us": v["p95_us"], "p99_us": v["p99_us"]})
    return rows


def run_span_breakdown(total_mib: float = 3.0, file_mib: float = 2.0,
                       bs: int = 4096, log_mib: float = 2.0) -> dict:
    """Single-writer fsync=1 random writes with the profiler at level 2."""
    st = backends.make_stack("nvcache+ssd", log_mib=log_mib, obs_level=2)
    try:
        t0 = time.perf_counter()
        res = fio_like.random_write(st.fs, total_mib=total_mib,
                                    file_mib=file_mib, bs=bs)
        wall_s = time.perf_counter() - t0
        m = st.nv.metrics()
        s = st.nv.stats()
    finally:
        st.close()
    op = m["write.op_us"]
    barrier = m["stall.barrier_us"]
    commit = m["write.commit_us"]
    # one writer: the op spans plus the fsync drain-barrier stalls ARE the
    # foreground time; whatever wall-clock they fail to cover is harness
    # overhead (rng, timestamping) and must stay inside 10%
    fg_span_s = (op["sum_us"] + barrier["sum_us"]) * 1e-6
    fences = max(1, s["nvmm_fences"])
    return {
        "mode": "span-breakdown",
        "obs_level": 2,
        "wall_s": wall_s,
        "mib_per_s": res["mib_per_s"],
        "clat": res["lat"],
        "op_p50_us": op["p50_us"], "op_p95_us": op["p95_us"],
        "op_p99_us": op["p99_us"],
        "foreground_span_s": fg_span_s,
        "span_coverage_ratio": fg_span_s / max(1e-12, wall_s),
        "stages": _stage_rows(m),
        "fence_cost": {
            "nvmm_pwbs": s["nvmm_pwbs"],
            "nvmm_pwb_lines": s["nvmm_pwb_lines"],
            "nvmm_fences": s["nvmm_fences"],
            "nvmm_psyncs": s["nvmm_psyncs"],
            "commit_spans": commit["count"],
            "commit_span_sum_us": commit["sum_us"],
            "pwbs_per_commit": s["nvmm_pwbs"] / max(1, commit["count"]),
            "fences_per_commit": s["nvmm_fences"] / max(1, commit["count"]),
            "us_per_fence": commit["sum_us"] / fences,
        },
    }


def _gate_us_per_op(obs_level: int, *, log_mib: float,
                    total_mib: float = 1.0, file_mib: float = 1.0) -> float:
    st = backends.make_stack("nvcache+ssd", log_mib=log_mib,
                             obs_level=obs_level)
    try:
        res = fio_like.random_write(st.fs, total_mib=total_mib,
                                    file_mib=file_mib, bs=4096)
    finally:
        st.close()
    return res["avg_lat_us"]


def _stress_seconds(obs_level: int, *, threads: int, total_mib: float,
                    log_mib: float) -> float:
    st = backends.make_stack("nvcache+ssd", log_mib=log_mib, shards=2,
                             obs_level=obs_level)
    try:
        res = fio_like.concurrent_random_write(st.fs, threads=threads,
                                               total_mib=total_mib,
                                               file_mib=2.0)
    finally:
        st.close()
    return res["seconds"]


def _hot_cpu_us_per_op(obs_level: int, n: int = 4096, bs: int = 4096) -> float:
    """Pure log-commit path (no fsync, drain quiescent, free device):
    CPU µs per pwrite — the worst case for instrumentation, since nothing
    dilutes the span/flight cost."""
    st = backends.make_stack("nvcache+ssd", log_mib=32, scale=0.0,
                             batch_min=10 ** 6, batch_max=10 ** 6,
                             obs_level=obs_level)
    buf = b"x" * bs
    try:
        fd = st.fs.open("/hot.dat")
        for i in range(64):
            st.fs.pwrite(fd, buf, i * bs)
        t0 = time.process_time()
        for i in range(n):
            st.fs.pwrite(fd, buf, (i % 256) * bs)
        dt = time.process_time() - t0
    finally:
        st.nv.cleanup.power_loss()
    return 1e6 * dt / n


def run_obs_overhead(threads: int = 4, total_mib: float = 2.0,
                     log_mib: float = 2.0, repeats: int = 5) -> dict:
    """Plain vs obs_level=2 overhead — the CI gate (<10%) is
    ``overhead_pct``: fsync=1 single-writer µs-per-op, where each op's
    cost is dominated by the deterministic modeled device time (the
    deployment-realistic denominator).  Plain/instrumented runs are
    interleaved back-to-back and the gate takes the MEDIAN of the
    per-pair overheads — back-to-back pairs share the machine's noise
    phase (CPU frequency, co-tenant load), and the median discards the
    pairs a hiccup landed on, so a single slow run can't fail the
    build.  All raw samples are emitted for forensics.  Two context
    rows ride along un-gated: the N-thread stress wall seconds (same
    workload family, but batching dynamics dominate its run-to-run
    noise) and the pure hot-path CPU µs/op — the undiluted worst case,
    i.e. what spans plus sampled flight records cost when nothing else
    is on the op (expect tens of percent there; that is exactly why
    level 2 is opt-in and level 0 is the default)."""
    pairs = []
    gate_plain, gate_full = [], []
    for _ in range(repeats):
        p_us = _gate_us_per_op(0, log_mib=log_mib)
        f_us = _gate_us_per_op(2, log_mib=log_mib)
        gate_plain.append(p_us)
        gate_full.append(f_us)
        pairs.append(100.0 * (f_us - p_us) / max(1e-12, p_us))
    pairs.sort()
    median = pairs[len(pairs) // 2]
    plain, full = [], []
    for _ in range(2):
        plain.append(_stress_seconds(0, threads=threads,
                                     total_mib=total_mib, log_mib=log_mib))
        full.append(_stress_seconds(2, threads=threads,
                                    total_mib=total_mib, log_mib=log_mib))
    cp = min(_hot_cpu_us_per_op(0) for _ in range(2))
    cf = min(_hot_cpu_us_per_op(2) for _ in range(2))
    return {
        "mode": "obs-overhead",
        "threads": threads,
        "us_per_op_plain": min(gate_plain),
        "us_per_op_obs2": min(gate_full),
        "overhead_pct": median,
        "overhead_pct_pairs": pairs,
        "samples_us_plain": gate_plain,
        "samples_us_obs2": gate_full,
        "stress_s_plain": min(plain),
        "stress_s_obs2": min(full),
        "stress_overhead_pct": 100.0 * (min(full) - min(plain))
            / max(1e-12, min(plain)),
        "hot_cpu_us_per_op_plain": cp,
        "hot_cpu_us_per_op_obs2": cf,
        "hot_cpu_overhead_pct": 100.0 * (cf - cp) / max(1e-12, cp),
    }


if __name__ == "__main__":
    import json
    print(json.dumps({"span_breakdown": run_span_breakdown(),
                      "obs_overhead": run_obs_overhead()}, indent=2))
