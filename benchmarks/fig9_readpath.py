"""Fig. 9 (beyond paper) — the extent-granular read path and batch-spanning
drain coalescing (PR 3), vs the PR-2 tip and the paper baseline.

Three experiments:

* ``run_cold_read`` — cold sequential scan of a file that lives only on the
  slow tier (page cache dropped): with ``readahead_pages=R`` a cache miss
  loads one aligned R-page extent through ``TierFile.preadv`` instead of R
  single-page ``pread`` calls.  Figure of merit: *backend page-read
  operations (syscalls) per byte read* — the read-side twin of PR 2's
  page-writes-per-committed-byte.  ``readahead_pages=1`` is the paper's
  Fig. 2 per-page miss procedure.
* ``run_mixed`` — 50/50 random read/write (fio-style, fsync=1 semantics):
  end-to-end throughput with and without readahead, dirty misses included —
  readahead must never bypass the dirty-page-index replay, so this also
  guards the consistency cost.
* ``run_trickle`` — a slow writer issuing small contiguous writes so every
  drain batch is tiny (``batch_min`` low): the PR-2 tip
  (``coalesce_span_batches=False``) degenerates to ~one backend page write
  per batch because each batch re-writes the still-filling tail page; the
  batch-spanning carry defers the open tail page until it is full (or the
  ``coalesce_deadline_ms`` expires), restoring ~one write per page.
"""
from __future__ import annotations

import time

from benchmarks.backends import make_stack
from benchmarks.fio_like import random_write

PS = 4096


def _prefill_cold(stack, path: str, nbytes: int) -> None:
    """Put ``nbytes`` on the slow tier only, then drop the page cache so
    the next reads are cold (device-cost) reads."""
    f = stack.tier.open(path)
    f.pwrite(b"\xC5" * nbytes, 0)
    f.fsync()
    f.drop_page_cache()


def run_cold_read(total_mib: float = 8, readaheads=(1, 8), bs: int = PS):
    """Cold sequential read at each readahead setting."""
    nbytes = int(total_mib * (1 << 20))
    rows = []
    for ra in readaheads:
        st = make_stack("nvcache+ssd", log_mib=2, readahead=ra)
        try:
            _prefill_cold(st, "/cold.dat", nbytes)
            fd = st.fs.open("/cold.dat")
            t0 = time.perf_counter()
            for off in range(0, nbytes, bs):
                st.fs.pread(fd, bs, off)
            dt = time.perf_counter() - t0
            tf = st.tier.open("/cold.dat")
            s = st.nv.stats()
            row = {
                "readahead_pages": ra,
                "bs": bs,
                "bytes": nbytes,
                "seconds": dt,
                "mib_per_s": nbytes / dt / (1 << 20),
                "backend_preads": tf.stats_preads,
                "backend_page_reads": tf.stats_page_reads,
                "read_ops_per_byte": tf.stats_preads / nbytes,
                "readahead_loads": s["readahead_loads"],
                "readahead_hit_rate": s["readahead_hit_rate"],
                "log_full_scans": s["log_full_scans"],
            }
        finally:
            st.close()
        rows.append(row)
        print(f"fig9/cold_read_ra{ra},{1e6 * dt * bs / nbytes:.1f},"
              f"{row['mib_per_s']:.1f} MiB/s "
              f"ops/MiB={row['backend_preads'] / max(1e-9, nbytes / (1 << 20)):.0f}",
              flush=True)
    return rows


def run_mixed(total_mib: float = 6, readaheads=(1, 8)):
    """Mixed 50/50 random read/write through the full stack."""
    rows = []
    for ra in readaheads:
        st = make_stack("nvcache+ssd", log_mib=4 * total_mib, readahead=ra)
        try:
            r = random_write(st.fs, total_mib=total_mib, file_mib=total_mib,
                             read_fraction=0.5)
            s = st.nv.stats()
            tf = st.tier.open("/fio.dat")
            row = {
                "readahead_pages": ra,
                "mib_per_s": r["mib_per_s"],
                "avg_lat_us": r["avg_lat_us"],
                "reads": r["reads"], "writes": r["writes"],
                "backend_preads": tf.stats_preads,
                "dirty_misses": s["dirty_misses"],
                "readahead_hit_rate": s["readahead_hit_rate"],
                "log_full_scans": s["log_full_scans"],
            }
        finally:
            st.close()
        rows.append(row)
        print(f"fig9/mixed_ra{ra},{row['avg_lat_us']:.1f},"
              f"{row['mib_per_s']:.1f} MiB/s", flush=True)
    return rows


def run_trickle(n_writes: int = 192, bs: int = 1024, gap_s: float = 0.002,
                deadline_ms: float = 100.0):
    """Small-batch trickle: one writer, contiguous ``bs``-byte writes with a
    think-time gap, ``batch_min=1`` so the drain runs per tiny batch."""
    rows = []
    for span in (False, True):
        st = make_stack("nvcache+ssd", log_mib=2, batch_min=1, batch_max=500,
                        span_batches=span, deadline_ms=deadline_ms)
        try:
            fd = st.fs.open("/trickle.dat")
            buf = b"t" * bs
            t0 = time.perf_counter()
            for i in range(n_writes):
                st.fs.pwrite(fd, buf, i * bs)
                if gap_s:
                    time.sleep(gap_s)
            st.nv.flush()
            dt = time.perf_counter() - t0
            tf = st.tier.open("/trickle.dat")
            s = st.nv.stats()
            committed = n_writes * bs
            row = {
                "mode": "span-batches" if span else "pr2-tip",
                "writes": n_writes, "bs": bs,
                "committed_bytes": committed,
                "seconds": dt,
                "backend_pwrites": tf.stats_writes,
                "backend_page_writes": tf.stats_page_writes,
                "backend_page_writes_per_committed_byte":
                    tf.stats_page_writes / committed,
                "drain_deferred": s["drain_deferred"],
                "drain_span_merges": s["drain_span_merges"],
                "cleanup_batches": s["cleanup_batches"],
            }
        finally:
            st.close()
        rows.append(row)
        print(f"fig9/trickle_{row['mode']},{1e6 * dt / n_writes:.1f},"
              f"pagewrites/MiB="
              f"{row['backend_page_writes'] / max(1e-9, committed / (1 << 20)):.0f}",
              flush=True)
    return rows


if __name__ == "__main__":
    run_cold_read()
    run_mixed()
    run_trickle()
