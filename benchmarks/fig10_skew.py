"""Fig. 10 — skewed fdid distributions vs the adaptive shard router.

PR 1's static ``fdid % K`` route partitions *unrelated* files cleanly —
until the workload is skewed: fdid assignment is arbitrary (open order), so
several hot files can collide on one shard and the whole multi-writer
workload collapses back to a single shard's commit lock + drain thread (the
per-core-log contention problem of "NVMM cache design: Logging vs.
Paging").  This experiment constructs exactly that adversarial-but-
realistic case: ``FILES`` files whose per-op popularity is Zipf(s), with
the Zipf *ranks* laid out so the hottest K files all collide on shard 0
under ``fdid % K`` (rank r -> file (r % (FILES/K)) * K + r // (FILES/K)).

``run_skew`` measures committed-write throughput of ``threads`` concurrent
writers in the saturated regime (log much smaller than the data), static
``fdid`` route vs ``shard_rebalance=True``: the epoch router samples
per-key load, migrates the colliding hot fdids to lighter shards (each
migration behind the per-file drain barrier) and the workload spreads back
across all K drain threads.  Headline: rebalanced / static committed MiB/s
(acceptance: >= 1.5x at K = 4, 4 writers).
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.backends import make_stack


def zipf_file_map(files: int, k: int) -> list:
    """Rank -> file index such that ranks 0..K-1 (the hot files) all map to
    files that are ≡ 0 (mod K): a worst-case-but-legal fdid layout."""
    per = files // k
    return [(r % per) * k + r // per for r in range(files)]


def zipf_probs(files: int, s: float) -> np.ndarray:
    p = 1.0 / np.power(np.arange(1, files + 1), s)
    return p / p.sum()


def concurrent_zipf_write(fs, *, threads: int, total_mib: float,
                          files: int, k: int, zipf_s: float = 1.0,
                          file_mib: float = 4.0, bs: int = 4096,
                          seed: int = 11):
    """N writers; each op picks its file by Zipf rank (shared popularity,
    per-thread RNG) and writes a random ``bs``-aligned offset in it."""
    n_ops = int(total_mib * (1 << 20)) // bs
    per_thread = max(1, n_ops // threads)
    n_slots = max(1, int(file_mib * (1 << 20)) // bs)
    rank_to_file = zipf_file_map(files, k)
    probs = zipf_probs(files, zipf_s)
    buf = b"x" * bs
    fds = [fs.open(f"/skew{i}.dat") for i in range(files)]  # fdid == i
    done = [0] * threads
    lat = [0.0] * threads

    def worker(t):
        rng = np.random.default_rng(seed + t)
        ranks = rng.choice(files, size=per_thread, p=probs)
        offs = rng.integers(0, n_slots, size=per_thread)
        for i in range(per_thread):
            fd = fds[rank_to_file[int(ranks[i])]]
            t0 = time.perf_counter()
            fs.pwrite(fd, buf, int(offs[i]) * bs)
            fs.fsync(fd)
            lat[t] += time.perf_counter() - t0
            done[t] = i + 1

    ts = [threading.Thread(target=worker, args=(t,)) for t in range(threads)]
    t_start = time.perf_counter()
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    total = time.perf_counter() - t_start
    ops = sum(done)
    return {
        "seconds": total,
        "mib_per_s": ops * bs / total / (1 << 20),
        "avg_lat_us": 1e6 * sum(lat) / max(1, ops),
        "writes": ops,
        "threads": threads,
        "files": files,
        "zipf_s": zipf_s,
    }


def run_skew(total_mib: float = 10, log_mib: float = 2, threads: int = 4,
             files: int = 16, k: int = 4, zipf_s: float = 1.0,
             warmup_mib: float = 3.0, rebalance_epoch_ms: float = 25.0):
    """Static fdid route vs adaptive rebalancing on the colliding-hot-fdid
    Zipf workload; identical policy otherwise.  ``warmup_mib`` is an
    untimed ramp (fio ``ramp_time`` style) so the figure reports
    *steady-state* throughput — for the static route the ramp changes
    nothing; for the rebalancer it covers the few epochs of convergence
    (migrations keep running in the timed phase; steady state just means
    the table has stopped moving hot keys every epoch)."""
    rows = []
    for mode in ("static-fdid", "rebalance"):
        st = make_stack("nvcache+ssd", log_mib=log_mib, batch_min=50,
                        batch_max=500, shards=k, shard_route="fdid",
                        rebalance=(mode == "rebalance"),
                        rebalance_epoch_ms=rebalance_epoch_ms)
        try:
            if warmup_mib > 0:
                concurrent_zipf_write(st.fs, threads=threads,
                                      total_mib=warmup_mib, files=files,
                                      k=k, zipf_s=zipf_s, seed=7)
            r = concurrent_zipf_write(st.fs, threads=threads,
                                      total_mib=total_mib, files=files,
                                      k=k, zipf_s=zipf_s)
        finally:
            stats = st.nv.stats()
            st.close()
        r.update({"mode": mode, "shards": k,
                  "route_epoch": stats["route_epoch"],
                  "route_migrations": stats["route_migrations"],
                  "route_overrides": stats["route_overrides"],
                  "alloc_wait_s": stats["alloc_wait_s"]})
        rows.append(r)
        print(f"fig10/{mode}@K{k}x{threads}w,{r['avg_lat_us']:.1f},"
              f"{r['mib_per_s']:.1f} MiB/s "
              f"(epoch={r['route_epoch']} migs={r['route_migrations']})",
              flush=True)
    return rows


def run_uniform_guard(total_mib: float = 8, log_mib: float = 2,
                      threads: int = 4, k: int = 4):
    """Uniform (non-skewed) multi-writer load, rebalance on vs off: the
    rebalancer must not tax the balanced case (hysteresis keeps it idle)."""
    from benchmarks.fio_like import concurrent_random_write
    rows = []
    for mode in ("static-fdid", "rebalance"):
        st = make_stack("nvcache+ssd", log_mib=log_mib, batch_min=50,
                        batch_max=500, shards=k, shard_route="fdid",
                        rebalance=(mode == "rebalance"))
        try:
            r = concurrent_random_write(st.fs, threads=threads,
                                        total_mib=total_mib,
                                        file_mib=total_mib)
        finally:
            stats = st.nv.stats()
            st.close()
        rows.append({"mode": mode, "shards": k,
                     "mib_per_s": r["mib_per_s"],
                     "route_migrations": stats["route_migrations"]})
        print(f"fig10/uniform-{mode}@K{k},{r['mib_per_s']:.1f} MiB/s",
              flush=True)
    return rows


if __name__ == "__main__":
    run_skew()
    run_uniform_guard()
