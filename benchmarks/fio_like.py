"""FIO-style random-write engine shared by Figs. 4-7: psync 4 KiB buffers,
fsync=1 semantics (synchronous durability on every stack), per-interval
instantaneous throughput + running average latency + cumulative bytes."""
from __future__ import annotations

import time

import numpy as np


def random_write(fs, *, total_mib: float, file_mib: float, bs: int = 4096,
                 interval_s: float = 0.05, path="/fio.dat", seed=11,
                 read_fraction: float = 0.0):
    fd = fs.open(path)
    rng = np.random.default_rng(seed)
    n_ops = int(total_mib * (1 << 20)) // bs
    n_slots = max(1, int(file_mib * (1 << 20)) // bs)
    buf = b"x" * bs
    samples = []
    t_start = time.perf_counter()
    t_mark, ops_mark = t_start, 0
    lat_sum = 0.0
    done_reads = 0
    for i in range(n_ops):
        off = int(rng.integers(0, n_slots)) * bs
        t0 = time.perf_counter()
        if read_fraction and rng.random() < read_fraction:
            fs.pread(fd, bs, off)
            done_reads += 1
        else:
            fs.pwrite(fd, buf, off)
            fs.fsync(fd)
        lat_sum += time.perf_counter() - t0
        now = time.perf_counter()
        if now - t_mark >= interval_s:
            samples.append({
                "t": now - t_start,
                "inst_mib_s": (i + 1 - ops_mark) * bs / (now - t_mark) / (1 << 20),
                "avg_lat_us": 1e6 * lat_sum / (i + 1),
                "cum_mib": (i + 1) * bs / (1 << 20),
            })
            t_mark, ops_mark = now, i + 1
    total = time.perf_counter() - t_start
    return {
        "seconds": total,
        "mib_per_s": n_ops * bs / total / (1 << 20),
        "avg_lat_us": 1e6 * lat_sum / max(1, n_ops),
        "samples": samples,
        "writes": n_ops - done_reads,
        "reads": done_reads,
    }
