"""FIO-style random-write engines shared by Figs. 4-7: psync 4 KiB buffers,
fsync=1 semantics (synchronous durability on every stack), per-interval
instantaneous throughput + running average latency + cumulative bytes.
``concurrent_random_write`` is the numjobs=N variant used by the sharded-log
scaling experiment.

Per-op commit latency is recorded into a :class:`repro.obs.metrics`
histogram (per-thread cells, so N writers never contend on it) and every
result carries a ``lat`` snapshot with p50/p95/p99 — fio's
``clat percentiles``, not just the running average."""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.obs.metrics import Histogram


def random_write(fs, *, total_mib: float, file_mib: float, bs: int = 4096,
                 interval_s: float = 0.05, path="/fio.dat", seed=11,
                 read_fraction: float = 0.0):
    fd = fs.open(path)
    rng = np.random.default_rng(seed)
    n_ops = int(total_mib * (1 << 20)) // bs
    n_slots = max(1, int(file_mib * (1 << 20)) // bs)
    buf = b"x" * bs
    samples = []
    hist = Histogram("fio.clat_us")
    t_start = time.perf_counter()
    t_mark, ops_mark = t_start, 0
    lat_sum = 0.0
    done_reads = 0
    for i in range(n_ops):
        off = int(rng.integers(0, n_slots)) * bs
        t0 = time.perf_counter()
        if read_fraction and rng.random() < read_fraction:
            fs.pread(fd, bs, off)
            done_reads += 1
        else:
            fs.pwrite(fd, buf, off)
            fs.fsync(fd)
        dt = time.perf_counter() - t0
        hist.record_ns(int(dt * 1e9))
        lat_sum += dt
        now = time.perf_counter()
        if now - t_mark >= interval_s:
            samples.append({
                "t": now - t_start,
                "inst_mib_s": (i + 1 - ops_mark) * bs / (now - t_mark) / (1 << 20),
                "avg_lat_us": 1e6 * lat_sum / (i + 1),
                "cum_mib": (i + 1) * bs / (1 << 20),
            })
            t_mark, ops_mark = now, i + 1
    total = time.perf_counter() - t_start
    return {
        "seconds": total,
        "mib_per_s": n_ops * bs / total / (1 << 20),
        "avg_lat_us": 1e6 * lat_sum / max(1, n_ops),
        "lat": hist.snapshot(),
        "samples": samples,
        "writes": n_ops - done_reads,
        "reads": done_reads,
    }


def _concurrent_write(fs, *, threads: int, total_mib: float, bs: int,
                      interval_s: float, path_tmpl: str, make_offsets):
    """Shared N-writer engine (fio numjobs=N), synchronous durability on
    every op.  ``make_offsets(t)`` returns the per-thread ``i -> offset``
    access pattern.  The returned ``mib_per_s`` is *committed-write*
    throughput: a pwrite only returns once its group is durable, so bytes
    written per wall second == bytes committed per second."""
    n_ops = int(total_mib * (1 << 20)) // bs
    per_thread = max(1, n_ops // threads)
    buf = b"x" * bs
    done = [0] * threads
    lat = [0.0] * threads
    hist = Histogram("fio.clat_us")      # per-thread cells: no contention
    finished = threading.Event()

    def worker(t):
        fd = fs.open(path_tmpl.format(t=t))
        offset = make_offsets(t)
        for i in range(per_thread):
            off = offset(i)
            t0 = time.perf_counter()
            fs.pwrite(fd, buf, off)
            fs.fsync(fd)
            dt = time.perf_counter() - t0
            hist.record_ns(int(dt * 1e9))
            lat[t] += dt
            done[t] = i + 1

    ts = [threading.Thread(target=worker, args=(t,)) for t in range(threads)]
    samples = []
    t_start = time.perf_counter()
    for t in ts:
        t.start()

    def sampler():
        mark_ops, mark_t = 0, t_start
        while not finished.wait(interval_s):
            now = time.perf_counter()
            ops = sum(done)
            samples.append({
                "t": now - t_start,
                "inst_mib_s": (ops - mark_ops) * bs / (now - mark_t) / (1 << 20),
                "cum_mib": ops * bs / (1 << 20),
            })
            mark_ops, mark_t = ops, now

    s = threading.Thread(target=sampler, daemon=True)
    s.start()
    for t in ts:
        t.join()
    finished.set()
    s.join(timeout=5)
    total = time.perf_counter() - t_start
    ops = sum(done)
    return {
        "seconds": total,
        "mib_per_s": ops * bs / total / (1 << 20),
        "avg_lat_us": 1e6 * sum(lat) / max(1, ops),
        "lat": hist.snapshot(),
        "samples": samples,
        "writes": ops,
        "bytes": ops * bs,
        "threads": threads,
    }


def concurrent_seq_write(fs, *, threads: int = 4, total_mib: float,
                         bs: int = 1024, interval_s: float = 0.05,
                         path_tmpl: str = "/seq{t}.dat"):
    """Sequential ``bs``-byte writes, one file per thread — the
    small-sequential workload where drain-side page/extent coalescing pays
    (many log entries per backend page, long contiguous runs per batch)."""
    return _concurrent_write(fs, threads=threads, total_mib=total_mib, bs=bs,
                             interval_s=interval_s, path_tmpl=path_tmpl,
                             make_offsets=lambda t: lambda i: i * bs)


def concurrent_random_write(fs, *, threads: int = 4, total_mib: float,
                            file_mib: float, bs: int = 4096,
                            interval_s: float = 0.05,
                            path_tmpl: str = "/fio{t}.dat", seed: int = 11):
    """Random ``bs``-aligned writes over ``file_mib``/threads slots per
    thread, one file per thread."""
    n_slots = max(1, int(file_mib * (1 << 20)) // bs // threads)

    def make_offsets(t):
        rng = np.random.default_rng(seed + t)
        return lambda i: int(rng.integers(0, n_slots)) * bs

    return _concurrent_write(fs, threads=threads, total_mib=total_mib, bs=bs,
                             interval_s=interval_s, path_tmpl=path_tmpl,
                             make_offsets=make_offsets)
