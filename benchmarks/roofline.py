"""Roofline analysis from the dry-run artifacts (single-pod mesh).

Per (arch x shape): three terms in seconds (v5e constants), dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS useful-compute ratio, one-line
bottleneck note.  Reads benchmarks/artifacts/*.json + *.hlo.gz; writes a
markdown table (stdout or EXPERIMENTS.md include) and a CSV.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI.  All analyzer numbers are per-device (post-SPMD HLO),
so terms are per-device seconds per step.
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
import hlo_analysis  # noqa: E402

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

SHAPE_TOKENS = {
    "train_4k": ("train", 4096 * 256),
    "prefill_32k": ("prefill", 32768 * 32),
    "decode_32k": ("decode", 128),
    "long_500k": ("decode", 1),
}


def model_flops(rec: dict) -> float:
    """Analytic 6·N·D (train) / 2·N·D (serve) per device."""
    kind, tokens = SHAPE_TOKENS[rec["shape"]]
    n = rec["active_params"]
    mult = 6 if kind == "train" else 2
    return mult * n * tokens / rec["devices"]


def analyze_cell(json_path: str, *, use_cache: bool = True) -> dict:
    with open(json_path) as f:
        rec = json.load(f)
    if rec["status"] != "ok":
        return rec
    hlo_path = json_path.replace(".json", ".hlo.gz")
    cache_path = json_path.replace(".json", ".roofline.json")
    if use_cache and os.path.exists(cache_path) and \
            os.path.getmtime(cache_path) > max(os.path.getmtime(hlo_path),
                                               os.path.getmtime(hlo_analysis.__file__)):
        with open(cache_path) as f:
            return json.load(f)
    h = hlo_analysis.analyze_file(hlo_path)
    out = dict(rec)
    out.pop("memory_analysis", None)
    out.pop("cost_analysis", None)
    out["hlo_flops"] = h["flops"]
    out["hlo_hbm_bytes"] = h["hbm_bytes"]
    out["hlo_collectives"] = h["collectives"]
    out["wire_bytes"] = h["wire_bytes"]
    out["t_compute"] = h["flops"] / PEAK_FLOPS
    out["t_memory"] = h["hbm_bytes"] / HBM_BW
    out["t_collective"] = h["wire_bytes"] / ICI_BW
    out["model_flops"] = model_flops(rec)
    out["useful_ratio"] = out["model_flops"] / max(h["flops"], 1.0)
    terms = {"compute": out["t_compute"], "memory": out["t_memory"],
             "collective": out["t_collective"]}
    out["bottleneck"] = max(terms, key=terms.get)
    # roofline fraction: useful compute time / modeled step time
    t_star = out["model_flops"] / PEAK_FLOPS
    t_step = max(terms.values())
    out["roofline_fraction"] = t_star / t_step if t_step else 0.0
    with open(cache_path, "w") as f:
        json.dump(out, f, indent=1)
    return out


def table(artifact_dir: str = None, mesh: str = "single"):
    artifact_dir = artifact_dir or os.path.join(os.path.dirname(__file__), "artifacts")
    rows = []
    for path in sorted(glob.glob(os.path.join(artifact_dir, f"*__{mesh}.json"))):
        rec = analyze_cell(path)
        rows.append(rec)
    return rows


def fmt_table(rows) -> str:
    hdr = ("| arch | shape | t_compute s | t_memory s | t_coll s | bottleneck "
           "| MODEL/HLO flops | roofline frac |\n|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3g} | "
            f"{r['t_memory']:.3g} | {r['t_collective']:.3g} | {r['bottleneck']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    return hdr + "\n".join(lines)


def main():
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    rows = table(mesh=mesh)
    print(fmt_table(rows))
    csv_path = os.path.join(os.path.dirname(__file__), f"roofline_{mesh}.csv")
    with open(csv_path, "w") as f:
        f.write("arch,shape,status,t_compute,t_memory,t_collective,bottleneck,"
                "useful_ratio,roofline_fraction,hlo_flops,model_flops,wire_bytes\n")
        for r in rows:
            if r["status"] != "ok":
                f.write(f"{r['arch']},{r['shape']},{r['status']},,,,,,,,,\n")
                continue
            f.write(f"{r['arch']},{r['shape']},ok,{r['t_compute']:.6g},"
                    f"{r['t_memory']:.6g},{r['t_collective']:.6g},{r['bottleneck']},"
                    f"{r['useful_ratio']:.4f},{r['roofline_fraction']:.4f},"
                    f"{r['hlo_flops']:.6g},{r['model_flops']:.6g},{r['wire_bytes']:.6g}\n")
    print(f"\nwrote {csv_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
