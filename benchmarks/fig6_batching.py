"""Fig. 6 — batching effect: in the saturated regime throughput tracks the
cleanup thread's fsync amortization; batch=1 is worse than the raw slow
tier (syscall per entry), large batches converge (write-combining)."""
from __future__ import annotations

from benchmarks.backends import make_stack
from benchmarks.fio_like import random_write


def run(total_mib: float = 12, log_mib: float = 2,
        batch_sizes=(1, 10, 100, 1000)):
    rows = []
    for b in batch_sizes:
        st = make_stack("nvcache+ssd", log_mib=log_mib, batch_min=b,
                        batch_max=max(b, b * 10))
        try:
            r = random_write(st.fs, total_mib=total_mib, file_mib=total_mib)
            stats = st.nv.stats()
        finally:
            st.close()
        rows.append({"batch": b, "mib_per_s": r["mib_per_s"],
                     "fsyncs": stats["cleanup_fsyncs"],
                     "entries": stats["cleanup_entries"],
                     "seconds": r["seconds"]})
        print(f"fig6/batch{b},{r['avg_lat_us']:.1f},{r['mib_per_s']:.1f}MiB/s"
              f" fsyncs={stats['cleanup_fsyncs']}", flush=True)
    return rows


if __name__ == "__main__":
    run()
