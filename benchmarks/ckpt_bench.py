"""Beyond-paper bench: the checkpoint path.

Compares save() critical-path latency and durability for a ~pytree of
training state across:
  * blob-sync      — synchronous write to the blob tier (no booster)
  * nvcache        — the paper's technique: durable at NVMM speed, drained
                     to blob in background (drain time reported separately)
  * page-cache     — volatile write-back (fast but loses the step on crash)
  * nvcache+int8   — NVCache with int8-quantized shards (compressed entries
                     push the Fig.-5 saturation point out ~4x)
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.backends import SCALE, make_stack
from repro.checkpoint import codec
from repro.checkpoint.manager import CheckpointManager
from repro.storage import tiers
from repro.storage.fsapi import NVCacheFS, TierFS
from repro.core import NVCache
from benchmarks.backends import policy


def _state(mib: float = 16, seed=0):
    rng = np.random.default_rng(seed)
    n = int(mib * (1 << 20) / 4 / 4)
    return {"params": {"w": rng.standard_normal((4, n)).astype(np.float32)},
            "opt": {"m": rng.standard_normal((4, n)).astype(np.float32) * .01,
                    "v": rng.standard_normal((4, n)).astype(np.float32) ** 2,
                    "step": np.int32(7)}}


def run(mib: float = 16):
    state = _state(mib)
    rows = []

    def bench(name, fs, nv=None, encoding=codec.ENC_ZSTD):
        mgr = CheckpointManager(fs, keep=2, encoding=encoding)
        t0 = time.perf_counter()
        info = mgr.save(1, state)
        t_save = time.perf_counter() - t0      # durability latency (critical path)
        t0 = time.perf_counter()
        if nv is not None:
            nv.flush()                          # background drain to blob
        mgr.finalize()
        t_drain = time.perf_counter() - t0
        got = mgr.restore(state)
        ok = np.allclose(got["params"]["w"], state["params"]["w"],
                         atol=0 if encoding != codec.ENC_INT8 else 0.05)
        rows.append({"stack": name, "save_s": t_save, "drain_s": t_drain,
                     "bytes": info["size"], "restore_ok": bool(ok)})
        print(f"ckpt/{name},{1e6 * t_save:.0f},"
              f"save={t_save:.3f}s drain={t_drain:.3f}s "
              f"size={info['size'] / (1 << 20):.1f}MiB ok={ok}", flush=True)

    blob = tiers.Tier(tiers.BLOB, sync=True, scale=SCALE)
    bench("blob-sync", TierFS(blob))

    # checkpoint-tuned NVCache: 64 KiB entries (large sequential writes ->
    # fewer, bigger log entries; the entry size is a first-class Policy knob)
    def nv_stack():
        tier = tiers.Tier(tiers.BLOB, sync=False, scale=SCALE)
        return NVCache(policy(max(64, mib * 4), entry=65536), tier), tier

    nv, _ = nv_stack()
    bench("nvcache", NVCacheFS(nv), nv)
    nv.shutdown()

    pc = tiers.Tier(tiers.BLOB, sync=False, scale=SCALE)
    bench("page-cache-unsafe", TierFS(pc))

    nv, _ = nv_stack()
    bench("nvcache+int8", NVCacheFS(nv), nv, encoding=codec.ENC_INT8)
    nv.shutdown()
    return rows


if __name__ == "__main__":
    run()
