"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Scaled to finish in a few
minutes on this 1-core container (see benchmarks/backends.py SCALE for how
device-time calibration keeps the paper's cross-stack ratios meaningful).

  fig3  db_bench-style kvlite workloads x 7 stacks        (paper Fig. 3)
  fig4  ideal-case FIO random write, log never saturates  (paper Fig. 4)
  fig5  log-saturation collapse vs log size               (paper Fig. 5)
  fig6  cleanup batching effect                           (paper Fig. 6)
  fig7  read-cache size insensitivity                     (paper Fig. 7)
  fig8  drain coalescing vs entry-at-a-time + fsync epoch (beyond paper;
        machine-readable via benchmarks/run_all.py -> BENCH_pr2.json)
  ckpt  checkpoint-path booster comparison                (beyond paper)
  kern  kernel micro-bench + oracle parity                (framework)
  roofline  per-(arch x shape) terms from dry-run HLO     (see EXPERIMENTS.md)
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    which = set(sys.argv[1:]) or {"fig3", "fig4", "fig5", "fig6", "fig7",
                                  "fig8", "ckpt", "kern"}
    if "fig3" in which:
        from benchmarks import fig3_dbbench
        fig3_dbbench.run(n_ops=1200)
    if "fig4" in which:
        from benchmarks import fig4_ideal
        fig4_ideal.run(total_mib=8)
    if "fig5" in which:
        from benchmarks import fig5_saturation
        fig5_saturation.run(total_mib=12, log_sizes_mib=(1, 3, 24))
    if "fig6" in which:
        from benchmarks import fig6_batching
        fig6_batching.run(total_mib=6, log_mib=1, batch_sizes=(1, 10, 100, 1000))
    if "fig7" in which:
        from benchmarks import fig7_readcache
        fig7_readcache.run(total_mib=6, cache_pages=(8, 128, 4096))
    if "fig8" in which:
        from benchmarks import fig8_coalescing
        fig8_coalescing.run_coalesce_compare(total_mib=4)
        fig8_coalescing.run_fsync_epoch(total_mib=2)
        fig8_coalescing.run_dirty_miss(n_pages=64)
    if "ckpt" in which:
        from benchmarks import ckpt_bench
        ckpt_bench.run(mib=16)
    if "kern" in which:
        from benchmarks import kernels_bench
        kernels_bench.run()
    if "roofline" in which:
        from benchmarks import roofline
        rows = roofline.table()
        print(roofline.fmt_table(rows))


if __name__ == "__main__":
    main()
