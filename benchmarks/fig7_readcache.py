"""Fig. 7 — read-cache size insensitivity: mixed 50/50 random read/write;
the read cache exists for *consistency* (dirty reads), not performance, so
throughput is flat across 100 entries ... 1M entries (scaled)."""
from __future__ import annotations

from benchmarks.backends import make_stack
from benchmarks.fio_like import random_write


def run(total_mib: float = 12, cache_pages=(8, 128, 4096)):
    rows = []
    for pages in cache_pages:
        # readahead pinned to 1: this figure reproduces the paper's
        # per-page Fig. 2 miss procedure (the PR-3 extent read path has
        # its own figure, benchmarks/fig9_readpath.py)
        st = make_stack("nvcache+ssd", log_mib=4 * total_mib,
                        read_pages=pages, readahead=1)
        try:
            r = random_write(st.fs, total_mib=total_mib, file_mib=total_mib,
                             read_fraction=0.5)
            stats = st.nv.stats()
        finally:
            st.close()
        rows.append({"pages": pages, "mib_per_s": r["mib_per_s"],
                     "lru_hits": stats["lru_hits"], "lru_misses": stats["lru_misses"],
                     "dirty_misses": stats["dirty_misses"],
                     "seconds": r["seconds"]})
        hr = stats["lru_hits"] / max(1, stats["lru_hits"] + stats["lru_misses"])
        print(f"fig7/cache{pages}p,{r['avg_lat_us']:.1f},"
              f"{r['mib_per_s']:.1f}MiB/s hit={hr:.0%}", flush=True)
    return rows


if __name__ == "__main__":
    run()
