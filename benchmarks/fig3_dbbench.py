"""Fig. 3 — db_bench-style workloads over kvlite on the seven stacks,
plus the journal-mode legacy workloads (PR 5).

Write-heavy: fillseq / fillrandom / overwrite (synchronous mode — every put
durable).  Read-heavy: readrandom / readseq.  The paper's claims checked:
NVCache+SSD >= 1.9x over the other large-storage stacks (DM-WriteCache,
SSD) on write-heavy loads; read-heavy roughly tied across stacks.

``run_journal_workload`` drives the §IV application protocols through
:mod:`repro.storage.legacy`: SQLite rollback-journal transactions (journal
fsync + db fsync + unlink per txn), SQLite WAL transactions (WAL append +
periodic checkpoint/ftruncate), and RocksDB-style sync puts (WAL fsync per
put, MANIFEST rename + WAL unlink per flush) — metadata-heavy commit paths
the durable namespace makes crash-safe over NVCache.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.backends import ALL_STACKS, make_stack
from repro.storage.kvlite import KVLite
from repro.storage.legacy import RocksLite, SQLiteRollbackDB, SQLiteWALDB

VALUE = 4096
KEY = 16


def _keys(n, *, shuffle, seed=7):
    idx = np.arange(n)
    if shuffle:
        np.random.default_rng(seed).shuffle(idx)
    return [f"k{i:014d}".encode() for i in idx]


def run_workload(stack_name: str, workload: str, n_ops: int):
    st = make_stack(stack_name, log_mib=max(64, n_ops * VALUE * 1.5 / 1e6))
    val = b"v" * VALUE
    try:
        db = KVLite(st.fs, sync=True)
        t0 = time.perf_counter()
        if workload == "fillseq":
            for k in _keys(n_ops, shuffle=False):
                db.put(k, val)
        elif workload == "fillrandom":
            for k in _keys(n_ops, shuffle=True):
                db.put(k, val)
        elif workload == "overwrite":
            base = _keys(max(16, n_ops // 4), shuffle=False)
            for k in base:
                db.put(k, val)
            rng = np.random.default_rng(3)
            t0 = time.perf_counter()
            for i in rng.integers(0, len(base), n_ops):
                db.put(base[i], val)
        elif workload in ("readrandom", "readseq"):
            keys = _keys(n_ops, shuffle=False)
            for k in keys:
                db.put(k, val)
            if st.nv is not None:
                st.nv.flush()
            t0 = time.perf_counter()
            for k in (_keys(n_ops, shuffle=True) if workload == "readrandom" else keys):
                assert db.get(k) is not None
        dt = time.perf_counter() - t0
        return {"stack": stack_name, "workload": workload, "ops": n_ops,
                "seconds": dt, "ops_per_s": n_ops / dt,
                "mib_per_s": n_ops * VALUE / dt / (1 << 20)}
    finally:
        st.close()


JOURNAL_MODELS = ["sqlite-rj", "sqlite-wal", "rocksdb"]


def run_journal_workload(stack_name: str, model: str, n_txn: int):
    """One journal-mode legacy workload on one stack; returns txn/s."""
    st = make_stack(stack_name, log_mib=max(64, n_txn * 0.05))
    try:
        if model == "sqlite-rj":
            db = SQLiteRollbackDB(st.fs, page_size=4096, npages=32)
            t0 = time.perf_counter()
            for t in range(1, n_txn + 1):
                db.commit(t)
            db.close()
        elif model == "sqlite-wal":
            db = SQLiteWALDB(st.fs, page_size=4096, npages=32)
            t0 = time.perf_counter()
            for t in range(1, n_txn + 1):
                db.commit(t)
                if t % 16 == 0:
                    db.checkpoint()
            db.close()
        elif model == "rocksdb":
            db = RocksLite(st.fs)
            val = b"v" * 4096
            t0 = time.perf_counter()
            for i in range(1, n_txn + 1):
                db.put(f"k{i % 97:08d}".encode(), val)
                if i % 64 == 0:
                    db.flush()
            db.close()
        else:
            raise KeyError(model)
        dt = time.perf_counter() - t0
        row = {"stack": stack_name, "model": model, "txns": n_txn,
               "seconds": dt, "txn_per_s": n_txn / dt}
        if st.nv is not None:
            s = st.nv.stats()
            row["meta_ops"] = s["meta_ops"]
            row["log_full_scans"] = s["log_full_scans"]
        return row
    finally:
        st.close()


def run_journal(n_txn: int = 300, stacks=("nvcache+ssd", "ssd"),
                models=None):
    rows = []
    for model in (models or JOURNAL_MODELS):
        for s in stacks:
            rows.append(run_journal_workload(s, model, n_txn))
            r = rows[-1]
            print(f"fig3-journal/{model}/{s},{1e6 * r['seconds'] / n_txn:.1f}us,"
                  f"{r['txn_per_s']:.0f}txn/s", flush=True)
    return rows


def run(n_ops: int = 2000, stacks=None, workloads=None):
    rows = []
    for wl in (workloads or ["fillseq", "fillrandom", "readrandom"]):
        for s in (stacks or ALL_STACKS):
            rows.append(run_workload(s, wl, n_ops))
            r = rows[-1]
            print(f"fig3/{wl}/{s},{1e6 * r['seconds'] / n_ops:.1f},"
                  f"{r['mib_per_s']:.1f}MiB/s", flush=True)
    return rows


if __name__ == "__main__":
    run()
