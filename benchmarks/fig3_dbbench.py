"""Fig. 3 — db_bench-style workloads over kvlite on the seven stacks.

Write-heavy: fillseq / fillrandom / overwrite (synchronous mode — every put
durable).  Read-heavy: readrandom / readseq.  The paper's claims checked:
NVCache+SSD >= 1.9x over the other large-storage stacks (DM-WriteCache,
SSD) on write-heavy loads; read-heavy roughly tied across stacks.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.backends import ALL_STACKS, make_stack
from repro.storage.kvlite import KVLite

VALUE = 4096
KEY = 16


def _keys(n, *, shuffle, seed=7):
    idx = np.arange(n)
    if shuffle:
        np.random.default_rng(seed).shuffle(idx)
    return [f"k{i:014d}".encode() for i in idx]


def run_workload(stack_name: str, workload: str, n_ops: int):
    st = make_stack(stack_name, log_mib=max(64, n_ops * VALUE * 1.5 / 1e6))
    val = b"v" * VALUE
    try:
        db = KVLite(st.fs, sync=True)
        t0 = time.perf_counter()
        if workload == "fillseq":
            for k in _keys(n_ops, shuffle=False):
                db.put(k, val)
        elif workload == "fillrandom":
            for k in _keys(n_ops, shuffle=True):
                db.put(k, val)
        elif workload == "overwrite":
            base = _keys(max(16, n_ops // 4), shuffle=False)
            for k in base:
                db.put(k, val)
            rng = np.random.default_rng(3)
            t0 = time.perf_counter()
            for i in rng.integers(0, len(base), n_ops):
                db.put(base[i], val)
        elif workload in ("readrandom", "readseq"):
            keys = _keys(n_ops, shuffle=False)
            for k in keys:
                db.put(k, val)
            if st.nv is not None:
                st.nv.flush()
            t0 = time.perf_counter()
            for k in (_keys(n_ops, shuffle=True) if workload == "readrandom" else keys):
                assert db.get(k) is not None
        dt = time.perf_counter() - t0
        return {"stack": stack_name, "workload": workload, "ops": n_ops,
                "seconds": dt, "ops_per_s": n_ops / dt,
                "mib_per_s": n_ops * VALUE / dt / (1 << 20)}
    finally:
        st.close()


def run(n_ops: int = 2000, stacks=None, workloads=None):
    rows = []
    for wl in (workloads or ["fillseq", "fillrandom", "readrandom"]):
        for s in (stacks or ALL_STACKS):
            rows.append(run_workload(s, wl, n_ops))
            r = rows[-1]
            print(f"fig3/{wl}/{s},{1e6 * r['seconds'] / n_ops:.1f},"
                  f"{r['mib_per_s']:.1f}MiB/s", flush=True)
    return rows


if __name__ == "__main__":
    run()
