"""CI entry point: run the PR's headline benchmarks and emit ONE
machine-readable JSON (``BENCH_pr10.json``) so the perf trajectory of the
repo is diffable from PR 2 onward.

    PYTHONPATH=src python benchmarks/run_all.py [--out BENCH_pr10.json] [--quick]

Emitted metrics (schema ``bench_schema: 10``):

* ``latency`` — the PR-10 observability plane: per-stage write-path
  latency percentiles from the span profiler at ``obs_level=2``
  (p50/p95/p99 per stage, foreground spans reconciled against
  wall-clock, a fence-cost row dividing commit-span time through the
  NVMM pwb/fence counters) plus the plain-vs-instrumented overhead
  rows CI gates on; fio-style results across all figures now carry a
  ``lat`` percentile snapshot, not just a running average;
* ``meta`` — reproducibility stamp: git sha, schema, device scale,
  policy knobs and the RNG seeds every figure draws from;
* ``dualmode`` — the PR-7 adaptive logging-vs-paging engine: steady-state
  persisted bytes (NVMM + backend) per committed byte on an
  overwrite-heavy stream, paged vs log mode (acceptance >= 1.5x fewer),
  plus the trickle-parity guard (classifier keeps small-write streams on
  the log; within 5% of the PR-5 tip);

* ``legacy`` — the §IV journal-mode legacy workloads over the durable
  namespace (PR 5): SQLite rollback-journal (per-txn journal fsync +
  hot-journal unlink commit point), SQLite WAL (append + checkpoint/
  ftruncate reset) and RocksDB-style sync puts (WAL fsync per put,
  MANIFEST rename-install per flush), each nvcache+ssd vs the sync-SSD
  baseline;
* ``skew`` — the PR-4 Zipf-skewed rebalancing figure (acceptance >= 1.5x
  vs the static ``fdid`` route) plus the uniform guard;
* ``cold_read`` / ``mixed`` / ``trickle`` / ``coalesce`` /
  ``fsync_epoch_hot_file`` / ``dirty_miss`` — the PR-2/PR-3 figures
  re-measured at this tip (all with ``shard_rebalance=False``, the static
  paper baseline) so regressions stay visible.  ``cold_read`` now runs
  with the PR-5 adaptive readahead ramp (2->4->8).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import (backends, fig3_dbbench, fig8_coalescing,  # noqa: E402
                        fig9_readpath, fig10_skew, fig_dualmode, fig_obs)


def _meta(quick: bool) -> dict:
    """Reproducibility stamp: enough to re-run THIS emission bit-for-bit
    (modulo wall-clock noise) from a clean checkout."""
    import dataclasses
    import subprocess
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        sha = subprocess.run(["git", "rev-parse", "HEAD"], cwd=root,
                             capture_output=True, text=True,
                             timeout=10).stdout.strip() or None
    except Exception:
        sha = None
    return {
        "git_sha": sha,
        "bench_schema": 10,
        "quick": quick,
        "device_scale": backends.SCALE,
        "policy_defaults": dataclasses.asdict(backends.policy(64)),
        "seeds": {"fio": 11, "skew_workload": 11, "skew_zipf": 7,
                  "dbbench_keys": 7},
    }


def run(quick: bool = False) -> dict:
    total_mib = 4 if quick else 8
    legacy = fig3_dbbench.run_journal(n_txn=60 if quick else 200)
    skew = fig10_skew.run_skew(total_mib=3 if quick else 10,
                               warmup_mib=1.5 if quick else 3.0)
    uniform = fig10_skew.run_uniform_guard(total_mib=3 if quick else 8)
    cold = fig9_readpath.run_cold_read(total_mib=2 if quick else 8)
    mixed = fig9_readpath.run_mixed(total_mib=2 if quick else 6)
    trickle = fig9_readpath.run_trickle(n_writes=64 if quick else 192)
    rows = fig8_coalescing.run_coalesce_compare(total_mib=total_mib)
    epoch = fig8_coalescing.run_fsync_epoch(total_mib=2 if quick else 4)
    dm = fig8_coalescing.run_dirty_miss(n_pages=64 if quick else 192)
    dual = fig_dualmode.run_bytes_per_committed(
        n_pages=16 if quick else 32, passes=4 if quick else 8)
    dual_trickle = fig_dualmode.run_trickle_parity(
        n_writes=64 if quick else 192)
    spans = fig_obs.run_span_breakdown(total_mib=1.5 if quick else 3.0)
    overhead = fig_obs.run_obs_overhead(total_mib=1.0 if quick else 2.0,
                                        repeats=3 if quick else 5)

    leg_by = {(r["model"], r["stack"]): r for r in legacy}

    def _legacy_block(model):
        nv = leg_by[(model, "nvcache+ssd")]
        ssd = leg_by[(model, "ssd")]
        return {
            "txn_per_s": nv["txn_per_s"],
            "txn_per_s_ssd": ssd["txn_per_s"],
            "speedup_x_vs_ssd": nv["txn_per_s"] / max(1e-12,
                                                      ssd["txn_per_s"]),
            "meta_ops": nv.get("meta_ops"),
            "log_full_scans": nv.get("log_full_scans"),
        }

    skew_by = {r["mode"]: r for r in skew}
    uni_by = {r["mode"]: r for r in uniform}
    cold_by_ra = {r["readahead_pages"]: r for r in cold}
    mixed_by_ra = {r["readahead_pages"]: r for r in mixed}
    trickle_by = {r["mode"]: r for r in trickle}
    by_mode = {r["mode"]: r for r in rows}
    entry, coal = by_mode["entry-at-a-time"], by_mode["coalesced"]
    ropb1 = cold_by_ra[1]["read_ops_per_byte"]
    ropb8 = cold_by_ra[8]["read_ops_per_byte"]
    ppb_tip = trickle_by["pr2-tip"]["backend_page_writes_per_committed_byte"]
    ppb_span = trickle_by["span-batches"]["backend_page_writes_per_committed_byte"]
    dual_by = {r["mode"]: r for r in dual}
    dual_tr_by = {r["mode"]: r for r in dual_trickle}
    bpc_log = dual_by["log"]["persisted_per_committed_byte"]
    bpc_paged = dual_by["paged"]["persisted_per_committed_byte"]
    clat = spans["clat"]
    return {
        "bench_schema": 10,
        "pr": 10,
        "meta": _meta(quick),
        "latency": {
            "clat_p50_us": clat["p50_us"],
            "clat_p95_us": clat["p95_us"],
            "clat_p99_us": clat["p99_us"],
            "op_p50_us": spans["op_p50_us"],
            "op_p95_us": spans["op_p95_us"],
            "op_p99_us": spans["op_p99_us"],
            "span_coverage_ratio": spans["span_coverage_ratio"],
            "stages": spans["stages"],
            "fence_cost": spans["fence_cost"],
            "obs_overhead_pct": overhead["overhead_pct"],
            "detail": [spans, overhead],
        },
        "dualmode": {
            "persisted_bytes_per_committed_byte_paged": bpc_paged,
            "persisted_bytes_per_committed_byte_log": bpc_log,
            "byte_reduction_x": bpc_log / max(1e-12, bpc_paged),
            "mode_migrations": dual_by["paged"]["mode_migrations"],
            "log_full_scans": dual_by["paged"]["log_full_scans"],
            "trickle_us_per_write": dual_tr_by["dual-engine"]["us_per_write"],
            "trickle_us_per_write_pr5_tip": dual_tr_by["pr5-tip"]["us_per_write"],
            "trickle_overhead_pct": 100.0
                * (dual_tr_by["dual-engine"]["us_per_write"]
                   - dual_tr_by["pr5-tip"]["us_per_write"])
                / max(1e-12, dual_tr_by["pr5-tip"]["us_per_write"]),
            "detail": dual + dual_trickle,
        },
        "legacy": {
            "sqlite_rollback_journal": _legacy_block("sqlite-rj"),
            "sqlite_wal": _legacy_block("sqlite-wal"),
            "rocksdb_style": _legacy_block("rocksdb"),
            "detail": legacy,
        },
        "skew": {
            "mib_per_s": skew_by["rebalance"]["mib_per_s"],
            "mib_per_s_static_fdid": skew_by["static-fdid"]["mib_per_s"],
            "rebalance_speedup_x": skew_by["rebalance"]["mib_per_s"]
                / max(1e-12, skew_by["static-fdid"]["mib_per_s"]),
            "route_epoch": skew_by["rebalance"]["route_epoch"],
            "route_migrations": skew_by["rebalance"]["route_migrations"],
            "uniform_mib_per_s": uni_by["rebalance"]["mib_per_s"],
            "uniform_mib_per_s_static_fdid": uni_by["static-fdid"]["mib_per_s"],
            "uniform_migrations": uni_by["rebalance"]["route_migrations"],
            "detail": skew + uniform,
        },
        "cold_read": {
            "mib_per_s": cold_by_ra[8]["mib_per_s"],
            "mib_per_s_readahead1": cold_by_ra[1]["mib_per_s"],
            "read_ops_per_byte": ropb8,
            "read_ops_per_byte_readahead1": ropb1,
            "read_op_reduction_x": ropb1 / max(1e-12, ropb8),
            "readahead_hit_rate": cold_by_ra[8]["readahead_hit_rate"],
            "detail": cold,
        },
        "mixed": {
            "mib_per_s": mixed_by_ra[8]["mib_per_s"],
            "mib_per_s_readahead1": mixed_by_ra[1]["mib_per_s"],
            "log_full_scans": mixed_by_ra[8]["log_full_scans"],
            "detail": mixed,
        },
        "trickle": {
            "page_writes_per_committed_byte": ppb_span,
            "page_writes_per_committed_byte_pr2_tip": ppb_tip,
            "page_write_reduction_x": ppb_tip / max(1e-12, ppb_span),
            "detail": trickle,
        },
        "coalesce": {
            "committed_mib_s": coal["mib_per_s"],
            "committed_mib_s_entry_at_a_time": entry["mib_per_s"],
            "page_writes_per_committed_byte":
                coal["backend_page_writes_per_committed_byte"],
            "page_writes_per_committed_byte_entry_at_a_time":
                entry["backend_page_writes_per_committed_byte"],
            "page_write_reduction_x":
                entry["backend_page_writes_per_committed_byte"]
                / max(1e-12, coal["backend_page_writes_per_committed_byte"]),
            "detail": rows,
        },
        "fsync_epoch_hot_file": epoch,
        "dirty_miss": dm,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_pr10.json"))
    ap.add_argument("--quick", action="store_true",
                    help="smaller workload for CI smoke runs")
    args = ap.parse_args()
    result = run(quick=args.quick)
    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=2, sort_keys=True)
        fh.write("\n")
    leg = result["legacy"]
    lat = result["latency"]
    print(f"latency plane: commit p50/p95/p99 "
          f"{lat['clat_p50_us']:.0f}/{lat['clat_p95_us']:.0f}/"
          f"{lat['clat_p99_us']:.0f}us, span coverage "
          f"{100 * lat['span_coverage_ratio']:.1f}% of wall-clock, "
          f"obs_level=2 overhead {lat['obs_overhead_pct']:+.1f}%",
          flush=True)
    print(f"wrote {args.out}: dual persistence engine — paged mode persists "
          f"{result['dualmode']['byte_reduction_x']:.2f}x fewer bytes per "
          f"committed byte than the log on overwrite-heavy streams "
          f"(trickle overhead "
          f"{result['dualmode']['trickle_overhead_pct']:+.1f}%); "
          f"legacy workloads over the durable namespace — "
          f"SQLite rollback-journal "
          f"{leg['sqlite_rollback_journal']['speedup_x_vs_ssd']:.1f}x, "
          f"SQLite WAL {leg['sqlite_wal']['speedup_x_vs_ssd']:.1f}x, "
          f"RocksDB-style {leg['rocksdb_style']['speedup_x_vs_ssd']:.1f}x "
          f"vs sync SSD; "
          f"{result['skew']['rebalance_speedup_x']:.2f}x skewed-fdid "
          f"rebalance, "
          f"{result['cold_read']['read_op_reduction_x']:.1f}x fewer backend "
          f"read ops/byte (ramped ra=8 vs 1), "
          f"{result['coalesce']['committed_mib_s']:.1f} MiB/s committed",
          flush=True)


if __name__ == "__main__":
    main()
