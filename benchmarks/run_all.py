"""CI entry point: run the PR's headline benchmarks and emit ONE
machine-readable JSON (``BENCH_pr2.json``) so the perf trajectory of the
repo is diffable from this PR onward.

    PYTHONPATH=src python benchmarks/run_all.py [--out BENCH_pr2.json] [--quick]

Emitted metrics (schema ``bench_schema: 2``):

* ``committed_mib_s``            — committed-write throughput of the
  coalescing drain engine on the 4-writer 1 KiB-sequential saturated
  workload (and ``committed_mib_s_entry_at_a_time`` for the baseline mode);
* ``page_writes_per_committed_byte`` / ``..._entry_at_a_time`` — backend
  page writes per committed byte in each mode, plus the reduction factor;
* ``dirty_miss`` — average dirty-miss read latency and entries inspected
  per miss (must equal the page's live-entry count: O(E), never O(log)).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import fig8_coalescing  # noqa: E402


def run(quick: bool = False) -> dict:
    total_mib = 4 if quick else 8
    rows = fig8_coalescing.run_coalesce_compare(total_mib=total_mib)
    epoch = fig8_coalescing.run_fsync_epoch(total_mib=2 if quick else 4)
    dm = fig8_coalescing.run_dirty_miss(n_pages=64 if quick else 192)
    by_mode = {r["mode"]: r for r in rows}
    entry, coal = by_mode["entry-at-a-time"], by_mode["coalesced"]
    ppb_entry = entry["backend_page_writes_per_committed_byte"]
    ppb_coal = coal["backend_page_writes_per_committed_byte"]
    return {
        "bench_schema": 2,
        "pr": 2,
        "workload": {"threads": coal["threads"], "bs": coal["bs"],
                     "shards": coal["shards"], "total_mib": total_mib,
                     "pattern": "sequential", "log_saturated": True},
        "committed_mib_s": coal["mib_per_s"],
        "committed_mib_s_entry_at_a_time": entry["mib_per_s"],
        "throughput_speedup_x": coal["mib_per_s"] / max(1e-9, entry["mib_per_s"]),
        "page_writes_per_committed_byte": ppb_coal,
        "page_writes_per_committed_byte_entry_at_a_time": ppb_entry,
        "page_write_reduction_x": ppb_entry / max(1e-12, ppb_coal),
        "pwrites_per_committed_byte": coal["backend_pwrites_per_committed_byte"],
        "pwrites_per_committed_byte_entry_at_a_time":
            entry["backend_pwrites_per_committed_byte"],
        "fsync_merge": {"requested": coal["fsyncs_requested"],
                        "issued": coal["fsyncs_issued"]},
        "fsync_epoch_hot_file": epoch,
        "dirty_miss": dm,
        "detail": rows,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_pr2.json"))
    ap.add_argument("--quick", action="store_true",
                    help="smaller workload for CI smoke runs")
    args = ap.parse_args()
    result = run(quick=args.quick)
    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"wrote {args.out}: "
          f"{result['committed_mib_s']:.1f} MiB/s committed, "
          f"{result['page_write_reduction_x']:.1f}x fewer backend page "
          f"writes per committed byte vs entry-at-a-time", flush=True)


if __name__ == "__main__":
    main()
