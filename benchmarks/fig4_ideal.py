"""Fig. 4 — ideal case: the log never saturates (log 2x the written data).
Claims checked: NVCache+SSD beats every other synchronous-durability stack,
including the NVMM-native FS (no syscall on the write path)."""
from __future__ import annotations

from benchmarks.backends import make_stack
from benchmarks.fio_like import random_write

STACKS = ["nvcache+ssd", "nova", "ext4-dax", "dm-writecache", "ssd"]


def run(total_mib: float = 24, stacks=STACKS):
    rows = []
    for name in stacks:
        st = make_stack(name, log_mib=2 * total_mib)
        try:
            r = random_write(st.fs, total_mib=total_mib, file_mib=total_mib)
        finally:
            st.close()
        rows.append({"stack": name, **{k: r[k] for k in
                                       ("seconds", "mib_per_s", "avg_lat_us")}})
        print(f"fig4/{name},{r['avg_lat_us']:.1f},{r['mib_per_s']:.1f}MiB/s",
              flush=True)
    return rows


if __name__ == "__main__":
    run()
