"""Kernel micro-bench: jnp oracle wall time on CPU (the portable path) and
interpret-mode parity check per kernel.  Real TPU timings are out of scope
for this container; the roofline table covers the compiled-path analysis."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(f, *args, reps=5):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return 1e6 * (time.perf_counter() - t0) / reps


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    B, S, H, KV, D = 1, 512, 8, 4, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    v = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    us = _time(jax.jit(lambda a, b, c: ref.attention_ref(a, b, c)), q, k, v)
    rows.append(("kernel/attention_ref_512", us,
                 f"{4 * B * H * S * S * D / us / 1e3:.1f}GFLOP/s"))

    b, s, h, p, n = 1, 1024, 8, 64, 64
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(key, (b, s, h)))
    A = -jnp.exp(jax.random.normal(key, (h,)))
    Bm = jax.random.normal(key, (b, s, 1, n))
    Cm = jax.random.normal(key, (b, s, 1, n))
    us = _time(jax.jit(lambda *a: ref.ssd_ref(*a, chunk=128)[0]), x, dt, A, Bm, Cm)
    rows.append(("kernel/ssd_ref_1k", us, ""))

    xq = jax.random.normal(key, (1024, 4096))
    us = _time(jax.jit(lambda a: ref.quantize_ref(a)[0]), xq)
    rows.append(("kernel/quantize_4M", us, f"{xq.size * 4 / us / 1e3:.1f}GB/s"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    return rows


if __name__ == "__main__":
    run()
