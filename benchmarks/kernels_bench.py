"""Kernel micro-bench: jnp oracle wall time on CPU (the portable path) and
interpret-mode parity check per kernel, plus the NVMM log commit-path
micro-kernel at K ∈ {1, 4} shards (the storage hot path is as much a
"kernel" of this system as the jax ops).  Real TPU timings are out of scope
for this container; the roofline table covers the compiled-path analysis."""
from __future__ import annotations

import threading
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def log_commit_rows(writers: int = 4, ops_per_writer: int = 400):
    """Raw append+drain cycle through the sharded NVMM log, no slow tier:
    measures commit-path overhead and allocation contention per shard count.
    """
    from repro.core import NVMM, Policy
    from repro.core.log import NVLog

    rows = []
    for k in (1, 4):
        pol = Policy(entry_size=4096, log_entries=1024 * k, page_size=4096,
                     batch_min=64, batch_max=256, verify_crc=False,
                     shards=k, shard_route="fdid")
        log = NVLog(NVMM(pol.nvmm_bytes), pol, format=True)
        stop = threading.Event()

        def drainer(sh):
            while not stop.is_set():
                run = sh.committed_run(sh.persistent_tail, pol.batch_max)
                if run:
                    sh.consume(sh.persistent_tail, run)
                else:
                    time.sleep(0.0005)

        ds = [threading.Thread(target=drainer, args=(sh,), daemon=True)
              for sh in log.shards]
        for d in ds:
            d.start()
        buf = b"z" * 4000

        def writer(w):
            for i in range(ops_per_writer):
                log.append(w, i * 4096, buf, timeout=30.0)

        ws = [threading.Thread(target=writer, args=(w,))
              for w in range(writers)]
        t0 = time.perf_counter()
        for w in ws:
            w.start()
        for w in ws:
            w.join()
        dt = time.perf_counter() - t0
        stop.set()
        for d in ds:
            d.join(timeout=5)
        n = writers * ops_per_writer
        rows.append((f"kernel/log_commit_k{k}_{writers}w",
                     1e6 * dt / n, f"{n / dt:.0f}commits/s"))
    return rows


def _time(f, *args, reps=5):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return 1e6 * (time.perf_counter() - t0) / reps


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    B, S, H, KV, D = 1, 512, 8, 4, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    v = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    us = _time(jax.jit(lambda a, b, c: ref.attention_ref(a, b, c)), q, k, v)
    rows.append(("kernel/attention_ref_512", us,
                 f"{4 * B * H * S * S * D / us / 1e3:.1f}GFLOP/s"))

    b, s, h, p, n = 1, 1024, 8, 64, 64
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(key, (b, s, h)))
    A = -jnp.exp(jax.random.normal(key, (h,)))
    Bm = jax.random.normal(key, (b, s, 1, n))
    Cm = jax.random.normal(key, (b, s, 1, n))
    us = _time(jax.jit(lambda *a: ref.ssd_ref(*a, chunk=128)[0]), x, dt, A, Bm, Cm)
    rows.append(("kernel/ssd_ref_1k", us, ""))

    xq = jax.random.normal(key, (1024, 4096))
    us = _time(jax.jit(lambda a: ref.quantize_ref(a)[0]), xq)
    rows.append(("kernel/quantize_4M", us, f"{xq.size * 4 / us / 1e3:.1f}GB/s"))

    rows.extend(log_commit_rows())

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    return rows


if __name__ == "__main__":
    run()
