"""Fig. 8 (beyond paper) — the page-coalescing drain engine vs the paper's
entry-at-a-time forwarding, on the workload it targets: several writers
issuing small (1 KiB) *sequential* synchronous writes into a saturated log,
so the drain rate IS the committed-write throughput (cf. Fig. 5).

``run_coalesce_compare`` runs the identical workload twice — once with
``drain_coalesce=False, fsync_epoch=False`` (one backend pwrite + one
dirty-counter dance per log entry) and once with the plan/apply engine —
and reports, per mode, committed MiB/s and *backend page writes per
committed byte* (from the tier's ``stats_page_writes``), the
dm-writeboost-style figure of merit: one submitted write for hundreds of
data blocks.

``run_dirty_miss`` measures the read half of the refactor: dirty-miss
latency with the per-page entry index (O(entries-on-page) replay) and the
entries-inspected-per-miss ratio, with the drain held off so every miss is
maximally dirty.
"""
from __future__ import annotations

import time

from benchmarks.backends import make_stack
from benchmarks.fio_like import concurrent_seq_write


def _tier_write_stats(tier) -> dict:
    files = [tier.open(p) for p in tier.paths()]
    return {
        "pwrites": sum(f.stats_writes for f in files),
        "page_writes": sum(f.stats_page_writes for f in files),
        "wvec_segments": sum(f.stats_wvec_segments for f in files),
        "bytes": sum(f.stats_bytes for f in files),
    }


def run_coalesce_compare(total_mib: float = 8, log_mib: float = 2,
                         threads: int = 4, bs: int = 1024, shards: int = 4):
    """The PR-2 headline experiment: 4 writers x 1 KiB sequential, log much
    smaller than the data (saturated), K=4 shards routed by fdid."""
    rows = []
    for coalesce in (False, True):
        st = make_stack("nvcache+ssd", log_mib=log_mib, batch_min=50,
                        batch_max=500, shards=shards, shard_route="fdid",
                        drain_coalesce=coalesce, fsync_epoch=coalesce)
        try:
            r = concurrent_seq_write(st.fs, threads=threads,
                                     total_mib=total_mib, bs=bs)
            st.nv.flush()                      # count every drained byte
            tstats = _tier_write_stats(st.tier)
            nvstats = st.nv.stats()
        finally:
            st.close()
        committed = r["bytes"]
        row = {
            "mode": "coalesced" if coalesce else "entry-at-a-time",
            "threads": threads, "bs": bs, "shards": shards,
            "mib_per_s": r["mib_per_s"],
            "avg_lat_us": r["avg_lat_us"],
            "seconds": r["seconds"],
            "committed_bytes": committed,
            "backend_pwrites": tstats["pwrites"],
            "backend_page_writes": tstats["page_writes"],
            "backend_page_writes_per_committed_byte":
                tstats["page_writes"] / max(1, committed),
            "backend_pwrites_per_committed_byte":
                tstats["pwrites"] / max(1, committed),
            "fsyncs_requested": nvstats["cleanup_fsyncs"],
            "fsyncs_issued": nvstats["cleanup_fsyncs_issued"],
            "drain_extents": nvstats["drain_extents"],
            "drain_pwritevs": nvstats["drain_pwritevs"],
        }
        rows.append(row)
        print(f"fig8/{row['mode']},{r['avg_lat_us']:.1f},"
              f"{r['mib_per_s']:.1f} MiB/s "
              f"pagewrites/MiB={row['backend_page_writes'] / max(1e-9, committed / (1 << 20)):.0f}",
              flush=True)
    return rows


def run_fsync_epoch(total_mib: float = 4, log_mib: float = 2,
                    threads: int = 4, bs: int = 1024, shards: int = 4):
    """Cross-shard fsync merging: one HOT file under stripe routing spreads
    across every shard, so K drain threads keep fsyncing the same backend
    file — the epoch scheduler collapses the concurrent ones."""
    st = make_stack("nvcache+ssd", log_mib=log_mib, batch_min=50,
                    batch_max=500, shards=shards, shard_route="stripe")
    try:
        r = concurrent_seq_write(st.fs, threads=threads, total_mib=total_mib,
                                 bs=bs, path_tmpl="/hot.dat")
        st.nv.flush()
        s = st.nv.stats()
    finally:
        st.close()
    out = {"threads": threads, "shards": shards,
           "mib_per_s": r["mib_per_s"],
           "fsyncs_requested": s["cleanup_fsyncs"],
           "fsyncs_issued": s["cleanup_fsyncs_issued"],
           "fsyncs_merged": s["cleanup_fsyncs_merged"]}
    print(f"fig8/fsync_epoch,{r['avg_lat_us']:.1f},"
          f"{out['fsyncs_requested']} fsync requests -> "
          f"{out['fsyncs_issued']} issued "
          f"({out['fsyncs_merged']} merged)", flush=True)
    return out


def run_dirty_miss(n_pages: int = 192, writes_per_page: int = 4,
                   bs: int = 1024):
    """Dirty-miss read latency with the per-page index.

    The log is large and ``batch_min`` high, so nothing drains: every page
    has ``writes_per_page`` live entries and a tiny read cache forces every
    pread through the miss path."""
    st = make_stack("nvcache+ssd", log_mib=16, batch_min=10000,
                    batch_max=10000, read_pages=2)
    try:
        fd = st.fs.open("/dm.dat")
        ps = st.nv.policy.page_size
        assert bs * writes_per_page <= ps
        for p in range(n_pages):
            for j in range(writes_per_page):
                st.fs.pwrite(fd, b"d" * bs, p * ps + j * bs)
        t0 = time.perf_counter()
        for p in range(n_pages):
            st.fs.pread(fd, ps, p * ps)
        dt = time.perf_counter() - t0
        s = st.nv.stats()
        out = {
            "pages": n_pages,
            "writes_per_page": writes_per_page,
            "dirty_misses": s["dirty_misses"],
            "replay_entries": s["replay_entries"],
            "entries_inspected_per_miss":
                s["replay_entries"] / max(1, s["dirty_misses"]),
            "log_full_scans": s["log_full_scans"],
            "avg_miss_lat_us": 1e6 * dt / n_pages,
        }
        print(f"fig8/dirty_miss,{out['avg_miss_lat_us']:.1f},"
              f"{out['entries_inspected_per_miss']:.1f} entries/miss "
              f"(full log scans: {out['log_full_scans']})", flush=True)
        return out
    finally:
        st.close()


if __name__ == "__main__":
    run_coalesce_compare()
    run_dirty_miss()
