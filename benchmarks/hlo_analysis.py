"""Trip-count-aware post-SPMD HLO analyzer.

XLA's built-in ``cost_analysis`` visits while bodies ONCE, so a
scan-over-layers model under-reports FLOPs by ~L x.  This analyzer parses
the compiled (per-device) HLO text, resolves operand shapes, and walks the
call graph multiplying while-loop bodies by their ``known_trip_count`` —
giving per-device:

  * flops        — dot/convolution FLOPs (2·M·N·K), the roofline compute term
  * hbm_bytes    — 2x the trip-weighted result bytes of top-level
                   (fusion-boundary) instructions: every materialized tensor
                   is written once and read ~once.  Counting operand bytes
                   instead overstates traffic by the operand fan-out.
  * collectives  — per-kind operand/result bytes and wire-byte estimates
                   (ring factors: AR 2x operand, AG result-operand,
                   RS operand, A2A operand, CP operand)

Shapes in post-SPMD HLO are per-device, so all numbers are per-device.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_ATOM = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\((.*)\)\s*->\s*(.+?)\s*\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_TRIP = re.compile(r'known_trip_count[\\"]*:\s*\{[\\"]*n[\\"]*:[\\"]*(\d+)')
_CALLS = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
               "after-all", "partition-id", "replica-id", "iota"}


def type_bytes(type_str: str) -> int:
    total = 0
    for m in _ATOM.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = _DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def first_atom_dims(type_str: str) -> List[int]:
    m = _ATOM.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult


def parse_computations(text: str):
    comps: Dict[str, List[Instr]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr:
            cur = hdr.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            comps[cur].append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4)))
    return comps, entry


def _dot_flops(instr: Instr, types: Dict[str, str]) -> float:
    out_dims = first_atom_dims(instr.type_str)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    cm = _CONTRACT.search(instr.rest)
    k = 1
    if cm:
        ops = _OPERANDS.findall(instr.rest.split("),")[0] + ")")
        lhs = ops[0] if ops else None
        lhs_dims = first_atom_dims(types.get(lhs, "")) if lhs else []
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                k *= lhs_dims[int(idx)]
    return 2.0 * out_elems * k


def analyze(text: str) -> dict:
    comps, entry = parse_computations(text)
    types_by_comp = {c: {i.name: i.type_str for i in instrs}
                     for c, instrs in comps.items()}
    memo: Dict[str, Cost] = {}

    def comp_cost(cname: str, *, flops_only: bool = False) -> Cost:
        key = cname + ("!f" if flops_only else "")
        if key in memo:
            return memo[key]
        cost = Cost()
        types = types_by_comp.get(cname, {})
        for ins in comps.get(cname, ()):
            op = ins.opcode
            if op == "while":
                trip = 1.0
                tm = _TRIP.search(ins.rest)
                if tm:
                    trip = float(tm.group(1))
                for target in _CALLS.findall(ins.rest) + _COND.findall(ins.rest):
                    cost.add(comp_cost(target, flops_only=flops_only), trip)
            elif op in ("call", "conditional", "custom-call", "map",
                        "reduce", "reduce-window", "sort", "scatter", "fusion",
                        "async-start", "select-and-scatter"):
                for target in _CALLS.findall(ins.rest):
                    cost.add(comp_cost(target, flops_only=True))
                if not flops_only and op != "call":
                    cost.hbm_bytes += 2 * type_bytes(ins.type_str)
            elif op in ("dot", "convolution"):
                cost.flops += _dot_flops(ins, types)
                if not flops_only:
                    cost.hbm_bytes += 2 * type_bytes(ins.type_str)
            elif op in COLLECTIVES or any(op.startswith(c + "-") for c in COLLECTIVES):
                base = op
                for c in COLLECTIVES:
                    if op.startswith(c):
                        base = c
                if base.endswith("-start"):
                    base = base[:-6]
                res = type_bytes(ins.type_str)
                opb = 0
                for oname in _OPERANDS.findall(ins.rest):
                    if oname in types:
                        opb += type_bytes(types[oname])
                wire = {"all-reduce": 2 * opb,
                        "all-gather": max(res - opb, opb),
                        "reduce-scatter": opb,
                        "all-to-all": opb,
                        "collective-permute": opb}[base]
                if not flops_only:
                    cost.coll[base + "_operand"] = cost.coll.get(base + "_operand", 0) + opb
                    cost.coll[base + "_wire"] = cost.coll.get(base + "_wire", 0) + wire
                    cost.coll[base + "_count"] = cost.coll.get(base + "_count", 0) + 1
                    cost.hbm_bytes += 2 * res
            elif op in _SKIP_BYTES:
                continue
            else:
                if not flops_only:
                    cost.hbm_bytes += 2 * type_bytes(ins.type_str)
        memo[key] = cost
        return cost

    if entry is None:
        raise ValueError("no ENTRY computation found")
    total = comp_cost(entry)
    return {
        "flops": total.flops,
        "hbm_bytes": total.hbm_bytes,
        "collectives": dict(total.coll),
        "wire_bytes": sum(v for k, v in total.coll.items() if k.endswith("_wire")),
        "n_computations": len(comps),
    }


def analyze_file(path: str) -> dict:
    import gzip
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rt") as f:
        return analyze(f.read())


if __name__ == "__main__":
    import json
    import sys
    print(json.dumps(analyze_file(sys.argv[1]), indent=1))
