"""Fleet fault runtime: heartbeats, straggler detection, failover planning.

At 1000+ nodes the controller must (a) notice dead/slow workers fast,
(b) decide a restart plan from the last durable checkpoint (which, with
NVCache, is at most one step old — synchronous durability), and (c) keep
spares warm.  This module is the control-plane logic, written against an
injectable clock so every policy is unit-testable on CPU.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class WorkerState:
    worker_id: str
    last_step: int = -1
    last_beat: float = 0.0
    step_times: List[float] = dataclasses.field(default_factory=list)
    alive: bool = True

    def rate(self) -> Optional[float]:
        if len(self.step_times) < 2:
            return None
        recent = self.step_times[-8:]
        return sum(recent) / len(recent)


@dataclasses.dataclass
class FailoverPlan:
    restart_step: int
    dead: List[str]
    stragglers: List[str]
    replacements: Dict[str, str]
    remesh: bool                      # no spares left -> elastic re-mesh


class HeartbeatMonitor:
    def __init__(self, *, dead_after_s: float = 30.0,
                 straggler_factor: float = 2.0,
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.dead_after_s = dead_after_s
        self.straggler_factor = straggler_factor
        self.workers: Dict[str, WorkerState] = {}
        self.spares: List[str] = []
        self.checkpointed_step = -1

    def register(self, worker_id: str, *, spare: bool = False) -> None:
        self.workers[worker_id] = WorkerState(worker_id, last_beat=self.clock())
        if spare:
            self.spares.append(worker_id)

    def beat(self, worker_id: str, step: int) -> None:
        w = self.workers[worker_id]
        now = self.clock()
        if w.last_step >= 0 and step > w.last_step:
            dt = (now - w.last_beat) / max(1, step - w.last_step)
            w.step_times.append(dt)
        w.last_step = step
        w.last_beat = now
        w.alive = True

    def note_checkpoint(self, step: int) -> None:
        self.checkpointed_step = max(self.checkpointed_step, step)

    # ------------------------------------------------------------- policies
    def dead_workers(self) -> List[str]:
        now = self.clock()
        return [w.worker_id for w in self.workers.values()
                if w.worker_id not in self.spares
                and now - w.last_beat > self.dead_after_s]

    def stragglers(self) -> List[str]:
        rates = [w.rate() for w in self.workers.values()
                 if w.rate() is not None and w.worker_id not in self.spares]
        if len(rates) < 3:
            return []
        med = sorted(rates)[len(rates) // 2]
        return [w.worker_id for w in self.workers.values()
                if w.worker_id not in self.spares and w.rate() is not None
                and w.rate() > self.straggler_factor * med]

    def plan(self) -> Optional[FailoverPlan]:
        dead = self.dead_workers()
        stragglers = self.stragglers()
        if not dead and not stragglers:
            return None
        to_replace = dead + stragglers
        replacements, spares = {}, list(self.spares)
        for w in to_replace:
            if spares:
                replacements[w] = spares.pop(0)
        return FailoverPlan(
            restart_step=self.checkpointed_step,
            dead=dead, stragglers=stragglers,
            replacements=replacements,
            remesh=len(replacements) < len(to_replace))

    def apply(self, plan: FailoverPlan) -> None:
        for old, new in plan.replacements.items():
            self.spares.remove(new)
            self.workers.pop(old, None)
        for w in plan.dead:
            self.workers.pop(w, None)
