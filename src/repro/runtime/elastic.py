"""Elastic scaling: re-mesh to a surviving device count and re-slice the
checkpoint to the new topology.

The checkpoint codec stores row-chunked leaves with global shapes, so a
host joining a smaller/larger mesh restores exactly the rows of each leaf
its shard owns (``repro.checkpoint.manager.restore(slice_rows=...)``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np


def viable_mesh(n_devices: int, *, model_parallel: int = 16,
                multi_pod_threshold: int = 512) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest (pod, data, model) grid that fits the surviving devices,
    keeping the model axis intact (TP degree is fixed by memory), shedding
    data-parallel rows first — the standard elastic policy."""
    if n_devices % model_parallel:
        model_parallel = _largest_pow2_divisor(n_devices, model_parallel)
    data = n_devices // model_parallel
    if n_devices >= multi_pod_threshold and data % 2 == 0:
        return (2, data // 2, model_parallel), ("pod", "data", "model")
    return (data, model_parallel), ("data", "model")


def _largest_pow2_divisor(n: int, cap: int) -> int:
    p = 1
    while p * 2 <= cap and n % (p * 2) == 0:
        p *= 2
    return p


def shard_rows(key: str, global_shape: tuple, *, shard_idx: int,
               n_shards: int) -> Optional[tuple]:
    """Row range of leaf ``key`` owned by FSDP shard ``shard_idx``.

    Row-sharding applies to rank>=2 leaves whose leading dim divides the
    shard count; vectors/scalars (norm weights, counters) replicate —
    matching the partition rules in repro.parallel.sharding."""
    if len(global_shape) < 2 or global_shape[0] % n_shards:
        return None
    per = global_shape[0] // n_shards
    return (shard_idx * per, (shard_idx + 1) * per)


def reshard_restore(manager, tree_like, *, shard_idx: int, n_shards: int,
                    step: Optional[int] = None):
    """Restore this shard's slice of every leaf for a new topology."""
    def slicer(key, shape):
        return shard_rows(key, shape, shard_idx=shard_idx, n_shards=n_shards)
    return manager.restore(tree_like, step=step, slice_rows=slicer)
