"""Trace-time mesh context.

Model code is mesh-agnostic under pjit, but the explicit-EP MoE path uses
``shard_map`` and therefore needs the concrete Mesh at trace time.  Step
builders set it around tracing; with no mesh set, models fall back to the
pjit-auto code paths.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

from jax.sharding import Mesh

_LOCAL = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_LOCAL, "mesh", None)


@contextlib.contextmanager
def with_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _LOCAL.mesh = mesh
    try:
        yield
    finally:
        _LOCAL.mesh = prev


def constrain(x, axes):
    """Divisibility-checked ``with_sharding_constraint`` against the current
    mesh; identity when no mesh is in scope (single-device paths).

    ``axes``: per-dim mesh-axis name (or None).  Dims that don't divide the
    axis size fall back to unconstrained.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax == "__dp__":               # all non-model axes (the DP front)
            ax = tuple(a for a in mesh.axis_names if a != "model")
        if isinstance(ax, tuple):
            total = 1
            for a in ax:
                total *= sizes.get(a, 1)
            spec.append(ax if total > 0 and dim % total == 0 else None)
        elif ax is not None and ax in sizes and dim % sizes[ax] == 0:
            spec.append(ax)
        else:
            spec.append(None)
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
