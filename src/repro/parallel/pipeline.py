"""Pipeline parallelism: GPipe schedule over a mesh axis via shard_map +
collective-permute (the rotating-buffer formulation).

Layers are split into ``n_stages`` contiguous groups; stage s holds its
group's params (leading dim sharded over the stage axis).  Microbatches
enter at stage 0, activations rotate stage->stage+1 each tick, outputs
drain from the last stage.  The whole schedule is differentiable
(``ppermute`` has a transpose), so ``jax.grad`` through
:func:`pipeline_apply` runs the reverse schedule automatically — the
1F1B-style memory optimization is left as a further §Perf iteration.

Intended mapping at production scale: ``pod`` axis = stage axis (pods are
the slow-link tier, and PP's point-to-point activations are the cheapest
traffic to put there); within a stage the usual DP/TP shardings apply.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

try:
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover - older spelling
    from jax.experimental.shard_map import shard_map as _shard_map


def pipeline_apply(mesh, axis: str, stage_fn, stage_params, microbatches):
    """Run ``microbatches`` (M, mb, ...) through ``n_stages`` of
    ``stage_fn(params_slice, x) -> y``.

    ``stage_params``: pytree whose leaves have leading dim n_stages ==
    mesh axis size.  Returns (M, mb, ...) outputs.
    """
    n = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    M = microbatches.shape[0]
    perm = [(i, (i + 1) % n) for i in range(n)]

    def local(params, mbs):
        # params: this stage's slice (leading dim 1); mbs: full microbatches
        params = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(mbs[0])
        outs = jnp.zeros_like(mbs)
        for t in range(M + n - 1):
            x_in = jnp.where(stage == 0,
                             mbs[min(t, M - 1)] if t < M else jnp.zeros_like(buf),
                             buf)
            y = stage_fn(params, x_in)
            buf = jax.lax.ppermute(y, axis, perm)
            # after the rotate, stage 0 holds what the LAST stage produced
            # at tick t, which is microbatch t-(n-1) fully processed
            o = t - (n - 1)
            if o >= 0:
                outs = outs.at[o].set(jnp.where(stage == 0, buf, outs[o]))
        # only stage 0 holds real outputs (others kept zeros); a psum makes
        # the result replicated so out_specs can be P()
        outs = jax.lax.psum(outs, axis)
        return outs

    P = jax.sharding.PartitionSpec
    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    kwargs = dict(mesh=mesh, in_specs=(pspec, P()), out_specs=P())
    try:
        f = _shard_map(local, check_vma=False, **kwargs)
    except TypeError:
        f = _shard_map(local, check_rep=False, **kwargs)
    return f(stage_params, microbatches)


def split_stages(stacked_layer_params, n_stages: int):
    """Reshape scan-stacked layer params (L, ...) -> (n_stages, L/stages, ...)."""
    def r(a):
        L = a.shape[0]
        assert L % n_stages == 0, "layers must divide stages"
        return a.reshape(n_stages, L // n_stages, *a.shape[1:])
    return jax.tree.map(r, stacked_layer_params)
