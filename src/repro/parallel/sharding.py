"""Partition rules: DP / FSDP / TP / EP over the production mesh.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  Baseline policy (MaxText-style fsdp+tensor):

  * batch dims           -> ("pod", "data")           (DP across pods)
  * attention heads / ffn / vocab -> "model"          (TP)
  * MoE expert dim       -> "model"                   (EP: E/16 per shard)
  * the largest remaining weight dim -> "data"        (FSDP / ZeRO-3;
    optimizer moments follow the same specs, so ZeRO falls out)
  * pods never shard parameters (inter-pod ICI is the slow tier: pods do
    pure DP with one gradient all-reduce across "pod")

Every axis assignment is divisibility-checked against the mesh so that
e.g. granite's single KV head or hymba's 50 SSM heads silently fall back
to replication instead of erroring.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

FSDP = "__fsdp__"    # placeholder resolved to "data" when fsdp is on

# spec for the trailing dims of each named leaf (leading dims -> None)
_RULES = {
    # dense attention
    "wq": (FSDP, "model"), "wk": (FSDP, "model"), "wv": (FSDP, "model"),
    "wo": ("model", FSDP),
    # mlp (swiglu)
    "wg": (FSDP, "model"), "wu": (FSDP, "model"), "wd": ("model", FSDP),
    # whisper mlp / biases
    "w1": (FSDP, "model"), "b1": ("model",), "w2": ("model", FSDP), "b2": (None,),
    # MLA
    "wq_a": (FSDP, "model"), "wq_b": (FSDP, "model"),
    "wkv_a": (FSDP, None), "wkv_b": (FSDP, "model"),
    # MoE (rank>=3 leaves resolved by _MOE_RULES)
    "router": (FSDP, None),
    # SSM (activations replicated over model; weights FSDP only)
    "in_proj": (FSDP, None), "out_proj": (FSDP, None),
    "conv_w": (None, None), "conv_b": (None,),
    # embeddings
    "embed": ("model", FSDP), "unembed": (FSDP, "model"),
    "pos_table": (FSDP, None), "dec_pos": (FSDP, None),
}

_MOE_RULES = {   # (E, d, f) / (E, f, d)
    "wg": ("model", FSDP, None), "wu": ("model", FSDP, None),
    "wd": ("model", None, FSDP),
}


def mesh_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _fits(dim: int, axis, sizes) -> bool:
    if axis is None:
        return True
    if isinstance(axis, tuple):
        total = 1
        for a in axis:
            total *= sizes.get(a, 1)
        return dim % total == 0
    return dim % sizes.get(axis, 1) == 0


def _resolve(tail_spec, shape, sizes, fsdp):
    """Right-align ``tail_spec`` onto ``shape``; divisibility-checked."""
    spec = [None] * len(shape)
    off = len(shape) - len(tail_spec)
    if off < 0:
        tail_spec = tail_spec[-len(shape):]
        off = 0
    for i, ax in enumerate(tail_spec):
        if ax == FSDP:
            ax = "data" if fsdp else None
        if ax is not None and _fits(shape[off + i], ax, sizes):
            spec[off + i] = ax
    return P(*spec)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def _in_moe(path) -> bool:
    return any(getattr(e, "key", None) == "moe" for e in path)


def param_specs(params, mesh: Mesh, *, fsdp: bool = True):
    """PartitionSpec tree mirroring ``params``."""
    sizes = mesh_sizes(mesh)

    def rule(path, leaf):
        name = _leaf_name(path)
        if _in_moe(path) and name in _MOE_RULES and leaf.ndim >= 3:
            return _resolve(_MOE_RULES[name], leaf.shape, sizes, fsdp)
        if name in _RULES:
            return _resolve(_RULES[name], leaf.shape, sizes, fsdp)
        return P()      # norms, scalars, gates: replicated

    return jax.tree_util.tree_map_with_path(rule, params)


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_specs(batch, mesh: Mesh):
    """Shard the leading batch dim over the DP axes.  For mrope positions
    (3, B, S) the batch dim is axis 1."""
    dp = dp_axes(mesh)
    sizes = mesh_sizes(mesh)

    def rule(path, leaf):
        name = _leaf_name(path)
        bdim = 1 if name == "positions" and leaf.ndim == 3 else 0
        spec = [None] * leaf.ndim
        if _fits(leaf.shape[bdim], dp, sizes):
            spec[bdim] = dp
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, batch)


def cache_specs(cache, mesh: Mesh):
    """Decode caches: batch over DP; KV-heads over model when divisible.
    Layout (stacked over layers): k/v (L,B,S,KV,hd), ckv (L,B,S,r),
    ssm_state (L,B,h,P,n), conv_state (L,B,K,c), pos scalar."""
    dp = dp_axes(mesh)
    sizes = mesh_sizes(mesh)

    def rule(path, leaf):
        name = _leaf_name(path)
        if leaf.ndim == 0 or name == "pos":
            return P()
        spec = [None] * leaf.ndim
        if leaf.ndim >= 2 and _fits(leaf.shape[1], dp, sizes):
            spec[1] = dp
        if name in ("k", "v", "ek", "ev") and leaf.ndim == 5:
            if _fits(leaf.shape[3], "model", sizes):
                spec[3] = "model"            # TP over KV heads
            elif _fits(leaf.shape[2], "model", sizes):
                spec[2] = "model"            # context-parallel cache (MQA/GQA<16)
        elif name in ("ckv", "krope") and leaf.ndim == 4 and \
                _fits(leaf.shape[2], "model", sizes):
            spec[2] = "model"                # MLA latent cache: shard sequence
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, cache)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
