"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax init; everything else
sees the single real CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 v5e pod (data, model) or 2 pods (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests (8 fake devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_single_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))
