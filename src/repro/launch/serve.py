"""Serving launcher: batched prefill + decode with NVCache-backed request
logging (every accepted request is synchronously durable before decode —
no request is lost to a crash).

    python -m repro.launch.serve --arch llama3.2-1b --smoke --tokens 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import all_archs, get_config, get_smoke
from repro.core import NVCache, Policy
from repro.models.registry import build
from repro.storage.fsapi import NVCacheFS
from repro.storage.tiers import BLOB, Tier


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=all_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    nv = NVCache(Policy(entry_size=4096, log_entries=4096,
                        read_cache_pages=64, batch_min=8, batch_max=256,
                        verify_crc=False), Tier(BLOB))
    fs = NVCacheFS(nv)
    log_fd = fs.open("/requests.jsonl")
    log_off = 0

    B, P = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 1,
                                 cfg.vocab - 1).astype(jnp.int32)
    # request accepted == durably logged (synchronous durability)
    line = (json.dumps({"batch": B, "prompt_len": P}) + "\n").encode()
    log_off += fs.pwrite(log_fd, line, log_off)

    if cfg.family == "encdec":
        batch = {"frames": jnp.zeros((B, P, cfg.d_model), cfg.cdt),
                 "dec_tokens": prompts[:, :8]}
    else:
        batch = {"tokens": prompts}
    t0 = time.perf_counter()
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, P + args.tokens + 8)
                            )(params, batch)
    step = jax.jit(model.decode_step)
    out = []
    for _ in range(args.tokens):
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(tok)
        logits, cache = step(params, cache, tok)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    tokens = jnp.concatenate(out, 1)
    line = (json.dumps({"completed": tokens.shape[0] * tokens.shape[1],
                        "seconds": dt}) + "\n").encode()
    fs.pwrite(log_fd, line, log_off)
    print(json.dumps({"arch": cfg.arch, "batch": B,
                      "tokens_per_s": B * args.tokens / dt,
                      "sample": tokens[0, :8].tolist()}))
    fs.close(log_fd)
    nv.shutdown()


if __name__ == "__main__":
    main()
