"""Training launcher.

    python -m repro.launch.train --arch llama3.2-1b --smoke --steps 50

Wires: config -> model -> AdamW -> deterministic data pipeline -> NVCache
(fast persistent tier in front of the blob tier) -> train loop with
synchronous-durability checkpoints, metrics JSONL and crash-safe resume.
On this container use --smoke (reduced config); the full configs are for
the production mesh (see repro.launch.dryrun).
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs.registry import all_archs, get_config, get_smoke
from repro.core import NVCache, Policy
from repro.data.pipeline import SyntheticTokens
from repro.launch.mesh import make_debug_mesh
from repro.models.registry import build
from repro.optim.adamw import AdamW
from repro.optim.schedules import warmup_cosine
from repro.storage.fsapi import NVCacheFS
from repro.storage.tiers import BLOB, Tier
from repro.train import loop as train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=all_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config runnable on CPU")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="none", choices=["none", "debug"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-mib", type=float, default=64)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build(cfg)
    opt = AdamW(lr=args.lr, schedule=warmup_cosine(10, args.steps))
    pipe = SyntheticTokens(cfg.vocab, args.batch, args.seq, seed=0,
                           family=cfg.family, d_model=cfg.d_model)

    policy = Policy(entry_size=16384,
                    log_entries=max(64, int(args.log_mib * (1 << 20) // 16384)),
                    read_cache_pages=256, batch_min=16, batch_max=1024,
                    verify_crc=False)
    tier = Tier(BLOB)                      # the slow/blob tier
    nv = NVCache(policy, tier)
    fs = NVCacheFS(nv)

    mesh = make_debug_mesh() if args.mesh == "debug" else None
    state, hist = train_loop.train(
        model, opt, pipe, fs, total_steps=args.steps,
        ckpt_every=args.ckpt_every, mesh=mesh,
        compress_grads=args.compress_grads)
    nv.flush()
    print(json.dumps({
        "arch": cfg.arch, "steps": len(hist),
        "first_loss": hist[0]["loss"] if hist else None,
        "last_loss": hist[-1]["loss"] if hist else None,
        "nvcache": nv.stats(),
    }, indent=1))
    nv.shutdown()


if __name__ == "__main__":
    main()
