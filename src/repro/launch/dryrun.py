import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, record memory/cost/collective analysis to JSON.

The two lines above MUST precede any other import (jax locks the device
count on first init); do not move them.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] --out benchmarks/artifacts
"""

import argparse
import gzip
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.registry import all_archs, get_config
from repro.configs.shapes import SHAPES, applicable, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build
from repro.optim.adamw import AdamW
from repro.parallel import sharding as shd
from repro.train import steps as tsteps

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3": 1,
                "f8e5m2": 1, "s16": 2, "u16": 2, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^\s]*\s+(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective kind from post-SPMD HLO.

    Post-partitioning shapes are per-device, so these are per-device bytes
    crossing the interconnect (all-gather results count received bytes;
    all-reduce counts one traversal — the ring factor is applied in the
    roofline, not here)."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        out[kind] = out.get(kind, 0) + nbytes
        out[kind + "_count"] = out.get(kind + "_count", 0) + 1
    return out


def _analyze(compiled):
    res = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                res["mem_" + k] = int(v)
        res["memory_analysis"] = str(ma)
    except Exception as e:  # CPU backend may not implement everything
        res["memory_analysis_error"] = repr(e)
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        res["flops"] = float(ca.get("flops", 0.0))
        res["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
        res["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))}
    except Exception as e:
        res["cost_analysis_error"] = repr(e)
    try:
        res["collectives"] = collective_bytes(compiled.as_text())
    except Exception as e:
        res["collectives_error"] = repr(e)
    return res


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *, fsdp=True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build(cfg)
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            opt = AdamW(moment_dtype="bfloat16" if cfg.param_dtype == "bfloat16" else None)
            step = tsteps.bind_mesh(tsteps.make_train_step(model, opt), mesh)
            spec = input_specs(cfg, shape)
            (in_sh, b_sh), (out_sh, _m), state_abs = tsteps.train_shardings(
                model, opt, mesh, spec, fsdp=fsdp)
            jitted = jax.jit(step, in_shardings=(in_sh, b_sh),
                             out_shardings=(out_sh, None), donate_argnums=(0,))
            lowered = jitted.lower(state_abs, spec)
        elif shape.kind == "prefill":
            step = tsteps.bind_mesh(tsteps.make_prefill_step(model, shape.seq), mesh)
            spec = input_specs(cfg, shape)
            shards, params_abs = tsteps.serve_shardings(
                model, mesh, jax.eval_shape(
                    lambda: model.init_cache(shape.batch, shape.seq)),
                batch_like=spec)
            jitted = jax.jit(step, in_shardings=(shards["params"], shards["batch"]),
                             out_shardings=(None, shards["cache"]))
            lowered = jitted.lower(params_abs, spec)
        else:  # decode
            step = tsteps.bind_mesh(tsteps.make_serve_step(model), mesh)
            cache_abs, tokens_abs = input_specs(cfg, shape)
            shards, params_abs = tsteps.serve_shardings(model, mesh, cache_abs)
            tok_sh = shd.named(mesh, shd.batch_specs({"tokens": tokens_abs}, mesh))["tokens"]
            jitted = jax.jit(step, in_shardings=(shards["params"], shards["cache"], tok_sh),
                             out_shardings=(None, shards["cache"]),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs, tokens_abs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single",
           "status": "ok", "fsdp": fsdp,
           "devices": int(mesh.devices.size),
           "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
           "params": cfg.param_count(), "active_params": cfg.active_param_count()}
    rec.update(_analyze(compiled))
    try:
        rec["_hlo_text"] = compiled.as_text()
    except Exception:
        pass
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = all_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for arch in archs:
        for shape in shapes:
            for m in meshes:
                cells.append((arch, shape, m == "multi"))

    failures = 0
    for arch, shape, multi in cells:
        tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
        path = os.path.join(args.out, tag + ".json")
        try:
            rec = lower_cell(arch, shape, multi, fsdp=not args.no_fsdp)
        except Exception as e:
            rec = {"arch": arch, "shape": shape,
                   "mesh": "multi" if multi else "single",
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()}
            failures += 1
        hlo = rec.pop("_hlo_text", None)
        if hlo is not None:
            with gzip.open(os.path.join(args.out, tag + ".hlo.gz"), "wt") as f:
                f.write(hlo)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f" flops={rec.get('flops', 0):.3e}"
                     f" coll={sum(v for k, v in rec.get('collectives', {}).items() if not k.endswith('_count')):.3e}B"
                     f" compile={rec.get('compile_s')}s")
        elif status == "error":
            extra = " " + rec["error"][:200]
        print(f"[dryrun] {tag}: {status}{extra}", flush=True)
        if status == "ok" and rec.get("memory_analysis"):
            print("  " + rec["memory_analysis"].replace("\n", "\n  ")[:400], flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
