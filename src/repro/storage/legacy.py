"""Legacy-application workload models: SQLite-style and RocksDB-style
crash-consistency protocols over plain file operations (paper §IV).

The paper's §IV experiments run *unmodified* SQLite and RocksDB over
NVCache.  What makes those applications interesting for a cache claiming
synchronous durability is not their data plane — it is their **metadata
protocols**: every one of them turns a multi-write transaction into an
atomic event through a namespace operation the kernel promises to be
atomic.  These models reproduce exactly those protocols, small enough to
fuse-crash at every step (tests/test_legacy_crash.py) and fast enough to
benchmark (benchmarks/fig3_dbbench.py):

* :class:`SQLiteRollbackDB` — rollback-journal mode (SQLite's default
  ``journal_mode=DELETE``): before touching the database, the *original*
  images of every page a transaction modifies are written to a side
  journal and fsynced; the database pages are then updated in place and
  fsynced; the **unlink of the journal is the commit point**.  Recovery
  ("hot journal" detection): a surviving journal with a valid header means
  the transaction did not commit — roll the original pages back and delete
  the journal.
* :class:`SQLiteWALDB` — write-ahead-log mode: a transaction appends page
  frames plus a commit frame to the WAL and fsyncs it (the database is
  untouched); a checkpoint copies committed frames into the database,
  fsyncs it, then **resets the WAL with an ftruncate-to-zero**.  Recovery:
  replay every whole committed transaction from the WAL, ignore the torn
  tail.
* :class:`RocksLite` — LSM-style: synchronous puts append CRC'd records to
  a numbered WAL; a flush writes the memtable to an SST file, then
  **renames a freshly-written MANIFEST into place** — the install point
  that atomically switches the live file set to {SSTs, new WAL} — and
  unlinks the old WAL.  Recovery: read the MANIFEST (or start empty), load
  the SSTs it lists, replay the current WAL up to the first torn record.

All three run over the :class:`repro.storage.fsapi.FS` protocol, so the
same unmodified code drives ``NVCacheFS`` (the paper's stack: fsync free,
namespace ops journaled in NVMM) and ``TierFS`` (the legacy baselines).

Each model doubles as its own **crash-consistency oracle**: database pages
carry content deterministic in (txn, page), page 0 carries the committed
transaction counter, and :meth:`check_consistent` verifies that the state
observed after crash + recovery is the one produced by a legal prefix of
transactions — every acknowledged transaction present, the in-flight one
whole or absent, never a torn mix.
"""
from __future__ import annotations

import struct
import zlib
from typing import Dict, List, Optional, Tuple

from repro.storage.fsapi import FS

# ---------------------------------------------------------------------------
# deterministic page/transaction content: the oracle's ground truth


def page_content(txn: int, page_no: int, page_size: int) -> bytes:
    """Deterministic content of ``page_no`` as written by ``txn``."""
    seed = (txn * 1_000_003 + page_no) & 0xFFFFFFFF
    unit = struct.pack("<IIQ", txn, page_no, seed)
    return (unit * (page_size // len(unit) + 1))[:page_size]


def touched_pages(txn: int, npages: int, spread: int = 3) -> List[int]:
    """Deterministic page set of transaction ``txn`` (pages 1..npages-1;
    page 0 is the header)."""
    if npages <= 1:
        return []
    rng = txn * 2_654_435_761
    out = []
    for i in range(spread):
        rng = (rng * 6_364_136_223_846_793_005 + 1_442_695_040_888_963_407) \
            & (2 ** 64 - 1)
        out.append(1 + (rng >> 33) % (npages - 1))
    return sorted(set(out))


def expected_pages(t_star: int, npages: int) -> Dict[int, int]:
    """page_no -> the txn whose content the page holds after txns 1..t_star
    (0 == never written: all zeros)."""
    last: Dict[int, int] = {p: 0 for p in range(1, npages)}
    for t in range(1, t_star + 1):
        for p in touched_pages(t, npages):
            last[p] = t
    return last


_HDRPAGE = struct.Struct("<QI")     # committed txn counter, crc32(counter)


def header_bytes(txn: int, page_size: int) -> bytes:
    raw = _HDRPAGE.pack(txn, zlib.crc32(struct.pack("<Q", txn)))
    return raw + b"\x00" * (page_size - len(raw))


def parse_header(raw: bytes) -> Optional[int]:
    """The committed txn counter, or None if the header page is torn."""
    if len(raw) < _HDRPAGE.size:
        return 0 if not any(raw) else None
    txn, crc = _HDRPAGE.unpack_from(raw)
    if txn == 0 and crc == 0:
        return 0
    return txn if zlib.crc32(struct.pack("<Q", txn)) == crc else None


# ---------------------------------------------------------------------------
class SQLiteRollbackDB:
    """SQLite rollback-journal mode (``journal_mode=DELETE``).

    Commit protocol per transaction (paper §IV's db_bench synchronous
    mode):

    1. write the original images of every page about to change (header
       page included) to ``<db>-journal`` — body first, magic header last
       — and fsync it: the undo log is durable before the db is touched;
    2. write the new page images into the database and fsync it;
    3. **unlink the journal — the commit point.**

    A crash before (3) leaves a hot journal; :meth:`__init__` rolls the
    original pages back (the transaction never happened).  A crash after
    (3) keeps the transaction.  Either way the database equals a legal
    prefix — what :meth:`check_consistent` verifies.
    """

    MAGIC = 0x4A524E4C          # "JRNL"
    _JHDR = struct.Struct("<II")       # magic, page count
    _JREC = struct.Struct("<I")        # page_no (+ page image)

    def __init__(self, fs: FS, path: str = "/app.db", *,
                 page_size: int = 512, npages: int = 8):
        self.fs = fs
        self.path = path
        self.jpath = path + "-journal"
        self.page_size = page_size
        self.npages = npages
        self._recover()
        self.fd = fs.open(path)
        if fs.size(self.fd) == 0:
            fs.pwrite(self.fd, header_bytes(0, page_size), 0)
            fs.fsync(self.fd)

    # ------------------------------------------------------------ recovery
    def _recover(self) -> None:
        """Hot-journal detection + rollback (SQLite's pager recovery)."""
        if not self.fs.exists(self.jpath):
            return
        jfd = self.fs.open(self.jpath)
        try:
            jsize = self.fs.size(jfd)
            hdr = self.fs.pread(jfd, self._JHDR.size, 0)
            if len(hdr) < self._JHDR.size:
                return                      # header never landed: cold
            magic, count = self._JHDR.unpack(hdr)
            rec = self._JREC.size + self.page_size
            if magic != self.MAGIC or jsize < self._JHDR.size + count * rec:
                return                      # torn/cold journal: db untouched
            dbfd = self.fs.open(self.path)
            try:
                for i in range(count):
                    off = self._JHDR.size + i * rec
                    pno, = self._JREC.unpack(
                        self.fs.pread(jfd, self._JREC.size, off))
                    img = self.fs.pread(jfd, self.page_size,
                                        off + self._JREC.size)
                    img = img + b"\x00" * (self.page_size - len(img))
                    self.fs.pwrite(dbfd, img, pno * self.page_size)
                self.fs.fsync(dbfd)
            finally:
                self.fs.close(dbfd)
        finally:
            self.fs.close(jfd)
            self.fs.unlink(self.jpath)

    # -------------------------------------------------------------- commit
    def commit(self, txn: int) -> None:
        ps = self.page_size
        pages = touched_pages(txn, self.npages)
        # 1. journal the ORIGINAL images (header page too) and fsync.
        #    Body before header: a journal without its magic is cold.
        jfd = self.fs.open(self.jpath)
        off = self._JHDR.size
        for pno in [0] + pages:
            orig = self.fs.pread(self.fd, ps, pno * ps)
            orig = orig + b"\x00" * (ps - len(orig))
            self.fs.pwrite(jfd, self._JREC.pack(pno) + orig, off)
            off += self._JREC.size + ps
        self.fs.pwrite(jfd, self._JHDR.pack(self.MAGIC, 1 + len(pages)), 0)
        self.fs.fsync(jfd)
        # 2. update the database in place, fsync
        for pno in pages:
            self.fs.pwrite(self.fd, page_content(txn, pno, ps), pno * ps)
        self.fs.pwrite(self.fd, header_bytes(txn, ps), 0)
        self.fs.fsync(self.fd)
        # 3. commit point: delete the journal — while it is still OPEN,
        #    exactly like SQLite's pager (POSIX keeps the anonymous file
        #    alive until the close below, which costs nothing)
        self.fs.unlink(self.jpath)
        self.fs.close(jfd)

    def close(self) -> None:
        self.fs.close(self.fd)

    # -------------------------------------------------------------- oracle
    def observed_txn(self) -> Optional[int]:
        return parse_header(self.fs.pread(self.fd, self.page_size, 0))

    def check_consistent(self, acked: int, started: int) -> int:
        """After crash + recovery: the db must equal the state after txns
        1..t* for a single t* with acked <= t* <= started.  Returns t*."""
        t_star = self.observed_txn()
        assert t_star is not None, "torn header page"
        assert acked <= t_star <= started, \
            f"t*={t_star} outside [{acked}, {started}]"
        ps = self.page_size
        for pno, towner in expected_pages(t_star, self.npages).items():
            got = self.fs.pread(self.fd, ps, pno * ps)
            got = got + b"\x00" * (ps - len(got))
            want = page_content(towner, pno, ps) if towner else b"\x00" * ps
            assert got == want, \
                f"page {pno}: holds neither pre- nor post-t*={t_star} bytes"
        assert not self.fs.exists(self.jpath), "journal survived recovery"
        return t_star


# ---------------------------------------------------------------------------
class SQLiteWALDB:
    """SQLite WAL mode: append-only commits, checkpoint truncates the WAL.

    A transaction appends one frame per modified page plus a CRC'd commit
    frame, then fsyncs the WAL (``synchronous=FULL``); readers overlay
    committed frames over the database.  ``checkpoint()`` copies the
    latest committed frames into the database, fsyncs it, and resets the
    WAL with **ftruncate(0)** — the metadata op whose durability NVCache
    must guarantee: losing it resurrects stale frames; tearing it corrupts
    the overlay.
    """

    _FRAME = struct.Struct("<IQI")      # page_no, txn, crc32(data)
    COMMIT = 0xFFFFFFFF                 # commit frame's page_no

    def __init__(self, fs: FS, path: str = "/app.db", *,
                 page_size: int = 512, npages: int = 8):
        self.fs = fs
        self.path = path
        self.wpath = path + "-wal"
        self.page_size = page_size
        self.npages = npages
        self.fd = fs.open(path)
        self.wfd = fs.open(self.wpath)
        if fs.size(self.fd) == 0:
            fs.pwrite(self.fd, header_bytes(0, page_size), 0)
            fs.fsync(self.fd)
        self._index: Dict[int, int] = {}    # page_no -> wal offset of data
        self._wal_end = 0
        self._recover()

    # ------------------------------------------------------------ recovery
    def _recover(self) -> None:
        """Replay whole committed transactions; ignore the torn tail."""
        size = self.fs.size(self.wfd)
        ps, fs_ = self.page_size, self.fs
        frame = self._FRAME.size + ps
        off = 0
        pending: Dict[int, int] = {}
        while off + self._FRAME.size <= size:
            pno, txn, crc = self._FRAME.unpack(
                fs_.pread(self.wfd, self._FRAME.size, off))
            if pno == self.COMMIT:
                # commit frame carries no page image
                if crc != zlib.crc32(struct.pack("<QI", txn, len(pending))):
                    break                    # torn commit: stop
                self._index.update(pending)
                pending.clear()
                off += self._FRAME.size
                self._wal_end = off
                continue
            if off + frame > size or pno >= self.npages:
                break                        # torn data frame
            data = fs_.pread(self.wfd, ps, off + self._FRAME.size)
            if zlib.crc32(bytes(data)) != crc:
                break
            pending[pno] = off + self._FRAME.size
            off += frame
        # uncommitted tail frames (pending) are discarded; the next commit
        # overwrites them at _wal_end

    # ------------------------------------------------------------ data ops
    def _read_page(self, pno: int) -> bytes:
        woff = self._index.get(pno)
        if woff is not None:
            raw = self.fs.pread(self.wfd, self.page_size, woff)
        else:
            raw = self.fs.pread(self.fd, self.page_size, pno * self.page_size)
        return raw + b"\x00" * (self.page_size - len(raw))

    def commit(self, txn: int) -> None:
        ps = self.page_size
        pages = touched_pages(txn, self.npages)
        off = self._wal_end
        staged: Dict[int, int] = {}
        for pno in pages + [0]:
            data = (page_content(txn, pno, ps) if pno
                    else header_bytes(txn, ps))
            hdr = self._FRAME.pack(pno, txn, zlib.crc32(data))
            self.fs.pwrite(self.wfd, hdr + data, off)
            staged[pno] = off + self._FRAME.size
            off += self._FRAME.size + ps
        nframes = len(pages) + 1
        commit = self._FRAME.pack(
            self.COMMIT, txn, zlib.crc32(struct.pack("<QI", txn, nframes)))
        self.fs.pwrite(self.wfd, commit, off)
        self.fs.fsync(self.wfd)              # durable == committed
        self._wal_end = off + self._FRAME.size
        self._index.update(staged)

    def checkpoint(self) -> None:
        """Copy committed frames into the db, then reset the WAL."""
        if not self._index:
            return
        for pno, woff in sorted(self._index.items()):
            raw = self.fs.pread(self.wfd, self.page_size, woff)
            self.fs.pwrite(self.fd, raw, pno * self.page_size)
        self.fs.fsync(self.fd)               # db durable BEFORE the reset
        self.fs.ftruncate(self.wfd, 0)       # WAL reset (the metadata op)
        self.fs.fsync(self.wfd)
        self._index.clear()
        self._wal_end = 0

    def close(self) -> None:
        self.fs.close(self.wfd)
        self.fs.close(self.fd)

    # -------------------------------------------------------------- oracle
    def observed_txn(self) -> Optional[int]:
        return parse_header(self._read_page(0))

    def check_consistent(self, acked: int, started: int) -> int:
        t_star = self.observed_txn()
        assert t_star is not None, "torn header"
        assert acked <= t_star <= started, \
            f"t*={t_star} outside [{acked}, {started}]"
        ps = self.page_size
        for pno, towner in expected_pages(t_star, self.npages).items():
            got = self._read_page(pno)
            want = page_content(towner, pno, ps) if towner else b"\x00" * ps
            assert got == want, \
                f"page {pno}: neither pre- nor post-t*={t_star} bytes"
        return t_star


# ---------------------------------------------------------------------------
class RocksLite:
    """RocksDB-style LSM shell: synchronous WAL + rename-installed MANIFEST.

    ``put`` appends a CRC'd record to the current WAL and fsyncs (db_bench
    sync mode).  ``flush`` persists the memtable as an SST, then writes the
    new MANIFEST — the list of live SSTs plus the current WAL number — to a
    temp file and **renames it over /MANIFEST**: the rename is the atomic
    install that simultaneously publishes the SST and retires the old WAL,
    which is unlinked afterwards.  Crash anywhere: the MANIFEST read at
    open names a consistent (SSTs, WAL) pair, and an unlinked WAL must
    never resurrect (its records would double-apply over the SST).
    """

    _REC = struct.Struct("<III")        # crc32(key+val), klen, vlen

    def __init__(self, fs: FS, root: str = "/rocks"):
        self.fs = fs
        self.root = root
        self.mpath = root + "/MANIFEST"
        self.map: Dict[bytes, bytes] = {}
        self.ssts: List[str] = []
        self.wal_num = 1
        self.sst_num = 0
        if fs.exists(self.mpath):
            self._load_manifest()
        for sst in self.ssts:
            self._load_sst(sst)
        valid_end = self._replay_wal(self._wal_path(self.wal_num))
        self.wfd = fs.open(self._wal_path(self.wal_num))
        # append after the last WHOLE record: a torn tail is dead bytes the
        # next put must overwrite, or every later replay would stop there
        self.wal_end = valid_end

    def _wal_path(self, n: int) -> str:
        return f"{self.root}/wal-{n:06d}"

    # ----------------------------------------------------------- manifest
    def _load_manifest(self) -> None:
        fd = self.fs.open_ro(self.mpath)
        try:
            raw = self.fs.pread(fd, self.fs.size(fd), 0)
        finally:
            self.fs.close(fd)
        for line in bytes(raw).decode().splitlines():
            if line.startswith("sst:"):
                self.ssts.append(line[4:])
                self.sst_num = max(self.sst_num,
                                   int(line.rsplit("-", 1)[1]))
            elif line.startswith("wal:"):
                self.wal_num = int(line[4:])

    def _load_sst(self, path: str) -> None:
        fd = self.fs.open_ro(path)
        try:
            size = self.fs.size(fd)
            off = 0
            while off + self._REC.size <= size:
                crc, klen, vlen = self._REC.unpack(
                    self.fs.pread(fd, self._REC.size, off))
                kv = self.fs.pread(fd, klen + vlen, off + self._REC.size)
                self.map[bytes(kv[:klen])] = bytes(kv[klen:])
                off += self._REC.size + klen + vlen
        finally:
            self.fs.close(fd)

    def _replay_wal(self, path: str) -> int:
        """Apply the WAL's whole, CRC-valid records; returns the offset
        just past the last one (the append point)."""
        if not self.fs.exists(path):
            return 0
        fd = self.fs.open_ro(path)
        try:
            size = self.fs.size(fd)
            off = 0
            while off + self._REC.size <= size:
                crc, klen, vlen = self._REC.unpack(
                    self.fs.pread(fd, self._REC.size, off))
                if off + self._REC.size + klen + vlen > size:
                    break                    # torn tail record
                kv = bytes(self.fs.pread(fd, klen + vlen,
                                         off + self._REC.size))
                if zlib.crc32(kv) != crc:
                    break                    # torn tail record
                self.map[kv[:klen]] = kv[klen:]
                off += self._REC.size + klen + vlen
            return off
        finally:
            self.fs.close(fd)

    # ------------------------------------------------------------ data ops
    def put(self, key: bytes, val: bytes) -> None:
        rec = self._REC.pack(zlib.crc32(key + val), len(key), len(val)) \
            + key + val
        self.fs.pwrite(self.wfd, rec, self.wal_end)
        self.fs.fsync(self.wfd)              # sync mode: durable on return
        self.wal_end += len(rec)
        self.map[key] = val

    def get(self, key: bytes) -> Optional[bytes]:
        return self.map.get(key)

    def flush(self) -> None:
        """Memtable -> SST, MANIFEST rename-install, old WAL unlink."""
        self.sst_num += 1
        sst = f"{self.root}/sst-{self.sst_num:06d}"
        fd = self.fs.open(sst)
        off = 0
        for k in sorted(self.map):
            v = self.map[k]
            rec = self._REC.pack(zlib.crc32(k + v), len(k), len(v)) + k + v
            self.fs.pwrite(fd, rec, off)
            off += len(rec)
        self.fs.fsync(fd)
        self.fs.close(fd)
        old_wal = self._wal_path(self.wal_num)
        self.wal_num += 1
        body = (f"sst:{sst}\nwal:{self.wal_num}\n").encode()
        tmp = self.mpath + ".tmp"
        tfd = self.fs.open(tmp)
        self.fs.ftruncate(tfd, 0)            # the path may hold a stale tmp
        self.fs.pwrite(tfd, body, 0)
        self.fs.fsync(tfd)
        self.fs.close(tfd)
        self.fs.close(self.wfd)
        self.fs.rename(tmp, self.mpath)      # the atomic install point
        self.fs.unlink(old_wal)              # records now live in the SST
        for obsolete in self.ssts:           # superseded by the merged SST
            self.fs.unlink(obsolete)
        self.ssts = [sst]
        self.wfd = self.fs.open(self._wal_path(self.wal_num))
        self.wal_end = 0

    def close(self) -> None:
        self.fs.close(self.wfd)

    # -------------------------------------------------------------- oracle
    @staticmethod
    def kv(i: int) -> Tuple[bytes, bytes]:
        """Deterministic key/value of the i-th put (keys collide mod 7 so
        overwrites are exercised)."""
        key = f"key-{i % 7}".encode()
        val = struct.pack("<I", i) * 5
        return key, val

    def check_consistent(self, acked: int, started: int,
                         flushed_wals: List[str]) -> int:
        """The reopened map must equal the state after puts 1..m for one m
        with acked <= m <= started; acked-unlinked WALs must stay gone."""
        # reconstruct candidate states and match
        want: Dict[bytes, bytes] = {}
        match = None
        for m in range(0, started + 1):
            if m:
                k, v = self.kv(m)
                want[k] = v
            if m >= acked and self.map == want:
                match = m
                break
        assert match is not None, \
            f"map matches no legal prefix in [{acked}, {started}]"
        for wal in flushed_wals:
            assert not self.fs.exists(wal), f"unlinked WAL {wal} resurrected"
        return match
