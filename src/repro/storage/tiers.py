"""Storage-tier device models (evaluation substrate for the paper's figures).

The paper's machine has Optane NVDIMMs (µs writes, GB/s) in front of a SATA
SSD (~80 MiB/s random-4k-with-fsync, ~ms fsync).  This container has one
real disk and a 1-core CPU, so throughput ratios between tiers would be
noise.  We therefore model devices *analytically*: every operation charges a
cost to a :class:`CostGate` which converts owed time into real sleeps in
chunks (per-op ``time.sleep`` of microseconds is impossible; aggregated
sleeping preserves throughput shapes exactly).

Semantics mirror the kernel model the paper relies on:

* ``buffered`` files: ``pwrite`` lands in a volatile page cache (cheap,
  write-combining by page — the paper's "kernel combines the writes"),
  ``fsync`` pays per *unique dirty page* at device random-write cost plus a
  base latency.  This is what the NVCache cleanup thread writes to.
* ``sync`` files: every ``pwrite`` pays device cost immediately
  (O_SYNC/O_DIRECT-style baselines).

Content lives in memory (bytearray per file) — durability across a process
restart is out of scope for benchmarks; crash-consistency tests use the NVMM
shadow instead.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Cost model of one device (all costs in seconds)."""

    name: str
    page_write_s: float          # random 4-KiB page write (durable)
    seq_write_bps: float         # sequential streaming bandwidth
    page_read_s: float           # uncached 4-KiB read
    fsync_base_s: float          # per-fsync fixed latency
    syscall_s: float = 2e-6      # per-syscall overhead on this path
    iov_seg_s: float = 0.3e-6    # per-extra-segment overhead of pwritev
    #                              (kernel iterates the iovec inside ONE
    #                               syscall: far cheaper than a syscall each)


# Calibrated to the paper's hardware (§IV-A): SATA SSD ~80 MiB/s random-4k
# synchronous writes, Optane ~2.3 GB/s writes with ~µs latency.
SSD_SATA = DeviceProfile("ssd", page_write_s=48e-6, seq_write_bps=460e6,
                         page_read_s=90e-6, fsync_base_s=300e-6)
NVMM_OPTANE = DeviceProfile("nvmm", page_write_s=1.7e-6, seq_write_bps=2.3e9,
                            page_read_s=1.2e-6, fsync_base_s=0.0, syscall_s=0.0)
DRAM = DeviceProfile("dram", page_write_s=0.0, seq_write_bps=0.0,
                     page_read_s=0.0, fsync_base_s=0.0, syscall_s=0.5e-6)
# Blob-store-class backend for checkpoint benches (high bw, high latency).
BLOB = DeviceProfile("blob", page_write_s=8e-6, seq_write_bps=1.2e9,
                     page_read_s=30e-6, fsync_base_s=15e-3)


class CostGate:
    """Converts modeled device time into wall time with chunked sleeping.

    Owed time is tracked PER THREAD: the cleanup thread's drain costs must
    never be paid by an application thread that happens to touch the gate
    (that would serialize exactly the overlap the paper's design buys)."""

    SLEEP_CHUNK = 2e-3

    def __init__(self, scale: float = 1.0):
        self.scale = scale          # <1.0 speeds up benchmarks uniformly
        self._local = threading.local()
        self._lock = threading.Lock()
        self.total_cost = 0.0

    def charge(self, seconds: float) -> None:
        if seconds <= 0.0:
            return
        with self._lock:
            self.total_cost += seconds
        owed = getattr(self._local, "owed", 0.0) + seconds * self.scale
        if owed < self.SLEEP_CHUNK:
            self._local.owed = owed
            return
        self._local.owed = 0.0
        time.sleep(owed)


PAGE = 4096


class TierFile:
    """One file on a modeled device."""

    def __init__(self, path: str, device: DeviceProfile, gate: CostGate,
                 *, sync: bool, volatile: bool = False):
        self.path = path
        self.device = device
        self.gate = gate
        self.sync = sync              # True: every pwrite is durable (pays now)
        self.volatile = volatile      # True: fsync is a no-op that buys nothing
        self._data = bytearray()
        self._dirty_pages: set[int] = set()
        self._cached_pages: set[int] = set()   # kernel page cache (reads free)
        self._lock = threading.Lock()
        self.stats_writes = 0
        self.stats_fsyncs = 0
        self.stats_bytes = 0
        self.stats_page_writes = 0    # pages touched by write calls (the
        #                               drain-coalescing figure of merit)
        self.stats_wvec_segments = 0  # iovec segments across pwritev calls
        self.stats_preads = 0         # read syscalls (pread + preadv calls —
        #                               the readahead figure of merit)
        self.stats_page_reads = 0     # uncached pages paid at device cost
        self.stats_rvec_segments = 0  # iovec segments across preadv calls

    # -- data plane ---------------------------------------------------------
    def pwrite(self, data: bytes, off: int) -> int:
        n = len(data)
        with self._lock:
            end = off + n
            if end > len(self._data):
                self._data.extend(b"\x00" * (end - len(self._data)))
            self._data[off:end] = data
            pages = range(off // PAGE, (end - 1) // PAGE + 1) if n else ()
            npages = len(pages)
            self._cached_pages.update(pages)   # writes populate the page cache
            if not self.sync:
                self._dirty_pages.update(pages)
        self.stats_writes += 1
        self.stats_bytes += n
        self.stats_page_writes += npages
        cost = self.device.syscall_s
        if self.sync:
            cost += npages * self.device.page_write_s
        self.gate.charge(cost)
        return n

    def pwritev(self, iov) -> int:
        """Vectored write: ``iov`` is an iterable of ``(data, off)``.

        One syscall's worth of overhead for the whole vector plus a small
        per-extra-segment cost (``iov_seg_s``) — the extent/vectored cost
        model the coalescing drain engine is measured against.  Page-cache
        and dirty accounting are identical to issuing the segments
        individually; a page touched by several segments is still counted
        (and, on sync devices, charged) once per call.
        """
        total = 0
        nseg = 0
        touched: set[int] = set()
        with self._lock:
            for data, off in iov:
                n = len(data)
                if n == 0:
                    continue
                nseg += 1
                end = off + n
                if end > len(self._data):
                    self._data.extend(b"\x00" * (end - len(self._data)))
                self._data[off:end] = data
                pages = range(off // PAGE, (end - 1) // PAGE + 1)
                touched.update(pages)
                self._cached_pages.update(pages)
                if not self.sync:
                    self._dirty_pages.update(pages)
                total += n
        self.stats_writes += 1
        self.stats_bytes += total
        self.stats_page_writes += len(touched)
        self.stats_wvec_segments += nseg
        cost = self.device.syscall_s + max(0, nseg - 1) * self.device.iov_seg_s
        if self.sync:
            cost += len(touched) * self.device.page_write_s
        self.gate.charge(cost)
        return total

    def pread(self, n: int, off: int) -> bytes:
        with self._lock:
            out = bytes(self._data[off:off + n])
            pages = range(off // PAGE, (off + max(n, 1) - 1) // PAGE + 1)
            misses = [p for p in pages if p not in self._cached_pages]
            self._cached_pages.update(misses)
        self.stats_preads += 1
        self.stats_page_reads += len(misses)
        self.gate.charge(self.device.syscall_s + len(misses) * self.device.page_read_s)
        return out

    def preadv(self, iov) -> list:
        """Vectored read: ``iov`` is an iterable of ``(n, off)``; returns the
        list of chunks (short chunks past EOF, like ``pread``).

        One syscall's worth of overhead for the whole vector plus a small
        per-extra-segment cost (``iov_seg_s``) plus device cost per
        *uncached* page — the extent/vectored cost model the readahead miss
        path is measured against.  Page-cache accounting is identical to
        issuing the segments individually.
        """
        out = []
        nseg = 0
        misses = 0
        with self._lock:
            for n, off in iov:
                out.append(bytes(self._data[off:off + n]))
                if n <= 0:
                    continue
                nseg += 1
                pages = range(off // PAGE, (off + n - 1) // PAGE + 1)
                miss = [p for p in pages if p not in self._cached_pages]
                misses += len(miss)
                self._cached_pages.update(miss)
        self.stats_preads += 1
        self.stats_page_reads += misses
        self.stats_rvec_segments += nseg
        self.gate.charge(self.device.syscall_s
                         + max(0, nseg - 1) * self.device.iov_seg_s
                         + misses * self.device.page_read_s)
        return out

    def fsync(self) -> None:
        self.stats_fsyncs += 1
        if self.volatile or self.sync:
            self.gate.charge(self.device.syscall_s)
            return
        with self._lock:
            npages = len(self._dirty_pages)
            self._dirty_pages.clear()
        self.gate.charge(self.device.fsync_base_s + npages * self.device.page_write_s
                         + self.device.syscall_s)

    def drop_page_cache(self) -> None:
        """Evict this file's clean pages from the modeled kernel page cache
        (the per-file half of ``echo 3 > drop_caches``) — cold-read
        benchmarks use it so reads pay device cost.  Dirty pages stay: the
        kernel cannot drop them before writeback."""
        with self._lock:
            self._cached_pages &= self._dirty_pages

    def size(self) -> int:
        with self._lock:
            return len(self._data)

    def truncate(self, n: int) -> None:
        with self._lock:
            if n < len(self._data):
                del self._data[n:]
                # drop page-cache/dirty state beyond the new size: a later
                # fsync must not pay device cost for pages that no longer
                # exist (the page holding byte n-1 survives — it may still
                # be dirty)
                last = (n + PAGE - 1) // PAGE  # first wholly-truncated page
                self._dirty_pages = {p for p in self._dirty_pages if p < last}
                self._cached_pages = {p for p in self._cached_pages if p < last}
            elif n > len(self._data):
                # ftruncate growth: sparse zero extension (no dirty pages —
                # the kernel materializes holes lazily)
                self._data.extend(b"\x00" * (n - len(self._data)))
        self.gate.charge(self.device.syscall_s)

    def close(self) -> None:
        pass

    def snapshot(self) -> bytes:
        with self._lock:
            return bytes(self._data)


class Tier:
    """A namespace of files on one device model (a mounted filesystem)."""

    def __init__(self, device: DeviceProfile = SSD_SATA, *, sync: bool = False,
                 volatile: bool = False, scale: float = 1.0):
        self.device = device
        self.sync = sync
        self.volatile = volatile
        self.gate = CostGate(scale)
        self._files: Dict[str, TierFile] = {}
        self._lock = threading.Lock()
        self.ns_seq = 0     # applied-watermark of the durable namespace
        #                     (repro.core.namespace): the seq of the last
        #                     metadata op reflected in this tier's dict —
        #                     set by the owner as part of applying, read by
        #                     recovery to replay exactly the ops above it

    def open(self, path: str) -> TierFile:
        with self._lock:
            f = self._files.get(path)
            if f is None:
                f = TierFile(path, self.device, self.gate, sync=self.sync,
                             volatile=self.volatile)
                self._files[path] = f
            return f

    def exists(self, path: str) -> bool:
        with self._lock:
            return path in self._files

    def size_of(self, path: str) -> int:
        """Size of an existing file WITHOUT creating it on miss — the
        non-mutating stat path (``Tier.open`` inserts on miss, which a
        stat of a nonexistent path must never do)."""
        with self._lock:
            f = self._files.get(path)
        if f is None:
            raise FileNotFoundError(path)
        return f.size()

    def unlink(self, path: str) -> None:
        with self._lock:
            self._files.pop(path, None)
        self.gate.charge(self.device.syscall_s)

    def rename(self, old: str, new: str) -> None:
        """Atomic rename-into-place (the install primitive of the legacy
        metadata protocols): an existing ``new`` is replaced.  The moved
        :class:`TierFile` handle stays valid — I/O through it is
        path-independent, like an open fd across a rename."""
        with self._lock:
            f = self._files.pop(old, None)
            if f is None:
                raise FileNotFoundError(old)
            self._files[new] = f
            f.path = new
        self.gate.charge(self.device.syscall_s)

    def paths(self):
        return list(self._files)


class DMWriteCacheTier(Tier):
    """DM-WriteCache analogue (paper Table IV): an NVMM write cache *behind*
    the kernel page cache.  Synchronous durability requires O_SYNC through
    the kernel: each write pays the kernel block path + an NVMM commit, and
    once the NVMM cache is full, drains at SSD speed (paper Fig. 4: slower
    than NVCache for sync writes, faster than the bare SSD)."""

    def __init__(self, *, cache_bytes: int = 1 << 30, scale: float = 1.0):
        super().__init__(SSD_SATA, sync=True, scale=scale)
        self.cache_bytes = cache_bytes
        self._outstanding = 0
        self._last = time.monotonic()
        self._dm_lock = threading.Lock()

    def open(self, path: str) -> TierFile:
        f = super().open(path)
        # wrap exactly once: re-opening the same path used to stack another
        # wrapper on the already-wrapped bound method, double-charging the
        # NVMM commit cost (and double-counting stats) per reopen
        if not getattr(f, "_dm_wrapped", False):
            f.pwrite = self._wrap_pwrite(f)  # type: ignore[method-assign]
            # dm-writecache sits below the kernel block layer: a vectored
            # write still pays the block path per segment, so route pwritev
            # through the wrapped pwrite rather than the free base model
            f.pwritev = lambda iov: sum(f.pwrite(d, o) for d, o in iov)  # type: ignore[method-assign]
            f._dm_wrapped = True             # type: ignore[attr-defined]
        return f

    def _wrap_pwrite(self, f: TierFile):
        inner_data = f

        def pwrite(data: bytes, off: int) -> int:
            n = len(data)
            npages = 0
            with inner_data._lock:
                end = off + n
                if end > len(inner_data._data):
                    inner_data._data.extend(b"\x00" * (end - len(inner_data._data)))
                inner_data._data[off:end] = data
                if n:
                    pages = range(off // PAGE, (end - 1) // PAGE + 1)
                    npages = len(pages)
                    inner_data._cached_pages.update(pages)
            # kernel block path + commit record into NVMM (two flushed lines)
            cost = 6e-6 + max(1, (n + PAGE - 1) // PAGE) * (NVMM_OPTANE.page_write_s + 4e-6)
            with self._dm_lock:
                now = time.monotonic()
                drained = (now - self._last) * SSD_SATA.seq_write_bps
                self._last = now
                self._outstanding = max(0, self._outstanding - drained) + n
                if self._outstanding > self.cache_bytes:
                    # cache full: writes proceed at SSD drain speed
                    cost += max(1, (n + PAGE - 1) // PAGE) * SSD_SATA.page_write_s
                    self._outstanding = self.cache_bytes
            self.gate.charge(cost)
            f.stats_writes += 1
            f.stats_bytes += n
            f.stats_page_writes += npages
            return n

        return pwrite
