"""kvlite — a small log-structured embedded KV store.

Stands in for SQLite/RocksDB in the paper's §IV benchmarks: it is a
*legacy application* in the paper's sense — it persists through plain
file calls (append record, fsync, pread) and knows nothing about NVMM.
Running it over :class:`NVCacheFS` vs :class:`TierFS` reproduces the
paper's transparent-boost experiment.

Record format (append-only data log)::

    u32 klen | u32 vlen | key | value

An in-memory hash index maps key -> (offset, vlen).  ``sync`` mode calls
fsync after every put (db_bench synchronous mode).
"""
from __future__ import annotations

import struct
from typing import Optional

from repro.storage.fsapi import FS

_REC = struct.Struct("<II")


class KVLite:
    def __init__(self, fs: FS, path: str = "/kvlite.db", *, sync: bool = True):
        self.fs = fs
        self.sync = sync
        self.fd = fs.open(path)
        self._index: dict[bytes, tuple[int, int]] = {}
        self._end = fs.size(self.fd)
        if self._end:
            self._replay()

    def _replay(self) -> None:
        off = 0
        while off + _REC.size <= self._end:
            hdr = self.fs.pread(self.fd, _REC.size, off)
            if len(hdr) < _REC.size:
                break
            klen, vlen = _REC.unpack(hdr)
            if off + _REC.size + klen + vlen > self._end:
                # torn tail record: a crash mid-append left a header whose
                # key/value extend past EOF.  Stop at the last complete
                # record — indexing the truncated tail would hand out reads
                # of bytes that were never written (and the next put must
                # overwrite the torn bytes, not append after them).
                break
            key = self.fs.pread(self.fd, klen, off + _REC.size)
            if len(key) < klen:
                break
            self._index[bytes(key)] = (off + _REC.size + klen, vlen)
            off += _REC.size + klen + vlen
        self._end = off

    def put(self, key: bytes, value: bytes) -> None:
        rec = _REC.pack(len(key), len(value)) + key + value
        off = self._end
        self.fs.pwrite(self.fd, rec, off)
        if self.sync:
            self.fs.fsync(self.fd)
        self._index[key] = (off + _REC.size + len(key), len(value))
        self._end = off + len(rec)

    def get(self, key: bytes) -> Optional[bytes]:
        loc = self._index.get(key)
        if loc is None:
            return None
        off, vlen = loc
        return self.fs.pread(self.fd, vlen, off)

    def close(self) -> None:
        self.fs.close(self.fd)

    def __len__(self) -> int:
        return len(self._index)
