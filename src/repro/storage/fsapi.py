"""A minimal POSIX-ish file-system interface so that "legacy" components
(kvlite, the checkpoint codec, metrics writers, the data pipeline) run
unmodified over either NVCache or a raw tier — the paper's plug-and-play
boundary, one level above libc.
"""
from __future__ import annotations

from typing import Protocol

from repro.core.api import NVCache, O_CREAT, O_RDWR


class FS(Protocol):
    def open(self, path: str) -> int: ...
    def open_ro(self, path: str) -> int: ...
    def pread(self, fd: int, n: int, off: int) -> bytes: ...
    def pwrite(self, fd: int, data: bytes, off: int) -> int: ...
    def write(self, fd: int, data: bytes) -> int: ...
    def fsync(self, fd: int) -> None: ...
    def close(self, fd: int) -> None: ...
    def size(self, fd: int) -> int: ...
    # namespace surface (the metadata half of the plug-and-play boundary —
    # what SQLite's journal unlink / WAL reset and RocksDB's MANIFEST
    # rename-into-place actually call):
    def exists(self, path: str) -> bool: ...
    def unlink(self, path: str) -> None: ...
    def rename(self, old: str, new: str) -> None: ...
    def ftruncate(self, fd: int, length: int) -> None: ...


class NVCacheFS:
    """Files routed through NVCache: synchronous durability, fsync no-op."""

    def __init__(self, nv: NVCache):
        self.nv = nv

    def open(self, path: str) -> int:
        return self.nv.open(path, O_RDWR | O_CREAT)

    def open_ro(self, path: str) -> int:
        # read-only open bypasses the read cache entirely (paper §II-A)
        import os
        return self.nv.open(path, os.O_RDONLY)

    def pread(self, fd, n, off):
        return self.nv.pread(fd, n, off)

    def pwrite(self, fd, data, off):
        return self.nv.pwrite(fd, data, off)

    def write(self, fd, data):
        return self.nv.write(fd, data)

    def fsync(self, fd):
        self.nv.fsync(fd)          # no-op (paper Table III)

    def close(self, fd):
        self.nv.close(fd)

    def size(self, fd):
        return self.nv.stat_size(fd)

    # namespace ops: journaled in the NVMM log (core/namespace.py), so a
    # rename/unlink the app observed is crash-durable — unlike the raw
    # TierFS below, where only what reached the device survives
    def exists(self, path):
        if self.nv.ns.lookup(path) is not None:
            return True
        return self.nv.tier.exists(path)

    def unlink(self, path):
        self.nv.unlink(path)

    def rename(self, old, new):
        self.nv.rename(old, new)

    def ftruncate(self, fd, length):
        self.nv.ftruncate(fd, length)


class TierFS:
    """Files directly on a tier (the baselines).

    ``sync_each``: force synchronous durability the legacy way — an fsync
    after every write (the paper's "synchronous mode" of db_bench).  On a
    ``sync=True`` tier (O_SYNC/O_DIRECT model) the write itself already
    paid device cost, and fsync is cheap.
    """

    def __init__(self, tier, *, sync_each: bool = False):
        self.tier = tier
        self.sync_each = sync_each
        self._fds: dict[int, object] = {}
        self._cursor: dict[int, int] = {}
        self._next = 3

    def open(self, path: str) -> int:
        fd = self._next
        self._next += 1
        self._fds[fd] = self.tier.open(path)
        self._cursor[fd] = 0
        return fd

    def open_ro(self, path: str) -> int:
        return self.open(path)

    def pread(self, fd, n, off):
        return self._fds[fd].pread(n, off)

    def pwrite(self, fd, data, off):
        n = self._fds[fd].pwrite(data, off)
        if self.sync_each:
            self._fds[fd].fsync()
        return n

    def write(self, fd, data):
        off = self._cursor[fd]
        n = self.pwrite(fd, data, off)
        self._cursor[fd] = off + n
        return n

    def fsync(self, fd):
        self._fds[fd].fsync()

    def close(self, fd):
        self._fds.pop(fd).close()
        self._cursor.pop(fd, None)

    def size(self, fd):
        return self._fds[fd].size()

    def exists(self, path):
        return self.tier.exists(path)

    def unlink(self, path):
        self.tier.unlink(path)

    def rename(self, old, new):
        self.tier.rename(old, new)

    def ftruncate(self, fd, length):
        self._fds[fd].truncate(length)
