"""Deterministic, resumable token pipeline.

Batches are a pure function of (seed, step) — resuming after a crash needs
only the step counter, which the train loop persists through the same
NVCache-backed FS as the checkpoints (one more "legacy" consumer of the
paper's technique).  A file-backed mode streams token shards through the
FS, exercising the NVCache read path.
"""
from __future__ import annotations

import json
from typing import Optional

import numpy as np


class SyntheticTokens:
    """Zipf-ish synthetic corpus, deterministic per (seed, step)."""

    def __init__(self, vocab: int, batch: int, seq: int, *, seed: int = 0,
                 family: str = "dense", d_model: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        self.family = family
        self.d_model = d_model
        self.step = 0

    def _rng(self, step):
        return np.random.default_rng((self.seed << 20) ^ step)

    def next(self) -> dict:
        rng = self._rng(self.step)
        self.step += 1
        z = rng.zipf(1.3, size=(self.batch, self.seq))
        tokens = (z % (self.vocab - 2)).astype(np.int32) + 1
        if self.family == "encdec":
            frames = rng.standard_normal(
                (self.batch, self.seq, self.d_model)).astype(np.float32) * 0.02
            dec = (rng.zipf(1.3, size=(self.batch, max(2, self.seq // 8)))
                   % (self.vocab - 2)).astype(np.int32) + 1
            return {"frames": frames, "dec_tokens": dec}
        return {"tokens": tokens}

    # -- resumable state ------------------------------------------------
    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def load_state(self, state: dict) -> None:
        assert state["seed"] == self.seed, "corpus seed mismatch"
        self.step = state["step"]

    def save_state(self, fs, path: str = "/datapipe.json") -> None:
        blob = json.dumps(self.state()).encode()
        fd = fs.open(path)
        fs.pwrite(fd, blob.ljust(256), 0)
        fs.close(fd)

    def restore_state(self, fs, path: str = "/datapipe.json") -> bool:
        try:
            fd = fs.open(path)
            raw = fs.pread(fd, 256, 0)
            fs.close(fd)
            if not raw.strip():
                return False
            self.load_state(json.loads(raw.decode()))
            return True
        except Exception:
            return False


class FileBackedTokens:
    """Token shards stored as int32 files behind the FS (read-path load)."""

    RECORD = 4  # bytes per token

    def __init__(self, fs, paths: list[str], batch: int, seq: int):
        self.fs = fs
        self.fds = [fs.open(p) for p in paths]
        self.sizes = [fs.size(fd) // self.RECORD for fd in self.fds]
        self.batch, self.seq = batch, seq
        self.cursor = [0] * len(self.fds)
        self.shard = 0

    @staticmethod
    def write_shard(fs, path: str, tokens: np.ndarray) -> None:
        fd = fs.open(path)
        fs.pwrite(fd, tokens.astype(np.int32).tobytes(), 0)
        fs.close(fd)

    def next(self) -> dict:
        need = self.batch * self.seq
        out = np.empty((need,), np.int32)
        got = 0
        while got < need:
            i = self.shard
            avail = self.sizes[i] - self.cursor[i]
            if avail <= 0:
                self.cursor[i] = 0
                self.shard = (i + 1) % len(self.fds)
                continue
            take = min(avail, need - got)
            raw = self.fs.pread(self.fds[i], take * self.RECORD,
                                self.cursor[i] * self.RECORD)
            out[got:got + take] = np.frombuffer(raw, np.int32)
            self.cursor[i] += take
            got += take
            self.shard = (i + 1) % len(self.fds)
        return {"tokens": out.reshape(self.batch, self.seq)}
