"""Checkpoint codec: pytree <-> chunked byte records.

Layout (append-only stream, written through the plain file API so NVCache
can boost it transparently):

    [record 0][record 1]...[record N-1][index][footer]

Each record is one row-chunk of one leaf:  ``msgpack header || payload``.
Chunking along axis 0 is what makes *resharded restore* possible: a reader
assembling any slice of a leaf touches only the chunks that overlap it —
the elastic-scaling path re-slices checkpoints to a new device count
without ever materializing the full array on one host.

Payload encodings: raw | zstd | int8 group-quantized (+f32 scales, zstd'd)
| zlib — the quantized mode shrinks NVMM log entries, pushing the paper's
Fig.-5 log-saturation point out by ~4x for checkpoint traffic.

``zstandard`` is an *optional* dependency: when absent, compressed writes
transparently downgrade to zlib (recorded per record in its header, so a
reader on any host decodes correctly), and only streams that were actually
written with zstd require the package to read.
"""
from __future__ import annotations

import struct
import zlib
from typing import Optional

import msgpack
import numpy as np

try:
    import zstandard
except ImportError:                       # optional dependency (see docstring)
    zstandard = None

MAGIC = b"RPCKPT01"
_FOOT = struct.Struct("<QQI")       # index_off, index_len, index_crc

ENC_RAW, ENC_ZSTD, ENC_INT8, ENC_ZLIB = 0, 1, 2, 3


def _compress(raw: bytes, *, force_zlib: bool = False) -> tuple[bytes, bool]:
    """Compress with zstd when available (and not overridden), zlib otherwise.

    Returns ``(payload, used_zlib)``.
    """
    if not force_zlib and zstandard is not None:
        return zstandard.compress(raw, 3), False
    return zlib.compress(raw, 6), True


def _decompress(payload: bytes, used_zlib: bool) -> bytes:
    if used_zlib:
        return zlib.decompress(payload)
    if zstandard is None:
        raise ImportError(
            "checkpoint record is zstd-compressed but `zstandard` is not "
            "installed; install it or re-write the checkpoint")
    return zstandard.decompress(payload)


def _quant_np(x: np.ndarray, group: int = 256):
    flat = x.astype(np.float32).reshape(-1)
    pad = (-flat.size) % group
    if pad:
        flat = np.pad(flat, (0, pad))
    g = flat.reshape(-1, group)
    amax = np.abs(g).max(axis=1)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.round(g / scale[:, None]), -127, 127).astype(np.int8)
    return q.reshape(-1), scale, pad


def _dequant_np(q: np.ndarray, scale: np.ndarray, pad: int, group: int = 256):
    g = q.reshape(-1, group).astype(np.float32) * scale[:, None]
    flat = g.reshape(-1)
    return flat[:flat.size - pad] if pad else flat


class Writer:
    """Streams records through an FS (see repro.storage.fsapi)."""

    def __init__(self, fs, path: str, *, encoding: int = ENC_ZSTD,
                 chunk_bytes: int = 4 << 20, close_on_finish: bool = True):
        self.fs = fs
        self.fd = fs.open(path)
        self.off = 0
        self.encoding = encoding
        self.chunk_bytes = chunk_bytes
        self.close_on_finish = close_on_finish
        self.index = []
        self._w(MAGIC)

    def _w(self, data: bytes):
        self.fs.pwrite(self.fd, data, self.off)
        self.off += len(data)

    def put_leaf(self, path: str, arr) -> None:
        a = np.asarray(arr)
        rows = max(1, a.shape[0]) if a.ndim else 1
        row_bytes = max(1, a.nbytes // rows)
        rows_per_chunk = max(1, self.chunk_bytes // row_bytes)
        if a.ndim == 0:
            chunks = [(0, 1, a.reshape(1))]
        else:
            chunks = [(s, min(s + rows_per_chunk, a.shape[0]),
                       a[s:min(s + rows_per_chunk, a.shape[0])])
                      for s in range(0, a.shape[0], rows_per_chunk)]
        for start, end, part in chunks:
            self._put_chunk(path, a, start, end, part)

    def _put_chunk(self, path, a, start, end, part):
        raw = np.ascontiguousarray(part)
        meta = {"p": path, "dt": str(a.dtype), "gs": list(a.shape),
                "s": start, "e": end, "enc": self.encoding}
        if self.encoding == ENC_INT8 and raw.dtype.kind == "f" and raw.size >= 256:
            q, scale, pad = _quant_np(raw.view(raw.dtype))
            payload, used_zlib = _compress(q.tobytes() + scale.tobytes())
            meta["pad"] = pad
            meta["nsc"] = scale.size
            if used_zlib:
                meta["zc"] = 1          # int8 payload compressed with zlib
        elif self.encoding in (ENC_ZSTD, ENC_ZLIB):
            # ENC_ZLIB is an explicit request for the portable codec — honour
            # it even when zstandard is installed
            payload, used_zlib = _compress(raw.tobytes(),
                                           force_zlib=self.encoding == ENC_ZLIB)
            meta["enc"] = ENC_ZLIB if used_zlib else ENC_ZSTD
        else:
            meta["enc"] = ENC_RAW
            payload = raw.tobytes()
        hdr = msgpack.packb(meta)
        rec = struct.pack("<II", len(hdr), len(payload)) + hdr + payload
        self.index.append((path, int(start), int(end), self.off, len(rec)))
        self._w(rec)

    def finish(self) -> dict:
        idx = msgpack.packb(self.index)
        idx_off = self.off
        self._w(idx)
        self._w(_FOOT.pack(idx_off, len(idx), zlib.crc32(idx)))
        size = self.off
        if self.close_on_finish:
            self.fs.close(self.fd)      # close() drains (paper semantics)
            self.fd = None
        return {"size": size, "index_off": idx_off}


class Reader:
    def __init__(self, fs, path: str):
        self.fs = fs
        self.fd = fs.open_ro(path) if hasattr(fs, "open_ro") else fs.open(path)
        size = fs.size(self.fd)
        foot = fs.pread(self.fd, _FOOT.size, size - _FOOT.size)
        idx_off, idx_len, crc = _FOOT.unpack(foot)
        idx = fs.pread(self.fd, idx_len, idx_off)
        if zlib.crc32(idx) != crc:
            raise IOError("checkpoint index corrupt")
        self.index = msgpack.unpackb(idx)
        assert fs.pread(self.fd, len(MAGIC), 0) == MAGIC

    def leaf_paths(self):
        return sorted({e[0] for e in self.index})

    def read_leaf(self, path: str, *, rows: Optional[tuple] = None) -> np.ndarray:
        entries = sorted((e for e in self.index if e[0] == path),
                         key=lambda e: e[1])
        if not entries:
            raise KeyError(path)
        parts, meta0 = [], None
        for _p, start, end, off, ln in entries:
            if rows is not None and (end <= rows[0] or start >= rows[1]):
                continue
            rec = self.fs.pread(self.fd, ln, off)
            hlen, plen = struct.unpack("<II", rec[:8])
            meta = msgpack.unpackb(rec[8:8 + hlen])
            payload = rec[8 + hlen:8 + hlen + plen]
            arr = self._decode(meta, payload, start, end)
            if rows is not None:
                lo = max(rows[0], start) - start
                hi = min(rows[1], end) - start
                arr = arr[lo:hi]
            parts.append(arr)
            meta0 = meta
        gs = meta0["gs"]
        out = np.concatenate(parts, axis=0) if gs else parts[0].reshape(())
        if rows is None and gs:
            out = out.reshape(gs)
        return out

    def _decode(self, meta, payload, start, end):
        dt = np.dtype(meta["dt"])
        shape = [end - start] + meta["gs"][1:] if meta["gs"] else [1]
        if meta["enc"] == ENC_INT8:
            blob = _decompress(payload, bool(meta.get("zc")))
            n = int(np.prod(shape))
            pad = meta["pad"]
            q = np.frombuffer(blob[:n + pad], np.int8)
            scale = np.frombuffer(blob[n + pad:], np.float32)
            return _dequant_np(q, scale, pad).astype(dt).reshape(shape)
        if meta["enc"] in (ENC_ZSTD, ENC_ZLIB):
            blob = _decompress(payload, meta["enc"] == ENC_ZLIB)
            return np.frombuffer(blob, dt).reshape(shape)
        return np.frombuffer(payload, dt).reshape(shape)

    def close(self):
        self.fs.close(self.fd)
