"""Checkpoint manager on top of the plain file API.

The paper's technique, applied to training state: ``save()`` returns once
the checkpoint bytes are *synchronously durable* in the fast tier (when the
FS is NVCache-backed, that is the NVMM log append — Alg. 1), while the
cleanup thread drains to the blob tier in the background, overlapping the
next training steps.  The manifest write is the commit point (the paper's
group-commit at application granularity): a crash mid-save restores the
previous step, never a torn pytree.

Restore supports *resharding*: ``restore(slice_rows=...)`` reads only the
row-chunks a host needs, which is how elastic scaling re-slices state to a
new device count.
"""
from __future__ import annotations

import json
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import codec


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, fs, directory: str = "/ckpt", *, keep: int = 2,
                 encoding: int = codec.ENC_ZSTD):
        self.fs = fs
        self.dir = directory.rstrip("/")
        self.keep = keep
        self.encoding = encoding
        self._manifest_path = f"{self.dir}/MANIFEST.json"
        self._manifest_fd = None      # held open: close() would wait behind
        self._deferred_fds: list = []  # the whole FIFO log drain

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree) -> dict:
        # finalize previous steps' files now (their background drain has had
        # a full checkpoint interval to complete — close() barely blocks)
        self.finalize()
        path = f"{self.dir}/step_{step:08d}.ckpt"
        w = codec.Writer(self.fs, path, encoding=self.encoding,
                         close_on_finish=False)
        flat, _ = _flatten(tree)
        for key, leaf in flat:
            w.put_leaf(key, leaf)
        info = w.finish()
        self._deferred_fds.append(w.fd)
        manifest = self._read_manifest()
        manifest["steps"] = sorted(set(manifest.get("steps", []) + [step]))
        manifest["latest"] = max(manifest["steps"])
        manifest["files"] = {**manifest.get("files", {}),
                             str(step): {"path": path, **info}}
        self._gc(manifest)
        # the manifest write commits the checkpoint (crash before it ->
        # previous step restores; the data file is garbage-collected)
        self._write_manifest(manifest)
        return {"step": step, **info}

    def finalize(self) -> None:
        """Close deferred checkpoint files (waits for their drain)."""
        for fd in self._deferred_fds:
            try:
                self.fs.close(fd)
            except Exception:
                pass
        self._deferred_fds.clear()

    def close(self) -> None:
        self.finalize()
        if self._manifest_fd is not None:
            try:
                self.fs.close(self._manifest_fd)
            except Exception:
                pass
            self._manifest_fd = None

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        m = self._read_manifest()
        return m.get("latest")

    def restore(self, tree_like, step: Optional[int] = None,
                slice_rows: Optional[Callable[[str, tuple], Optional[tuple]]] = None):
        """Rebuild a pytree shaped like ``tree_like``.

        ``slice_rows(key, global_shape) -> (lo, hi) | None`` selects a
        row-range per leaf for resharded restore."""
        m = self._read_manifest()
        step = step if step is not None else m.get("latest")
        if step is None:
            raise FileNotFoundError("no checkpoint")
        path = m["files"][str(step)]["path"]
        r = codec.Reader(self.fs, path)
        flat, treedef = _flatten(tree_like)
        leaves = []
        for key, like in flat:
            rows = slice_rows(key, tuple(np.shape(like))) if slice_rows else None
            arr = r.read_leaf(key, rows=rows)
            leaves.append(arr)
        r.close()
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # ------------------------------------------------------------- internals
    def _mfd(self):
        if self._manifest_fd is None:
            self._manifest_fd = self.fs.open(self._manifest_path)
        return self._manifest_fd

    def _read_manifest(self) -> dict:
        try:
            fd = self._mfd()
            size = self.fs.size(fd)
            raw = self.fs.pread(fd, size, 0) if size else b""
            return json.loads(raw) if raw else {}
        except Exception:
            return {}

    def _write_manifest(self, manifest: dict) -> None:
        blob = json.dumps(manifest).encode()
        fd = self._mfd()
        # single pwrite -> one atomic committed group in NVCache
        self.fs.pwrite(fd, blob.ljust(max(self.fs.size(fd), len(blob)), b" "), 0)
        self.fs.fsync(fd)

    def _gc(self, manifest: dict) -> None:
        steps = manifest.get("steps", [])
        while len(steps) > self.keep:
            steps.pop(0)
        manifest["steps"] = steps
        manifest["files"] = {k: v for k, v in manifest.get("files", {}).items()
                             if int(k) in steps}
