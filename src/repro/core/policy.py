"""NVCache policy knobs (paper §IV-A defaults, scaled down in tests).

Paper defaults: 4 KiB entries, 16 Mi entries (~64 GiB log), 250k-page read
cache (~1 GiB), cleanup batches of [1000, 10000] entries.
"""
from __future__ import annotations

import dataclasses

KIB = 1024
MIB = 1024 * KIB
GIB = 1024 * MIB

CACHELINE = 64
ENTRY_HEADER = 32
PATH_MAX = 256
FD_MAX = 256
SUPERBLOCK = 4096  # superblock + fd table live in the first region of NVMM


@dataclasses.dataclass(frozen=True)
class Policy:
    """Configuration of one NVCache instance."""

    entry_size: int = 4 * KIB          # fixed-size log entries (paper §II-D)
    log_entries: int = 16 * 1024       # paper: 16 Mi; tests/benches scale down
    page_size: int = 4 * KIB           # read-cache page (power of two, §II-C fn2)
    read_cache_pages: int = 1024       # paper: 250k pages (~1 GiB)
    batch_min: int = 1000              # min entries before cleanup batches (§IV-A)
    batch_max: int = 10000             # max entries per cleanup batch
    verify_crc: bool = True            # beyond-paper: per-entry payload CRC32
    fd_max: int = FD_MAX
    path_max: int = PATH_MAX

    def __post_init__(self):
        if self.page_size & (self.page_size - 1):
            raise ValueError("page_size must be a power of two (radix tree)")
        if self.entry_size <= ENTRY_HEADER:
            raise ValueError("entry_size must exceed the 32-byte header")
        if self.log_entries < 2:
            raise ValueError("log needs at least 2 entries")
        # a batch larger than the log can never fill: clamp (paper's config
        # always has batch << log; this guards scaled-down test configs)
        cap = max(1, self.log_entries // 2)
        if self.batch_min > cap:
            object.__setattr__(self, "batch_min", cap)
        if self.batch_max < self.batch_min:
            object.__setattr__(self, "batch_max", self.batch_min)

    @property
    def entry_data(self) -> int:
        return self.entry_size - ENTRY_HEADER

    @property
    def fd_table_bytes(self) -> int:
        return self.fd_max * self.path_max

    @property
    def entries_base(self) -> int:
        base = SUPERBLOCK + self.fd_table_bytes
        return (base + self.page_size - 1) & ~(self.page_size - 1)

    @property
    def nvmm_bytes(self) -> int:
        return self.entries_base + self.log_entries * self.entry_size


#: Paper §IV-A configuration (64 GiB log, 1 GiB read cache).
PAPER_DEFAULT = Policy(
    entry_size=4 * KIB,
    log_entries=16 * 1024 * 1024,
    read_cache_pages=250_000,
    batch_min=1000,
    batch_max=10000,
)

#: Small configuration for unit/property tests.
TEST_SMALL = Policy(
    entry_size=256,
    log_entries=64,
    page_size=256,
    read_cache_pages=8,
    batch_min=4,
    batch_max=16,
)
