"""NVCache policy knobs (paper §IV-A defaults, scaled down in tests).

Paper defaults: 4 KiB entries, 16 Mi entries (~64 GiB log), 250k-page read
cache (~1 GiB), cleanup batches of [1000, 10000] entries.

Beyond the paper: the log can be partitioned into ``shards`` independent
sub-logs (cf. "NVMM cache design: Logging vs. Paging" and NVLog's per-core
logs), each with its own commit path, persistent tail and drain thread.
``shard_route`` picks how writes map to shards:

* ``"fdid"``   — strict per-file affinity: shard = fdid % K.  Unrelated
  files never contend on the same fetch-and-add; all writes of one file
  stay totally ordered by one shard's log.
* ``"stripe"`` — per-file *stripe* affinity (the sound version of
  "round-robin for a hot fd"): shard = (fdid + off // stripe_bytes) % K.
  A hot file spreads across every shard, while any two overlapping writes
  still land in the same shard (writes are split at stripe boundaries
  upstream), which keeps per-location ordering a single-log property.

Both routes are *static*: a skewed fdid distribution (several hot files
colliding under ``fdid % K``) collapses back to single-shard throughput.
``shard_rebalance`` layers an epoch-based adaptive router on top
(:mod:`repro.core.router`): per-key load is sampled every
``rebalance_epoch_ms`` and hot fdids (or hot stripes) are migrated to
lighter shards by installing a new routing epoch — each migration takes the
per-file drain barrier first, so the PR-1 invariant (overlapping writes
share a shard log) survives the route change.  ``placement_groups``
partitions the K shards into G NUMA-style groups: a migration never moves a
key out of its group, so a file keeps its shard→drain-thread affinity.
The route table is persisted next to the superblock (``route_base``) so an
attach after a mid-epoch crash routes exactly as before the crash.
``shard_rebalance=False`` (the default, and the paper baseline) leaves the
static routes bit-identical to the PR 3 behavior.

Dual persistence (layout VERSION 4, cf. "NVMM cache design: Logging vs.
Paging"): ``page_frames > 0`` carves a *paged region* out of the NVMM
between the route table and the shard logs.  Each frame holds one
read-cache-page-sized file page as a ping-pong pair of data slots plus a
one-cacheline header (seq / fdid / page / active slot / length / crc), so
an overwrite builds the new page image in the inactive slot and commits it
with a single atomic header flip — in place, with no log append and no
drain replay.  A per-file :class:`StreamClassifier` watches each write
stream (average write size and overwrite ratio over ``classify_window``
writes, the write-side twin of the ``File.ra_next`` readahead detector)
and routes the stream to log or page mode; mode flips take the same
freeze + drain-barrier protocol as route migrations.  ``page_frames=0``
(the default) leaves the layout byte-identical to VERSION 3 modulo the
superblock version/field.
"""
from __future__ import annotations

import dataclasses

KIB = 1024
MIB = 1024 * KIB
GIB = 1024 * MIB

CACHELINE = 64
ENTRY_HEADER = 48
PATH_MAX = 256
FD_MAX = 256
SUPERBLOCK = 4096  # superblock + shard tail table live in the first region
SHARD_TAILS = 64   # per-shard persistent tails start here, one cacheline each
MAX_SHARDS = (SUPERBLOCK - SHARD_TAILS) // CACHELINE
ROUTE_HDR = 16     # persisted route record header (epoch, count, crc)
ROUTE_ENT = 12     # one persisted route override (key u64, sid u32)
FRAME_HDR = 64     # paged-region frame header: one cacheline, so the
#                    commit (header overwrite) is a single-line atomic store


@dataclasses.dataclass(frozen=True)
class Policy:
    """Configuration of one NVCache instance."""

    entry_size: int = 4 * KIB          # fixed-size log entries (paper §II-D)
    log_entries: int = 16 * 1024       # total across shards; paper: 16 Mi
    page_size: int = 4 * KIB           # read-cache page (power of two, §II-C fn2)
    read_cache_pages: int = 1024       # paper: 250k pages (~1 GiB)
    batch_min: int = 1000              # min entries before cleanup batches (§IV-A)
    batch_max: int = 10000             # max entries per cleanup batch
    verify_crc: bool = True            # beyond-paper: per-entry payload CRC32
    fd_max: int = FD_MAX
    path_max: int = PATH_MAX
    shards: int = 1                    # independent sub-logs (1 == paper design)
    shard_route: str = "stripe"        # "stripe" | "fdid" (see module docstring)
    stripe_pages: int = 64             # stripe width, in read-cache pages
    # drain engine (beyond paper; cf. dm-writeboost's coalesced submission):
    drain_coalesce: bool = True        # plan/apply page+extent coalescing;
    #                                    False == the paper's entry-at-a-time
    coalesce_max_extent: int = MIB     # max bytes per coalesced extent write
    fsync_epoch: bool = True           # merge concurrent per-shard fsyncs of
    #                                    the same backend file into epochs
    # batch-spanning coalescing (cf. NVLog keeping its tail extent open
    # across syncs): a drain batch may leave its contiguous tail extent
    # (capped at one page-span of bytes) unconsumed so the next batch's
    # contiguous entries merge into the same backend write.  Deferred
    # entries stay committed in the log with their dirty-page-index refs
    # live, and are force-flushed once they are older than the deadline or
    # whenever a drain barrier (close/flush/fsync) is requested.
    coalesce_span_batches: bool = True  # carry the open tail extent across
    #                                     batches (requires drain_coalesce)
    coalesce_deadline_ms: float = 5.0   # max age of a carried tail extent
    # read path (the read-side twin of the drain engine, paper Fig. 2 miss
    # procedure generalized from one page to one aligned extent): a cache
    # miss loads up to ``readahead_pages`` pages in a single backend
    # operation (``TierFile.preadv``).  1 == the paper's per-page miss.
    # The effective extent is clamped to half the read cache so readahead
    # can never flush the cache it feeds.
    readahead_pages: int = 8
    # adaptive window ramp (kernel-style): a fresh sequential miss stream
    # starts with a 2-page extent and doubles (2 -> 4 -> 8 ...) up to
    # ``readahead_pages`` while the stream stays sequential; any random
    # miss resets the ramp.  Short sequential bursts thus stop paying the
    # full-window device cost.  False == PR-3 behavior (full aligned
    # window on the first sequential miss).
    readahead_ramp: bool = True
    # adaptive shard routing (see module docstring): epoch-based rebalancer
    # migrating hot route keys (fdids, or (fdid, stripe) pairs) to lighter
    # shards.  False == the static routes above, bit-identical to PR 3.
    shard_rebalance: bool = False
    rebalance_epoch_ms: float = 50.0    # load-sampling / rebalance period
    placement_groups: int = 1           # NUMA-style shard groups: migrations
    #                                     stay inside a key's group (1 == any
    #                                     shard is a candidate target)
    route_table_max: int = 64           # max persisted route overrides
    # dual persistence (VERSION 4, see module docstring): paged NVMM region
    # absorbing large / overwrite-heavy streams in place.  0 == log-only,
    # layout-compatible with VERSION 3.
    page_frames: int = 0                # frames in the paged region
    classify_window: int = 32           # writes per classifier window
    page_min_avg_write: int = 0         # avg write size that votes "page";
    #                                     0 == default to page_size
    page_overwrite_ratio: float = 0.5   # overwrite fraction that votes "page"
    page_wb_watermark: float = 0.75     # dirty-frame fraction that wakes the
    #                                     background writeback path
    # stripe-width auto-tuning (router follow-up): a fdid that stays hot for
    # this many consecutive rebalance epochs gets its stripe narrowed (fan-out
    # widened across shards) instead of being re-migrated each epoch.  0
    # disables tuning.
    stripe_tune_streak: int = 3
    stripe_tune_max_shift: int = 4      # stripe never narrows below
    #                                     stripe_bytes >> max_shift (>= page)
    # observability plane (VERSION 5, repro.obs): span-profiler level —
    # 0 == off (a branch per op), 1 == op-level spans + flight commit
    # events, 2 == full per-stage write/read/drain breakdown.
    obs_level: int = 0
    # flight-recorder ring: fixed 64-byte event records carved between the
    # route table and the paged region.  0 == no ring (layout matches
    # VERSION 4 modulo the superblock version/field).
    flight_records: int = 256

    def __post_init__(self):
        if self.obs_level not in (0, 1, 2):
            raise ValueError("obs_level must be 0, 1 or 2")
        if self.flight_records < 0:
            raise ValueError("flight_records must be >= 0")
        if self.page_size & (self.page_size - 1):
            raise ValueError("page_size must be a power of two (radix tree)")
        if self.entry_size <= ENTRY_HEADER:
            raise ValueError(f"entry_size must exceed the {ENTRY_HEADER}-byte header")
        if not 1 <= self.shards <= MAX_SHARDS:
            raise ValueError(f"shards must be in [1, {MAX_SHARDS}]")
        if self.shard_route not in ("stripe", "fdid"):
            raise ValueError("shard_route must be 'stripe' or 'fdid'")
        if self.stripe_pages < 1:
            raise ValueError("stripe_pages must be >= 1")
        if self.coalesce_max_extent < self.page_size:
            raise ValueError("coalesce_max_extent must be >= page_size "
                             "(extents never split a page's merged range)")
        if self.readahead_pages < 1:
            raise ValueError("readahead_pages must be >= 1")
        if self.coalesce_deadline_ms < 0:
            raise ValueError("coalesce_deadline_ms must be >= 0")
        if self.rebalance_epoch_ms <= 0:
            raise ValueError("rebalance_epoch_ms must be > 0")
        if self.route_table_max < 1:
            raise ValueError("route_table_max must be >= 1")
        if self.page_frames < 0:
            raise ValueError("page_frames must be >= 0")
        if self.classify_window < 2:
            raise ValueError("classify_window must be >= 2")
        if not 0.0 < self.page_overwrite_ratio <= 1.0:
            raise ValueError("page_overwrite_ratio must be in (0, 1]")
        if not 0.0 < self.page_wb_watermark <= 1.0:
            raise ValueError("page_wb_watermark must be in (0, 1]")
        if self.stripe_tune_streak < 0:
            raise ValueError("stripe_tune_streak must be >= 0")
        if self.stripe_tune_max_shift < 0:
            raise ValueError("stripe_tune_max_shift must be >= 0")
        if not 1 <= self.placement_groups <= self.shards:
            raise ValueError("placement_groups must be in [1, shards]")
        if self.shards % self.placement_groups:
            raise ValueError("placement_groups must divide shards evenly")
        per = self.log_entries // self.shards
        if per < 2:
            raise ValueError("each shard needs at least 2 entries")
        # normalize: the layout carves equal shards out of the region
        object.__setattr__(self, "log_entries", per * self.shards)
        # a batch larger than a shard can never fill: clamp (paper's config
        # always has batch << log; this guards scaled-down test configs)
        cap = max(1, per // 2)
        if self.batch_min > cap:
            object.__setattr__(self, "batch_min", cap)
        if self.batch_max < self.batch_min:
            object.__setattr__(self, "batch_max", self.batch_min)

    @property
    def entry_data(self) -> int:
        return self.entry_size - ENTRY_HEADER

    @property
    def entries_per_shard(self) -> int:
        return self.log_entries // self.shards

    @property
    def stripe_bytes(self) -> int:
        return self.stripe_pages * self.page_size

    @property
    def fd_table_bytes(self) -> int:
        return self.fd_max * self.path_max

    @property
    def route_base(self) -> int:
        """Persisted route record (epoch + overrides), next to the
        superblock's tables: [superblock | fd table | route table | shards]."""
        return SUPERBLOCK + self.fd_table_bytes

    @property
    def route_table_bytes(self) -> int:
        return ROUTE_HDR + self.route_table_max * ROUTE_ENT

    @property
    def flight_base(self) -> int:
        """Start of the flight-recorder ring (VERSION 5): cacheline-
        aligned, between the route table and the paged region.  Empty
        when ``flight_records == 0``."""
        base = self.route_base + self.route_table_bytes
        return (base + CACHELINE - 1) & ~(CACHELINE - 1)

    @property
    def flight_region_bytes(self) -> int:
        return self.flight_records * CACHELINE

    @property
    def page_base(self) -> int:
        """Start of the paged region (VERSION 4/5): page-aligned, between
        the flight ring and the shard logs.  Empty when
        ``page_frames == 0``."""
        base = self.flight_base + self.flight_region_bytes
        return (base + self.page_size - 1) & ~(self.page_size - 1)

    @property
    def frame_size(self) -> int:
        """One paged frame: header cacheline + two ping-pong data slots."""
        return FRAME_HDR + 2 * self.page_size

    @property
    def page_region_bytes(self) -> int:
        return self.page_frames * self.frame_size

    @property
    def entries_base(self) -> int:
        base = self.page_base + self.page_region_bytes
        return (base + self.page_size - 1) & ~(self.page_size - 1)

    def placement_group(self, sid: int) -> int:
        """NUMA-style group of shard ``sid``: shards are carved into
        ``placement_groups`` equal contiguous runs."""
        return sid // (self.shards // self.placement_groups)

    def static_shard(self, fdid: int, off: int) -> int:
        """The static route formula (see module docstring) — the single
        definition shared by ``NVLog.route`` and the adaptive router's
        fallback."""
        if self.shards == 1:
            return 0
        if self.shard_route == "fdid":
            return fdid % self.shards
        return (fdid + off // self.stripe_bytes) % self.shards

    def frame_base(self, idx: int) -> int:
        return self.page_base + idx * self.frame_size

    @property
    def page_min_avg(self) -> int:
        """Effective classifier size threshold (0 defaults to page_size)."""
        return self.page_min_avg_write or self.page_size

    def shard_base(self, sid: int) -> int:
        return self.entries_base + sid * self.entries_per_shard * self.entry_size

    def shard_tail_off(self, sid: int) -> int:
        return SHARD_TAILS + sid * CACHELINE

    @property
    def nvmm_bytes(self) -> int:
        return self.entries_base + self.log_entries * self.entry_size


#: Paper §IV-A configuration (64 GiB log, 1 GiB read cache), with the
#: paper's propagation path: entry-at-a-time draining behind the kernel
#: page cache, no user-space coalescing or fsync-epoch merging — the
#: faithful-reproduction baseline the beyond-paper engine is measured
#: against (benchmarks/fig8_coalescing.py).
PAPER_DEFAULT = Policy(
    entry_size=4 * KIB,
    log_entries=16 * 1024 * 1024,
    read_cache_pages=250_000,
    batch_min=1000,
    batch_max=10000,
    drain_coalesce=False,
    fsync_epoch=False,
    coalesce_span_batches=False,
    readahead_pages=1,
    readahead_ramp=False,
)

class StreamClassifier:
    """Per-file write-stream classifier for the dual persistence engine.

    The write-side twin of the ``File.ra_next`` readahead detector: instead
    of watching miss offsets it watches write sizes and page reuse.  Every
    ``classify_window`` writes it closes a window and votes:

    * ``"page"`` if the window's average write size reaches
      ``page_min_avg`` (large streams — the log's double copy dominates), or
      if at least ``page_overwrite_ratio`` of the window's bytes landed on
      pages already written recently *and* writes are at least half a page
      (rewrite-heavy streams — in-place frames absorb the churn);
    * ``"log"`` otherwise (small synchronous writes — append wins).

    A mode switch needs two consecutive windows voting the same way
    (hysteresis), so a flip-flop stream that alternates window by window
    never migrates.  The classifier only *proposes*: :meth:`note_write`
    returns the new mode when a switch is confirmed and the caller flips
    ``mode`` once the migration actually lands (a failed freeze leaves the
    proposal standing, so it fires again next window).
    """

    __slots__ = ("page_size", "window", "min_avg", "ow_ratio",
                 "mode", "_vote", "_count", "_bytes", "_ow_bytes",
                 "_pages", "_prev_pages", "stats_windows", "stats_switches")

    _PAGES_CAP = 8192  # bound the recent-page sets for huge streams

    def __init__(self, policy: Policy):
        self.page_size = policy.page_size
        self.window = policy.classify_window
        self.min_avg = policy.page_min_avg
        self.ow_ratio = policy.page_overwrite_ratio
        self.mode = "log"
        self._vote = None        # last window's vote, for hysteresis
        self._count = 0
        self._bytes = 0
        self._ow_bytes = 0
        self._pages = set()      # pages written in the open window
        self._prev_pages = set() # pages written in the previous window
        self.stats_windows = 0
        self.stats_switches = 0

    def note_write(self, off: int, n: int):
        """Record one write; returns ``"log"``/``"page"`` when a confirmed
        mode switch is proposed, else ``None``."""
        if n <= 0:
            return None
        ps = self.page_size
        p0, p1 = off // ps, (off + n - 1) // ps
        for p in range(p0, p1 + 1):
            if p in self._pages or p in self._prev_pages:
                s = max(off, p * ps)
                e = min(off + n, (p + 1) * ps)
                self._ow_bytes += e - s
            elif len(self._pages) < self._PAGES_CAP:
                self._pages.add(p)
        self._count += 1
        self._bytes += n
        if self._count < self.window:
            return None
        return self._close_window()

    def _close_window(self):
        avg = self._bytes / self._count
        ow = self._ow_bytes / self._bytes if self._bytes else 0.0
        want = ("page" if avg >= self.min_avg
                or (ow >= self.ow_ratio and 2 * avg >= self.min_avg)
                else "log")
        prev_vote = self._vote
        self._vote = want
        self._prev_pages = self._pages
        self._pages = set()
        self._count = self._bytes = self._ow_bytes = 0
        self.stats_windows += 1
        if want != self.mode and prev_vote == want:
            self.stats_switches += 1
            return want
        return None

    def confirm(self, mode: str) -> None:
        """The caller completed the migration; the stream is now ``mode``."""
        self.mode = mode


#: Small configuration for unit/property tests.
TEST_SMALL = Policy(
    entry_size=256,
    log_entries=64,
    page_size=256,
    read_cache_pages=8,
    batch_min=4,
    batch_max=16,
)
