"""The NVMM write log (paper §II-B, §II-D, §III Algorithm 1), sharded.

Layout inside the NVMM region (VERSION 4)::

    [superblock + shard tail table | fd-path table | route table
     | paged region (page_frames frames; empty when page_frames == 0)
     | shard 0 | ... | shard K-1]

The *paged region* (VERSION 4, :mod:`repro.core.pager`) is the second
persistence mode: per-file page frames whose overwrites are absorbed in
place instead of appended here.  The two modes compose under one ordering
rule — every frame commit draws its ``seq`` from the same global
:meth:`NVLog.next_seq` counter as log groups, so recovery merges frame
images and log groups into a single ascending-seq replay.  Routing
invariant: a (file, page) is persisted by exactly one mode at a time — a
frame is only materialized for a page with zero live log refs, a framed
page's writes never append to the log, and mode flips happen behind the
per-file freeze + drain barrier — so for any page either the log holds the
newest committed bytes, or the frame does (with a strictly larger seq than
any drained log entry for that page); never a mix that recovery could
interleave wrongly.

The region is partitioned into ``K = policy.shards`` independent sub-logs
(*shards*), each a circular array of fixed-size entries with its own
monotonic indices, its own persistent tail slot in the superblock's shard
table (one cacheline per shard — no false sharing of tail updates), and its
own volatile head/tail pair, i.e. free-space accounting.  ``K == 1`` is
exactly the paper's single circular log.  Writes are routed to a shard by
(fdid, offset) — see :mod:`repro.core.policy` — so unrelated files never
contend on the same fetch-and-add and each shard is drained by its own
cleanup thread (:class:`repro.core.cleanup.CleanupPool`).

Entries are fixed-size (paper §II-D: fixed size is what lets a thread commit
its entry independently of uncommitted neighbours, and lets recovery skip an
uncommitted hole and keep scanning).  Each 48-byte entry header packs the
commit flag and the group index into a single word ``cg`` that lives in the
first cacheline of the entry (paper: one flush, no extra cache miss):

    cg == 0        free, or allocated-but-uncommitted
    cg == 1        committed group head (or single-entry write)
    cg == idx + 2  committed follower of the group whose head has monotonic
                   index ``idx`` (indices are per shard)

The header also carries ``seq``, a *global* commit sequence number shared by
all shards.  ``seq`` is drawn while holding the shard's allocation lock, so
within one shard log order and seq order agree; across shards ``seq`` is the
merge key: recovery scans each shard independently and replays the union of
committed groups in ascending ``seq``, which restores the durable-
linearizability order per file location (any two overlapping writes are
routed to the same shard, so their seqs are also ordered by that shard's
log).  Per-shard indices are monotonic u64; the slot of index ``i`` is
``i % N`` with ``N = policy.entries_per_shard``.

A write larger than one entry allocates a *contiguous* block of entries in
one shard with a single fetch-and-add and commits atomically through the
head's commit flag alone (paper §II-D), in this order:

    fill followers -> pwb -> fill head (cg=0) -> pwb -> pfence
    -> head.cg = 1 -> pwb -> psync        (durable linearizability, §III)

Two tails per shard (paper §III "cleanup thread"):
  * ``persistent_tail`` in NVMM (shard table slot) — where recovery starts
    scanning this shard;
  * ``volatile_tail`` in DRAM — what writers check for free space.  An entry
    is recycled for writers only after it is durably consumed
    (cg zeroed + persistent tail advanced + pwb/pfence).
"""
from __future__ import annotations

import struct
import threading
import time
import zlib
from typing import Iterator, List, Optional

from repro.core import locking
from repro.core.nvmm import NVMM
from repro.core.policy import Policy, SUPERBLOCK
from repro.obs import flight as obs_flight
from repro.obs import metrics

MAGIC = 0x4E56_4341_4348_4532  # "NVCACHE2" (v1 was the unsharded layout)
VERSION = 5                    # v3 added the persisted route table region;
#                                v4 added the paged region (dual persistence);
#                                v5 added the flight-recorder ring (repro.obs)

_SB = struct.Struct("<QIIIIIIII")  # magic, ver, entry_size, entries/shard,
#                                    shards, fd_max, path_max, page_frames,
#                                    flight_records
_HDR = struct.Struct("<QQQIIII")  # cg, seq, off, fdid, length, nfollow, crc
HDR_SIZE = 48                     # header struct (44B) padded to 48
assert _HDR.size <= HDR_SIZE

CG_FREE = 0
CG_HEAD = 1

# ---------------------------------------------------------------- metadata
# Namespace (metadata) operations are first-class log entries: they carry
# the sentinel fdid below instead of a real file-table slot, and their
# payload is a :data:`_META`-encoded record instead of file bytes.  They
# commit through the exact same per-shard alloc/fill/commit protocol as
# data writes — drawing a global ``seq`` under the shard allocation lock —
# so recovery's cross-shard seq-merge serializes them against every data
# group (see :mod:`repro.core.namespace` for the protocol and its
# old-or-new guarantee).
META_FDID = 0xFFFF_FFFF            # u32 sentinel; real fdids are < fd_max
META_NO_FDID = 0xFFFF_FFFE         # payload fdid for ops on paths with no
#                                    live File (a closed, fully-drained
#                                    file): no in-log data group can carry
#                                    it, so recovery's dead-fdid tracking
#                                    ignores it (0 is a REAL fdid slot)

MOP_CREATE = 1                     # bind a path into the namespace
MOP_RENAME = 2                     # atomically move path a over path b
MOP_UNLINK = 3                     # remove path a
MOP_FTRUNCATE = 4                  # set path a's length to aux

_META = struct.Struct("<BIQHH")    # op, fdid, aux, len(a), len(b)


def encode_meta(op: int, fdid: int, aux: int, a: str, b: str = "") -> bytes:
    ra, rb = a.encode(), b.encode()
    return _META.pack(op, fdid, aux, len(ra), len(rb)) + ra + rb


def decode_meta(payload: bytes) -> tuple[int, int, int, str, str]:
    """Returns ``(op, fdid, aux, a, b)``; raises ValueError on a payload
    that does not parse (recovery drops such groups whole)."""
    if len(payload) < _META.size:
        raise ValueError("short metadata payload")
    op, fdid, aux, la, lb = _META.unpack_from(payload)
    if len(payload) < _META.size + la + lb:
        raise ValueError("truncated metadata payload")
    a = bytes(payload[_META.size:_META.size + la]).decode()
    b = bytes(payload[_META.size + la:_META.size + la + lb]).decode()
    return op, fdid, aux, a, b


class LogFullTimeout(RuntimeError):
    pass


class Entry:
    """Decoded view of a committed entry (header + payload memoryview)."""

    __slots__ = ("sid", "idx", "cg", "seq", "off", "fdid", "length", "nfollow",
                 "crc", "data")

    def __init__(self, sid, idx, cg, seq, off, fdid, length, nfollow, crc, data):
        self.sid = sid
        self.idx = idx
        self.cg = cg
        self.seq = seq
        self.off = off
        self.fdid = fdid
        self.length = length
        self.nfollow = nfollow
        self.crc = crc
        self.data = data  # memoryview of length bytes (valid until recycled)

    @property
    def is_meta(self) -> bool:
        """A namespace (metadata) entry rather than file data."""
        return self.fdid == META_FDID


class EntryRef:
    """Stable, recycle-safe handle to one live log entry.

    Per-shard indices are *monotonic* u64 (the slot of index ``i`` is
    ``i % N``), so ``(sid, idx)`` names one entry for the lifetime of the
    region: a recycled slot is refilled under a strictly larger index and a
    stale ref can never silently alias the new occupant — ``seq`` (and the
    header's off/length) double-check it.  The dirty-page index
    (:class:`repro.core.readcache.PageDesc`) holds these instead of payload
    copies; the payload is read back from NVMM via
    :meth:`NVLog.ref_payload`, which is valid exactly while the ref is live
    (refs are retired by the drain engine strictly before the entry is
    recycled).
    """

    __slots__ = ("sid", "idx", "seq", "off", "length")

    def __init__(self, sid: int, idx: int, seq: int, off: int, length: int):
        self.sid = sid
        self.idx = idx
        self.seq = seq
        self.off = off
        self.length = length

    def __repr__(self) -> str:  # debugging aid for index dumps
        return (f"EntryRef(sid={self.sid}, idx={self.idx}, seq={self.seq}, "
                f"off={self.off}, len={self.length})")


class LogShard:
    """One independent circular sub-log (the paper's whole log when K=1)."""

    GUARDED_BY = {
        # one shard lock, three faces: the conditions share _lock, so
        # holding any of them is the same mutual exclusion
        "head": ("_lock", "_space", "_committed"),
        "volatile_tail": ("_lock", "_space", "_committed"),
        "stats_appended": ("_lock", "_space", "_committed"),
        # internally synchronized / publish-before-threads (see __init__)
        "alloc_wait": locking.VOLATILE,
        "obs": locking.VOLATILE,
        # benign race: the EV_COMMIT sampling phase counter.  Concurrent
        # appenders may lose an increment, which only shifts which commit
        # the 1-in-16 sample lands on — never correctness, never a seq.
        "_commit_tick": locking.VOLATILE,
    }

    def __init__(self, nvmm: NVMM, policy: Policy, sid: int):
        self.nvmm = nvmm
        self.policy = policy
        self.sid = sid
        self.n = policy.entries_per_shard
        self.entry_size = policy.entry_size
        self.base = policy.shard_base(sid)
        self.tail_off = policy.shard_tail_off(sid)

        self._lock = locking.make_lock("shard")  # guards head/volatile_tail
        self._space = locking.make_condition("shard", self._lock)
        #                                       ^ writers wait for space
        self._committed = locking.make_condition("shard", self._lock)
        #                                       ^ drainer waits for work
        # guarded-by: _lock (via _space/_committed too) — the shard cursor
        # pair and the per-shard counters load_sample() snapshots
        self.head = 0                           # volatile head (paper §II-B fn1)
        self.volatile_tail = 0
        self.stats_appended = 0                 # entries ever reserved here
        # guarded-by: VOLATILE — the histogram is internally synchronized
        # (per-thread cells, repro.obs.metrics); one episode per log-full
        # wait, so the rebalance planner reads a real distribution instead
        # of a count-less duration sum.
        self.alloc_wait = metrics.Histogram("log.alloc_wait_us")
        # guarded-by: VOLATILE — the engine's ObsPlane, wired once by
        # NVCache before any writer or drain thread starts and read-only
        # after (publication rides the thread-start edge).  None when the
        # shard is used standalone (recovery, unit tests).
        self.obs = None
        self._commit_tick = 0                   # EV_COMMIT sampling phase

    def format(self) -> None:
        """Zero every entry header (cg == CG_FREE) and this shard's tail."""
        for i in range(self.n):
            self.nvmm.store(self.base + i * self.entry_size, b"\x00" * HDR_SIZE)
            self.nvmm.pwb(self.base + i * self.entry_size, HDR_SIZE)
        self.nvmm.store_u64(self.tail_off, 0)
        self.nvmm.pwb(self.tail_off, 8)
        # format/attach run before any writer or drain thread exists —
        # single-owner setup, no lock needed
        self.head = 0                          # lint: allow(L004)
        self.volatile_tail = 0                 # lint: allow(L004)

    def attach(self) -> int:
        """Adopt on-NVMM state after a restart; returns the max committed seq
        seen (0 if the shard is empty)."""
        ptail = self.persistent_tail
        # pre-start single-owner adoption (see format)
        self.head = ptail                      # lint: allow(L004)
        self.volatile_tail = ptail             # lint: allow(L004)
        max_seq = 0
        for e in self.scan_committed(ptail, ptail + self.n):
            max_seq = max(max_seq, e.seq)
            if e.idx + 1 > self.head:          # lint: allow(L004)
                self.head = e.idx + 1          # lint: allow(L004)
        return max_seq

    @property
    def persistent_tail(self) -> int:
        return self.nvmm.load_u64(self.tail_off)

    def _store_persistent_tail(self, val: int) -> None:
        self.nvmm.store_u64(self.tail_off, val)
        self.nvmm.pwb(self.tail_off, 8)

    # ---------------------------------------------------------- entry codec
    def _eoff(self, idx: int) -> int:
        return self.base + (idx % self.n) * self.entry_size

    def read_cg(self, idx: int) -> int:
        return self.nvmm.load_u64(self._eoff(idx))

    def read_entry(self, idx: int) -> Entry:
        off = self._eoff(idx)
        cg, seq, foff, fdid, length, nfollow, crc = _HDR.unpack_from(
            self.nvmm.load(off, _HDR.size))
        data = self.nvmm.load(off + HDR_SIZE, length)
        return Entry(self.sid, idx, cg, seq, foff, fdid, length, nfollow, crc, data)

    def is_committed(self, idx: int) -> bool:
        """Committed = head with cg==1, or follower whose head has cg==1."""
        cg = self.read_cg(idx)
        if cg == CG_HEAD:
            return True
        if cg >= 2:
            return self.read_cg(cg - 2) == CG_HEAD
        return False

    # ------------------------------------------------------------ allocation
    def alloc(self, k: int, timeout: Optional[float] = None,
              seq_source=None) -> tuple[int, int]:
        """Reserve ``k`` contiguous entries; returns (index, seq).

        Blocks while the shard is full (paper Alg. 1 ``next_entry`` line 37).
        ``timeout`` bounds the TOTAL wait as a monotonic deadline — each
        ``Condition.wait`` gets only the remaining budget, so spurious
        wakeups and near-miss frees (woken, still full, wait again) cannot
        extend the wait beyond ``timeout``.  ``seq_source`` is drawn
        *inside* the allocation lock so that within this shard, allocation
        order == seq order (drain order and the recovery merge then agree
        for every pair of entries in one shard).
        """
        if k > self.n - 1:
            raise ValueError("write exceeds shard capacity; split upstream")
        deadline = None if timeout is None else time.monotonic() + timeout
        waited_ns = 0
        try:
            with self._space:
                while self.head + k - self.volatile_tail > self.n:
                    remaining = None
                    if deadline is not None:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            raise LogFullTimeout(f"shard {self.sid} full")
                    t0 = time.monotonic_ns()
                    self._space.wait(timeout=remaining)
                    waited_ns += time.monotonic_ns() - t0
                idx = self.head
                self.head += k
                self.stats_appended += k
                seq = seq_source() if seq_source is not None else 0
                return idx, seq
        finally:
            if waited_ns:
                # one episode per log-full wait (including timed-out ones)
                self.alloc_wait.record_ns(waited_ns)
                obs = self.obs
                if obs is not None and obs.flight is not None:
                    obs.flight.record(obs_flight.EV_BACKPRESSURE,
                                      self.sid, waited_ns)

    @property
    def stats_alloc_wait_s(self) -> float:
        """Total time writers spent log-full (back-compat view over the
        ``log.alloc_wait_us`` histogram)."""
        return self.alloc_wait.sum_s

    def try_alloc(self, k: int, seq_source=None) -> Optional[tuple[int, int]]:
        with self._space:
            if self.head + k - self.volatile_tail > self.n:
                return None
            idx = self.head
            self.head += k
            self.stats_appended += k
            seq = seq_source() if seq_source is not None else 0
            return idx, seq

    # ---------------------------------------------------------------- write
    def fill_entry(self, idx: int, fdid: int, off: int, data: bytes, cg: int,
                   seq: int = 0) -> None:
        """Fill one entry (no commit).  ``cg`` is 0 for heads, head+2 for
        followers; ``nfollow`` is patched on the head before commit."""
        eoff = self._eoff(idx)
        crc = zlib.crc32(data) if self.policy.verify_crc else 0
        self.nvmm.store(eoff, _HDR.pack(cg, seq, off, fdid, len(data), 0, crc))
        self.nvmm.store(eoff + HDR_SIZE, data)
        self.nvmm.pwb(eoff, HDR_SIZE + len(data))

    def append(self, fdid: int, off: int, data: bytes, *, seq_source,
               timeout: Optional[float] = None,
               on_alloc=None) -> tuple[int, int, int]:
        """The paper's write-cache append: alloc, fill, commit.

        Returns ``(head_idx, k, seq)``.  On return the write is durable
        (synchronous durability) and ordered (durable linearizability).

        ``on_alloc(head, k, seq)`` runs after allocation but BEFORE the
        commit flag is set.  The write path registers the group's refs in
        the dirty-page index here: only once the commit makes the entries
        visible can the drain retire them, so retire always finds the refs
        — registering after ``append`` returned would race the drain the
        way the paper's dirty counter did (its fn. 4 transient negative),
        except an index cannot absorb a lost retirement the way a counter
        absorbs a transient negative.
        """
        ed = self.policy.entry_data
        k = max(1, -(-len(data) // ed))
        head, seq = self.alloc(k, timeout=timeout, seq_source=seq_source)
        if on_alloc is not None:
            on_alloc(head, k, seq)
        obs = self.obs
        lv2 = obs is not None and obs.prof.lv2
        t_fill = time.perf_counter_ns() if lv2 else 0
        # followers first (paper §II-D: they must be durable before the head
        # commit makes the whole group visible to recovery)
        for j in range(1, k):
            chunk = data[j * ed:(j + 1) * ed]
            self.fill_entry(head + j, fdid, off + j * ed, chunk, cg=head + 2,
                            seq=seq)
        self.fill_entry(head, fdid, off, data[:ed], cg=CG_FREE, seq=seq)
        # patch nfollow on the head before the commit flush
        eoff = self._eoff(head)
        self.nvmm.store(eoff + 32, struct.pack("<I", k - 1))
        self.nvmm.pwb(eoff, HDR_SIZE)
        self.nvmm.pfence()                    # entries durable before commit
        t_commit = time.perf_counter_ns() if lv2 else 0
        if lv2:
            obs.prof.h_fill.record_ns(t_commit - t_fill)
        self.nvmm.store_u64(eoff, CG_HEAD)    # commit the group
        self.nvmm.pwb(eoff, 8)
        self.nvmm.psync()                     # durable linearizability (§III)
        with self._lock:
            self._committed.notify_all()
        if lv2:
            obs.prof.h_commit.record_ns(time.perf_counter_ns() - t_commit)
        if obs is not None and obs.prof.lv1 and obs.flight is not None:
            # Sampled 1-in-16 per shard: commits are the only high-rate
            # flight event, and a per-group record would both dominate the
            # instrumented hot-path cost (~5µs pack+crc+store each) and
            # wrap the small ring in milliseconds.  Sampling keeps a
            # commit heartbeat in the forensic window (seq payloads show
            # the gaps) at 1/16th the cost; rare events stay unsampled.
            tick = self._commit_tick
            self._commit_tick = tick + 1
            if tick & 0xF == 0:
                obs.flight.record(obs_flight.EV_COMMIT, self.sid, seq,
                                  head % self.n, k)
        return head, k, seq

    # -------------------------------------------------- consumption (drain)
    def committed_run(self, start: int, limit: int) -> int:
        """Number of consecutive committed entries at ``start`` (whole groups
        only), capped at ``limit``.  Used by this shard's drain thread to
        build a batch; stops at the first uncommitted head (in-flight)."""
        count = 0
        with self._lock:
            head = self.head
        while count < limit and start + count < head:
            cg = self.read_cg(start + count)
            if cg != CG_HEAD:
                break  # hole: in-flight, uncommitted (wait for the writer)
            group = 1 + self.read_entry(start + count).nfollow
            if count + group > limit and count > 0:
                break
            count += group
        return count

    def wait_committed(self, min_entries: int, *, drain_event: threading.Event,
                       stop_event: threading.Event, poll: float = 0.05,
                       deferred: int = 0,
                       deadline_at: Optional[float] = None) -> int:
        """Block until >= min_entries consecutive committed entries exist at
        the persistent tail, or a drain/stop is requested.  Returns the run
        length found (0 if stopping).

        ``deferred`` entries at the tail were intentionally held back by the
        drain's batch-spanning coalescer: they alone are not "new work", so
        the wait ignores them until either fresh entries commit behind them
        (``run > deferred``), the carried extent's ``deadline_at``
        (monotonic seconds) expires, or a drain/stop is requested — the
        three events that close the open tail extent."""
        while True:
            run = self.committed_run(self.persistent_tail, self.policy.batch_max)
            if run > 0:
                if drain_event.is_set():
                    return run
                if run >= min_entries and run > deferred:
                    return run
                if deadline_at is not None and time.monotonic() >= deadline_at:
                    return run
                with self._lock:
                    used = self.head - self.volatile_tail
                if 2 * used >= self.n:
                    # log-full backpressure: writers may be blocked on
                    # recycling while the ready run is below batch_min
                    # (e.g. a small group ahead of one that exceeds
                    # batch_max) — never idle on a starving shard
                    return run
            if stop_event.is_set():
                return run
            timeout = poll
            if deadline_at is not None:
                timeout = min(poll, max(0.0, deadline_at - time.monotonic()))
            with self._committed:
                self._committed.wait(timeout=max(1e-4, timeout))

    def consume(self, start: int, count: int) -> None:
        """Durably retire ``count`` entries at ``start`` (== persistent tail).

        Paper cleanup step 2: zero the commit flags and advance the persistent
        tail with pwb/pfence; step 3: advance the volatile tail so writers can
        recycle the slots.
        """
        if start != self.persistent_tail:
            raise AssertionError("drain must consume at the persistent tail")
        for i in range(count):
            eoff = self._eoff(start + i)
            self.nvmm.store_u64(eoff, CG_FREE)
            self.nvmm.pwb(eoff, 8)
        self._store_persistent_tail(start + count)
        self.nvmm.pfence()
        with self._space:
            self.volatile_tail = start + count
            self._space.notify_all()

    # ------------------------------------------------------------------ scan
    def scan_committed(self, start: int, end: int) -> Iterator[Entry]:
        """Yield committed entries in ``[start, end)`` in shard-log order,
        skipping holes.  Safe concurrently with writers (an entry is only
        yielded when its group head is committed) — used by the dirty-miss
        procedure and by recovery."""
        idx = start
        while idx < end:
            cg = self.read_cg(idx)
            if cg == CG_HEAD:
                head = self.read_entry(idx)
                yield head
                for j in range(head.nfollow):
                    e = self.read_entry(idx + 1 + j)
                    if e.cg == idx + 2:
                        yield e
                idx += 1 + head.nfollow
            else:
                idx += 1

    def snapshot_bounds(self) -> tuple[int, int]:
        with self._lock:
            return self.volatile_tail, self.head

    @property
    def used_entries(self) -> int:
        with self._lock:
            return self.head - self.volatile_tail

    def load_sample(self) -> dict:
        """One rebalance-epoch load sample: live entries, drain backlog
        (committed-or-in-flight entries the drain has not yet retired), and
        the cumulative counters the sampler turns into per-epoch deltas."""
        with self._lock:
            head, vtail = self.head, self.volatile_tail
            appended = self.stats_appended
        # the alloc-wait histogram is internally synchronized: a real
        # distribution (count + sum), not a count-less duration sum
        waits = self.alloc_wait.count
        wait_ns = self.alloc_wait.sum_ns
        return {"sid": self.sid, "used": head - vtail,
                "queue": head - self.persistent_tail,
                "alloc_wait_s": wait_ns * 1e-9, "appended": appended,
                "alloc_waits": waits,
                "alloc_wait_mean_us": (wait_ns / waits) * 1e-3
                                      if waits else 0.0}

    def notify_committed(self) -> None:
        with self._committed:
            self._committed.notify_all()


class NVLog:
    """The sharded log facade: K :class:`LogShard` sub-logs, the global
    superblock + fd-path table, the global ``seq`` source, and write routing.
    """

    GUARDED_BY = {
        "_seq": "_seq_lock",
        # diagnostic counter read by the conftest full-scan guard after
        # the run; a racy live read only under-counts — and any full scan
        # on a hot path is itself the bug being guarded against
        "stats_full_scans": locking.VOLATILE,
    }

    def __init__(self, nvmm: NVMM, policy: Policy, *, format: bool = True,
                 adopt: bool = True):
        """``adopt=False`` (with ``format=False``) skips restoring the
        volatile heads/seq from a scan — for read-only consumers like
        recovery, which scans the shards itself anyway."""
        self.nvmm = nvmm
        self.policy = policy
        self.n = policy.entries_per_shard
        self.entry_size = policy.entry_size
        if nvmm.size < policy.nvmm_bytes:
            raise ValueError(f"NVMM region too small: {nvmm.size} < {policy.nvmm_bytes}")
        self.shards: List[LogShard] = [LogShard(nvmm, policy, s)
                                       for s in range(policy.shards)]
        self._seq_lock = locking.make_lock("leaf:seq")
        self._seq = 0
        self.stats_full_scans = 0   # whole-log scans (must stay off hot paths)
        self.router = None          # optional EpochRouter (adaptive routing);
        #                             None == the static formula below, the
        #                             PR 3 behavior bit for bit
        if format:
            self._format()
        else:
            self._check_superblock()
            if adopt:
                self._seq = max(sh.attach() for sh in self.shards)
                if policy.page_frames:
                    # frames draw from the same seq counter: never reuse a
                    # seq below a live frame's (recovery merges by seq)
                    from repro.core.pager import max_frame_seq
                    self._seq = max(self._seq, max_frame_seq(nvmm, policy))
                # a persisted route record means a rebalance-enabled
                # instance installed overrides while (possibly) leaving
                # live entries in the overridden shards.  Honor it even if
                # this policy has shard_rebalance off: falling back to the
                # static route would send an overlapping write to a
                # different shard than the live entries it overlaps —
                # breaking the invariant the whole design rests on.  An
                # owner that enables rebalancing replaces this router with
                # its own (loaded from the same record, so routes agree).
                from repro.core.router import EpochRouter, load_route_record
                epoch, table, shifts = load_route_record(nvmm, policy)
                if epoch or table or shifts:
                    # route-only (sampling=False): without a rebalance
                    # thread nobody would ever drain the load counters
                    self.router = EpochRouter(nvmm, policy, sampling=False)

    def next_seq(self) -> int:
        with self._seq_lock:
            self._seq += 1
            return self._seq

    # ------------------------------------------------------------ superblock
    def _format(self) -> None:
        # zeroes everything below the shards — fd table, route table,
        # (VERSION 5) the flight-recorder ring, and (VERSION 4) every
        # paged-frame header, so a reformat frees frames
        self.nvmm.store(0, b"\x00" * self.policy.entries_base)
        self.nvmm.store(0, _SB.pack(MAGIC, VERSION, self.entry_size, self.n,
                                    self.policy.shards, self.policy.fd_max,
                                    self.policy.path_max,
                                    self.policy.page_frames,
                                    self.policy.flight_records))
        self.nvmm.pwb(0, self.policy.entries_base)
        for sh in self.shards:
            sh.format()
        self.nvmm.psync()
        # __init__-only helper: single-owner setup
        self._seq = 0                          # lint: allow(L004)

    def _check_superblock(self) -> None:
        magic, ver, esz, n, k, fdm, pm, pf, fr = _SB.unpack_from(
            self.nvmm.load(0, _SB.size))
        if magic != MAGIC or ver != VERSION:
            raise ValueError("not an NVCache log region")
        if esz != self.entry_size or n != self.n or k != self.policy.shards:
            raise ValueError("policy mismatch with on-NVMM superblock")
        if pf != self.policy.page_frames:
            raise ValueError("paged-region mismatch with on-NVMM superblock")
        if fr != self.policy.flight_records:
            raise ValueError("flight-ring mismatch with on-NVMM superblock")

    # ------------------------------------------------------------- fd table
    def fd_table_set(self, fdid: int, path: str) -> None:
        raw = path.encode()
        if len(raw) >= self.policy.path_max:
            raise ValueError("path too long for fd table")
        off = SUPERBLOCK + fdid * self.policy.path_max
        self.nvmm.store(off, raw + b"\x00" * (self.policy.path_max - len(raw)))
        self.nvmm.pwb(off, self.policy.path_max)
        self.nvmm.psync()

    def fd_table_get(self, fdid: int) -> Optional[str]:
        off = SUPERBLOCK + fdid * self.policy.path_max
        raw = bytes(self.nvmm.load(off, self.policy.path_max))
        raw = raw.split(b"\x00", 1)[0]
        return raw.decode() if raw else None

    def fd_table_clear(self) -> None:
        self.nvmm.store(SUPERBLOCK, b"\x00" * self.policy.fd_table_bytes)
        self.nvmm.pwb(SUPERBLOCK, self.policy.fd_table_bytes)
        self.nvmm.psync()

    # --------------------------------------------------------------- routing
    def route(self, fdid: int, off: int) -> int:
        """Map a write to a shard.  Overlapping writes always map to the same
        shard (per-file in "fdid" mode, per-stripe in "stripe" mode, where the
        caller splits writes at stripe boundaries).  With an
        :class:`repro.core.router.EpochRouter` installed the lookup goes
        through the current routing epoch's override table; migrations
        preserve the overlap invariant via the per-file drain barrier (see
        the router module docstring for the proof)."""
        if self.router is not None:
            return self.router.route(fdid, off)
        return self.policy.static_shard(fdid, off)

    def entries_needed(self, nbytes: int) -> int:
        return max(1, -(-nbytes // self.policy.entry_data))

    # ---------------------------------------------------------------- write
    def append(self, fdid: int, off: int, data: bytes,
               timeout: Optional[float] = None,
               shard: Optional[int] = None,
               on_alloc=None) -> tuple[int, int, int, int]:
        """Route and commit one write; returns ``(sid, head_idx, k, seq)``.

        ``on_alloc(sid, head, k, seq)`` runs pre-commit (see
        :meth:`LogShard.append`) — the write path's hook for registering
        the group in the dirty-page index before the drain can see it.
        """
        sid = self.route(fdid, off) if shard is None else shard
        if self.router is not None:
            self.router.note_append(fdid, off, self.entries_needed(len(data)))
        cb = None if on_alloc is None else (
            lambda head, k, seq: on_alloc(sid, head, k, seq))
        head, k, seq = self.shards[sid].append(fdid, off, data,
                                               seq_source=self.next_seq,
                                               timeout=timeout,
                                               on_alloc=cb)
        return sid, head, k, seq

    def append_meta(self, payload: bytes, *, route_key: str = "",
                    timeout: Optional[float] = None,
                    on_alloc=None) -> tuple[int, int, int, int]:
        """Commit one namespace (metadata) record as a log entry group.

        The record routes by a hash of its primary path — metadata ops
        never overlap data writes in the log-ordering sense (the caller
        quiesces the file behind the drain barrier first), so any shard is
        sound; hashing spreads unrelated namespace traffic.  The global
        ``seq`` drawn inside the shard lock is what orders the op against
        every data group for recovery's merge.  ``on_alloc(sid, head, k,
        seq)`` runs pre-commit, exactly like the data path's hook — the
        namespace registers its not-yet-applied marker there, before the
        drain can possibly see the entry.
        """
        sid = zlib.crc32(route_key.encode()) % self.policy.shards
        cb = None if on_alloc is None else (
            lambda head, k, seq: on_alloc(sid, head, k, seq))
        head, k, seq = self.shards[sid].append(META_FDID, 0, payload,
                                               seq_source=self.next_seq,
                                               timeout=timeout,
                                               on_alloc=cb)
        return sid, head, k, seq

    # ------------------------------------------------------------------ refs
    def group_refs(self, sid: int, head: int, k: int, seq: int, off: int,
                   nbytes: int) -> List[EntryRef]:
        """One :class:`EntryRef` per entry of a just-committed group, with
        the per-entry file offset/length split that :meth:`LogShard.append`
        used — the write path feeds these into the dirty-page index."""
        ed = self.policy.entry_data
        return [EntryRef(sid, head + j, seq, off + j * ed,
                         min(ed, nbytes - j * ed))
                for j in range(k)]

    def ref_payload(self, ref: EntryRef) -> memoryview:
        """Payload bytes of a *live* ref (dirty-miss replay).

        The caller must hold the page's cleanup lock, which orders it
        against the drain engine: a ref still present in a page's index has
        not been retired, so its entry cannot have been recycled.  The
        header check turns a protocol violation (reading through a stale
        ref) into a loud error instead of silently replaying another
        write's bytes.
        """
        sh = self.shards[ref.sid]
        eoff = sh._eoff(ref.idx)
        _cg, seq, foff, _fdid, length, _nf, _crc = _HDR.unpack_from(
            self.nvmm.load(eoff, _HDR.size))
        if seq != ref.seq or foff != ref.off or length != ref.length:
            raise RuntimeError(f"stale {ref!r}: entry slot was recycled "
                               f"(seq={seq} off={foff} len={length})")
        return self.nvmm.load(eoff + HDR_SIZE, length)

    # ------------------------------------------------------------------ scan
    def scan_all_committed(self) -> Iterator[Entry]:
        """Committed entries of every shard, in no particular cross-shard
        order (sort by ``(seq, idx)`` when ordering matters).  O(log) — kept
        for recovery-style consumers and diagnostics only; the read path
        uses the per-page dirty index instead (``stats_full_scans`` guards
        that in tests)."""
        self.stats_full_scans += 1
        for sh in self.shards:
            tail, head = sh.snapshot_bounds()
            yield from sh.scan_committed(tail, head)

    @property
    def used_entries(self) -> int:
        return sum(sh.used_entries for sh in self.shards)

    def verify_entry(self, e: Entry) -> bool:
        return (not self.policy.verify_crc) or zlib.crc32(bytes(e.data)) == e.crc

    # --------------------------------------------- single-shard conveniences
    # (protocol-level tests and the K=1 path address the log as one object)
    @property
    def persistent_tail(self) -> int:
        return self.shards[0].persistent_tail
