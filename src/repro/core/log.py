"""The NVMM circular write log (paper §II-B, §II-D, §III Algorithm 1).

Layout inside the NVMM region::

    [superblock | fd-path table | entry 0 | entry 1 | ... | entry N-1 ]

Entries are fixed-size (paper §II-D: fixed size is what lets a thread commit
its entry independently of uncommitted neighbours, and lets recovery skip an
uncommitted hole and keep scanning).  Each 32-byte entry header packs the
commit flag and the group index into a single word ``cg`` that lives in the
first cacheline of the entry (paper: one flush, no extra cache miss):

    cg == 0        free, or allocated-but-uncommitted
    cg == 1        committed group head (or single-entry write)
    cg == idx + 2  committed follower of the group whose head has monotonic
                   index ``idx``

Indices are monotonic u64; the slot of index ``i`` is ``i % N``.  A write
larger than one entry allocates a *contiguous* block of entries with a single
fetch-and-add (a faithful refinement of the paper's per-entry allocation: it
keeps per-thread commit independence, and makes group extent recoverable via
the head's follower count).  The group commits atomically through the head's
commit flag alone (paper §II-D), in this order:

    fill followers -> pwb -> fill head (cg=0) -> pwb -> pfence
    -> head.cg = 1 -> pwb -> psync        (durable linearizability, §III)

Two tails (paper §III "cleanup thread"):
  * ``persistent_tail`` in NVMM — where recovery starts scanning;
  * ``volatile_tail`` in DRAM — what writers check for free space.  An entry
    is recycled for writers only after it is durably consumed
    (cg zeroed + persistent tail advanced + pwb/pfence).
"""
from __future__ import annotations

import struct
import threading
import zlib
from typing import Iterator, Optional

from repro.core.nvmm import NVMM
from repro.core.policy import Policy, SUPERBLOCK

MAGIC = 0x4E56_4341_4348_4531  # "NVCACHE1"
VERSION = 1

_SB = struct.Struct("<QII Q Q II")          # magic, ver, entry_size, n, ptail, fd_max, path_max
_HDR = struct.Struct("<QQIIII")             # cg, off, fdid, length, nfollow, crc
HDR_SIZE = _HDR.size                        # 32
assert HDR_SIZE == 32

CG_FREE = 0
CG_HEAD = 1


class LogFullTimeout(RuntimeError):
    pass


class Entry:
    """Decoded view of a committed entry (header + payload memoryview)."""

    __slots__ = ("idx", "cg", "off", "fdid", "length", "nfollow", "crc", "data")

    def __init__(self, idx, cg, off, fdid, length, nfollow, crc, data):
        self.idx = idx
        self.cg = cg
        self.off = off
        self.fdid = fdid
        self.length = length
        self.nfollow = nfollow
        self.crc = crc
        self.data = data  # memoryview of length bytes (valid until recycled)


class NVLog:
    def __init__(self, nvmm: NVMM, policy: Policy, *, format: bool = True):
        self.nvmm = nvmm
        self.policy = policy
        self.n = policy.log_entries
        self.entry_size = policy.entry_size
        self.base = policy.entries_base
        if nvmm.size < policy.nvmm_bytes:
            raise ValueError(f"NVMM region too small: {nvmm.size} < {policy.nvmm_bytes}")

        self._lock = threading.Lock()           # guards head/volatile_tail
        self._space = threading.Condition(self._lock)   # writers wait for space
        self._committed = threading.Condition(self._lock)  # cleanup waits for work

        if format:
            self._format()
            self.head = 0                       # volatile head (paper §II-B fn1)
            self.volatile_tail = 0
        else:
            self._check_superblock()
            ptail = self.persistent_tail
            # after restart the only safe head is derived by recovery; until
            # then treat log as starting where recovery left it.
            self.head = ptail
            self.volatile_tail = ptail

    # ------------------------------------------------------------ superblock
    def _format(self) -> None:
        self.nvmm.store(0, b"\x00" * self.policy.entries_base)
        self.nvmm.store(0, _SB.pack(MAGIC, VERSION, self.entry_size, self.n, 0,
                                    self.policy.fd_max, self.policy.path_max))
        # zero every entry header so cg == CG_FREE everywhere
        for i in range(self.n):
            self.nvmm.store(self.base + i * self.entry_size, b"\x00" * HDR_SIZE)
        self.nvmm.pwb(0, self.policy.entries_base)
        self.nvmm.psync()

    def _check_superblock(self) -> None:
        magic, ver, esz, n, _pt, fdm, pm = _SB.unpack_from(self.nvmm.load(0, _SB.size))
        if magic != MAGIC or ver != VERSION:
            raise ValueError("not an NVCache log region")
        if esz != self.entry_size or n != self.n:
            raise ValueError("policy mismatch with on-NVMM superblock")

    @property
    def persistent_tail(self) -> int:
        return self.nvmm.load_u64(0x18)

    def _store_persistent_tail(self, val: int) -> None:
        self.nvmm.store_u64(0x18, val)
        self.nvmm.pwb(0x18, 8)

    # ------------------------------------------------------------- fd table
    def fd_table_set(self, fdid: int, path: str) -> None:
        raw = path.encode()
        if len(raw) >= self.policy.path_max:
            raise ValueError("path too long for fd table")
        off = SUPERBLOCK + fdid * self.policy.path_max
        self.nvmm.store(off, raw + b"\x00" * (self.policy.path_max - len(raw)))
        self.nvmm.pwb(off, self.policy.path_max)
        self.nvmm.psync()

    def fd_table_get(self, fdid: int) -> Optional[str]:
        off = SUPERBLOCK + fdid * self.policy.path_max
        raw = bytes(self.nvmm.load(off, self.policy.path_max))
        raw = raw.split(b"\x00", 1)[0]
        return raw.decode() if raw else None

    def fd_table_clear(self) -> None:
        self.nvmm.store(SUPERBLOCK, b"\x00" * self.policy.fd_table_bytes)
        self.nvmm.pwb(SUPERBLOCK, self.policy.fd_table_bytes)
        self.nvmm.psync()

    # ---------------------------------------------------------- entry codec
    def _eoff(self, idx: int) -> int:
        return self.base + (idx % self.n) * self.entry_size

    def read_cg(self, idx: int) -> int:
        return self.nvmm.load_u64(self._eoff(idx))

    def read_entry(self, idx: int) -> Entry:
        off = self._eoff(idx)
        cg, foff, fdid, length, nfollow, crc = _HDR.unpack_from(self.nvmm.load(off, HDR_SIZE))
        data = self.nvmm.load(off + HDR_SIZE, length)
        return Entry(idx, cg, foff, fdid, length, nfollow, crc, data)

    def is_committed(self, idx: int) -> bool:
        """Committed = head with cg==1, or follower whose head has cg==1."""
        cg = self.read_cg(idx)
        if cg == CG_HEAD:
            return True
        if cg >= 2:
            return self.read_cg(cg - 2) == CG_HEAD
        return False

    # ------------------------------------------------------------ allocation
    def entries_needed(self, nbytes: int) -> int:
        return max(1, -(-nbytes // self.policy.entry_data))

    def alloc(self, k: int, timeout: Optional[float] = None) -> int:
        """Reserve ``k`` contiguous entries; returns monotonic head index.

        Blocks while the log is full (paper Alg. 1 ``next_entry`` line 37).
        """
        if k > self.n - 1:
            raise ValueError("write exceeds log capacity; split upstream")
        with self._space:
            while self.head + k - self.volatile_tail > self.n:
                if not self._space.wait(timeout=timeout):
                    raise LogFullTimeout("log full")
            idx = self.head
            self.head += k
            return idx

    def try_alloc(self, k: int) -> Optional[int]:
        with self._space:
            if self.head + k - self.volatile_tail > self.n:
                return None
            idx = self.head
            self.head += k
            return idx

    # ---------------------------------------------------------------- write
    def fill_entry(self, idx: int, fdid: int, off: int, data: bytes, cg: int) -> None:
        """Fill one entry (no commit).  ``cg`` is 0 for heads, head+2 for
        followers; ``nfollow`` is patched on the head by :meth:`commit_group`."""
        eoff = self._eoff(idx)
        crc = zlib.crc32(data) if self.policy.verify_crc else 0
        self.nvmm.store(eoff, _HDR.pack(cg, off, fdid, len(data), 0, crc))
        self.nvmm.store(eoff + HDR_SIZE, data)
        self.nvmm.pwb(eoff, HDR_SIZE + len(data))

    def append(self, fdid: int, off: int, data: bytes,
               timeout: Optional[float] = None) -> tuple[int, int]:
        """The paper's write-cache append: alloc, fill, commit.

        Returns ``(head_idx, k)``.  On return the write is durable
        (synchronous durability) and ordered (durable linearizability).
        """
        ed = self.policy.entry_data
        k = self.entries_needed(len(data))
        head = self.alloc(k, timeout=timeout)
        # followers first (paper §II-D: they must be durable before the head
        # commit makes the whole group visible to recovery)
        for j in range(1, k):
            chunk = data[j * ed:(j + 1) * ed]
            self.fill_entry(head + j, fdid, off + j * ed, chunk, cg=head + 2)
        self.fill_entry(head, fdid, off, data[:ed], cg=CG_FREE)
        # patch nfollow on the head before the commit flush
        eoff = self._eoff(head)
        self.nvmm.store(eoff + 0x18, struct.pack("<I", k - 1))
        self.nvmm.pwb(eoff, HDR_SIZE)
        self.nvmm.pfence()                    # entries durable before commit
        self.nvmm.store_u64(eoff, CG_HEAD)    # commit the group
        self.nvmm.pwb(eoff, 8)
        self.nvmm.psync()                     # durable linearizability (§III)
        with self._lock:
            self._committed.notify_all()
        return head, k

    # -------------------------------------------------- consumption (cleanup)
    def committed_run(self, start: int, limit: int) -> int:
        """Number of consecutive committed entries at ``start`` (whole groups
        only), capped at ``limit``.  Used by the cleanup thread to build a
        batch; stops at the first uncommitted head (in-flight write)."""
        count = 0
        with self._lock:
            head = self.head
        while count < limit and start + count < head:
            cg = self.read_cg(start + count)
            if cg != CG_HEAD:
                break  # hole: in-flight, uncommitted (wait for the writer)
            group = 1 + self.read_entry(start + count).nfollow
            if count + group > limit and count > 0:
                break
            count += group
        return count

    def wait_committed(self, min_entries: int, *, drain_event: threading.Event,
                       stop_event: threading.Event, poll: float = 0.05) -> int:
        """Block until >= min_entries consecutive committed entries exist at
        the persistent tail, or a drain/stop is requested.  Returns the run
        length found (0 if stopping)."""
        while True:
            run = self.committed_run(self.persistent_tail, self.policy.batch_max)
            if run >= min_entries or (run > 0 and drain_event.is_set()):
                return run
            if stop_event.is_set():
                return run
            with self._committed:
                self._committed.wait(timeout=poll)

    def consume(self, start: int, count: int) -> None:
        """Durably retire ``count`` entries at ``start`` (== persistent tail).

        Paper cleanup step 2: zero the commit flags and advance the persistent
        tail with pwb/pfence; step 3: advance the volatile tail so writers can
        recycle the slots.
        """
        if start != self.persistent_tail:
            raise AssertionError("cleanup must consume at the persistent tail")
        for i in range(count):
            eoff = self._eoff(start + i)
            self.nvmm.store_u64(eoff, CG_FREE)
            self.nvmm.pwb(eoff, 8)
        self._store_persistent_tail(start + count)
        self.nvmm.pfence()
        with self._space:
            self.volatile_tail = start + count
            self._space.notify_all()

    # ------------------------------------------------------------------ scan
    def scan_committed(self, start: int, end: int) -> Iterator[Entry]:
        """Yield committed entries in ``[start, end)`` in log order, skipping
        holes.  Safe concurrently with writers (an entry is only yielded when
        its group head is committed) — used by the dirty-miss procedure and by
        recovery."""
        idx = start
        while idx < end:
            cg = self.read_cg(idx)
            if cg == CG_HEAD:
                head = self.read_entry(idx)
                yield head
                for j in range(head.nfollow):
                    e = self.read_entry(idx + 1 + j)
                    if e.cg == idx + 2:
                        yield e
                idx += 1 + head.nfollow
            else:
                idx += 1

    def snapshot_bounds(self) -> tuple[int, int]:
        with self._lock:
            return self.volatile_tail, self.head

    @property
    def used_entries(self) -> int:
        with self._lock:
            return self.head - self.volatile_tail

    def verify_entry(self, e: Entry) -> bool:
        return (not self.policy.verify_crc) or zlib.crc32(bytes(e.data)) == e.crc
