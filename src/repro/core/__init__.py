"""NVCache core — the paper's contribution (user-space NVMM write-back
cache with synchronous durability and durable linearizability)."""
from repro.core.api import NVCache, O_APPEND, O_CREAT, O_RDONLY, O_RDWR, O_TRUNC, O_WRONLY
from repro.core.log import EntryRef, NVLog
from repro.core.namespace import Namespace
from repro.core.nvmm import NVMM
from repro.core.policy import PAPER_DEFAULT, TEST_SMALL, Policy
from repro.core.recovery import RecoveryStats, recover
from repro.core.router import EpochRouter

__all__ = [
    "NVCache", "NVLog", "NVMM", "Namespace", "EntryRef", "EpochRouter",
    "Policy", "PAPER_DEFAULT", "TEST_SMALL", "RecoveryStats", "recover",
    "O_RDONLY", "O_WRONLY", "O_RDWR", "O_CREAT", "O_APPEND", "O_TRUNC",
]
