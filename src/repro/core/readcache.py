"""Volatile read cache (paper §II-C): page descriptors in a radix tree,
page states {loaded, unloaded-clean, unloaded-dirty} via a per-page
**dirty-page index** (the ordered list of live log-entry refs touching the
page — a strict refinement of the paper's dirty *counter*), and an LRU
approximation with accessed flags (§II-D "scalable data structures").

The index is maintained at both ends of an entry's life: the write path
(``api._pwrite_op``) appends an :class:`~repro.core.log.EntryRef` to every
page the entry overlaps, and the drain engine (:mod:`repro.core.drain`)
retires the page's refs once the page's bytes are on the slow tier.  A
dirty-miss read therefore replays exactly the E live entries of that page —
O(E), where the dirty-counter design had to rescan the whole log to find
them.  The drain planner materializes page images from the same index.

CPython notes: the paper gets scalability from CAS-based lock-free inserts
and per-page locks.  Under the GIL, single bytecode dict/list mutations are
atomic; we keep the paper's *structure* (radix tree, per-page atomic +
cleanup locks, second-chance LRU with try-lock eviction) and use a short
insert lock where the paper uses CAS.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Optional

from repro.core import locking


class AtomicInt:
    __slots__ = ("_v", "_lock")

    def __init__(self, v: int = 0):
        self._v = v
        self._lock = locking.make_lock("leaf:atomic_int")

    def inc(self, d: int = 1) -> int:
        with self._lock:
            self._v += d
            return self._v

    def dec(self, d: int = 1) -> int:
        return self.inc(-d)

    def get(self) -> int:
        return self._v


class PageContent:
    """A cached page buffer; recycled through the LRU queue."""

    __slots__ = ("data", "desc")

    def __init__(self, page_size: int):
        self.data = bytearray(page_size)
        self.desc: Optional["PageDesc"] = None


class PageDesc:
    """Page descriptor (paper Table II / Fig. 2).

    States: loaded (content is not None), unloaded-dirty (content None,
    ``entries`` non-empty), unloaded-clean (content None, ``entries`` empty).

    ``entries`` is the dirty-page index: the live log-entry refs whose bytes
    overlap this page, in commit (``seq``) order.  Appends happen under the
    page's ``atomic_lock`` (the writer draws its seq while holding it, so
    list order == seq order); retirement happens under ``cleanup_lock``; the
    dedicated ``ref_lock`` makes the one remaining pairing — writer append
    vs drain retire — safe without coupling those two locks.
    """

    __slots__ = ("page_no", "atomic_lock", "cleanup_lock", "ref_lock",
                 "entries", "content", "accessed", "prefetched",
                 "__weakref__")

    GUARDED_BY = {
        # rebound/appended under ref_lock; the dirty_refs length probe is
        # a lock-free read by design (callers hold atomic_lock, and a
        # stale length only delays a replay decision)
        "entries": "write:ref_lock",
        "content": "atomic_lock",
        # second-chance recency hints: racy by design (the paper's clock
        # approximation) — a lost flag costs one early eviction at most
        "accessed": locking.VOLATILE, "prefetched": locking.VOLATILE,
    }

    def __init__(self, page_no: int):
        self.page_no = page_no
        # write/read atomicity (§II-D); ascending page order when stacked
        self.atomic_lock = locking.make_lock("page_atomic", order_key=page_no)
        # vs cleanup thread (§II-D); ascending page order when stacked
        self.cleanup_lock = locking.make_lock("page_cleanup",
                                              order_key=page_no)
        # writer append vs drain retire
        self.ref_lock = locking.make_lock("leaf:ref")
        self.entries: list = []                # live EntryRefs, seq order
        #                                        guarded-by: write:ref_lock
        self.content: Optional[PageContent] = None  # guarded-by: atomic_lock
        self.accessed = False                  # guarded-by: volatile (hint)
        self.prefetched = False                # loaded by readahead, unread
        #                                        guarded-by: volatile (hint)

    def add_ref(self, ref) -> None:
        """Write path: register a just-committed entry on this page."""
        with self.ref_lock:
            self.entries.append(ref)

    def retire_refs(self, sid: int, idxs) -> int:
        """Drain path: drop the refs of shard ``sid`` whose monotonic index
        is in ``idxs`` — their bytes reached the backend.  Returns the number
        retired (order of survivors is preserved, so the list stays
        seq-sorted)."""
        with self.ref_lock:
            keep = [r for r in self.entries
                    if r.sid != sid or r.idx not in idxs]
            retired = len(self.entries) - len(keep)
            if retired:
                self.entries = keep
            return retired

    def snapshot_refs(self) -> list:
        with self.ref_lock:
            return list(self.entries)

    @property
    def dirty_refs(self) -> int:
        return len(self.entries)


class RadixTree:
    """Radix tree keyed by page number (paper §II-C, like NOVA).

    Fanout 64 (6 bits/level).  Nodes are fixed-size lists; descriptors are
    created lazily on first touch and never removed until the tree is freed
    on close (paper §II-D), which is what makes lock-free lookup safe.
    """

    FANOUT_BITS = 6
    FANOUT = 1 << FANOUT_BITS

    GUARDED_BY = {
        # immutable-node publishes under the insert lock; lookups read
        # lock-free (descriptors are never removed until the tree dies)
        "_root": "write:_insert_lock", "_height": "write:_insert_lock",
    }

    def __init__(self):
        self._root: list = [None] * self.FANOUT
        self._height = 1                     # levels below root
        #                                      (both guarded-by:
        #                                      write:_insert_lock)
        self._insert_lock = locking.make_lock("leaf:radix")

    def _capacity_bits(self) -> int:
        return self.FANOUT_BITS * self._height

    def get(self, key: int) -> Optional[PageDesc]:
        if key >> self._capacity_bits():
            return None
        node = self._root
        for level in range(self._height - 1, -1, -1):
            node = node[(key >> (level * self.FANOUT_BITS)) & (self.FANOUT - 1)]
            if node is None:
                return None
        return node  # type: ignore[return-value]

    def get_or_create(self, key: int) -> PageDesc:
        if key < 0:
            # a negative key would right-shift to -1 forever and grow the
            # tree without bound; offsets are validated upstream (EINVAL)
            raise ValueError(f"negative page number {key}")
        found = self.get(key)
        if found is not None:
            return found
        with self._insert_lock:
            while key >> self._capacity_bits():   # grow upward
                new_root: list = [None] * self.FANOUT
                new_root[0] = self._root
                self._root = new_root
                self._height += 1
            node = self._root
            for level in range(self._height - 1, 0, -1):
                slot = (key >> (level * self.FANOUT_BITS)) & (self.FANOUT - 1)
                if node[slot] is None:
                    node[slot] = [None] * self.FANOUT
                node = node[slot]
            slot = key & (self.FANOUT - 1)
            if node[slot] is None:
                node[slot] = PageDesc(key)
            return node[slot]

    def iter_descs(self):
        """Every descriptor currently in the tree (ascending page order).

        Safe under the GIL concurrently with inserts (nodes are fixed-size
        lists mutated by slot assignment); descriptors inserted during the
        walk may or may not be yielded — callers that need a fixed point
        (e.g. the O_TRUNC purge) serialize writers at a higher level.
        """
        def walk(node, depth):
            for child in node:
                if child is None:
                    continue
                if depth == 1:
                    yield child
                else:
                    yield from walk(child, depth - 1)
        yield from walk(self._root, self._height)


class LRUCache:
    """Second-chance LRU over page contents (paper §II-D).

    Eviction uses *try*-acquire on the victim's atomic lock: a busy victim is
    re-enqueued and the next one is tried, which removes the lock-ordering
    cycle between two concurrent misses.
    """

    GUARDED_BY = {
        "_queue": "_lock", "_allocated": "_lock",
        "stats_evictions": "_lock", "stats_hits": "_lock",
        "stats_misses": "_lock",
    }

    def __init__(self, capacity: int, page_size: int):
        self.capacity = max(2, capacity)
        self.page_size = page_size
        self._queue: deque[PageContent] = deque()
        self._lock = locking.make_lock("leaf:lru")   # the paper's "LRU lock"
        # guarded-by: _lock — pool state and the hit/miss/eviction counters
        # (readers use note_hit/note_miss/snapshot_stats, never the bare
        # fields: the old bare `lru.stats_hits += 1` under two different
        # page locks was a lost-update race)
        self._allocated = 0
        self.stats_evictions = 0
        self.stats_hits = 0
        self.stats_misses = 0

    def acquire_buffer(self) -> PageContent:
        """Return a free page buffer, evicting if at capacity.

        Overflow allocations (taken when every victim is pinned) ratchet
        ``_allocated`` above ``capacity``; each later acquire makes one
        opportunistic shrink attempt, so the pool converges back to its
        bound once the pinning burst is over."""
        content = self._acquire_one()
        self._shrink_one()
        return content

    def _pop_victim(self) -> tuple:
        """One step of the second-chance protocol: pop a queue entry and
        try to detach it.  Returns ``(status, content)`` where status is
        ``"empty"`` (queue exhausted), ``"free"``/``"evicted"`` (content is
        a usable buffer), or ``"busy"``/``"hot"`` (victim skipped and
        requeued)."""
        with self._lock:
            if not self._queue:
                return "empty", None
            content = self._queue.popleft()
            desc = content.desc
            if desc is None:                   # already detached
                return "free", content
            if not desc.atomic_lock.acquire(blocking=False):
                self._queue.append(content)
                return "busy", None
        try:
            if desc.accessed:                  # second chance
                desc.accessed = False
                with self._lock:
                    self._queue.append(content)
                return "hot", None
            desc.content = None                # -> unloaded-{clean,dirty}
            content.desc = None
            with self._lock:
                # under _lock, not just the victim's atomic_lock: two
                # concurrent evictions of different pages would race here
                self.stats_evictions += 1
            return "evicted", content
        finally:
            desc.atomic_lock.release()

    def _acquire_one(self) -> PageContent:
        with self._lock:
            if self._allocated < self.capacity:
                self._allocated += 1
                return PageContent(self.page_size)
            scans = 2 * len(self._queue) + 4   # two second-chance passes
        while scans > 0:
            status, content = self._pop_victim()
            if status == "empty":
                break
            if content is not None:
                return content
            scans -= 1
        # everything pinned (or busy-locked by this very caller, e.g. an
        # extent load holding its pages' atomic locks): overflow rather
        # than livelock on our own locks
        with self._lock:
            self._allocated += 1
        return PageContent(self.page_size)

    def _shrink_one(self) -> None:
        """Drop one reclaimable buffer while over capacity (see
        :meth:`acquire_buffer`); a no-op at or under the bound."""
        with self._lock:
            if self._allocated <= self.capacity:
                return
        _status, content = self._pop_victim()
        if content is not None:                # dropped, not reused
            with self._lock:
                self._allocated -= 1

    def acquire_buffers(self, count: int) -> list:
        """``count`` free page buffers for a multi-page (extent) load.

        Safe to call while holding the atomic locks of the pages about to
        be loaded: eviction try-locks victims and the bounded scan in
        :meth:`acquire_buffer` falls back to overflow allocation instead of
        spinning on the caller's own locked pages.
        """
        return [self.acquire_buffer() for _ in range(count)]

    def attach(self, desc: PageDesc, content: PageContent) -> None:
        content.desc = desc
        desc.content = content
        desc.accessed = True
        with self._lock:
            self._queue.append(content)

    def note_hit(self) -> None:
        """Count a read-cache hit.  Call sites used to bump ``stats_hits``
        directly while holding only their page's atomic lock — two hits on
        different pages lost updates; the LRU lock makes it a counter."""
        with self._lock:
            self.stats_hits += 1

    def note_miss(self) -> None:
        with self._lock:
            self.stats_misses += 1

    def snapshot_stats(self) -> dict:
        """Coherent copy of the cache counters for api.stats()."""
        with self._lock:
            return {
                "hits": self.stats_hits,
                "misses": self.stats_misses,
                "evictions": self.stats_evictions,
                "allocated": self._allocated,
            }

    def drop_all(self) -> None:
        with self._lock:
            for c in self._queue:
                if c.desc is not None:
                    c.desc.content = None
                    c.desc = None
            self._queue.clear()
            self._allocated = 0
