"""NVCache POSIX-like facade (paper §II-A, §III, Table III).

``NVCache`` is the interception boundary: components open files and call
``read/write/pread/pwrite/lseek/stat/fsync/close`` exactly as they would
against libc, and transparently get

  * synchronous durability — ``write`` returns only once the data is
    committed in the NVMM log (paper Alg. 1),
  * durable linearizability — a write is visible to a reader only when it
    is durable (the psync before the per-page lock release),
  * asynchronous propagation to the slow tier via the per-shard drain pool
    and its page-coalescing plan/apply engine (:mod:`repro.core.drain`),
  * ``fsync`` as a no-op (Table III: writes are already durable),
  * user-space file size/cursor (the kernel's may be stale, §II-C),
  * durable namespace ops — ``rename``/``unlink``/``ftruncate`` (and the
    implicit create in ``open``) journaled as metadata log entries so the
    crash-consistency protocols of legacy apps (SQLite journal unlink,
    RocksDB MANIFEST rename) survive power loss; see
    :mod:`repro.core.namespace`.

One instance == one NVMM region (one "DAX file"); several instances can
coexist on separate regions (paper §III Multi-application).
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, Optional

from repro.core import locking
from repro.core.cleanup import CleanupPool
from repro.core.log import (META_NO_FDID, MOP_CREATE, MOP_FTRUNCATE,
                            MOP_RENAME, MOP_UNLINK, NVLog)
from repro.core.namespace import Namespace
from repro.core.nvmm import NVMM
from repro.core.pager import PagedRegion
from repro.core.policy import Policy, StreamClassifier
from repro.core.readcache import AtomicInt, LRUCache, RadixTree
from repro.core.router import EpochRouter
from repro.core import recovery as _recovery
# submodule-object imports only: pulling a NAME out of repro.obs here
# would deadlock the repro.obs -> repro.core.locking -> repro.core ->
# api import cycle (ObsPlane is imported lazily in NVCache.__init__)
from repro.obs import flight as obs_flight
from repro.obs import metrics as obs_metrics

O_RDONLY, O_WRONLY, O_RDWR = os.O_RDONLY, os.O_WRONLY, os.O_RDWR
O_CREAT, O_APPEND, O_TRUNC = os.O_CREAT, os.O_APPEND, os.O_TRUNC
_ACCMODE = os.O_ACCMODE


class File:
    """Per-(device,inode) state (paper §III "Open": the file table)."""

    __slots__ = ("path", "fdid", "backend", "radix", "size", "size_lock",
                 "refs", "pending", "shards_touched", "_drained", "ra_next",
                 "ra_window", "hwm", "_route_cv", "route_inflight",
                 "route_frozen", "unlinked", "pmode", "clf", "frames",
                 "skip_drain_fsync", "__weakref__")

    GUARDED_BY = {
        # route-epoch gate: every touch is inside `with self._route_cv`
        "route_inflight": "_route_cv", "route_frozen": "_route_cv",
        # logical length and committed high-water mark
        "size": "size_lock", "hwm": "size_lock",
        # readahead stream detector: racy by design (a heuristic, like the
        # kernel's per-file ra window — a lost update costs one prefetch)
        "ra_next": locking.VOLATILE, "ra_window": locking.VOLATILE,
        # refcount writes happen under NVCache._meta (another object's
        # lock, not expressible here); the drain thread's lock-free
        # `refs == 0` read is an opportunistic reap hint only — the
        # authoritative check re-runs in _maybe_retire_locked under _meta
        "refs": locking.VOLATILE,
        # monotonic flags set under _meta / the truncate journal window,
        # read lock-free on hot paths (stale False = one extra fsync)
        "unlinked": locking.VOLATILE, "skip_drain_fsync": locking.VOLATILE,
        # flips only inside a route_freeze window (writers excluded), so a
        # lock-free read sees a value stable for the write it gates
        "pmode": locking.VOLATILE,
        # published once at first write-open, before any write reaches us
        "clf": locking.VOLATILE,
        # never rebound; entries mutated under the owning page's
        # atomic_lock — a per-page guard is not one attribute
        "frames": locking.VOLATILE,
        # GIL-atomic set.add from writers; drain targeting reads via set()
        "shards_touched": locking.VOLATILE,
    }

    def __init__(self, path: str, fdid: int, backend):
        self.path = path
        self.fdid = fdid
        self.backend = backend
        self.radix: Optional[RadixTree] = None   # created on first write-open
        self.size = backend.size()
        self.hwm = self.size      # committed high-water mark: size minus any
        #                           not-yet-committed O_APPEND reservation
        self.size_lock = locking.make_lock("leaf:size")
        self.refs = 0
        self.pending = AtomicInt(0)              # log entries not yet drained
        self.shards_touched: set = set()         # sids holding entries for us
        self._drained = locking.make_condition("leaf:drained")
        self.ra_next = -1                        # readahead stream detector:
        #   the page a sequential miss stream would miss next; racy by
        #   design (a heuristic, like the kernel's per-file ra window)
        self.ra_window = 1                       # current ramped window size
        #   (grows 2->4->... toward Policy.readahead_pages on a sustained
        #    sequential miss stream, resets on a random miss)
        self.unlinked = False                    # POSIX unlink-while-open:
        #   the name is gone but the file lives until its last close; its
        #   drain skips the backend fsync (the bytes die with the name on
        #   any crash) and close() skips the drain barrier
        # dual persistence (VERSION 4): which mode this file's write stream
        # is in, the per-stream classifier (None without a paged region),
        # and the page_no -> frame index map of its NVMM-resident frames
        # (mutated under the page's atomic_lock)
        self.pmode = False                       # True == paged mode
        self.clf: Optional[StreamClassifier] = None
        self.frames: Dict[int, int] = {}
        self.skip_drain_fsync = False            # ftruncate(0) WAL-reset
        #   window: the barrier's drain skips the backend fsync for bytes
        #   the journaled truncate will discard anyway
        # route-epoch gate (adaptive routing only): writers enter before the
        # route lookup and exit after the log append, so a migration can
        # freeze the file and know no in-flight write still holds a stale
        # route (see core/router.py's ordering proof)
        self._route_cv = locking.make_condition("route_gate")
        self.route_inflight = 0
        self.route_frozen = False

    def note_drained(self, n: int) -> None:      # called by the cleanup thread
        self.pending.dec(n)
        with self._drained:
            self._drained.notify_all()

    def wait_drained(self, timeout: Optional[float] = None) -> bool:
        with self._drained:
            return self._drained.wait_for(lambda: self.pending.get() <= 0,
                                          timeout=timeout)

    # ------------------------------------------------- route-epoch gate
    def route_enter(self) -> None:
        """Writer side: pin the routing epoch for one write (blocks while a
        migration of this file is in progress)."""
        with self._route_cv:
            while self.route_frozen:
                self._route_cv.wait()
            self.route_inflight += 1

    def route_exit(self) -> None:
        with self._route_cv:
            self.route_inflight -= 1
            if self.route_inflight == 0 and self.route_frozen:
                self._route_cv.notify_all()

    def route_freeze(self, timeout: Optional[float] = None) -> bool:
        """Migration side: block new writes and wait until in-flight writes
        (which looked up their shard under the old epoch) have committed.
        Returns False (and unfreezes) on timeout."""
        with self._route_cv:
            if self.route_frozen:
                return False                     # one migration at a time
            self.route_frozen = True
            if self._route_cv.wait_for(lambda: self.route_inflight == 0,
                                       timeout=timeout):
                return True
            self.route_frozen = False
            self._route_cv.notify_all()
            return False

    def route_unfreeze(self) -> None:
        with self._route_cv:
            self.route_frozen = False
            self._route_cv.notify_all()


class OpenFile:
    """Per-descriptor state (paper §III: the opened table / cursor)."""

    __slots__ = ("file", "flags", "cursor", "cursor_lock", "__weakref__")

    GUARDED_BY = {"cursor": "cursor_lock"}

    def __init__(self, file: File, flags: int):
        self.file = file
        self.flags = flags
        self.cursor = 0
        self.cursor_lock = locking.make_lock("leaf:cursor")


class NVCache:
    GUARDED_BY = {
        # the observability plane (registry + profiler + flight recorder):
        # published in __init__ before any engine thread starts and never
        # rebound; the metric objects inside synchronize themselves
        # (per-thread cells merged under leaf:obs, flight under leaf:flight)
        "obs": locking.VOLATILE,
    }

    def __init__(self, policy: Policy, tier, *, nvmm: Optional[NVMM] = None,
                 track_crashes: bool = False, recover: bool = True):
        self.policy = policy
        self.tier = tier
        self.nvmm = nvmm or NVMM(policy.nvmm_bytes, track=track_crashes)
        if recover and nvmm is not None:
            try:
                self.recovery_stats = _recovery.recover(self.nvmm, policy, tier)
            except ValueError:
                self.recovery_stats = None     # fresh region
                NVLog(self.nvmm, policy, format=True)
            self.log = NVLog(self.nvmm, policy, format=False)
        else:
            self.recovery_stats = None
            self.log = NVLog(self.nvmm, policy, format=True)

        # observability plane (PR 10): metrics registry + span profiler +
        # persistent flight recorder.  Built before any engine thread starts
        # so publication is ordered by thread creation (happens-before).
        from repro.obs import ObsPlane
        self.obs = ObsPlane(policy, self.nvmm)
        for _sh in self.log.shards:
            _sh.obs = self.obs

        self.lru = LRUCache(policy.read_cache_pages, policy.page_size)
        # the durable namespace owns the file tables (path→File, fdid→File,
        # free fdid slots) and the metadata journaling protocol; the aliases
        # below are the same mutable objects, kept under the historic names
        self.ns = Namespace(self.log, tier, policy.fd_max)
        self._files: Dict[str, File] = self.ns.files
        self._by_fdid: Dict[int, File] = self.ns.by_fdid
        self._open: Dict[int, OpenFile] = {}
        self._next_fd = 3
        self._meta = self.ns.lock
        self._fdid_free = self.ns.fdid_free
        # adaptive shard routing (beyond paper, see core/router.py): the
        # router is created AFTER the log so it adopts the persisted route
        # record of an attached region (and an empty one after a format)
        self.router: Optional[EpochRouter] = None
        if policy.shard_rebalance:
            self.router = EpochRouter(self.nvmm, policy)
            self.log.router = self.router
        # dual persistence (VERSION 4): the paged region absorbing large /
        # overwrite-heavy streams in place (see core/pager.py)
        self.pager: Optional[PagedRegion] = None
        if policy.page_frames:
            self.pager = PagedRegion(self.nvmm, policy, self.log.next_seq)
        self.cleanup = CleanupPool(self.log, self._resolve_fdid,
                                   router=self.router,
                                   migrate=self._migrate_route
                                   if self.router is not None else None,
                                   meta_gate=self.ns,
                                   reap=self._reap_file,
                                   pager=self.pager,
                                   writeback=self._writeback_pressure,
                                   obs=self.obs)
        # engine counters live in the registry; the stats_* properties
        # below keep the historic read API
        reg = self.obs.registry
        self._c_mode_migrations = reg.counter("engine.mode_migration_total")
        self._c_dirty_misses = reg.counter("read.dirty_miss_total")
        self._c_replay_entries = reg.counter("read.replay_entry_total")
        self._c_ra_loads = reg.counter("read.readahead_load_total")
        self._c_ra_pages = reg.counter("read.readahead_page_total")
        self._c_ra_hits = reg.counter("read.readahead_hit_total")
        self._register_metrics()
        self.cleanup.start()
        self._crashed = False
        if self.obs.flight is not None:
            self.obs.flight.record(obs_flight.EV_ATTACH, self.obs.level,
                                   policy.shards, policy.flight_records)

    def _register_metrics(self) -> None:
        """Bind every legacy subsystem counter into the registry so
        ``stats()`` (and the ``--profile`` report) read one coherent
        snapshot.  Bound groups keep each subsystem's own locked
        ``snapshot_stats`` as the coherence unit."""
        reg = self.obs.registry
        reg.bind("engine.shard_count", lambda: self.policy.shards)
        reg.bind("log.used_count", lambda: self.log.used_entries)
        reg.bind("log.full_scan_total", lambda: self.log.stats_full_scans)
        reg.bind_summary(
            "log.alloc_wait_us",
            lambda: obs_metrics.Histogram.merged_snapshot(
                "log.alloc_wait_us",
                [sh.alloc_wait for sh in self.log.shards]))
        reg.bind_group({"lru.hit_total": "hits",
                        "lru.miss_total": "misses",
                        "lru.eviction_total": "evictions"},
                       self.lru.snapshot_stats)
        reg.bind_group({"nvmm.psync_total": "psync",
                        "nvmm.pwb_total": "pwb",
                        "nvmm.pwb_line_total": "pwb_lines",
                        "nvmm.fence_total": "fence",
                        "nvmm.stored_bytes": "stored"},
                       lambda: {"psync": self.nvmm.stats_psync,
                                "pwb": self.nvmm.stats_pwb,
                                "pwb_lines": self.nvmm.stats_pwb_lines,
                                "fence": self.nvmm.stats_fence,
                                "stored": self.nvmm.stats_stored_bytes})
        reg.bind_group({"drain.batch_total": "batches",
                        "drain.entry_total": "entries",
                        "drain.fsync_total": "fsyncs",
                        "drain.fsync_issued_total": "fsyncs_issued",
                        "drain.fsync_merged_total": "fsyncs_merged",
                        "drain.extent_total": "extents",
                        "drain.pwritev_total": "pwritevs",
                        "drain.deferred_total": "deferred",
                        "drain.span_merge_total": "span_merges"},
                       lambda: {"batches": self.cleanup.stats_batches,
                                "entries": self.cleanup.stats_entries,
                                "fsyncs": self.cleanup.stats_fsyncs,
                                "fsyncs_issued":
                                    self.cleanup.stats_fsyncs_issued,
                                "fsyncs_merged":
                                    self.cleanup.stats_fsyncs_merged,
                                "extents": self.cleanup.stats_extents,
                                "pwritevs": self.cleanup.stats_pwritevs,
                                "deferred": self.cleanup.stats_deferred,
                                "span_merges":
                                    self.cleanup.stats_span_merges})
        reg.bind_group({"route.epoch_count": "epoch",
                        "route.override_count": "overrides",
                        "route.skew_ratio": "skew_ratio",
                        "route.skipped_uneconomic_total":
                            "skipped_uneconomic",
                        "route.stripe_widening_total": "stripe_widenings"},
                       lambda: (self.router.snapshot_stats()
                                if self.router else {}))
        reg.bind("route.migration_total",
                 lambda: (self.cleanup.rebalancer.stats_migrations
                          if self.cleanup.rebalancer else 0))
        reg.bind_group({"meta.op_total": "meta_ops",
                        "meta.entry_total": "meta_entries",
                        "meta.deferred_apply_total": "deferred_applies"},
                       self.ns.snapshot_stats)
        reg.bind_group({"page.frame_used_count": "frames_used",
                        "page.frame_write_total": "frame_writes",
                        "page.frame_bytes": "frame_bytes",
                        "page.cow_bytes": "cow_bytes",
                        "page.writeback_total": "writebacks",
                        "page.alloc_fallback_total": "alloc_fail"},
                       lambda: (self.pager.snapshot_stats()
                                if self.pager else {}))

    # legacy read API for the registry-backed engine counters
    @property
    def stats_mode_migrations(self) -> int:
        return self._c_mode_migrations.value

    @property
    def stats_dirty_misses(self) -> int:
        return self._c_dirty_misses.value

    @property
    def stats_replay_entries(self) -> int:
        return self._c_replay_entries.value

    @property
    def stats_readahead_loads(self) -> int:
        return self._c_ra_loads.value

    @property
    def stats_readahead_pages(self) -> int:
        return self._c_ra_pages.value

    @property
    def stats_readahead_hits(self) -> int:
        return self._c_ra_hits.value

    def _flight_meta(self, op: int, fdid: int, mseq: int) -> None:
        """Record a journaled namespace op in the flight ring (rare event:
        recorded whenever the ring exists, regardless of obs_level)."""
        fl = self.obs.flight
        if fl is not None:
            fl.record(obs_flight.EV_META_OP, op,
                      0 if fdid is None else fdid, mseq)

    # ------------------------------------------------------------- lifecycle
    def _resolve_fdid(self, fdid: int) -> Optional[File]:
        return self._by_fdid.get(fdid)

    def _reap_file(self, f: File) -> None:
        """Drain-thread callback: an anonymous (unlinked) file's entries
        all landed.  Try-lock only — a drain thread must never wait on
        ``_meta`` (a writer holding it may itself be blocked on log space
        that only this drain can free); a missed reap is reclaimed by the
        ``flush()`` sweep or the fdid-exhaustion sweep in ``open()``."""
        if not self._meta.acquire(blocking=False):
            return
        try:
            self._maybe_retire_locked(f)
        finally:
            self._meta.release()

    def check(self) -> None:
        if self.cleanup.error is not None:
            raise RuntimeError("cleanup thread died") from self.cleanup.error
        if self._crashed:
            raise RuntimeError("instance crashed")

    def shutdown(self) -> None:
        """Graceful: drain the log, write back dirty frames, stop the
        cleanup threads."""
        if self.pager is not None:
            for f in list(self._by_fdid.values()):
                if not f.unlinked:
                    self._writeback_file_frames(f, free=False, do_fsync=True)
        self.cleanup.shutdown()
        self.check()

    def crash(self, choose_evicted=None) -> NVMM:
        """Simulated power loss; returns the NVMM region for recovery."""
        self._crashed = True
        self.cleanup.power_loss()
        if self.nvmm.track:
            self.nvmm.crash(choose_evicted)
        return self.nvmm

    def flush(self, timeout: Optional[float] = 60.0) -> None:
        """Drain the whole log to the slow tier (used as a barrier)."""
        self.cleanup.request_drain()
        try:
            # _by_fdid covers every bound File, including anonymous
            # (unlinked-while-open) ones that left the path table
            for f in list(self._by_fdid.values()):
                if not f.wait_drained(timeout=timeout):
                    raise TimeoutError(f"drain of {f.path} timed out")
            # namespace records are not any File's pending entries: wait
            # for them separately so "flush == the log is drained" holds
            if not self.ns.wait_consumed(timeout=timeout):
                raise TimeoutError("drain of namespace records timed out")
        finally:
            self.cleanup.end_drain()
        if self.pager is not None:
            # the paged half of the barrier: dirty frames reach the backend
            # (frames stay mapped — they are a valid NVMM-resident cache)
            for f in list(self._by_fdid.values()):
                if not f.unlinked:
                    self._writeback_file_frames(f, free=False, do_fsync=True)
        with self._meta:
            # sweep files orphaned by a timed-out close barrier or an
            # unlink-while-open (refs 0, kept only so the drain could
            # finish): they are drained now
            for f in list(self._by_fdid.values()):
                if f.refs == 0:
                    self._maybe_retire_locked(f)
        self.check()

    # ------------------------------------------------------------------ open
    def open(self, path: str, flags: int = O_RDWR | O_CREAT) -> int:
        self.check()
        accmode = flags & _ACCMODE
        with self._meta:
            # a queued rename apply may still be in flight: the backend
            # namespace must be current before exists()/open() consult it
            self.ns.apply_deferred()
            f = self.ns.lookup(path)
            if f is None:
                created = not self.tier.exists(path)
                if created and not flags & O_CREAT:
                    raise FileNotFoundError(path)
                if not self._fdid_free:
                    # reclaim drained anonymous/orphaned files whose reap
                    # lost the _meta try-lock race before giving up
                    for g in list(self._by_fdid.values()):
                        if g.refs == 0:
                            self._maybe_retire_locked(g)
                fdid = self.ns.alloc_fdid_locked()
                marks = None
                try:
                    self.log.fd_table_set(fdid, path)   # durable path for recovery
                    if created:
                        # journal the create BEFORE the backend file exists
                        # (WAL rule): a crash after this point re-creates
                        # the path from the log even if the kernel lost the
                        # directory update
                        marks, mseq = self.ns.journal_locked(MOP_CREATE, fdid, 0,
                                                      path)
                        self._flight_meta(MOP_CREATE, fdid, mseq)
                    backend = self.tier.open(path)
                    if created:
                        self.ns.note_backend_applied(mseq)
                except BaseException:
                    self.ns.free_fdid_locked(fdid)             # nothing references it
                    raise
                finally:
                    if marks is not None:
                        self.ns.mark_applied(marks)
                f = File(path, fdid, backend)
                if self.pager is not None:
                    f.clf = StreamClassifier(self.policy)
                self.ns.bind_locked(path, f)
            if accmode != O_RDONLY and f.radix is None:
                f.radix = RadixTree()               # read cache only for writers
            f.refs += 1
            fd = self._next_fd
            self._next_fd += 1
            of = OpenFile(f, flags)
            self._open[fd] = of
        if flags & O_TRUNC and accmode != O_RDONLY:
            try:
                self._truncate_file(f)
            except BaseException:
                # the caller gets an exception, not the fd — unwind the
                # registration above or the descriptor would leak forever
                with self._meta:
                    self._open.pop(fd, None)
                    self._release_file_locked(f)
                raise
        return fd

    def _release_file_locked(self, f: File) -> None:
        """Drop one reference; fully retire the file table entry once it is
        unreferenced AND drained.  Caller holds ``_meta``.

        The pending check is load-bearing: retiring the fdid while
        committed entries still point at it would make the drain drop them
        as orphans — or, worse, a reused fdid would route them into an
        unrelated file.  On a drain-barrier timeout the File therefore
        stays registered (and resolvable) until its entries land; it is
        reclaimed by a later open() of the same path (which adopts it) or
        by the orphan sweep in :meth:`flush`."""
        f.refs -= 1
        self._maybe_retire_locked(f)

    def _maybe_retire_locked(self, f: File) -> None:
        if f.refs != 0 or f.pending.get() > 0:
            return
        if self.pager is not None and f.frames:
            if f.unlinked:
                # the bytes die with the name: durably invalidate without
                # writeback, exactly like the fsync-free drain of unlinked
                # log entries.  Freeing BEFORE the fdid is reused below is
                # what stops a recovery from attributing the old frames to
                # the slot's next occupant.
                idxs = list(f.frames.values())
                f.frames.clear()
                self.pager.invalidate(idxs)
            else:
                # normally clean by now (close/flush wrote them back); a
                # timed-out barrier can leave dirty frames, so flush
                # defensively before the fdid slot is recycled
                self._writeback_file_frames(f, free=True, do_fsync=True)
        if f.unlinked:
            # anonymous (name already removed at unlink time): only the
            # fdid binding remains, kept so the drain could resolve it
            if self._by_fdid.get(f.fdid) is not f:
                return
            self._by_fdid.pop(f.fdid, None)
        else:
            if self._files.get(f.path) is not f:
                return
            self._files.pop(f.path, None)
            self._by_fdid.pop(f.fdid, None)
        self.log.fd_table_set(f.fdid, "")   # retire the NVMM slot
        if self.router is not None:
            # the file is drained (pending <= 0), so its overrides can
            # revert to static without stranding entries; keeping them
            # would leak table slots and mis-route a reused fdid
            self.router.drop_fdid(f.fdid)
        self._fdid_free.append(f.fdid)
        f.backend.close()

    def _truncate_file(self, f: File, length: int = 0) -> None:
        """Set the file's length *everywhere*, not just the backend
        (``O_TRUNC`` is ``length == 0``; ``ftruncate`` passes any length).

        Undrained log entries, dirty-page-index refs and loaded page
        contents all hold pre-truncate bytes; truncating only the backend
        let a later drain resurrect them and let cached reads serve stale
        data.  Order: drain the file's touched shards first (consuming its
        entries durably, exactly as ``close`` does — so a crash after this
        point cannot replay pre-truncate bytes either), journal the new
        length as a metadata log entry (the durable intent recovery
        replays, seq-ordered after every covered data entry), then purge
        the radix refs/contents beyond the new length under the page
        locks, then truncate the backend and the user-space size."""
        with f.size_lock:
            cur = f.size
        if cur == length and f.backend.size() == length:
            return                            # nothing to cut or extend
        # ftruncate(0) — the SQLite WAL reset — drains fsync-free: freeze
        # the route gate (no new commits; in-flight writes finish), journal
        # the truncate FIRST, and only then run the barrier with the
        # per-file fsync skip set.  Safe for the same reason the unlinked
        # drain is: every drained entry's seq is below the committed
        # truncate record's, so after any crash recovery either replays
        # entries-then-truncate or just the truncate — either way the
        # discarded bytes never needed to reach the device.  A gate that
        # cannot freeze (concurrent migration) falls back to the plain
        # ordering below.
        wal_reset = (length == 0 and not f.unlinked
                     and f.route_freeze(timeout=60.0))
        marks = None
        try:
            if wal_reset:
                with self._meta:
                    if f.unlinked:            # raced an unlink: plain path
                        pass
                    else:
                        marks, mseq = self.ns.journal_locked(MOP_FTRUNCATE, f.fdid,
                                                      0, f.path)
                        self._flight_meta(MOP_FTRUNCATE, f.fdid, mseq)
                f.skip_drain_fsync = True
                try:
                    self._drain_barrier(f, "ftruncate")
                finally:
                    f.skip_drain_fsync = False
            if marks is None:
                self._drain_barrier(f, "ftruncate")
                # journal under _meta like every namespace op (the Namespace
                # lock invariant): otherwise a concurrent unlink-while-open
                # could slip between the f.unlinked check and the journal
                # append, and recovery would replay the MOP_FTRUNCATE
                # *after* the unlink — re-creating the dead path as a
                # length-L file
                with self._meta:
                    if f.unlinked:
                        # anonymous file: no name to journal under (and none
                        # needed — the file is gone after any crash)
                        marks = None
                    else:
                        marks, mseq = self.ns.journal_locked(MOP_FTRUNCATE, f.fdid,
                                                      length, f.path)
                        self._flight_meta(MOP_FTRUNCATE, f.fdid, mseq)
            self._truncate_apply(f, length, marks, mseq if marks else 0)
        finally:
            if wal_reset:
                f.route_unfreeze()

    def _truncate_apply(self, f: File, length: int, marks, mseq: int) -> None:
        try:
            # order matters: size first (readers clamp against it, so no
            # new read can reach the cut bytes), then truncate the backend,
            # then purge — a reader that re-cached a pre-truncate page
            # between the drain and here is cleaned up by the purge.  A
            # load whose desc the purge walk could miss (inserted only
            # while the walk runs) is necessarily harmless: its backend
            # pread happens after the truncate below and reads zeros, while
            # any load that read the backend *before* the truncate inserted
            # its desc before the walk began and is purged under its locks.
            with f.size_lock:
                f.size = length
                f.hwm = min(f.hwm, length)
            f.backend.truncate(length)
            if f.radix is not None:
                ps = self.policy.page_size
                first_cut = -(-length // ps)      # first wholly-cut page
                cut_frames = []
                for d in f.radix.iter_descs():
                    if d.page_no < first_cut - 1:
                        continue                  # untouched by the cut
                    with d.atomic_lock, d.cleanup_lock:
                        fidx = f.frames.get(d.page_no)
                        if d.page_no >= first_cut:
                            if fidx is not None:
                                # wholly-cut frame: drop without writeback —
                                # the journaled truncate (higher seq) cuts
                                # it on replay too, so old-or-new holds
                                del f.frames[d.page_no]
                                cut_frames.append(fidx)
                            if d.content is not None:
                                d.content.desc = None  # LRU frees it
                                d.content = None
                                d.prefetched = False
                        elif length % ps:
                            if fidx is not None:
                                # boundary frame survives shorter: reseal
                                # its header so reads/recovery clamp to the
                                # new length (tail reads as zeros)
                                self.pager.truncate_frame(fidx, length % ps)
                            if d.content is not None:
                                # boundary page survives: zero its cut tail
                                # so a later size-growing write reads zeros
                                d.content.data[length % ps:] = \
                                    bytes(ps - length % ps)
                        # refs are NOT cleared here: the drain barrier above
                        # already retired every pre-truncate ref, so any ref
                        # present now belongs to a write committed *after*
                        # the barrier by a concurrent fd — clearing it would
                        # blind readers to an entry the drain will still land
                if cut_frames:
                    self.pager.invalidate(cut_frames)
            if marks is not None:
                self.ns.note_backend_applied(mseq)
        finally:
            if marks is not None:
                self.ns.mark_applied(marks)

    def _drain_barrier(self, f: File, label: str,
                       timeout: float = 60.0) -> None:
        """Drain the shards ``f`` touched and wait for its entries to land
        — the shared barrier under close/flock/O_TRUNC/route migration."""
        touched = set(f.shards_touched)
        prof = self.obs.prof
        fl = self.obs.flight
        if fl is not None:
            fl.record(obs_flight.EV_BARRIER_ENTER, f.fdid, len(touched))
        t0 = time.perf_counter_ns() if prof.lv1 else 0
        self.cleanup.request_drain(touched)
        try:
            if not f.wait_drained(timeout=timeout):
                raise TimeoutError(f"drain of {f.path} timed out on {label}")
        finally:
            self.cleanup.end_drain(touched)
            if prof.lv1:
                prof.h_barrier.record_ns(time.perf_counter_ns() - t0)
            if fl is not None:
                fl.record(obs_flight.EV_BARRIER_EXIT, f.fdid)

    def _migrate_route(self, mig) -> bool:
        """Execute one planned route migration (called by the pool's
        rebalance thread): freeze the file's route gate, drain the file's
        entries out of its old shard, install the new epoch, unfreeze.
        The barrier is what keeps the overlap invariant true across the
        epoch change — see core/router.py for the ordering proof.  Returns
        False (table untouched) when the freeze or barrier cannot complete.
        """
        with self._meta:
            f = self._by_fdid.get(mig.fdid)
        if f is None:
            # file retired since the plan was made: the load data is stale
            # and the fdid may already be reused by a NEW file (whose gate
            # we never froze) — installing now would reroute that file
            # without the barrier.  Skip; the next epoch re-plans.
            return False
        if not f.route_freeze(timeout=10.0):
            return False
        try:
            self._drain_barrier(f, "rebalance", timeout=10.0)
            with self._meta:
                if self._by_fdid.get(mig.fdid) is not f:
                    return False    # retired (and possibly reused) mid-
                    #                 migration: same hazard as above
                if mig.new_shift is not None:
                    # stripe-width widening: re-route the whole file at a
                    # narrower stripe instead of moving one key — the
                    # barrier above makes the width change safe for the
                    # same reason a key move is (no undrained entry spans
                    # the old and new stripe maps)
                    ok = self.router.install_width(mig.fdid, mig.new_shift)
                else:
                    ok = self.router.install(mig.key, mig.new_sid)
                if ok and self.obs.flight is not None:
                    self.obs.flight.record(obs_flight.EV_ROUTE_EPOCH,
                                           mig.fdid, mig.new_sid,
                                           0 if mig.new_shift is None
                                           else mig.new_shift)
                return ok
        except TimeoutError:
            return False
        finally:
            f.route_unfreeze()

    # --------------------------------------------- dual-mode machinery
    def _migrate_mode(self, f: File, to_paged: bool,
                      timeout: float = 10.0) -> bool:
        """Move a live file between persistence modes behind the shared
        freeze/barrier protocol (the generalized ``_migrate_route``):
        freeze the route gate (no new writes commit; in-flight ones
        finish), drain the file's log entries, and — for page→log — write
        its frames back and free them.  After the flip every page of the
        file is cleanly owned by the new mode.  Returns False (no state
        changed) when the freeze or barrier cannot complete."""
        if self.pager is None or f.pmode == to_paged or f.unlinked:
            return False
        if not f.route_freeze(timeout=timeout):
            return False
        try:
            self._drain_barrier(f, "mode-migration", timeout=timeout)
            if not to_paged:
                # leaving paged mode: frames flush to the backend and are
                # freed so subsequent log-mode writes re-own the pages
                self._writeback_file_frames(f, free=True, do_fsync=True)
            f.pmode = to_paged
            self._c_mode_migrations.inc()
            if self.obs.flight is not None:
                self.obs.flight.record(obs_flight.EV_MODE_MIGRATE, f.fdid,
                                       1 if to_paged else 0)
            return True
        except TimeoutError:
            return False
        finally:
            f.route_unfreeze()

    def _writeback_file_frames(self, f: File, idxs=None, *, free: bool,
                               do_fsync: bool) -> int:
        """Flush (a subset of) a file's frames to the backend — the paged
        twin of the drain's apply step, minus replay: the frame already IS
        the coalesced page image.  ``free`` additionally unmaps and
        durably invalidates the written frames (page→log migration,
        retirement); it always pairs with ``do_fsync=True`` — freeing a
        frame whose bytes only reached the device cache would open a
        data-loss window no log entry ever has."""
        if self.pager is None or not f.frames:
            return 0
        ps = self.policy.page_size
        items = sorted((pn, ix) for pn, ix in f.frames.items()
                       if idxs is None or ix in idxs)
        wrote = []
        for page_no, idx in items:
            d = f.radix.get_or_create(page_no)
            with d.atomic_lock:
                if f.frames.get(page_no) != idx:
                    continue                  # raced a truncate/retire
                view, ln = self.pager.read(idx)
                if ln:
                    f.backend.pwrite(bytes(view), page_no * ps)
                if free:
                    del f.frames[page_no]
                wrote.append(idx)
        if wrote and do_fsync and not f.unlinked:
            f.backend.fsync()
        for idx in wrote:
            self.pager.mark_clean(idx)
        if free and wrote:
            self.pager.invalidate(wrote)
        return len(wrote)

    def _writeback_pressure(self, max_frames: int = 32) -> int:
        """Pool-pressure callback (the pager's writeback thread): flush the
        oldest-dirty frames so allocation keeps finding clean capacity,
        mirroring the drain's role for the log half."""
        if self.pager is None:
            return 0
        total = 0
        for fdid, idxs in self.pager.dirty_victims(max_frames).items():
            f = self._by_fdid.get(fdid)
            if f is None:
                continue
            total += self._writeback_file_frames(f, idxs, free=False,
                                                 do_fsync=True)
        return total

    def close(self, fd: int) -> None:
        """Flush this file's pending writes to the kernel, then close
        (paper §I: coherence across processes via flush-on-close).  Only the
        shards this file actually touched are asked to drain."""
        of = self._pop_fd(fd)
        f = of.file
        try:
            if not f.unlinked:
                # an unlinked (anonymous) file dies with its last close:
                # nothing to make coherent for other processes, so no
                # barrier — its remaining entries drain (fsync-free) in
                # the background and the reap retires the fdid
                self._drain_barrier(f, "close")
                if self.pager is not None and f.frames:
                    # the paged half of flush-on-close: frames reach the
                    # kernel too (they stay mapped as cache — the last
                    # close retires them via _maybe_retire_locked)
                    self._writeback_file_frames(f, free=False, do_fsync=True)
        finally:
            # teardown must run even when the drain barrier fails: the fd
            # was already popped, so skipping the refcount would leak the
            # File, its fdid slot and its NVMM fd-table entry forever.
            # (_release_file_locked keeps the File resolvable while
            # undrained entries exist — a timed-out barrier must not turn
            # acknowledged bytes into orphans.)
            with self._meta:
                self._release_file_locked(f)
        self.check()

    def _pop_fd(self, fd: int) -> OpenFile:
        with self._meta:
            of = self._open.pop(fd, None)
        if of is None:
            raise OSError(f"bad fd {fd}")
        return of

    def _of(self, fd: int) -> OpenFile:
        of = self._open.get(fd)
        if of is None:
            raise OSError(f"bad fd {fd}")
        return of

    # ----------------------------------------------------------------- write
    def pwrite(self, fd: int, data: bytes, off: int) -> int:
        of = self._of(fd)
        if of.flags & _ACCMODE == O_RDONLY:
            raise OSError("fd is read-only")
        if off < 0:
            raise OSError("negative offset (EINVAL)")
        if not data:
            return 0
        return self._pwrite_split(of.file, data, off)

    def _pwrite_split(self, f: File, data: bytes, off: int,
                      progress: Optional[list] = None) -> int:
        """Split a write into per-op chunks and commit each (Alg. 1).

        ``progress``, when given, is a 1-element list updated with the
        bytes durably committed so far — after a mid-write failure those
        bytes are in the log (and will reach the backend / survive
        recovery), so callers that roll back bookkeeping must roll back to
        ``off + progress[0]``, never to ``off``."""
        pol = self.policy
        max_op = (pol.entries_per_shard - 1) * pol.entry_data
        split_stripes = pol.shards > 1 and pol.shard_route == "stripe"
        # stream classification (dual persistence): feed the write to the
        # per-file classifier BEFORE entering the route gate — a proposed
        # mode switch runs the migration protocol, which freezes that very
        # gate.  confirm() only after the migration actually lands, so a
        # failed freeze (concurrent migration) re-proposes on later writes.
        if f.clf is not None and not f.unlinked:
            switch = f.clf.note_write(off, len(data))
            if switch is not None and self._migrate_mode(f, switch == "page"):
                f.clf.confirm(switch)
        # the whole split runs under the file's route gate, so every
        # chunk's route lookup sees ONE routing epoch and a migration
        # cannot slip between lookup and log append (the stale-route race
        # core/router.py rules out); mode migration and the ftruncate(0)
        # WAL-reset freeze reuse the same gate, so it is held in every
        # configuration, not just under adaptive routing
        f.route_enter()
        prof = self.obs.prof
        lv1 = prof.lv1
        try:
            written = 0
            view = memoryview(data)
            while written < len(data):
                lim = max_op
                if split_stripes:
                    # ops never span a stripe: overlapping writes always
                    # route to the same shard, keeping per-location order a
                    # shard-local property (see core/log.py docstring)
                    sb = self._stripe_bytes_of(f)
                    lim = min(lim, sb - (off + written) % sb)
                chunk = view[written:written + lim]
                t0 = time.perf_counter_ns() if lv1 else 0
                self._pwrite_op(f, bytes(chunk), off + written)
                if lv1:
                    prof.h_op.record_ns(time.perf_counter_ns() - t0)
                written += len(chunk)
                if progress is not None:
                    progress[0] = written
        finally:
            f.route_exit()
        return len(data)

    def _stripe_bytes_of(self, f: File) -> int:
        """Effective stripe width for this file — narrowed by the router's
        per-fdid width tuning when the file is persistently hot."""
        if self.router is not None:
            return self.router.stripe_bytes_of(f.fdid)
        return self.policy.stripe_bytes

    def _pwrite_op(self, f: File, data: bytes, off: int) -> None:
        """One atomic write op == one committed entry group (Alg. 1)."""
        if f.pmode and self.pager is not None:
            return self._pwrite_paged(f, data, off)
        ps = self.policy.page_size
        n = len(data)
        p0, p1 = off // ps, (off + max(n, 1) - 1) // ps
        descs = [f.radix.get_or_create(p) for p in range(p0, p1 + 1)]

        def register(sid: int, head: int, k: int, seq: int) -> None:
            # runs between log allocation and commit: the refs are in the
            # dirty-page index before the drain can possibly see (and try
            # to retire) the entries.  shard membership likewise becomes
            # visible before the pending count below can, so a concurrent
            # close() that sees pending > 0 also sees the shard id.
            f.shards_touched.add(sid)
            for ref in self.log.group_refs(sid, head, k, seq, off, n):
                r1 = (ref.off + max(ref.length, 1) - 1) // ps
                for p in range(ref.off // ps, r1 + 1):
                    descs[p - p0].add_ref(ref)

        for d in descs:                       # ascending page order: no deadlock
            d.atomic_lock.acquire()
        try:
            sid, head, k, seq = self.log.append(f.fdid, off, data,
                                                on_alloc=register)  # durable
            f.pending.inc(k)
            # update loaded pages so reads stay fresh (Alg. 1 lines 29-31)
            for d in descs:
                if d.content is not None:
                    pstart = d.page_no * ps
                    s = max(off, pstart)
                    e = min(off + n, pstart + ps)
                    if s < e:
                        d.content.data[s - pstart:e - pstart] = data[s - off:e - off]
                d.accessed = True
            with f.size_lock:
                if off + n > f.size:
                    f.size = off + n
                if off + n > f.hwm:
                    f.hwm = off + n
        finally:
            for d in reversed(descs):
                d.atomic_lock.release()

    # ------------------------------------------------- paged write path
    def _pwrite_paged(self, f: File, data: bytes, off: int) -> None:
        """One write op in paged mode: each touched page lands in its NVMM
        frame **in place** (the ping-pong slot flip in core/pager.py is the
        commit point) instead of appending a log entry — the whole point of
        the mode: N overwrites of a page cost N page-stores, not N log
        entries that each drain to the backend.

        Per-page old-or-new (same guarantee the log gives per op group):
        each page's flip is atomic, pages commit in ascending order under
        their atomic locks.  A page that cannot get a frame — pool
        exhausted, or the page still has undrained log refs (mode just
        flipped and the barrier raced a concurrent fd) — falls back to a
        per-page log append, preserving the ownership invariant: a (file,
        page) is either framed or logged, never both."""
        ps = self.policy.page_size
        n = len(data)
        p0, p1 = off // ps, (off + max(n, 1) - 1) // ps
        descs = [f.radix.get_or_create(p) for p in range(p0, p1 + 1)]
        for d in descs:                       # ascending page order: no deadlock
            d.atomic_lock.acquire()
        try:
            for d in descs:
                pstart = d.page_no * ps
                s = max(off, pstart)
                e = min(off + n, pstart + ps)
                chunk = memoryview(data)[s - off:e - off]
                idx = f.frames.get(d.page_no)
                if idx is None and not d.dirty_refs:
                    # materialize only once the page has no live log refs:
                    # a frame's image must already contain every committed
                    # byte of the page, or recovery (which replays the
                    # frame at its seq) would resurrect pre-ref state
                    idx = self.pager.alloc(f.fdid, d.page_no)
                    if idx is not None:
                        f.frames[d.page_no] = idx
                        base, valid = self._page_base_image(f, d, pstart)
                        self.pager.frame_write(idx, f.fdid, d.page_no,
                                               s - pstart, e - pstart,
                                               chunk, base, valid)
                elif idx is not None:
                    self.pager.frame_write(idx, f.fdid, d.page_no,
                                           s - pstart, e - pstart,
                                           chunk, None, 0)
                if idx is None:
                    # per-page log fallback (pool exhausted / refs present)
                    self._append_page_chunk(f, d, bytes(chunk), s)
                if d.content is not None:
                    d.content.data[s - pstart:e - pstart] = chunk
                d.accessed = True
            with f.size_lock:
                if off + n > f.size:
                    f.size = off + n
                if off + n > f.hwm:
                    f.hwm = off + n
        finally:
            for d in reversed(descs):
                d.atomic_lock.release()

    def _page_base_image(self, f: File, d, pstart: int) -> tuple:
        """Committed bytes of page ``d`` for frame materialization, as
        ``(image, valid_len)``.  Caller holds ``d.atomic_lock`` and has
        checked ``not d.dirty_refs`` — so a cached content IS the committed
        state, and absent that the backend is (every log entry for the
        page has drained)."""
        ps = self.policy.page_size
        with f.size_lock:
            valid = max(0, min(ps, f.size - pstart))
        if valid == 0:
            return None, 0
        if d.content is not None:
            return bytes(d.content.data[:valid]), valid
        raw = f.backend.pread(valid, pstart)
        if len(raw) < valid:
            raw = raw + bytes(valid - len(raw))
        return raw, valid

    def _append_page_chunk(self, f: File, d, chunk: bytes, abs_s: int) -> None:
        """Log fallback for ONE page of a paged-mode write: a normal
        committed entry group confined to ``d`` (the caller already holds
        ``d.atomic_lock``)."""
        def register(sid: int, head: int, k: int, seq: int) -> None:
            f.shards_touched.add(sid)
            for ref in self.log.group_refs(sid, head, k, seq, abs_s,
                                           len(chunk)):
                d.add_ref(ref)

        _sid, _head, k, _seq = self.log.append(f.fdid, abs_s, chunk,
                                               on_alloc=register)
        f.pending.inc(k)

    def write(self, fd: int, data: bytes) -> int:
        of = self._of(fd)
        f = of.file
        with of.cursor_lock:
            if of.flags & O_APPEND:
                # reserve the range up front so concurrent appends get
                # disjoint offsets; roll the reservation back if the log
                # append fails (LogFullTimeout), else the size stays
                # inflated forever and readers see zero-filled bytes that
                # were never written.  A split write that fails midway
                # rolls back only to the committed prefix — those bytes
                # are durable in the log and recovery WILL land them, so
                # hiding them behind a smaller size would resurrect them
                # as "stale bytes past EOF" after a crash.
                if of.flags & _ACCMODE == O_RDONLY:
                    raise OSError("fd is read-only")
                with f.size_lock:
                    off = f.size
                    f.size = off + len(data)
                progress = [0]
                try:
                    n = (self._pwrite_split(f, data, off, progress)
                         if data else 0)
                except BaseException:
                    with f.size_lock:
                        if f.size == off + len(data):   # no append raced past
                            # never shrink below the committed high-water
                            # mark: a concurrent pwrite INTO our reserved
                            # range leaves size untouched but its bytes
                            # are durable — hiding them behind a smaller
                            # size would lose acknowledged data
                            f.size = max(off + progress[0], f.hwm)
                    raise
            else:
                off = of.cursor
                n = self.pwrite(fd, data, off)
            of.cursor = off + n
            return n

    # ------------------------------------------------------------------ read
    def pread(self, fd: int, n: int, off: int) -> bytes:
        of = self._of(fd)
        if off < 0:
            raise OSError("negative offset (EINVAL)")
        f = of.file
        with f.size_lock:
            size = f.size
        if off >= size:
            return b""
        n = min(n, size - off)
        if f.radix is None:
            # read-only file: bypass the read cache entirely (§II-A) — the
            # kernel page cache is fresh because nothing is in flight.
            out = f.backend.pread(n, off)
            return out + b"\x00" * (n - len(out))
        return self._pread_cached(f, n, off)

    def _pread_cached(self, f: File, n: int, off: int) -> bytes:
        ps = self.policy.page_size
        out = bytearray(n)
        pos = off
        just_loaded = -1
        while pos < off + n:
            p = pos // ps
            d = f.radix.get_or_create(p)
            with d.atomic_lock:
                c = d.content
                if c is not None:
                    if p != just_loaded:      # the retry after our own
                        self.lru.note_hit()        # miss load is not a hit
                        if d.prefetched:      # first demand-hit on a
                            d.prefetched = False   # readahead-loaded page
                            self._c_ra_hits.inc()
                    d.accessed = True
                    pstart = p * ps
                    s = pos - pstart
                    e = min(off + n - pstart, ps)
                    out[pos - off:pstart + e - off] = c.data[s:e]
                    pos = pstart + e
                    continue
            # miss: load the aligned extent covering p (takes its own
            # locks), then retry this page — it can in principle be evicted
            # again before the retry, in which case the loop reloads it
            prof = self.obs.prof
            if prof.lv2:
                t0 = time.perf_counter_ns()
                self._load_extent(f, p)
                prof.h_read_load.record_ns(time.perf_counter_ns() - t0)
            else:
                self._load_extent(f, p)
            just_loaded = p
        return bytes(out)

    def _extent_range(self, f: File, p: int) -> tuple:
        """Readahead window [e0, e1) around page ``p``: up to
        ``Policy.readahead_pages`` pages (clamped to half the read cache so
        a load can never flush the cache it feeds), clipped to the file's
        last page.

        Readahead opens only for a *sequential* miss stream (``p`` is the
        page the previous miss predicted, kernel-style): a random miss
        loads just its own page, so random workloads never pay device cost
        for 7 prefetched pages they will evict unused.

        With ``Policy.readahead_ramp`` (the default) the window *ramps*
        like the kernel's: the first sequential miss after a reset loads 2
        pages, then 4, then 8 ... up to the cap, and any random miss
        resets the ramp — a short sequential burst pays for 2-4 pages
        instead of the full window it would never use.  ``ramp=False``
        keeps the PR-3 behavior: the full aligned window on the first
        sequential miss."""
        cap = min(self.policy.readahead_pages, max(1, self.lru.capacity // 2))
        if cap <= 1 or p != f.ra_next:
            f.ra_next = p + 1
            f.ra_window = 1                   # random miss: reset the ramp
            return p, p + 1
        with f.size_lock:
            size = f.size
        last = (size - 1) // self.policy.page_size if size > 0 else 0
        if self.policy.readahead_ramp:
            w = min(cap, max(2, 2 * f.ra_window))
            f.ra_window = w
            e0, e1 = p, max(p + 1, min(p + w, last + 1))
        else:
            e0 = (p // cap) * cap
            e1 = max(p + 1, min(e0 + cap, last + 1))
        f.ra_next = e1
        return e0, e1

    def _load_extent(self, f: File, p: int) -> None:
        """Cache-miss path, extent-granular (the read-side twin of the
        drain engine; paper Fig. 2 generalized from one page to one aligned
        extent): acquire buffers, one vectored backend read for the
        extent's uncached runs, then the per-page dirty-index replay —
        readahead NEVER bypasses the replay, so prefetched pages obey the
        same durable-linearizability rules as demand misses."""
        ps = self.policy.page_size
        e0, e1 = self._extent_range(f, p)
        descs = [f.radix.get_or_create(q) for q in range(e0, e1)]
        held = descs
        for d in descs:                       # ascending: same order writers use
            d.atomic_lock.acquire()
        try:
            need = [d for d in descs if d.content is None]
            if not any(d.page_no == p for d in need):
                return                        # raced: another reader loaded p
            # drop the locks of in-window pages that are already cached:
            # nothing below touches them, and holding them would stall
            # writers to those pages for a device-read latency
            needset = {id(d) for d in need}
            for d in descs:
                if id(d) not in needset:
                    d.atomic_lock.release()
            held = need
            self.lru.note_miss()
            if len(need) > 1:
                self._c_ra_loads.inc()
                self._c_ra_pages.inc(len(need) - 1)
            bufs = self.lru.acquire_buffers(len(need))
            for d in need:                    # ascending, after atomic locks
                d.cleanup_lock.acquire()
            try:
                # NVMM-framed pages (paged mode) are served straight from
                # their frame — the frame IS the committed page image, so
                # they cost no device read and no replay; only the rest
                # goes to the backend
                frames = f.frames if self.pager is not None else {}
                fetch = [d for d in need if d.page_no not in frames]
                raw_by_page = {}
                if fetch:
                    # one backend operation: contiguous runs of missing
                    # pages become the iovec segments (pages loaded/cached
                    # in between are skipped, not re-read)
                    iov = []
                    run_start = prev = None
                    for d in fetch:
                        if prev is not None and d.page_no == prev + 1:
                            prev = d.page_no
                            continue
                        if run_start is not None:
                            iov.append(((prev - run_start + 1) * ps,
                                        run_start * ps))
                        run_start = prev = d.page_no
                    iov.append(((prev - run_start + 1) * ps, run_start * ps))
                    preadv = getattr(f.backend, "preadv", None)
                    if preadv is not None:
                        chunks = preadv(iov)
                    else:
                        chunks = [f.backend.pread(nn, oo) for nn, oo in iov]
                    for (nn, oo), chunk in zip(iov, chunks):
                        for q in range(oo // ps, (oo + nn) // ps):
                            raw_by_page[q] = chunk[q * ps - oo:(q + 1) * ps - oo]
                for d, content in zip(need, bufs):
                    fidx = frames.get(d.page_no)
                    if fidx is not None:
                        view, ln = self.pager.read(fidx)
                        content.data[:ln] = view
                        if ln < ps:
                            content.data[ln:] = bytes(ps - ln)
                        # no replay: a framed page has no live log refs
                        # (the ownership invariant — see _pwrite_paged)
                    else:
                        raw = raw_by_page[d.page_no]
                        content.data[:len(raw)] = raw
                        if len(raw) < ps:
                            content.data[len(raw):] = bytes(ps - len(raw))
                        self._replay_page(d, content)
                    self.lru.attach(d, content)
                    d.prefetched = d.page_no != p
            finally:
                for d in reversed(need):
                    d.cleanup_lock.release()
        finally:
            for d in reversed(held):
                d.atomic_lock.release()

    def _replay_page(self, d, content) -> None:
        """Dirty-miss replay under the page's cleanup lock: apply ONLY this
        page's live entries from the dirty-page index, already in commit
        (seq) order — O(E) for E entries on the page, where the
        dirty-counter design had to rescan the whole log.  All of a page's
        entries live in one shard (overlap routing), and holding
        cleanup_lock means none of them can be retired/recycled mid-replay,
        so ref_payload reads are stable."""
        refs = d.snapshot_refs()
        if not refs:
            return
        ps = self.policy.page_size
        base = d.page_no * ps
        self._c_dirty_misses.inc()
        self._c_replay_entries.inc(len(refs))
        prof = self.obs.prof
        t0 = time.perf_counter_ns() if prof.lv2 else 0
        for ref in refs:
            edata = self.log.ref_payload(ref)
            s = max(ref.off, base)
            t = min(ref.off + ref.length, base + ps)
            if s < t:
                content.data[s - base:t - base] = edata[s - ref.off:t - ref.off]
        if prof.lv2:
            prof.h_read_replay.record_ns(time.perf_counter_ns() - t0)

    def read(self, fd: int, n: int) -> bytes:
        of = self._of(fd)
        with of.cursor_lock:
            out = self.pread(fd, n, of.cursor)
            of.cursor += len(out)
            return out

    # ----------------------------------------------------- metadata (§II-C)
    def fsync(self, fd: int) -> None:
        """No-op: writes are already synchronously durable (Table III)."""
        self._of(fd)

    # -- durable namespace ops (core/namespace.py): each quiesces the
    #    touched file(s) behind the drain barrier, journals the op as a
    #    committed NVMM log entry, then applies the backend effect — so an
    #    acknowledged rename/unlink/ftruncate survives any crash, and
    #    recovery's seq-merge replays it old-or-new, never torn.
    def _lookup_closed_locked(self, path: str) -> Optional[File]:
        """The File at ``path`` verified to have no open descriptors
        (namespace ops refuse open files — the legacy protocols we model
        close before rename/unlink).  Caller holds ``_meta``."""
        f = self._files.get(path)
        if f is not None and f.refs > 0:
            raise OSError(f"{path} is open (EBUSY)")
        return f

    def unlink(self, path: str) -> None:
        """Remove ``path`` (the SQLite rollback-journal commit point).

        The journal record commits BEFORE the backend unlink, so a crash
        at any point leaves the file either present (op not acknowledged)
        or durably gone — its bytes can never resurrect: recovery replays
        the unlink at a seq above every covered data entry.

        POSIX unlink-while-open: with live descriptors the *name* is
        removed now and the file turns anonymous — reads/writes through
        open fds keep working, the file is reclaimed at its last close,
        and after a crash it is simply gone (its post-unlink writes are
        dropped as orphans: the fd-table slot is cleared with the name).
        This is what lets SQLite delete a hot journal without first paying
        a close barrier, and what makes the journal's drain skip the
        backend fsync entirely (see ``File.unlinked``)."""
        self.check()
        with self._meta:
            self.ns.apply_deferred()   # backend must be current for exists()
            f = self._files.get(path)
            if f is None and not self.tier.exists(path):
                raise FileNotFoundError(path)
            marks, mseq = self.ns.journal_locked(
                MOP_UNLINK, f.fdid if f is not None else META_NO_FDID,
                0, path)
            self._flight_meta(MOP_UNLINK,
                              f.fdid if f is not None else META_NO_FDID,
                              mseq)
            try:
                if f is not None:
                    f.unlinked = True
                    self._files.pop(path, None)    # fdid stays bound
                    # undrained and post-unlink entries die with a crash
                    # (POSIX): clearing the slot makes recovery drop them
                    # as orphans instead of re-creating the dead name —
                    # the unlink record above outranks them all by seq
                    self.log.fd_table_set(f.fdid, "")
                self.tier.unlink(path)
                self.ns.note_backend_applied(mseq)
                if f is not None:
                    # closed and already drained: reclaim on the spot;
                    # otherwise the drain's reap (or the flush sweep)
                    # retires it once its entries are consumed
                    self._maybe_retire_locked(f)
            finally:
                self.ns.mark_applied(marks)
        self.check()

    def rename(self, old: str, new: str) -> None:
        """Atomically move ``old`` over ``new`` (the RocksDB MANIFEST
        install).  Both paths must have no open descriptors; an existing
        ``new`` is replaced, and after recovery the data is attributed to
        exactly one of the two names — never both, never neither."""
        self.check()
        if old == new:
            with self._meta:
                self.ns.apply_deferred()
                if (self._files.get(old) is None
                        and not self.tier.exists(old)):
                    raise FileNotFoundError(old)
            return
        deadline = time.monotonic() + 120.0
        while True:
            with self._meta:
                self.ns.apply_deferred()   # prior renames must be visible
                fo = self._lookup_closed_locked(old)
                fn = self._lookup_closed_locked(new)
                if fo is None and not self.tier.exists(old):
                    raise FileNotFoundError(old)
                stale = fo if (fo is not None and fo.pending.get() > 0) \
                    else (fn if (fn is not None and fn.pending.get() > 0)
                          else None)
                if stale is None:
                    marks, mseq = self.ns.journal_locked(
                        MOP_RENAME,
                        fo.fdid if fo is not None else META_NO_FDID, 0,
                        old, new)
                    self._flight_meta(
                        MOP_RENAME,
                        fo.fdid if fo is not None else META_NO_FDID, mseq)
                    if fo is not None:
                        self._maybe_retire_locked(fo)
                    if fn is not None:
                        self._maybe_retire_locked(fn)
                    # deferred backend apply (core/namespace.py): the
                    # slow-tier directory update leaves the _meta critical
                    # section — queued here, run just below without the
                    # lock (or by a drain thread if we lose the race)
                    self.ns.queue_apply(
                        mseq,
                        lambda o=old, n=new: self.tier.rename(o, n),
                        marks)
                    break
            self._drain_barrier(stale, "rename")
            if time.monotonic() > deadline:
                raise TimeoutError(f"rename {old} -> {new} could not quiesce")
        # run the queued apply ourselves, outside _meta: the call returns
        # with the backend current, but racing namespace ops no longer
        # serialize behind the directory update
        self.ns.apply_deferred()
        self.check()

    def ftruncate(self, fd: int, length: int) -> None:
        """Set the open file's length (SQLite WAL reset).  Journaled like
        rename/unlink; shrinking purges cached/dirty state beyond the new
        length so cut bytes never resurrect, growing zero-fills."""
        of = self._of(fd)
        if of.flags & _ACCMODE == O_RDONLY:
            raise OSError("fd is read-only")
        if length < 0:
            raise OSError("negative length (EINVAL)")
        self._truncate_file(of.file, length)
        self.check()

    def flock(self, fd: int, unlock: bool = False) -> None:
        """Advisory lock hook (paper §I): releasing a lock flushes this
        file's pending writes to the kernel so other processes see them."""
        of = self._of(fd)
        if unlock:
            self._drain_barrier(of.file, "flock release")

    def lseek(self, fd: int, off: int, whence: int = os.SEEK_SET) -> int:
        of = self._of(fd)
        with of.cursor_lock:
            if whence == os.SEEK_SET:
                target = off
            elif whence == os.SEEK_CUR:
                target = of.cursor + off
            elif whence == os.SEEK_END:
                with of.file.size_lock:
                    target = of.file.size + off
            else:
                raise OSError("bad whence")
            if target < 0:
                raise OSError("negative seek (EINVAL)")  # cursor unchanged
            of.cursor = target
            return of.cursor

    def stat_size(self, fd_or_path) -> int:
        if isinstance(fd_or_path, int):
            f = self._of(fd_or_path).file
        else:
            f = self._files.get(fd_or_path)
            if f is None:
                self.ns.apply_deferred()   # queued renames affect existence
                # stat must not mutate the namespace: Tier.open inserts on
                # miss, which used to create an empty phantom file here
                size_of = getattr(self.tier, "size_of", None)
                if size_of is not None:
                    return size_of(fd_or_path)   # raises FileNotFoundError
                if not self.tier.exists(fd_or_path):
                    raise FileNotFoundError(fd_or_path)
                return self.tier.open(fd_or_path).size()
        with f.size_lock:
            return f.size

    # ------------------------------------------------------------- stats
    def metrics(self) -> dict:
        """The registry snapshot under canonical ``subsystem.noun_unit``
        names — counters, bound legacy stats and latency-histogram
        summaries in one dict (see ``src/repro/obs/README.md``)."""
        return self.obs.registry.snapshot()

    def profile_report(self) -> str:
        """Human-readable per-stage latency table (``--profile``).
        Empty-ish at ``obs_level=0`` — spans are not recorded."""
        return self.obs.prof.report()

    def stats(self) -> dict:
        """Aggregate counters under the historic flat key names.

        One registry snapshot backs the whole dict: each subsystem's
        legacy counters are bound into the registry as a group whose
        callback still reads under that subsystem's own lock
        (``snapshot_stats``), so no key exposes a torn or mid-update
        view.  New callers should prefer :meth:`metrics`, which returns
        the same snapshot under canonical names."""
        m = self.obs.registry.snapshot()
        aw = m["log.alloc_wait_us"]
        ra_pages = m["read.readahead_page_total"]
        ra_hits = m["read.readahead_hit_total"]
        return {
            "shards": m["engine.shard_count"],
            "log_used": m["log.used_count"],
            "dirty_misses": m["read.dirty_miss_total"],
            "replay_entries": m["read.replay_entry_total"],
            "log_full_scans": m["log.full_scan_total"],
            "lru_hits": m["lru.hit_total"],
            "lru_misses": m["lru.miss_total"],
            "lru_evictions": m["lru.eviction_total"],
            "readahead_loads": m["read.readahead_load_total"],
            "readahead_pages": ra_pages,
            "readahead_hits": ra_hits,
            "readahead_hit_rate": ra_hits / max(1, ra_pages),
            "cleanup_batches": m["drain.batch_total"],
            "cleanup_entries": m["drain.entry_total"],
            "cleanup_fsyncs": m["drain.fsync_total"],
            "cleanup_fsyncs_issued": m["drain.fsync_issued_total"],
            "cleanup_fsyncs_merged": m["drain.fsync_merged_total"],
            "drain_extents": m["drain.extent_total"],
            "drain_pwritevs": m["drain.pwritev_total"],
            "drain_deferred": m["drain.deferred_total"],
            "drain_span_merges": m["drain.span_merge_total"],
            "nvmm_psyncs": m["nvmm.psync_total"],
            "nvmm_pwbs": m["nvmm.pwb_total"],
            "nvmm_pwb_lines": m["nvmm.pwb_line_total"],
            "nvmm_fences": m["nvmm.fence_total"],
            "nvmm_stored_bytes": m["nvmm.stored_bytes"],
            # alloc-wait is a real distribution now (PR 10): the flat
            # seconds sum stays for old readers, count/mean/p95 added so
            # a zero-count window can't masquerade as a measured average
            "alloc_wait_s": aw["sum_us"] * 1e-6,
            "alloc_waits": aw["count"],
            "alloc_wait_mean_us": aw["mean_us"],
            "alloc_wait_p95_us": aw["p95_us"],
            "route_epoch": m["route.epoch_count"],
            "route_overrides": m["route.override_count"],
            "route_migrations": m["route.migration_total"],
            "route_skew_ratio": m["route.skew_ratio"],
            "route_skipped_uneconomic":
                m["route.skipped_uneconomic_total"],
            "route_stripe_widenings": m["route.stripe_widening_total"],
            "meta_ops": m["meta.op_total"],
            "meta_entries": m["meta.entry_total"],
            "meta_deferred_applies": m["meta.deferred_apply_total"],
            "mode_migrations": m["engine.mode_migration_total"],
            "paged_frames_used": m["page.frame_used_count"],
            "paged_frame_writes": m["page.frame_write_total"],
            "paged_frame_bytes": m["page.frame_bytes"],
            "paged_cow_bytes": m["page.cow_bytes"],
            "paged_writebacks": m["page.writeback_total"],
            "paged_alloc_fallbacks": m["page.alloc_fallback_total"],
        }
