"""NVCache POSIX-like facade (paper §II-A, §III, Table III).

``NVCache`` is the interception boundary: components open files and call
``read/write/pread/pwrite/lseek/stat/fsync/close`` exactly as they would
against libc, and transparently get

  * synchronous durability — ``write`` returns only once the data is
    committed in the NVMM log (paper Alg. 1),
  * durable linearizability — a write is visible to a reader only when it
    is durable (the psync before the per-page lock release),
  * asynchronous propagation to the slow tier via the per-shard drain pool
    and its page-coalescing plan/apply engine (:mod:`repro.core.drain`),
  * ``fsync`` as a no-op (Table III: writes are already durable),
  * user-space file size/cursor (the kernel's may be stale, §II-C),
  * durable namespace ops — ``rename``/``unlink``/``ftruncate`` (and the
    implicit create in ``open``) journaled as metadata log entries so the
    crash-consistency protocols of legacy apps (SQLite journal unlink,
    RocksDB MANIFEST rename) survive power loss; see
    :mod:`repro.core.namespace`.

One instance == one NVMM region (one "DAX file"); several instances can
coexist on separate regions (paper §III Multi-application).
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, Optional

from repro.core.cleanup import CleanupPool
from repro.core.log import (META_NO_FDID, MOP_CREATE, MOP_FTRUNCATE,
                            MOP_RENAME, MOP_UNLINK, NVLog)
from repro.core.namespace import Namespace
from repro.core.nvmm import NVMM
from repro.core.policy import Policy
from repro.core.readcache import AtomicInt, LRUCache, RadixTree
from repro.core.router import EpochRouter
from repro.core import recovery as _recovery

O_RDONLY, O_WRONLY, O_RDWR = os.O_RDONLY, os.O_WRONLY, os.O_RDWR
O_CREAT, O_APPEND, O_TRUNC = os.O_CREAT, os.O_APPEND, os.O_TRUNC
_ACCMODE = os.O_ACCMODE


class File:
    """Per-(device,inode) state (paper §III "Open": the file table)."""

    __slots__ = ("path", "fdid", "backend", "radix", "size", "size_lock",
                 "refs", "pending", "shards_touched", "_drained", "ra_next",
                 "ra_window", "hwm", "_route_cv", "route_inflight",
                 "route_frozen", "unlinked")

    def __init__(self, path: str, fdid: int, backend):
        self.path = path
        self.fdid = fdid
        self.backend = backend
        self.radix: Optional[RadixTree] = None   # created on first write-open
        self.size = backend.size()
        self.hwm = self.size      # committed high-water mark: size minus any
        #                           not-yet-committed O_APPEND reservation
        self.size_lock = threading.Lock()
        self.refs = 0
        self.pending = AtomicInt(0)              # log entries not yet drained
        self.shards_touched: set = set()         # sids holding entries for us
        self._drained = threading.Condition()
        self.ra_next = -1                        # readahead stream detector:
        #   the page a sequential miss stream would miss next; racy by
        #   design (a heuristic, like the kernel's per-file ra window)
        self.ra_window = 1                       # current ramped window size
        #   (grows 2->4->... toward Policy.readahead_pages on a sustained
        #    sequential miss stream, resets on a random miss)
        self.unlinked = False                    # POSIX unlink-while-open:
        #   the name is gone but the file lives until its last close; its
        #   drain skips the backend fsync (the bytes die with the name on
        #   any crash) and close() skips the drain barrier
        # route-epoch gate (adaptive routing only): writers enter before the
        # route lookup and exit after the log append, so a migration can
        # freeze the file and know no in-flight write still holds a stale
        # route (see core/router.py's ordering proof)
        self._route_cv = threading.Condition()
        self.route_inflight = 0
        self.route_frozen = False

    def note_drained(self, n: int) -> None:      # called by the cleanup thread
        self.pending.dec(n)
        with self._drained:
            self._drained.notify_all()

    def wait_drained(self, timeout: Optional[float] = None) -> bool:
        with self._drained:
            return self._drained.wait_for(lambda: self.pending.get() <= 0,
                                          timeout=timeout)

    # ------------------------------------------------- route-epoch gate
    def route_enter(self) -> None:
        """Writer side: pin the routing epoch for one write (blocks while a
        migration of this file is in progress)."""
        with self._route_cv:
            while self.route_frozen:
                self._route_cv.wait()
            self.route_inflight += 1

    def route_exit(self) -> None:
        with self._route_cv:
            self.route_inflight -= 1
            if self.route_inflight == 0 and self.route_frozen:
                self._route_cv.notify_all()

    def route_freeze(self, timeout: Optional[float] = None) -> bool:
        """Migration side: block new writes and wait until in-flight writes
        (which looked up their shard under the old epoch) have committed.
        Returns False (and unfreezes) on timeout."""
        with self._route_cv:
            if self.route_frozen:
                return False                     # one migration at a time
            self.route_frozen = True
            if self._route_cv.wait_for(lambda: self.route_inflight == 0,
                                       timeout=timeout):
                return True
            self.route_frozen = False
            self._route_cv.notify_all()
            return False

    def route_unfreeze(self) -> None:
        with self._route_cv:
            self.route_frozen = False
            self._route_cv.notify_all()


class OpenFile:
    """Per-descriptor state (paper §III: the opened table / cursor)."""

    __slots__ = ("file", "flags", "cursor", "cursor_lock")

    def __init__(self, file: File, flags: int):
        self.file = file
        self.flags = flags
        self.cursor = 0
        self.cursor_lock = threading.Lock()


class NVCache:
    def __init__(self, policy: Policy, tier, *, nvmm: Optional[NVMM] = None,
                 track_crashes: bool = False, recover: bool = True):
        self.policy = policy
        self.tier = tier
        self.nvmm = nvmm or NVMM(policy.nvmm_bytes, track=track_crashes)
        if recover and nvmm is not None:
            try:
                self.recovery_stats = _recovery.recover(self.nvmm, policy, tier)
            except ValueError:
                self.recovery_stats = None     # fresh region
                NVLog(self.nvmm, policy, format=True)
            self.log = NVLog(self.nvmm, policy, format=False)
        else:
            self.recovery_stats = None
            self.log = NVLog(self.nvmm, policy, format=True)

        self.lru = LRUCache(policy.read_cache_pages, policy.page_size)
        # the durable namespace owns the file tables (path→File, fdid→File,
        # free fdid slots) and the metadata journaling protocol; the aliases
        # below are the same mutable objects, kept under the historic names
        self.ns = Namespace(self.log, tier, policy.fd_max)
        self._files: Dict[str, File] = self.ns.files
        self._by_fdid: Dict[int, File] = self.ns.by_fdid
        self._open: Dict[int, OpenFile] = {}
        self._next_fd = 3
        self._meta = self.ns.lock
        self._fdid_free = self.ns.fdid_free
        # adaptive shard routing (beyond paper, see core/router.py): the
        # router is created AFTER the log so it adopts the persisted route
        # record of an attached region (and an empty one after a format)
        self.router: Optional[EpochRouter] = None
        if policy.shard_rebalance:
            self.router = EpochRouter(self.nvmm, policy)
            self.log.router = self.router
        self.cleanup = CleanupPool(self.log, self._resolve_fdid,
                                   router=self.router,
                                   migrate=self._migrate_route
                                   if self.router is not None else None,
                                   meta_gate=self.ns,
                                   reap=self._reap_file)
        self.cleanup.start()
        self._crashed = False
        self.stats_dirty_misses = 0
        self.stats_replay_entries = 0   # refs inspected across dirty misses
        self.stats_readahead_loads = 0  # extent loads that prefetched pages
        self.stats_readahead_pages = 0  # pages loaded beyond the missed one
        self.stats_readahead_hits = 0   # first demand-hits on prefetched pages

    # ------------------------------------------------------------- lifecycle
    def _resolve_fdid(self, fdid: int) -> Optional[File]:
        return self._by_fdid.get(fdid)

    def _reap_file(self, f: File) -> None:
        """Drain-thread callback: an anonymous (unlinked) file's entries
        all landed.  Try-lock only — a drain thread must never wait on
        ``_meta`` (a writer holding it may itself be blocked on log space
        that only this drain can free); a missed reap is reclaimed by the
        ``flush()`` sweep or the fdid-exhaustion sweep in ``open()``."""
        if not self._meta.acquire(blocking=False):
            return
        try:
            self._maybe_retire_locked(f)
        finally:
            self._meta.release()

    def check(self) -> None:
        if self.cleanup.error is not None:
            raise RuntimeError("cleanup thread died") from self.cleanup.error
        if self._crashed:
            raise RuntimeError("instance crashed")

    def shutdown(self) -> None:
        """Graceful: drain the log, stop the cleanup thread."""
        self.cleanup.shutdown()
        self.check()

    def crash(self, choose_evicted=None) -> NVMM:
        """Simulated power loss; returns the NVMM region for recovery."""
        self._crashed = True
        self.cleanup.power_loss()
        if self.nvmm.track:
            self.nvmm.crash(choose_evicted)
        return self.nvmm

    def flush(self, timeout: Optional[float] = 60.0) -> None:
        """Drain the whole log to the slow tier (used as a barrier)."""
        self.cleanup.request_drain()
        try:
            # _by_fdid covers every bound File, including anonymous
            # (unlinked-while-open) ones that left the path table
            for f in list(self._by_fdid.values()):
                if not f.wait_drained(timeout=timeout):
                    raise TimeoutError(f"drain of {f.path} timed out")
            # namespace records are not any File's pending entries: wait
            # for them separately so "flush == the log is drained" holds
            if not self.ns.wait_consumed(timeout=timeout):
                raise TimeoutError("drain of namespace records timed out")
        finally:
            self.cleanup.end_drain()
        with self._meta:
            # sweep files orphaned by a timed-out close barrier or an
            # unlink-while-open (refs 0, kept only so the drain could
            # finish): they are drained now
            for f in list(self._by_fdid.values()):
                if f.refs == 0:
                    self._maybe_retire_locked(f)
        self.check()

    # ------------------------------------------------------------------ open
    def open(self, path: str, flags: int = O_RDWR | O_CREAT) -> int:
        self.check()
        accmode = flags & _ACCMODE
        with self._meta:
            f = self.ns.lookup(path)
            if f is None:
                created = not self.tier.exists(path)
                if created and not flags & O_CREAT:
                    raise FileNotFoundError(path)
                if not self._fdid_free:
                    # reclaim drained anonymous/orphaned files whose reap
                    # lost the _meta try-lock race before giving up
                    for g in list(self._by_fdid.values()):
                        if g.refs == 0:
                            self._maybe_retire_locked(g)
                fdid = self.ns.alloc_fdid()
                marks = None
                try:
                    self.log.fd_table_set(fdid, path)   # durable path for recovery
                    if created:
                        # journal the create BEFORE the backend file exists
                        # (WAL rule): a crash after this point re-creates
                        # the path from the log even if the kernel lost the
                        # directory update
                        marks, mseq = self.ns.journal(MOP_CREATE, fdid, 0,
                                                      path)
                    backend = self.tier.open(path)
                    if created:
                        self.ns.note_backend_applied(mseq)
                except BaseException:
                    self.ns.free_fdid(fdid)             # nothing references it
                    raise
                finally:
                    if marks is not None:
                        self.ns.mark_applied(marks)
                f = File(path, fdid, backend)
                self.ns.bind(path, f)
            if accmode != O_RDONLY and f.radix is None:
                f.radix = RadixTree()               # read cache only for writers
            f.refs += 1
            fd = self._next_fd
            self._next_fd += 1
            of = OpenFile(f, flags)
            self._open[fd] = of
        if flags & O_TRUNC and accmode != O_RDONLY:
            try:
                self._truncate_file(f)
            except BaseException:
                # the caller gets an exception, not the fd — unwind the
                # registration above or the descriptor would leak forever
                with self._meta:
                    self._open.pop(fd, None)
                    self._release_file_locked(f)
                raise
        return fd

    def _release_file_locked(self, f: File) -> None:
        """Drop one reference; fully retire the file table entry once it is
        unreferenced AND drained.  Caller holds ``_meta``.

        The pending check is load-bearing: retiring the fdid while
        committed entries still point at it would make the drain drop them
        as orphans — or, worse, a reused fdid would route them into an
        unrelated file.  On a drain-barrier timeout the File therefore
        stays registered (and resolvable) until its entries land; it is
        reclaimed by a later open() of the same path (which adopts it) or
        by the orphan sweep in :meth:`flush`."""
        f.refs -= 1
        self._maybe_retire_locked(f)

    def _maybe_retire_locked(self, f: File) -> None:
        if f.refs != 0 or f.pending.get() > 0:
            return
        if f.unlinked:
            # anonymous (name already removed at unlink time): only the
            # fdid binding remains, kept so the drain could resolve it
            if self._by_fdid.get(f.fdid) is not f:
                return
            self._by_fdid.pop(f.fdid, None)
        else:
            if self._files.get(f.path) is not f:
                return
            self._files.pop(f.path, None)
            self._by_fdid.pop(f.fdid, None)
        self.log.fd_table_set(f.fdid, "")   # retire the NVMM slot
        if self.router is not None:
            # the file is drained (pending <= 0), so its overrides can
            # revert to static without stranding entries; keeping them
            # would leak table slots and mis-route a reused fdid
            self.router.drop_fdid(f.fdid)
        self._fdid_free.append(f.fdid)
        f.backend.close()

    def _truncate_file(self, f: File, length: int = 0) -> None:
        """Set the file's length *everywhere*, not just the backend
        (``O_TRUNC`` is ``length == 0``; ``ftruncate`` passes any length).

        Undrained log entries, dirty-page-index refs and loaded page
        contents all hold pre-truncate bytes; truncating only the backend
        let a later drain resurrect them and let cached reads serve stale
        data.  Order: drain the file's touched shards first (consuming its
        entries durably, exactly as ``close`` does — so a crash after this
        point cannot replay pre-truncate bytes either), journal the new
        length as a metadata log entry (the durable intent recovery
        replays, seq-ordered after every covered data entry), then purge
        the radix refs/contents beyond the new length under the page
        locks, then truncate the backend and the user-space size."""
        with f.size_lock:
            cur = f.size
        if cur == length and f.backend.size() == length:
            return                            # nothing to cut or extend
        self._drain_barrier(f, "ftruncate")
        # journal under _meta like every namespace op (the Namespace lock
        # invariant): otherwise a concurrent unlink-while-open could slip
        # between the f.unlinked check and the journal append, and recovery
        # would replay the MOP_FTRUNCATE *after* the unlink — re-creating
        # the dead path as a length-L file
        with self._meta:
            if f.unlinked:
                # anonymous file: no name to journal under (and none
                # needed — the file is gone after any crash)
                marks = None
            else:
                marks, mseq = self.ns.journal(MOP_FTRUNCATE, f.fdid,
                                              length, f.path)
        try:
            # order matters: size first (readers clamp against it, so no
            # new read can reach the cut bytes), then truncate the backend,
            # then purge — a reader that re-cached a pre-truncate page
            # between the drain and here is cleaned up by the purge.  A
            # load whose desc the purge walk could miss (inserted only
            # while the walk runs) is necessarily harmless: its backend
            # pread happens after the truncate below and reads zeros, while
            # any load that read the backend *before* the truncate inserted
            # its desc before the walk began and is purged under its locks.
            with f.size_lock:
                f.size = length
                f.hwm = min(f.hwm, length)
            f.backend.truncate(length)
            if f.radix is not None:
                ps = self.policy.page_size
                first_cut = -(-length // ps)      # first wholly-cut page
                for d in f.radix.iter_descs():
                    if d.page_no < first_cut - 1:
                        continue                  # untouched by the cut
                    with d.atomic_lock, d.cleanup_lock:
                        if d.page_no >= first_cut and d.content is not None:
                            d.content.desc = None  # LRU reclaims it as free
                            d.content = None
                            d.prefetched = False
                        elif d.content is not None and length % ps:
                            # boundary page survives: zero its cut tail so
                            # a later size-growing write reads zeros there
                            d.content.data[length % ps:] = \
                                bytes(ps - length % ps)
                        # refs are NOT cleared here: the drain barrier above
                        # already retired every pre-truncate ref, so any ref
                        # present now belongs to a write committed *after*
                        # the barrier by a concurrent fd — clearing it would
                        # blind readers to an entry the drain will still land
            if marks is not None:
                self.ns.note_backend_applied(mseq)
        finally:
            if marks is not None:
                self.ns.mark_applied(marks)

    def _drain_barrier(self, f: File, label: str,
                       timeout: float = 60.0) -> None:
        """Drain the shards ``f`` touched and wait for its entries to land
        — the shared barrier under close/flock/O_TRUNC/route migration."""
        touched = set(f.shards_touched)
        self.cleanup.request_drain(touched)
        try:
            if not f.wait_drained(timeout=timeout):
                raise TimeoutError(f"drain of {f.path} timed out on {label}")
        finally:
            self.cleanup.end_drain(touched)

    def _migrate_route(self, mig) -> bool:
        """Execute one planned route migration (called by the pool's
        rebalance thread): freeze the file's route gate, drain the file's
        entries out of its old shard, install the new epoch, unfreeze.
        The barrier is what keeps the overlap invariant true across the
        epoch change — see core/router.py for the ordering proof.  Returns
        False (table untouched) when the freeze or barrier cannot complete.
        """
        with self._meta:
            f = self._by_fdid.get(mig.fdid)
        if f is None:
            # file retired since the plan was made: the load data is stale
            # and the fdid may already be reused by a NEW file (whose gate
            # we never froze) — installing now would reroute that file
            # without the barrier.  Skip; the next epoch re-plans.
            return False
        if not f.route_freeze(timeout=10.0):
            return False
        try:
            self._drain_barrier(f, "rebalance", timeout=10.0)
            with self._meta:
                if self._by_fdid.get(mig.fdid) is not f:
                    return False    # retired (and possibly reused) mid-
                    #                 migration: same hazard as above
                return self.router.install(mig.key, mig.new_sid)
        except TimeoutError:
            return False
        finally:
            f.route_unfreeze()

    def close(self, fd: int) -> None:
        """Flush this file's pending writes to the kernel, then close
        (paper §I: coherence across processes via flush-on-close).  Only the
        shards this file actually touched are asked to drain."""
        of = self._pop_fd(fd)
        f = of.file
        try:
            if not f.unlinked:
                # an unlinked (anonymous) file dies with its last close:
                # nothing to make coherent for other processes, so no
                # barrier — its remaining entries drain (fsync-free) in
                # the background and the reap retires the fdid
                self._drain_barrier(f, "close")
        finally:
            # teardown must run even when the drain barrier fails: the fd
            # was already popped, so skipping the refcount would leak the
            # File, its fdid slot and its NVMM fd-table entry forever.
            # (_release_file_locked keeps the File resolvable while
            # undrained entries exist — a timed-out barrier must not turn
            # acknowledged bytes into orphans.)
            with self._meta:
                self._release_file_locked(f)
        self.check()

    def _pop_fd(self, fd: int) -> OpenFile:
        with self._meta:
            of = self._open.pop(fd, None)
        if of is None:
            raise OSError(f"bad fd {fd}")
        return of

    def _of(self, fd: int) -> OpenFile:
        of = self._open.get(fd)
        if of is None:
            raise OSError(f"bad fd {fd}")
        return of

    # ----------------------------------------------------------------- write
    def pwrite(self, fd: int, data: bytes, off: int) -> int:
        of = self._of(fd)
        if of.flags & _ACCMODE == O_RDONLY:
            raise OSError("fd is read-only")
        if off < 0:
            raise OSError("negative offset (EINVAL)")
        if not data:
            return 0
        return self._pwrite_split(of.file, data, off)

    def _pwrite_split(self, f: File, data: bytes, off: int,
                      progress: Optional[list] = None) -> int:
        """Split a write into per-op chunks and commit each (Alg. 1).

        ``progress``, when given, is a 1-element list updated with the
        bytes durably committed so far — after a mid-write failure those
        bytes are in the log (and will reach the backend / survive
        recovery), so callers that roll back bookkeeping must roll back to
        ``off + progress[0]``, never to ``off``."""
        pol = self.policy
        max_op = (pol.entries_per_shard - 1) * pol.entry_data
        split_stripes = pol.shards > 1 and pol.shard_route == "stripe"
        # epoch versioning (adaptive routing only): the whole split runs
        # under the file's route gate, so every chunk's route lookup sees
        # ONE routing epoch and a migration cannot slip between lookup and
        # log append (the stale-route race core/router.py rules out)
        gated = self.router is not None
        if gated:
            f.route_enter()
        try:
            written = 0
            view = memoryview(data)
            while written < len(data):
                lim = max_op
                if split_stripes:
                    # ops never span a stripe: overlapping writes always
                    # route to the same shard, keeping per-location order a
                    # shard-local property (see core/log.py docstring)
                    sb = pol.stripe_bytes
                    lim = min(lim, sb - (off + written) % sb)
                chunk = view[written:written + lim]
                self._pwrite_op(f, bytes(chunk), off + written)
                written += len(chunk)
                if progress is not None:
                    progress[0] = written
        finally:
            if gated:
                f.route_exit()
        return len(data)

    def _pwrite_op(self, f: File, data: bytes, off: int) -> None:
        """One atomic write op == one committed entry group (Alg. 1)."""
        ps = self.policy.page_size
        n = len(data)
        p0, p1 = off // ps, (off + max(n, 1) - 1) // ps
        descs = [f.radix.get_or_create(p) for p in range(p0, p1 + 1)]

        def register(sid: int, head: int, k: int, seq: int) -> None:
            # runs between log allocation and commit: the refs are in the
            # dirty-page index before the drain can possibly see (and try
            # to retire) the entries.  shard membership likewise becomes
            # visible before the pending count below can, so a concurrent
            # close() that sees pending > 0 also sees the shard id.
            f.shards_touched.add(sid)
            for ref in self.log.group_refs(sid, head, k, seq, off, n):
                r1 = (ref.off + max(ref.length, 1) - 1) // ps
                for p in range(ref.off // ps, r1 + 1):
                    descs[p - p0].add_ref(ref)

        for d in descs:                       # ascending page order: no deadlock
            d.atomic_lock.acquire()
        try:
            sid, head, k, seq = self.log.append(f.fdid, off, data,
                                                on_alloc=register)  # durable
            f.pending.inc(k)
            # update loaded pages so reads stay fresh (Alg. 1 lines 29-31)
            for d in descs:
                if d.content is not None:
                    pstart = d.page_no * ps
                    s = max(off, pstart)
                    e = min(off + n, pstart + ps)
                    if s < e:
                        d.content.data[s - pstart:e - pstart] = data[s - off:e - off]
                d.accessed = True
            with f.size_lock:
                if off + n > f.size:
                    f.size = off + n
                if off + n > f.hwm:
                    f.hwm = off + n
        finally:
            for d in reversed(descs):
                d.atomic_lock.release()

    def write(self, fd: int, data: bytes) -> int:
        of = self._of(fd)
        f = of.file
        with of.cursor_lock:
            if of.flags & O_APPEND:
                # reserve the range up front so concurrent appends get
                # disjoint offsets; roll the reservation back if the log
                # append fails (LogFullTimeout), else the size stays
                # inflated forever and readers see zero-filled bytes that
                # were never written.  A split write that fails midway
                # rolls back only to the committed prefix — those bytes
                # are durable in the log and recovery WILL land them, so
                # hiding them behind a smaller size would resurrect them
                # as "stale bytes past EOF" after a crash.
                if of.flags & _ACCMODE == O_RDONLY:
                    raise OSError("fd is read-only")
                with f.size_lock:
                    off = f.size
                    f.size = off + len(data)
                progress = [0]
                try:
                    n = (self._pwrite_split(f, data, off, progress)
                         if data else 0)
                except BaseException:
                    with f.size_lock:
                        if f.size == off + len(data):   # no append raced past
                            # never shrink below the committed high-water
                            # mark: a concurrent pwrite INTO our reserved
                            # range leaves size untouched but its bytes
                            # are durable — hiding them behind a smaller
                            # size would lose acknowledged data
                            f.size = max(off + progress[0], f.hwm)
                    raise
            else:
                off = of.cursor
                n = self.pwrite(fd, data, off)
            of.cursor = off + n
            return n

    # ------------------------------------------------------------------ read
    def pread(self, fd: int, n: int, off: int) -> bytes:
        of = self._of(fd)
        if off < 0:
            raise OSError("negative offset (EINVAL)")
        f = of.file
        with f.size_lock:
            size = f.size
        if off >= size:
            return b""
        n = min(n, size - off)
        if f.radix is None:
            # read-only file: bypass the read cache entirely (§II-A) — the
            # kernel page cache is fresh because nothing is in flight.
            out = f.backend.pread(n, off)
            return out + b"\x00" * (n - len(out))
        return self._pread_cached(f, n, off)

    def _pread_cached(self, f: File, n: int, off: int) -> bytes:
        ps = self.policy.page_size
        out = bytearray(n)
        pos = off
        just_loaded = -1
        while pos < off + n:
            p = pos // ps
            d = f.radix.get_or_create(p)
            with d.atomic_lock:
                c = d.content
                if c is not None:
                    if p != just_loaded:      # the retry after our own
                        self.lru.stats_hits += 1   # miss load is not a hit
                        if d.prefetched:      # first demand-hit on a
                            d.prefetched = False   # readahead-loaded page
                            self.stats_readahead_hits += 1
                    d.accessed = True
                    pstart = p * ps
                    s = pos - pstart
                    e = min(off + n - pstart, ps)
                    out[pos - off:pstart + e - off] = c.data[s:e]
                    pos = pstart + e
                    continue
            # miss: load the aligned extent covering p (takes its own
            # locks), then retry this page — it can in principle be evicted
            # again before the retry, in which case the loop reloads it
            self._load_extent(f, p)
            just_loaded = p
        return bytes(out)

    def _extent_range(self, f: File, p: int) -> tuple:
        """Readahead window [e0, e1) around page ``p``: up to
        ``Policy.readahead_pages`` pages (clamped to half the read cache so
        a load can never flush the cache it feeds), clipped to the file's
        last page.

        Readahead opens only for a *sequential* miss stream (``p`` is the
        page the previous miss predicted, kernel-style): a random miss
        loads just its own page, so random workloads never pay device cost
        for 7 prefetched pages they will evict unused.

        With ``Policy.readahead_ramp`` (the default) the window *ramps*
        like the kernel's: the first sequential miss after a reset loads 2
        pages, then 4, then 8 ... up to the cap, and any random miss
        resets the ramp — a short sequential burst pays for 2-4 pages
        instead of the full window it would never use.  ``ramp=False``
        keeps the PR-3 behavior: the full aligned window on the first
        sequential miss."""
        cap = min(self.policy.readahead_pages, max(1, self.lru.capacity // 2))
        if cap <= 1 or p != f.ra_next:
            f.ra_next = p + 1
            f.ra_window = 1                   # random miss: reset the ramp
            return p, p + 1
        with f.size_lock:
            size = f.size
        last = (size - 1) // self.policy.page_size if size > 0 else 0
        if self.policy.readahead_ramp:
            w = min(cap, max(2, 2 * f.ra_window))
            f.ra_window = w
            e0, e1 = p, max(p + 1, min(p + w, last + 1))
        else:
            e0 = (p // cap) * cap
            e1 = max(p + 1, min(e0 + cap, last + 1))
        f.ra_next = e1
        return e0, e1

    def _load_extent(self, f: File, p: int) -> None:
        """Cache-miss path, extent-granular (the read-side twin of the
        drain engine; paper Fig. 2 generalized from one page to one aligned
        extent): acquire buffers, one vectored backend read for the
        extent's uncached runs, then the per-page dirty-index replay —
        readahead NEVER bypasses the replay, so prefetched pages obey the
        same durable-linearizability rules as demand misses."""
        ps = self.policy.page_size
        e0, e1 = self._extent_range(f, p)
        descs = [f.radix.get_or_create(q) for q in range(e0, e1)]
        held = descs
        for d in descs:                       # ascending: same order writers use
            d.atomic_lock.acquire()
        try:
            need = [d for d in descs if d.content is None]
            if not any(d.page_no == p for d in need):
                return                        # raced: another reader loaded p
            # drop the locks of in-window pages that are already cached:
            # nothing below touches them, and holding them would stall
            # writers to those pages for a device-read latency
            needset = {id(d) for d in need}
            for d in descs:
                if id(d) not in needset:
                    d.atomic_lock.release()
            held = need
            self.lru.stats_misses += 1
            if len(need) > 1:
                self.stats_readahead_loads += 1
                self.stats_readahead_pages += len(need) - 1
            bufs = self.lru.acquire_buffers(len(need))
            for d in need:                    # ascending, after atomic locks
                d.cleanup_lock.acquire()
            try:
                # one backend operation: contiguous runs of missing pages
                # become the iovec segments (pages loaded/cached in between
                # are skipped, not re-read)
                iov = []
                run_start = prev = None
                for d in need:
                    if prev is not None and d.page_no == prev + 1:
                        prev = d.page_no
                        continue
                    if run_start is not None:
                        iov.append(((prev - run_start + 1) * ps, run_start * ps))
                    run_start = prev = d.page_no
                iov.append(((prev - run_start + 1) * ps, run_start * ps))
                preadv = getattr(f.backend, "preadv", None)
                if preadv is not None:
                    chunks = preadv(iov)
                else:
                    chunks = [f.backend.pread(nn, oo) for nn, oo in iov]
                raw_by_page = {}
                for (nn, oo), chunk in zip(iov, chunks):
                    for q in range(oo // ps, (oo + nn) // ps):
                        raw_by_page[q] = chunk[q * ps - oo:(q + 1) * ps - oo]
                for d, content in zip(need, bufs):
                    raw = raw_by_page[d.page_no]
                    content.data[:len(raw)] = raw
                    if len(raw) < ps:
                        content.data[len(raw):] = bytes(ps - len(raw))
                    self._replay_page(d, content)
                    self.lru.attach(d, content)
                    d.prefetched = d.page_no != p
            finally:
                for d in reversed(need):
                    d.cleanup_lock.release()
        finally:
            for d in reversed(held):
                d.atomic_lock.release()

    def _replay_page(self, d, content) -> None:
        """Dirty-miss replay under the page's cleanup lock: apply ONLY this
        page's live entries from the dirty-page index, already in commit
        (seq) order — O(E) for E entries on the page, where the
        dirty-counter design had to rescan the whole log.  All of a page's
        entries live in one shard (overlap routing), and holding
        cleanup_lock means none of them can be retired/recycled mid-replay,
        so ref_payload reads are stable."""
        refs = d.snapshot_refs()
        if not refs:
            return
        ps = self.policy.page_size
        base = d.page_no * ps
        self.stats_dirty_misses += 1
        self.stats_replay_entries += len(refs)
        for ref in refs:
            edata = self.log.ref_payload(ref)
            s = max(ref.off, base)
            t = min(ref.off + ref.length, base + ps)
            if s < t:
                content.data[s - base:t - base] = edata[s - ref.off:t - ref.off]

    def read(self, fd: int, n: int) -> bytes:
        of = self._of(fd)
        with of.cursor_lock:
            out = self.pread(fd, n, of.cursor)
            of.cursor += len(out)
            return out

    # ----------------------------------------------------- metadata (§II-C)
    def fsync(self, fd: int) -> None:
        """No-op: writes are already synchronously durable (Table III)."""
        self._of(fd)

    # -- durable namespace ops (core/namespace.py): each quiesces the
    #    touched file(s) behind the drain barrier, journals the op as a
    #    committed NVMM log entry, then applies the backend effect — so an
    #    acknowledged rename/unlink/ftruncate survives any crash, and
    #    recovery's seq-merge replays it old-or-new, never torn.
    def _lookup_closed_locked(self, path: str) -> Optional[File]:
        """The File at ``path`` verified to have no open descriptors
        (namespace ops refuse open files — the legacy protocols we model
        close before rename/unlink).  Caller holds ``_meta``."""
        f = self._files.get(path)
        if f is not None and f.refs > 0:
            raise OSError(f"{path} is open (EBUSY)")
        return f

    def unlink(self, path: str) -> None:
        """Remove ``path`` (the SQLite rollback-journal commit point).

        The journal record commits BEFORE the backend unlink, so a crash
        at any point leaves the file either present (op not acknowledged)
        or durably gone — its bytes can never resurrect: recovery replays
        the unlink at a seq above every covered data entry.

        POSIX unlink-while-open: with live descriptors the *name* is
        removed now and the file turns anonymous — reads/writes through
        open fds keep working, the file is reclaimed at its last close,
        and after a crash it is simply gone (its post-unlink writes are
        dropped as orphans: the fd-table slot is cleared with the name).
        This is what lets SQLite delete a hot journal without first paying
        a close barrier, and what makes the journal's drain skip the
        backend fsync entirely (see ``File.unlinked``)."""
        self.check()
        with self._meta:
            f = self._files.get(path)
            if f is None and not self.tier.exists(path):
                raise FileNotFoundError(path)
            marks, mseq = self.ns.journal(
                MOP_UNLINK, f.fdid if f is not None else META_NO_FDID,
                0, path)
            try:
                if f is not None:
                    f.unlinked = True
                    self._files.pop(path, None)    # fdid stays bound
                    # undrained and post-unlink entries die with a crash
                    # (POSIX): clearing the slot makes recovery drop them
                    # as orphans instead of re-creating the dead name —
                    # the unlink record above outranks them all by seq
                    self.log.fd_table_set(f.fdid, "")
                self.tier.unlink(path)
                self.ns.note_backend_applied(mseq)
                if f is not None:
                    # closed and already drained: reclaim on the spot;
                    # otherwise the drain's reap (or the flush sweep)
                    # retires it once its entries are consumed
                    self._maybe_retire_locked(f)
            finally:
                self.ns.mark_applied(marks)
        self.check()

    def rename(self, old: str, new: str) -> None:
        """Atomically move ``old`` over ``new`` (the RocksDB MANIFEST
        install).  Both paths must have no open descriptors; an existing
        ``new`` is replaced, and after recovery the data is attributed to
        exactly one of the two names — never both, never neither."""
        self.check()
        if old == new:
            with self._meta:
                if (self._files.get(old) is None
                        and not self.tier.exists(old)):
                    raise FileNotFoundError(old)
            return
        deadline = time.monotonic() + 120.0
        while True:
            with self._meta:
                fo = self._lookup_closed_locked(old)
                fn = self._lookup_closed_locked(new)
                if fo is None and not self.tier.exists(old):
                    raise FileNotFoundError(old)
                stale = fo if (fo is not None and fo.pending.get() > 0) \
                    else (fn if (fn is not None and fn.pending.get() > 0)
                          else None)
                if stale is None:
                    marks, mseq = self.ns.journal(
                        MOP_RENAME,
                        fo.fdid if fo is not None else META_NO_FDID, 0,
                        old, new)
                    try:
                        if fo is not None:
                            self._maybe_retire_locked(fo)
                        if fn is not None:
                            self._maybe_retire_locked(fn)
                        self.tier.rename(old, new)
                        self.ns.note_backend_applied(mseq)
                    finally:
                        self.ns.mark_applied(marks)
                    self.check()
                    return
            self._drain_barrier(stale, "rename")
            if time.monotonic() > deadline:
                raise TimeoutError(f"rename {old} -> {new} could not quiesce")

    def ftruncate(self, fd: int, length: int) -> None:
        """Set the open file's length (SQLite WAL reset).  Journaled like
        rename/unlink; shrinking purges cached/dirty state beyond the new
        length so cut bytes never resurrect, growing zero-fills."""
        of = self._of(fd)
        if of.flags & _ACCMODE == O_RDONLY:
            raise OSError("fd is read-only")
        if length < 0:
            raise OSError("negative length (EINVAL)")
        self._truncate_file(of.file, length)
        self.check()

    def flock(self, fd: int, unlock: bool = False) -> None:
        """Advisory lock hook (paper §I): releasing a lock flushes this
        file's pending writes to the kernel so other processes see them."""
        of = self._of(fd)
        if unlock:
            self._drain_barrier(of.file, "flock release")

    def lseek(self, fd: int, off: int, whence: int = os.SEEK_SET) -> int:
        of = self._of(fd)
        with of.cursor_lock:
            if whence == os.SEEK_SET:
                target = off
            elif whence == os.SEEK_CUR:
                target = of.cursor + off
            elif whence == os.SEEK_END:
                with of.file.size_lock:
                    target = of.file.size + off
            else:
                raise OSError("bad whence")
            if target < 0:
                raise OSError("negative seek (EINVAL)")  # cursor unchanged
            of.cursor = target
            return of.cursor

    def stat_size(self, fd_or_path) -> int:
        if isinstance(fd_or_path, int):
            f = self._of(fd_or_path).file
        else:
            f = self._files.get(fd_or_path)
            if f is None:
                # stat must not mutate the namespace: Tier.open inserts on
                # miss, which used to create an empty phantom file here
                size_of = getattr(self.tier, "size_of", None)
                if size_of is not None:
                    return size_of(fd_or_path)   # raises FileNotFoundError
                if not self.tier.exists(fd_or_path):
                    raise FileNotFoundError(fd_or_path)
                return self.tier.open(fd_or_path).size()
        with f.size_lock:
            return f.size

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {
            "shards": self.policy.shards,
            "log_used": self.log.used_entries,
            "dirty_misses": self.stats_dirty_misses,
            "replay_entries": self.stats_replay_entries,
            "log_full_scans": self.log.stats_full_scans,
            "lru_hits": self.lru.stats_hits,
            "lru_misses": self.lru.stats_misses,
            "lru_evictions": self.lru.stats_evictions,
            "readahead_loads": self.stats_readahead_loads,
            "readahead_pages": self.stats_readahead_pages,
            "readahead_hits": self.stats_readahead_hits,
            "readahead_hit_rate": self.stats_readahead_hits
                / max(1, self.stats_readahead_pages),
            "cleanup_batches": self.cleanup.stats_batches,
            "cleanup_entries": self.cleanup.stats_entries,
            "cleanup_fsyncs": self.cleanup.stats_fsyncs,
            "cleanup_fsyncs_issued": self.cleanup.stats_fsyncs_issued,
            "cleanup_fsyncs_merged": self.cleanup.stats_fsyncs_merged,
            "drain_extents": self.cleanup.stats_extents,
            "drain_pwritevs": self.cleanup.stats_pwritevs,
            "drain_deferred": self.cleanup.stats_deferred,
            "drain_span_merges": self.cleanup.stats_span_merges,
            "nvmm_psyncs": self.nvmm.stats_psync,
            "alloc_wait_s": sum(sh.stats_alloc_wait_s
                                for sh in self.log.shards),
            "route_epoch": self.router.epoch if self.router else 0,
            "route_overrides": len(self.router.table) if self.router else 0,
            "route_migrations": (self.cleanup.rebalancer.stats_migrations
                                 if self.cleanup.rebalancer else 0),
            "route_skew_ratio": (self.router.stats_skew_ratio
                                 if self.router else 0.0),
            "route_skipped_uneconomic": (self.router.stats_skipped_uneconomic
                                         if self.router else 0),
            "meta_ops": dict(self.ns.stats_meta_ops),
            "meta_entries": self.ns.stats_meta_entries,
        }
