"""NVCache POSIX-like facade (paper §II-A, §III, Table III).

``NVCache`` is the interception boundary: components open files and call
``read/write/pread/pwrite/lseek/stat/fsync/close`` exactly as they would
against libc, and transparently get

  * synchronous durability — ``write`` returns only once the data is
    committed in the NVMM log (paper Alg. 1),
  * durable linearizability — a write is visible to a reader only when it
    is durable (the psync before the per-page lock release),
  * asynchronous propagation to the slow tier via the per-shard drain pool
    and its page-coalescing plan/apply engine (:mod:`repro.core.drain`),
  * ``fsync`` as a no-op (Table III: writes are already durable),
  * user-space file size/cursor (the kernel's may be stale, §II-C).

One instance == one NVMM region (one "DAX file"); several instances can
coexist on separate regions (paper §III Multi-application).
"""
from __future__ import annotations

import os
import threading
from typing import Dict, Optional

from repro.core.cleanup import CleanupPool
from repro.core.log import NVLog
from repro.core.nvmm import NVMM
from repro.core.policy import Policy
from repro.core.readcache import AtomicInt, LRUCache, RadixTree
from repro.core import recovery as _recovery

O_RDONLY, O_WRONLY, O_RDWR = os.O_RDONLY, os.O_WRONLY, os.O_RDWR
O_CREAT, O_APPEND, O_TRUNC = os.O_CREAT, os.O_APPEND, os.O_TRUNC
_ACCMODE = os.O_ACCMODE


class File:
    """Per-(device,inode) state (paper §III "Open": the file table)."""

    __slots__ = ("path", "fdid", "backend", "radix", "size", "size_lock",
                 "refs", "pending", "shards_touched", "_drained")

    def __init__(self, path: str, fdid: int, backend):
        self.path = path
        self.fdid = fdid
        self.backend = backend
        self.radix: Optional[RadixTree] = None   # created on first write-open
        self.size = backend.size()
        self.size_lock = threading.Lock()
        self.refs = 0
        self.pending = AtomicInt(0)              # log entries not yet drained
        self.shards_touched: set = set()         # sids holding entries for us
        self._drained = threading.Condition()

    def note_drained(self, n: int) -> None:      # called by the cleanup thread
        self.pending.dec(n)
        with self._drained:
            self._drained.notify_all()

    def wait_drained(self, timeout: Optional[float] = None) -> bool:
        with self._drained:
            return self._drained.wait_for(lambda: self.pending.get() <= 0,
                                          timeout=timeout)


class OpenFile:
    """Per-descriptor state (paper §III: the opened table / cursor)."""

    __slots__ = ("file", "flags", "cursor", "cursor_lock")

    def __init__(self, file: File, flags: int):
        self.file = file
        self.flags = flags
        self.cursor = 0
        self.cursor_lock = threading.Lock()


class NVCache:
    def __init__(self, policy: Policy, tier, *, nvmm: Optional[NVMM] = None,
                 track_crashes: bool = False, recover: bool = True):
        self.policy = policy
        self.tier = tier
        self.nvmm = nvmm or NVMM(policy.nvmm_bytes, track=track_crashes)
        if recover and nvmm is not None:
            try:
                self.recovery_stats = _recovery.recover(self.nvmm, policy, tier.open)
            except ValueError:
                self.recovery_stats = None     # fresh region
                NVLog(self.nvmm, policy, format=True)
            self.log = NVLog(self.nvmm, policy, format=False)
        else:
            self.recovery_stats = None
            self.log = NVLog(self.nvmm, policy, format=True)

        self.lru = LRUCache(policy.read_cache_pages, policy.page_size)
        self._files: Dict[str, File] = {}
        self._by_fdid: Dict[int, File] = {}
        self._open: Dict[int, OpenFile] = {}
        self._next_fd = 3
        self._meta = threading.Lock()
        self._fdid_free = list(range(policy.fd_max - 1, -1, -1))
        self.cleanup = CleanupPool(self.log, self._resolve_fdid)
        self.cleanup.start()
        self._crashed = False
        self.stats_dirty_misses = 0
        self.stats_replay_entries = 0   # refs inspected across dirty misses

    # ------------------------------------------------------------- lifecycle
    def _resolve_fdid(self, fdid: int) -> Optional[File]:
        return self._by_fdid.get(fdid)

    def check(self) -> None:
        if self.cleanup.error is not None:
            raise RuntimeError("cleanup thread died") from self.cleanup.error
        if self._crashed:
            raise RuntimeError("instance crashed")

    def shutdown(self) -> None:
        """Graceful: drain the log, stop the cleanup thread."""
        self.cleanup.shutdown()
        self.check()

    def crash(self, choose_evicted=None) -> NVMM:
        """Simulated power loss; returns the NVMM region for recovery."""
        self._crashed = True
        self.cleanup.power_loss()
        if self.nvmm.track:
            self.nvmm.crash(choose_evicted)
        return self.nvmm

    def flush(self, timeout: Optional[float] = 60.0) -> None:
        """Drain the whole log to the slow tier (used as a barrier)."""
        self.cleanup.request_drain()
        try:
            for f in list(self._files.values()):
                if not f.wait_drained(timeout=timeout):
                    raise TimeoutError(f"drain of {f.path} timed out")
        finally:
            self.cleanup.end_drain()
        self.check()

    # ------------------------------------------------------------------ open
    def open(self, path: str, flags: int = O_RDWR | O_CREAT) -> int:
        self.check()
        accmode = flags & _ACCMODE
        with self._meta:
            f = self._files.get(path)
            if f is None:
                backend = self.tier.open(path)
                if not self._fdid_free:
                    raise OSError("fd table full")
                fdid = self._fdid_free.pop()
                self.log.fd_table_set(fdid, path)   # durable path for recovery
                f = File(path, fdid, backend)
                self._files[path] = f
                self._by_fdid[fdid] = f
            if accmode != O_RDONLY and f.radix is None:
                f.radix = RadixTree()               # read cache only for writers
            f.refs += 1
            fd = self._next_fd
            self._next_fd += 1
            of = OpenFile(f, flags)
            self._open[fd] = of
        if flags & O_TRUNC and accmode != O_RDONLY:
            with f.size_lock:
                f.size = 0
            f.backend.truncate(0)
        return fd

    def close(self, fd: int) -> None:
        """Flush this file's pending writes to the kernel, then close
        (paper §I: coherence across processes via flush-on-close).  Only the
        shards this file actually touched are asked to drain."""
        of = self._pop_fd(fd)
        f = of.file
        touched = set(f.shards_touched)
        self.cleanup.request_drain(touched)
        try:
            if not f.wait_drained(timeout=60.0):
                raise TimeoutError(f"drain of {f.path} timed out on close")
        finally:
            self.cleanup.end_drain(touched)
        with self._meta:
            f.refs -= 1
            if f.refs == 0:
                self._files.pop(f.path, None)
                self._by_fdid.pop(f.fdid, None)
                self.log.fd_table_set(f.fdid, "")   # retire the NVMM slot
                self._fdid_free.append(f.fdid)
                f.backend.close()
        self.check()

    def _pop_fd(self, fd: int) -> OpenFile:
        with self._meta:
            of = self._open.pop(fd, None)
        if of is None:
            raise OSError(f"bad fd {fd}")
        return of

    def _of(self, fd: int) -> OpenFile:
        of = self._open.get(fd)
        if of is None:
            raise OSError(f"bad fd {fd}")
        return of

    # ----------------------------------------------------------------- write
    def pwrite(self, fd: int, data: bytes, off: int) -> int:
        of = self._of(fd)
        if of.flags & _ACCMODE == O_RDONLY:
            raise OSError("fd is read-only")
        if off < 0:
            raise OSError("negative offset (EINVAL)")
        f = of.file
        if not data:
            return 0
        pol = self.policy
        max_op = (pol.entries_per_shard - 1) * pol.entry_data
        split_stripes = pol.shards > 1 and pol.shard_route == "stripe"
        written = 0
        view = memoryview(data)
        while written < len(data):
            lim = max_op
            if split_stripes:
                # ops never span a stripe: overlapping writes always route to
                # the same shard, keeping per-location order a shard-local
                # property (see core/log.py docstring)
                sb = pol.stripe_bytes
                lim = min(lim, sb - (off + written) % sb)
            chunk = view[written:written + lim]
            self._pwrite_op(f, bytes(chunk), off + written)
            written += len(chunk)
        return len(data)

    def _pwrite_op(self, f: File, data: bytes, off: int) -> None:
        """One atomic write op == one committed entry group (Alg. 1)."""
        ps = self.policy.page_size
        n = len(data)
        p0, p1 = off // ps, (off + max(n, 1) - 1) // ps
        descs = [f.radix.get_or_create(p) for p in range(p0, p1 + 1)]

        def register(sid: int, head: int, k: int, seq: int) -> None:
            # runs between log allocation and commit: the refs are in the
            # dirty-page index before the drain can possibly see (and try
            # to retire) the entries.  shard membership likewise becomes
            # visible before the pending count below can, so a concurrent
            # close() that sees pending > 0 also sees the shard id.
            f.shards_touched.add(sid)
            for ref in self.log.group_refs(sid, head, k, seq, off, n):
                r1 = (ref.off + max(ref.length, 1) - 1) // ps
                for p in range(ref.off // ps, r1 + 1):
                    descs[p - p0].add_ref(ref)

        for d in descs:                       # ascending page order: no deadlock
            d.atomic_lock.acquire()
        try:
            sid, head, k, seq = self.log.append(f.fdid, off, data,
                                                on_alloc=register)  # durable
            f.pending.inc(k)
            # update loaded pages so reads stay fresh (Alg. 1 lines 29-31)
            for d in descs:
                if d.content is not None:
                    pstart = d.page_no * ps
                    s = max(off, pstart)
                    e = min(off + n, pstart + ps)
                    if s < e:
                        d.content.data[s - pstart:e - pstart] = data[s - off:e - off]
                d.accessed = True
            with f.size_lock:
                if off + n > f.size:
                    f.size = off + n
        finally:
            for d in reversed(descs):
                d.atomic_lock.release()

    def write(self, fd: int, data: bytes) -> int:
        of = self._of(fd)
        f = of.file
        with of.cursor_lock:
            if of.flags & O_APPEND:
                with f.size_lock:
                    off = f.size
                    f.size = off + len(data)
            else:
                off = of.cursor
            n = self.pwrite(fd, data, off)
            of.cursor = off + n
            return n

    # ------------------------------------------------------------------ read
    def pread(self, fd: int, n: int, off: int) -> bytes:
        of = self._of(fd)
        if off < 0:
            raise OSError("negative offset (EINVAL)")
        f = of.file
        with f.size_lock:
            size = f.size
        if off >= size:
            return b""
        n = min(n, size - off)
        if f.radix is None:
            # read-only file: bypass the read cache entirely (§II-A) — the
            # kernel page cache is fresh because nothing is in flight.
            out = f.backend.pread(n, off)
            return out + b"\x00" * (n - len(out))
        return self._pread_cached(f, n, off)

    def _pread_cached(self, f: File, n: int, off: int) -> bytes:
        ps = self.policy.page_size
        out = bytearray(n)
        pos = off
        while pos < off + n:
            p = pos // ps
            d = f.radix.get_or_create(p)
            with d.atomic_lock:
                if d.content is None:
                    self._load_page(f, d)     # miss path
                else:
                    self.lru.stats_hits += 1
                d.accessed = True
                pstart = p * ps
                s = pos - pstart
                e = min(off + n - pstart, ps)
                out[pos - off:pstart + e - off] = d.content.data[s:e]
                pos = pstart + e
        return bytes(out)

    def _load_page(self, f: File, d) -> None:
        """Cache-miss path (Fig. 2): evict, pread, dirty-miss replay."""
        ps = self.policy.page_size
        self.lru.stats_misses += 1
        content = self.lru.acquire_buffer()
        with d.cleanup_lock:                  # block cleanup for this page
            base = d.page_no * ps
            raw = f.backend.pread(ps, base)
            content.data[:len(raw)] = raw
            if len(raw) < ps:
                content.data[len(raw):] = bytes(ps - len(raw))
            refs = d.snapshot_refs()
            if refs:
                # dirty miss: replay ONLY this page's live entries from the
                # dirty-page index, already in commit (seq) order — O(E) for
                # E entries on the page, where the dirty-counter design had
                # to rescan the whole log.  All of a page's entries live in
                # one shard (overlap routing), and holding cleanup_lock
                # means none of them can be retired/recycled mid-replay, so
                # ref_payload reads are stable.
                self.stats_dirty_misses += 1
                self.stats_replay_entries += len(refs)
                for ref in refs:
                    edata = self.log.ref_payload(ref)
                    s = max(ref.off, base)
                    t = min(ref.off + ref.length, base + ps)
                    if s < t:
                        content.data[s - base:t - base] = \
                            edata[s - ref.off:t - ref.off]
            self.lru.attach(d, content)

    def read(self, fd: int, n: int) -> bytes:
        of = self._of(fd)
        with of.cursor_lock:
            out = self.pread(fd, n, of.cursor)
            of.cursor += len(out)
            return out

    # ----------------------------------------------------- metadata (§II-C)
    def fsync(self, fd: int) -> None:
        """No-op: writes are already synchronously durable (Table III)."""
        self._of(fd)

    def flock(self, fd: int, unlock: bool = False) -> None:
        """Advisory lock hook (paper §I): releasing a lock flushes this
        file's pending writes to the kernel so other processes see them."""
        of = self._of(fd)
        if unlock:
            touched = set(of.file.shards_touched)
            self.cleanup.request_drain(touched)
            try:
                if not of.file.wait_drained(timeout=60.0):
                    raise TimeoutError(f"flock drain of {of.file.path} timed out")
            finally:
                self.cleanup.end_drain(touched)

    def lseek(self, fd: int, off: int, whence: int = os.SEEK_SET) -> int:
        of = self._of(fd)
        with of.cursor_lock:
            if whence == os.SEEK_SET:
                target = off
            elif whence == os.SEEK_CUR:
                target = of.cursor + off
            elif whence == os.SEEK_END:
                with of.file.size_lock:
                    target = of.file.size + off
            else:
                raise OSError("bad whence")
            if target < 0:
                raise OSError("negative seek (EINVAL)")  # cursor unchanged
            of.cursor = target
            return of.cursor

    def stat_size(self, fd_or_path) -> int:
        if isinstance(fd_or_path, int):
            f = self._of(fd_or_path).file
        else:
            f = self._files.get(fd_or_path)
            if f is None:
                return self.tier.open(fd_or_path).size()
        with f.size_lock:
            return f.size

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {
            "shards": self.policy.shards,
            "log_used": self.log.used_entries,
            "dirty_misses": self.stats_dirty_misses,
            "replay_entries": self.stats_replay_entries,
            "log_full_scans": self.log.stats_full_scans,
            "lru_hits": self.lru.stats_hits,
            "lru_misses": self.lru.stats_misses,
            "lru_evictions": self.lru.stats_evictions,
            "cleanup_batches": self.cleanup.stats_batches,
            "cleanup_entries": self.cleanup.stats_entries,
            "cleanup_fsyncs": self.cleanup.stats_fsyncs,
            "cleanup_fsyncs_issued": self.cleanup.stats_fsyncs_issued,
            "cleanup_fsyncs_merged": self.cleanup.stats_fsyncs_merged,
            "drain_extents": self.cleanup.stats_extents,
            "drain_pwritevs": self.cleanup.stats_pwritevs,
            "nvmm_psyncs": self.nvmm.stats_psync,
        }
