"""The drain pool (paper §II-A step 6, §III "Cleanup thread and batching"),
one drain thread per log shard.

Each :class:`CleanupThread` consumes committed entries in log order from its
shard's persistent tail and propagates them to the slow tier through
ordinary ``pwrite`` calls (the writes land in the kernel page cache, which
write-combines them — the paper's "volatile write cache behind a durable
write cache"), then one ``fsync`` per touched file per batch, then durably
retires the batch (zero commit flags, advance the shard's persistent tail,
pwb/pfence, advance the volatile tail).  Because any two overlapping writes
are routed to the same shard (see :mod:`repro.core.log`), independent
per-shard drains cannot reorder conflicting updates, and K shards drain to
the slow tier concurrently.

Batching (paper §IV-C): each drainer waits for at least ``batch_min``
committed entries in its shard unless a drain is requested (close/flush/
log-full backpressure), and consumes at most ``batch_max`` — the shared
:class:`~repro.core.policy.Policy` bounds are the pool's common
backpressure contract.

:class:`CleanupPool` owns the threads and lets callers target a drain at
just the shards a file actually touched (``fsync``/``close`` wait only on
those) or at every shard (``flush``).
"""
from __future__ import annotations

import threading
from typing import Callable, Iterable, Optional

from repro.core.log import LogShard, NVLog


class CleanupThread(threading.Thread):
    """Drains one shard (the paper's cleanup thread when K == 1)."""

    def __init__(self, log: NVLog, shard: LogShard,
                 resolve_file: Callable[[int], Optional[object]],
                 *, name: Optional[str] = None):
        super().__init__(name=name or f"nvcache-drain-{shard.sid}", daemon=True)
        self.log = log
        self.shard = shard
        self.resolve_file = resolve_file      # fdid -> File (api.File) or None
        self.drain_event = threading.Event()  # ignore batch_min
        self.stop_event = threading.Event()   # finish current batch, then exit
        self.hard_stop = threading.Event()    # simulated power loss: exit NOW
        self._drain_count = 0                 # nested drain requests
        self._drain_lock = threading.Lock()
        self.error: Optional[BaseException] = None
        self.stats_batches = 0
        self.stats_entries = 0
        self.stats_fsyncs = 0

    def run(self) -> None:
        try:
            while not self.hard_stop.is_set():
                min_needed = 1 if self.drain_event.is_set() else self.log.policy.batch_min
                run = self.shard.wait_committed(min_needed,
                                               drain_event=self.drain_event,
                                               stop_event=self.stop_event)
                if run == 0:
                    if self.stop_event.is_set() or self.hard_stop.is_set():
                        return
                    continue
                self._consume_batch(run)
        except BaseException as exc:  # surfaces in api.check()
            self.error = exc

    # ------------------------------------------------------------------
    def _consume_batch(self, run: int) -> None:
        shard = self.shard
        ps = self.log.policy.page_size
        start = shard.persistent_tail
        touched = {}          # File -> n_entries drained for it
        for e in shard.scan_committed(start, start + run):
            if self.hard_stop.is_set():
                return        # power loss mid-batch: nothing retired, log replays
            f = self.resolve_file(e.fdid)
            if f is None:     # orphan (file force-closed); drop the entry
                continue
            p0, p1 = e.off // ps, (e.off + max(e.length, 1) - 1) // ps
            descs = []
            if f.radix is not None:
                for p in range(p0, p1 + 1):
                    d = f.radix.get_or_create(p)
                    d.cleanup_lock.acquire()   # block dirty-miss readers (§II-D)
                    descs.append(d)
            try:
                f.backend.pwrite(bytes(e.data), e.off)
                for d in descs:
                    d.dirty.dec()              # may transiently go negative (fn. 4)
            finally:
                for d in descs:
                    d.cleanup_lock.release()
            touched[f] = touched.get(f, 0) + 1
            self.stats_entries += 1
        if self.hard_stop.is_set():
            return
        for f in touched:
            f.backend.fsync()                  # one fsync per file per batch
            self.stats_fsyncs += 1
        shard.consume(start, run)              # durably retire the batch
        for f, n in touched.items():
            f.note_drained(n)
        self.stats_batches += 1

    # ------------------------------------------------------------------
    def request_drain(self) -> None:
        with self._drain_lock:
            self._drain_count += 1
            self.drain_event.set()
        self.shard.notify_committed()

    def end_drain(self) -> None:
        with self._drain_lock:
            self._drain_count = max(0, self._drain_count - 1)
            if self._drain_count == 0:
                self.drain_event.clear()

    def shutdown(self) -> None:
        """Graceful: drain everything, then stop."""
        self.request_drain()
        self.stop_event.set()
        self.shard.notify_committed()
        self.join(timeout=60)

    def power_loss(self) -> None:
        """Simulated crash: the thread dies wherever it is."""
        self.hard_stop.set()
        self.stop_event.set()
        self.shard.notify_committed()
        self.join(timeout=60)


class CleanupPool:
    """One drain thread per shard, addressed collectively or per shard."""

    def __init__(self, log: NVLog,
                 resolve_file: Callable[[int], Optional[object]]):
        self.log = log
        self.threads = [CleanupThread(log, sh, resolve_file)
                        for sh in log.shards]

    def start(self) -> None:
        for t in self.threads:
            t.start()

    def _targets(self, shards: Optional[Iterable[int]]):
        if shards is None:
            return self.threads
        return [self.threads[s] for s in sorted(set(shards))]

    def request_drain(self, shards: Optional[Iterable[int]] = None) -> None:
        for t in self._targets(shards):
            t.request_drain()

    def end_drain(self, shards: Optional[Iterable[int]] = None) -> None:
        for t in self._targets(shards):
            t.end_drain()

    def shutdown(self) -> None:
        for t in self.threads:
            t.shutdown()

    def power_loss(self) -> None:
        for t in self.threads:
            t.hard_stop.set()
            t.stop_event.set()
            t.shard.notify_committed()
        for t in self.threads:
            t.join(timeout=60)

    # ------------------------------------------------------------- status
    @property
    def error(self) -> Optional[BaseException]:
        for t in self.threads:
            if t.error is not None:
                return t.error
        return None

    @property
    def stats_batches(self) -> int:
        return sum(t.stats_batches for t in self.threads)

    @property
    def stats_entries(self) -> int:
        return sum(t.stats_entries for t in self.threads)

    @property
    def stats_fsyncs(self) -> int:
        return sum(t.stats_fsyncs for t in self.threads)
