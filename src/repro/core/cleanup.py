"""The drain pool (paper §II-A step 6, §III "Cleanup thread and batching"),
one drain thread per log shard, draining through the page-coalescing
plan/apply engine of :mod:`repro.core.drain`.

Each :class:`CleanupThread` consumes committed entries in log order from its
shard's persistent tail.  Where the paper forwards them to the slow tier one
``pwrite`` per entry and relies on the kernel page cache to write-combine
(§IV-C), we build an explicit :class:`~repro.core.drain.DrainPlan` — entries
grouped by (file, page), merged into page images, coalesced into extents —
and apply it with vectored writes, so each dirty backend page is written at
most once per batch.  Then one fsync per touched file per batch, routed
through the pool's cross-shard :class:`~repro.core.drain.FsyncEpochScheduler`
(concurrent per-shard fsyncs of the same backend file merge into one), and
only then is the batch durably retired (zero commit flags, advance the
shard's persistent tail, pwb/pfence, advance the volatile tail).  Because
any two overlapping writes are routed to the same shard (see
:mod:`repro.core.log`), independent per-shard drains cannot reorder
conflicting updates, and K shards drain to the slow tier concurrently.

Batching (paper §IV-C): each drainer waits for at least ``batch_min``
committed entries in its shard unless a drain is requested (close/flush/
log-full backpressure), and consumes at most ``batch_max`` — the shared
:class:`~repro.core.policy.Policy` bounds are the pool's common
backpressure contract.

Batch-*spanning* coalescing (beyond paper; cf. NVLog's open tail extent):
a batch may leave its contiguous tail extent — the still-filling tail page
— unconsumed (:func:`repro.core.drain.choose_deferred_suffix`), so the
next batch's contiguous entries merge into the same backend write instead
of re-writing the page per tiny batch.  The carry is closed by fresh
non-contiguous entries, by ``Policy.coalesce_deadline_ms``, by log-space
pressure, or by any drain barrier; carried entries remain committed in the
log with live dirty-page-index refs, so reads and recovery are untouched.

:class:`CleanupPool` owns the threads and lets callers target a drain at
just the shards a file actually touched (``fsync``/``close`` wait only on
those) or at every shard (``flush``).

With ``Policy.shard_rebalance`` the pool also owns the
:class:`RebalanceThread`: every ``Policy.rebalance_epoch_ms`` it samples
per-shard load (:meth:`repro.core.log.LogShard.load_sample` — live entries,
drain backlog, allocation-wait time) plus the router's per-key append
counters, asks :meth:`repro.core.router.EpochRouter.plan` for migrations,
and executes each through the owner's ``migrate`` callback
(:meth:`repro.core.api.NVCache._migrate_route`: freeze the file's route
gate, run the per-file drain barrier, install the new epoch).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Iterable, Optional

from repro.core import drain as _drain
from repro.core import locking
from repro.core.drain import FsyncEpochScheduler
from repro.core.log import CG_HEAD, META_FDID, LogShard, NVLog
from repro.obs import flight as _obs_flight


class CleanupThread(threading.Thread):
    """Drains one shard (the paper's cleanup thread when K == 1)."""

    GUARDED_BY = {
        "_drain_count": "_drain_lock",
        # the span carry is drain-thread-confined: only run() touches it,
        # and start()/join() order everything else against it
        "_span_deferred": None, "_span_oldest": None, "_span_since": None,
        "_span_maxidx": None, "_span_carry_batches": None,
        # single-writer per-thread counters, folded at read by the pool's
        # summing properties; a live read (api.stats() mid-run) sees a
        # monotonic approximation by design, exact after join()
        "error": locking.VOLATILE,
        "stats_batches": locking.VOLATILE, "stats_entries": locking.VOLATILE,
        "stats_fsyncs": locking.VOLATILE, "stats_extents": locking.VOLATILE,
        "stats_pwritevs": locking.VOLATILE,
        "stats_deferred": locking.VOLATILE,
        "stats_span_merges": locking.VOLATILE,
        # observability plane handle: set once before start() (publication
        # ordered by thread creation), internally synchronized
        "obs": locking.VOLATILE,
    }

    def __init__(self, log: NVLog, shard: LogShard,
                 resolve_file: Callable[[int], Optional[object]],
                 *, fsync_scheduler: Optional[FsyncEpochScheduler] = None,
                 meta_gate=None, reap: Optional[Callable] = None,
                 name: Optional[str] = None, obs=None):
        super().__init__(name=name or f"nvcache-drain-{shard.sid}", daemon=True)
        self.log = log
        self.shard = shard
        self.obs = obs                        # guarded-by: volatile (set
        #   before start(); see GUARDED_BY)
        self.resolve_file = resolve_file      # fdid -> File (api.File) or None
        self.fsync_scheduler = fsync_scheduler
        self.meta_gate = meta_gate            # namespace (or None): blocks
        #   consumption of committed-but-not-yet-applied metadata entries
        self.reap = reap                      # owner callback to reclaim a
        #   fully-drained anonymous (unlinked) file; must never block
        self.drain_event = threading.Event()  # ignore batch_min
        self.stop_event = threading.Event()   # finish current batch, then exit
        self.hard_stop = threading.Event()    # simulated power loss: exit NOW
        self.fault_hook: Optional[Callable[[str], None]] = None
        # ^ test-only: called at every plan/apply checkpoint (tag), may set
        #   hard_stop to simulate power loss at that exact drain point
        self._drain_count = 0                 # guarded-by: _drain_lock
        self._drain_lock = locking.make_lock("leaf:drain_gate")
        # batch-spanning coalescing: the carried (deferred, unconsumed)
        # tail-extent entries of the previous batch, their oldest log index
        # (the identity of the open extent) and when they were first carried
        # guarded-by: none — drain-thread-confined (ordered by start/join)
        self._span_deferred = 0
        self._span_oldest = -1
        self._span_since = 0.0
        self._span_maxidx = -1                # highest log idx ever carried
        self._span_carry_batches = 0          # batches feeding the open carry
        # guarded-by: volatile — single-writer (this thread); folded at
        # read by CleanupPool's properties, exact after join()
        self.error: Optional[BaseException] = None
        self.stats_batches = 0
        self.stats_entries = 0
        self.stats_fsyncs = 0                 # fsyncs *requested* (pre-merge)
        self.stats_extents = 0                # extent writes issued
        self.stats_pwritevs = 0               # vectored write calls issued
        self.stats_deferred = 0               # entries carried across batches
        self.stats_span_merges = 0            # batches that merged a carry

    def run(self) -> None:
        obs = self.obs
        lv2 = obs is not None and obs.prof.lv2
        try:
            while not self.hard_stop.is_set():
                min_needed = 1 if self.drain_event.is_set() else self.log.policy.batch_min
                deadline_at = None
                if self._span_deferred:
                    deadline_at = (self._span_since +
                                   self.log.policy.coalesce_deadline_ms / 1e3)
                t0 = time.perf_counter_ns() if lv2 else 0
                run = self.shard.wait_committed(min_needed,
                                               drain_event=self.drain_event,
                                               stop_event=self.stop_event,
                                               deferred=self._span_deferred,
                                               deadline_at=deadline_at)
                if lv2:
                    obs.prof.h_drain_wait.record_ns(
                        time.perf_counter_ns() - t0)
                if run == 0:
                    if self.stop_event.is_set() or self.hard_stop.is_set():
                        return
                    continue
                self._consume_batch(run)
        except BaseException as exc:  # surfaces in api.check()
            self.error = exc

    # ------------------------------------------------------------------
    def _abort(self, tag: str) -> bool:
        """Plan/apply checkpoint: power loss mid-batch leaves the log
        unconsumed, so recovery replays the whole batch (idempotent)."""
        if self.fault_hook is not None:
            self.fault_hook(tag)
        return self.hard_stop.is_set()

    def _clip_unapplied(self, start: int, run: int) -> int:
        """Stop the batch short of the first committed metadata entry whose
        backend effect is not applied yet (the journal→apply window of
        :mod:`repro.core.namespace`): consuming it would let a crash lose a
        namespace op the log still owes the backend.  The window is
        microseconds wide, so the clipped remainder drains on the next
        round."""
        for e in self.shard.scan_committed(start, start + run):
            if (e.cg == CG_HEAD and e.fdid == META_FDID
                    and self.meta_gate.meta_blocked(self.shard.sid, e.idx)):
                return e.idx - start
        return run

    def _consume_batch(self, run: int) -> None:
        shard = self.shard
        pol = self.log.policy
        start = shard.persistent_tail
        if self.meta_gate is not None and self.meta_gate.has_unapplied():
            # the drain's meta-apply path: a queued deferred apply (rename)
            # must not depend on its originating thread for progress — run
            # the queue here before clipping, so the blocking record is
            # usually already applied by the time we scan for it
            apply_deferred = getattr(self.meta_gate, "apply_deferred", None)
            if apply_deferred is not None:
                apply_deferred()
        if self.meta_gate is not None and self.meta_gate.has_unapplied():
            run = self._clip_unapplied(start, run)
            if run == 0:                      # blocked at the very tail:
                time.sleep(1e-3)              # wait out the apply window
                return
        # phase 0: batch-spanning coalescing — leave the contiguous tail
        # extent unconsumed (its consume/ref-retire deferred until it is
        # flushed) so the next batch's contiguous entries merge into one
        # backend write.  Everything below operates on the shortened run;
        # the deferred entries simply stay committed at the log tail.
        carried = self._span_deferred
        defer = self._choose_defer(run)
        eff = run - defer
        if eff == 0:                          # whole batch stays open
            self._note_deferred(start, run)
            return
        obs = self.obs
        lv2 = obs is not None and obs.prof.lv2
        # phase 1: group by (file, page), materialize images, coalesce extents
        t0 = time.perf_counter_ns() if lv2 else 0
        plan = _drain.build_plan(shard, start, eff, self.resolve_file, pol,
                                 abort=self._abort)
        if lv2:
            obs.prof.h_drain_plan.record_ns(time.perf_counter_ns() - t0)
        if plan is None:
            return
        # phase 2: extent writes under page cleanup locks + index retire
        t0 = time.perf_counter_ns() if lv2 else 0
        drained = _drain.apply_plan(plan, pol, abort=self._abort, stats=self)
        if lv2:
            obs.prof.h_drain_apply.record_ns(time.perf_counter_ns() - t0)
        if drained is None:
            return
        if self._abort(_drain.FSYNC):
            return
        t0 = time.perf_counter_ns() if lv2 else 0
        for f in drained:
            if getattr(f, "unlinked", False):
                continue    # anonymous (unlinked-while-open) file: its
                #             bytes die with the name on any crash, so
                #             device durability buys nothing — this skip is
                #             what makes deleting a hot journal cheap
            if getattr(f, "skip_drain_fsync", False):
                continue    # ftruncate(0) WAL-reset window: the journaled
                #             truncate (already committed, higher seq) will
                #             discard these bytes on any crash — same
                #             reasoning as the unlinked skip, scoped to the
                #             barrier the truncate itself runs

            self.stats_fsyncs += 1            # one request per file per batch
            if self.fsync_scheduler is not None:
                self.fsync_scheduler.fsync(f.backend)
            else:
                f.backend.fsync()
        if lv2:
            obs.prof.h_drain_fsync.record_ns(time.perf_counter_ns() - t0)
        if self._abort(_drain.CONSUME):
            return
        shard.consume(start, eff)             # durably retire the batch
        if obs is not None and obs.flight is not None:
            obs.flight.record(_obs_flight.EV_BATCH, shard.sid, start, eff)
        if self.meta_gate is not None and plan.meta_entries:
            self.meta_gate.note_consumed(shard.sid, start, eff)
        if carried and (run > carried or self._span_carry_batches > 1):
            # a real cross-batch write-combine: the plan joined carried
            # entries with newer ones, or flushed a carry that accumulated
            # over several batches — a lone carry flushed by the deadline
            # with nothing to merge does not count
            self.stats_span_merges += 1
        for f, n in drained.items():
            f.note_drained(n)
            if (self.reap is not None and getattr(f, "unlinked", False)
                    and f.refs == 0 and f.pending.get() <= 0):
                # last entries of a dead anonymous file just landed: give
                # the owner a chance to reclaim its fdid without waiting
                # for the next flush() sweep
                self.reap(f)
        self.stats_entries += sum(drained.values())
        self.stats_batches += 1
        self._note_deferred(start + eff, defer)

    def _choose_defer(self, run: int) -> int:
        """Entries of this batch to carry (see
        :func:`repro.core.drain.choose_deferred_suffix`), or 0 when a
        barrier forbids carrying: an explicit drain request (close/flush/
        fsync must make everything durable on the slow tier), shutdown, an
        expired carry deadline, or log-space pressure (writers may be
        blocked on recycling — the carry must never extend a log-full
        stall)."""
        pol = self.log.policy
        if not (pol.drain_coalesce and pol.coalesce_span_batches):
            return 0
        if (self.drain_event.is_set() or self.stop_event.is_set()
                or self.hard_stop.is_set()):
            return 0
        if (self._span_deferred
                and time.monotonic() - self._span_since
                >= pol.coalesce_deadline_ms / 1e3):
            return 0
        if 2 * self.shard.used_entries >= self.shard.n:
            return 0
        return _drain.choose_deferred_suffix(
            self.shard, self.shard.persistent_tail, run, pol)

    def _note_deferred(self, dstart: int, count: int) -> None:
        if count <= 0:
            self._span_deferred = 0
            self._span_oldest = -1
            return
        if not (self._span_deferred and self._span_oldest == dstart):
            # a different open extent; same extent (possibly grown) keeps
            # its age from the FIRST carry, so the deadline bounds real age
            self._span_since = time.monotonic()
            self._span_oldest = dstart
            self._span_carry_batches = 1
        elif count > self._span_deferred:     # another batch joined the carry
            self._span_carry_batches += 1
        last = dstart + count - 1
        if last > self._span_maxidx:          # count each entry's carry once
            self.stats_deferred += last - max(self._span_maxidx, dstart - 1)
            self._span_maxidx = last
        self._span_deferred = count

    # ------------------------------------------------------------------
    def request_drain(self) -> None:
        with self._drain_lock:
            self._drain_count += 1
            self.drain_event.set()
        self.shard.notify_committed()

    def end_drain(self) -> None:
        with self._drain_lock:
            self._drain_count = max(0, self._drain_count - 1)
            if self._drain_count == 0:
                self.drain_event.clear()

    def shutdown(self) -> None:
        """Graceful: drain everything, then stop."""
        self.request_drain()
        self.stop_event.set()
        self.shard.notify_committed()
        self.join(timeout=60)

    def power_loss(self) -> None:
        """Simulated crash: the thread dies wherever it is."""
        self.hard_stop.set()
        self.stop_event.set()
        self.shard.notify_committed()
        self.join(timeout=60)


class RebalanceThread(threading.Thread):
    """The router's epoch clock: sample shard load, plan, migrate.

    Migrations run OUTSIDE the drain threads (a migration's drain barrier
    *waits on* them), so a slow barrier never stalls draining.  A migration
    that fails its barrier (timeout) is simply skipped — the route table is
    untouched and the next epoch retries with fresh load data.
    """

    GUARDED_BY = {
        "_last_wait": None,                  # rebalance-thread-confined
        # guarded-by: volatile — single-writer per-thread counters (see
        # CleanupThread); live stats() reads are approximate by design
        "error": locking.VOLATILE, "stats_ticks": locking.VOLATILE,
        "stats_migrations": locking.VOLATILE,
        "stats_failed_migrations": locking.VOLATILE,
    }

    def __init__(self, log: NVLog, router,
                 migrate: Callable[[object], bool]):
        super().__init__(name="nvcache-rebalance", daemon=True)
        self.log = log
        self.router = router
        self.migrate = migrate               # Migration -> installed?
        self.stop_event = threading.Event()
        self.error: Optional[BaseException] = None  # guarded-by: volatile
        self._last_wait = [0.0] * len(log.shards)   # alloc-wait deltas
        self.stats_ticks = 0
        self.stats_migrations = 0
        self.stats_failed_migrations = 0

    def run(self) -> None:
        period = self.log.policy.rebalance_epoch_ms / 1e3
        try:
            while not self.stop_event.wait(period):
                self.tick()
        except BaseException as exc:         # surfaces in api.check()
            self.error = exc

    def tick(self) -> None:
        """One sampling epoch: visible separately so tests can step the
        rebalancer deterministically without the wall clock."""
        self.stats_ticks += 1
        samples = [sh.load_sample() for sh in self.log.shards]
        waits = [s["alloc_wait_s"] for s in samples]
        deltas = [w - p for w, p in zip(waits, self._last_wait)]
        self._last_wait = waits
        plan = self.router.plan([s["queue"] for s in samples],
                                wait_deltas=deltas)
        for mig in plan:
            if self.stop_event.is_set():
                return
            try:
                ok = self.migrate(mig)
            except TimeoutError:
                ok = False                   # barrier timed out: retry later
            if ok:
                self.stats_migrations += 1
            else:
                self.stats_failed_migrations += 1

    def shutdown(self) -> None:
        self.stop_event.set()
        if self.is_alive():
            self.join(timeout=60)


class PagerWritebackThread(threading.Thread):
    """The paged region's counterpart of the drain threads: flush the
    oldest dirty frames to the backend when the pool runs hot (over the
    dirty watermark, or an allocation found the free list short/empty and
    set the pressure event).  Writeback does NOT free frames — a clean
    frame is still a valid NVMM-resident cache; freeing happens on mode
    migration, truncate and retirement (:mod:`repro.core.api`)."""

    POLL_S = 0.01

    GUARDED_BY = {
        # guarded-by: volatile — single-writer per-thread counters (see
        # CleanupThread); live stats() reads are approximate by design
        "error": locking.VOLATILE, "stats_rounds": locking.VOLATILE,
    }

    def __init__(self, pager, writeback: Callable[[], int]):
        super().__init__(name="nvcache-pager-wb", daemon=True)
        self.pager = pager
        self.writeback = writeback           # owner cb: flush dirty victims
        self.stop_event = threading.Event()
        self.error: Optional[BaseException] = None  # guarded-by: volatile
        self.stats_rounds = 0

    def run(self) -> None:
        try:
            while not self.stop_event.is_set():
                self.pager.pressure.wait(timeout=self.POLL_S)
                if self.stop_event.is_set():
                    return
                if not (self.pager.pressure.is_set()
                        or self.pager.over_watermark()):
                    continue
                self.pager.pressure.clear()
                self.stats_rounds += 1
                while (self.pager.over_watermark()
                       and not self.stop_event.is_set()):
                    if self.writeback() == 0:
                        break                # victims' files unresolvable
                self.writeback()             # one pass even below watermark
        except BaseException as exc:         # surfaces in api.check()
            self.error = exc

    def shutdown(self) -> None:
        self.stop_event.set()
        self.pager.pressure.set()            # wake the wait
        if self.is_alive():
            self.join(timeout=60)


class CleanupPool:
    """One drain thread per shard, addressed collectively or per shard.

    The pool owns the cross-shard :class:`FsyncEpochScheduler`: per-shard
    batches that finish around the same time and touch the same backend
    file share one fsync epoch instead of issuing K device fsyncs.  With
    adaptive routing it also owns the :class:`RebalanceThread`, and with a
    paged region the :class:`PagerWritebackThread`.
    """

    def __init__(self, log: NVLog,
                 resolve_file: Callable[[int], Optional[object]],
                 *, router=None, migrate: Optional[Callable] = None,
                 meta_gate=None, reap: Optional[Callable] = None,
                 pager=None, writeback: Optional[Callable] = None,
                 obs=None):
        self.log = log
        self.fsync_scheduler = FsyncEpochScheduler(
            enabled=log.policy.fsync_epoch)
        self.threads = [CleanupThread(log, sh, resolve_file,
                                      fsync_scheduler=self.fsync_scheduler,
                                      meta_gate=meta_gate, reap=reap,
                                      obs=obs)
                        for sh in log.shards]
        self.rebalancer: Optional[RebalanceThread] = None
        if router is not None and migrate is not None:
            self.rebalancer = RebalanceThread(log, router, migrate)
        self.pager_wb: Optional[PagerWritebackThread] = None
        if pager is not None and writeback is not None:
            self.pager_wb = PagerWritebackThread(pager, writeback)

    def start(self) -> None:
        for t in self.threads:
            t.start()
        if self.rebalancer is not None:
            self.rebalancer.start()
        if self.pager_wb is not None:
            self.pager_wb.start()

    def _targets(self, shards: Optional[Iterable[int]]):
        if shards is None:
            return self.threads
        return [self.threads[s] for s in sorted(set(shards))]

    def request_drain(self, shards: Optional[Iterable[int]] = None) -> None:
        for t in self._targets(shards):
            t.request_drain()

    def end_drain(self, shards: Optional[Iterable[int]] = None) -> None:
        for t in self._targets(shards):
            t.end_drain()

    def shutdown(self) -> None:
        # the rebalancer first: a migration mid-flight may hold drain
        # requests the threads below must still serve before stopping
        if self.rebalancer is not None:
            self.rebalancer.shutdown()
        if self.pager_wb is not None:
            self.pager_wb.shutdown()
        for t in self.threads:
            t.shutdown()

    def power_loss(self) -> None:
        if self.rebalancer is not None:
            self.rebalancer.stop_event.set()
        if self.pager_wb is not None:
            self.pager_wb.stop_event.set()
            self.pager_wb.pager.pressure.set()
        for t in self.threads:
            t.hard_stop.set()
            t.stop_event.set()
            t.shard.notify_committed()
        for t in self.threads:
            t.join(timeout=60)
        if self.rebalancer is not None and self.rebalancer.is_alive():
            self.rebalancer.join(timeout=60)
        if self.pager_wb is not None and self.pager_wb.is_alive():
            self.pager_wb.join(timeout=60)

    # ------------------------------------------------------------- status
    @property
    def error(self) -> Optional[BaseException]:
        for t in self.threads:
            if t.error is not None:
                return t.error
        if self.rebalancer is not None and self.rebalancer.error is not None:
            return self.rebalancer.error
        if self.pager_wb is not None:
            return self.pager_wb.error
        return None

    @property
    def stats_batches(self) -> int:
        return sum(t.stats_batches for t in self.threads)

    @property
    def stats_entries(self) -> int:
        return sum(t.stats_entries for t in self.threads)

    @property
    def stats_fsyncs(self) -> int:
        return sum(t.stats_fsyncs for t in self.threads)

    @property
    def stats_extents(self) -> int:
        return sum(t.stats_extents for t in self.threads)

    @property
    def stats_pwritevs(self) -> int:
        return sum(t.stats_pwritevs for t in self.threads)

    @property
    def stats_deferred(self) -> int:
        return sum(t.stats_deferred for t in self.threads)

    @property
    def stats_span_merges(self) -> int:
        return sum(t.stats_span_merges for t in self.threads)

    @property
    def stats_fsyncs_issued(self) -> int:
        return self.fsync_scheduler.stats_issued_snapshot

    @property
    def stats_fsyncs_merged(self) -> int:
        return self.fsync_scheduler.stats_merged
