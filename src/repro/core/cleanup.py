"""The cleanup thread (paper §II-A step 6, §III "Cleanup thread and batching").

Consumes committed entries in log order from the persistent tail and
propagates them to the slow tier through ordinary ``pwrite`` calls (the
writes land in the kernel page cache, which write-combines them — the
paper's "volatile write cache behind a durable write cache"), then one
``fsync`` per touched file per batch, then durably retires the batch
(zero commit flags, advance persistent tail, pwb/pfence, advance volatile
tail).

Batching (paper §IV-C): waits for at least ``batch_min`` committed entries
unless a drain is requested (close/flush/log-full backpressure), consumes at
most ``batch_max``.
"""
from __future__ import annotations

import threading
from typing import Callable, Optional

from repro.core.log import NVLog


class CleanupThread(threading.Thread):
    def __init__(self, log: NVLog, resolve_file: Callable[[int], Optional[object]],
                 *, name: str = "nvcache-cleanup"):
        super().__init__(name=name, daemon=True)
        self.log = log
        self.resolve_file = resolve_file      # fdid -> File (api.File) or None
        self.drain_event = threading.Event()  # ignore batch_min
        self.stop_event = threading.Event()   # finish current batch, then exit
        self.hard_stop = threading.Event()    # simulated power loss: exit NOW
        self.error: Optional[BaseException] = None
        self.stats_batches = 0
        self.stats_entries = 0
        self.stats_fsyncs = 0

    def run(self) -> None:
        try:
            while not self.hard_stop.is_set():
                min_needed = 1 if self.drain_event.is_set() else self.log.policy.batch_min
                run = self.log.wait_committed(min_needed,
                                              drain_event=self.drain_event,
                                              stop_event=self.stop_event)
                if run == 0:
                    if self.stop_event.is_set() or self.hard_stop.is_set():
                        return
                    continue
                self._consume_batch(run)
        except BaseException as exc:  # surfaces in api.check()
            self.error = exc

    # ------------------------------------------------------------------
    def _consume_batch(self, run: int) -> None:
        log = self.log
        ps = log.policy.page_size
        start = log.persistent_tail
        touched = {}          # File -> n_entries drained for it
        for e in log.scan_committed(start, start + run):
            if self.hard_stop.is_set():
                return        # power loss mid-batch: nothing retired, log replays
            f = self.resolve_file(e.fdid)
            if f is None:     # orphan (file force-closed); drop the entry
                continue
            p0, p1 = e.off // ps, (e.off + max(e.length, 1) - 1) // ps
            descs = []
            if f.radix is not None:
                for p in range(p0, p1 + 1):
                    d = f.radix.get_or_create(p)
                    d.cleanup_lock.acquire()   # block dirty-miss readers (§II-D)
                    descs.append(d)
            try:
                f.backend.pwrite(bytes(e.data), e.off)
                for d in descs:
                    d.dirty.dec()              # may transiently go negative (fn. 4)
            finally:
                for d in descs:
                    d.cleanup_lock.release()
            touched[f] = touched.get(f, 0) + 1
            self.stats_entries += 1
        if self.hard_stop.is_set():
            return
        for f in touched:
            f.backend.fsync()                  # one fsync per file per batch
            self.stats_fsyncs += 1
        log.consume(start, run)                # durably retire the batch
        for f, n in touched.items():
            f.note_drained(n)
        self.stats_batches += 1

    # ------------------------------------------------------------------
    def request_drain(self) -> None:
        self.drain_event.set()
        with self.log._committed:
            self.log._committed.notify_all()

    def end_drain(self) -> None:
        self.drain_event.clear()

    def shutdown(self) -> None:
        """Graceful: drain everything, then stop."""
        self.request_drain()
        self.stop_event.set()
        with self.log._committed:
            self.log._committed.notify_all()
        self.join(timeout=60)

    def power_loss(self) -> None:
        """Simulated crash: the thread dies wherever it is."""
        self.hard_stop.set()
        self.stop_event.set()
        with self.log._committed:
            self.log._committed.notify_all()
        self.join(timeout=60)
