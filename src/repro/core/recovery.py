"""Recovery procedure (paper §III "Recovery procedure"), sharded.

On restart after a crash: re-open the files listed in the NVMM fd-path
table, scan *each shard* independently for committed entry groups starting
at that shard's persistent tail (uncommitted holes are skipped — possible
because entries are fixed-size, paper §II-D), then **merge the groups of
all shards by their global commit sequence number** and replay them in that
order, ``sync`` the backends, empty the log and clear the table.

The seq-merge is what preserves durable linearizability across shards: any
two overlapping writes were routed to the same shard (so their seqs are
ordered by that shard's log), and replaying the union in ascending seq
therefore applies every file location's writes in commit order.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List

from repro.core.log import CG_HEAD, Entry, NVLog
from repro.core.nvmm import NVMM
from repro.core.policy import Policy


@dataclasses.dataclass
class RecoveryStats:
    entries_replayed: int = 0
    bytes_replayed: int = 0
    holes_skipped: int = 0
    crc_failures: int = 0
    files: int = 0
    shards: int = 1
    groups_merged: int = 0


def recover(nvmm: NVMM, policy: Policy,
            open_backend: Callable[[str], object]) -> RecoveryStats:
    """Replay the log into the slow tier and reset the region.

    ``open_backend(path)`` must return a backend file object with
    ``pwrite(data, off)``, ``fsync()`` and ``close()``.
    """
    log = NVLog(nvmm, policy, format=False, adopt=False)
    stats = RecoveryStats(shards=policy.shards)

    # phase 1: scan each shard independently, collecting committed groups
    # (head entry + its committed followers) in shard-log order.
    groups: List[tuple[int, int, List[Entry]]] = []   # (seq, sid, entries)
    seen = 0
    for sh in log.shards:
        ptail = sh.persistent_tail
        cur: List[Entry] | None = None
        for e in sh.scan_committed(ptail, ptail + sh.n):
            seen += 1
            if e.cg == CG_HEAD:
                cur = [e]
                groups.append((e.seq, sh.sid, cur))
            elif cur is not None:
                cur.append(e)
    total = log.n * policy.shards
    stats.holes_skipped = total - seen if seen <= total else 0

    # phase 2: merge by global commit sequence and replay in that order.
    groups.sort(key=lambda g: (g[0], g[1]))
    stats.groups_merged = len(groups)
    files: dict[str, object] = {}
    for _seq, _sid, entries in groups:
        for e in entries:
            if not log.verify_entry(e):
                stats.crc_failures += 1
                continue
            path = log.fd_table_get(e.fdid)
            if path is None:
                continue  # orphan entry: its file slot was already retired
            f = files.get(path)
            if f is None:
                f = open_backend(path)
                files[path] = f
            f.pwrite(bytes(e.data), e.off)
            stats.entries_replayed += 1
            stats.bytes_replayed += e.length

    for f in files.values():
        f.fsync()
        f.close()
    stats.files = len(files)

    # paper: "empties the log" — reformat the region for the next run
    NVLog(nvmm, policy, format=True)
    return stats
