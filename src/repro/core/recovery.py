"""Recovery procedure (paper §III "Recovery procedure").

On restart after a crash: re-open the files listed in the NVMM fd-path
table, replay every committed log entry in log order starting at the
persistent tail, ``sync`` the backends, then empty the log and clear the
table.  Uncommitted holes are skipped — possible because entries are
fixed-size (paper §II-D).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.log import NVLog
from repro.core.nvmm import NVMM
from repro.core.policy import Policy


@dataclasses.dataclass
class RecoveryStats:
    entries_replayed: int = 0
    bytes_replayed: int = 0
    holes_skipped: int = 0
    crc_failures: int = 0
    files: int = 0


def recover(nvmm: NVMM, policy: Policy,
            open_backend: Callable[[str], object]) -> RecoveryStats:
    """Replay the log into the slow tier and reset the region.

    ``open_backend(path)`` must return a backend file object with
    ``pwrite(data, off)``, ``fsync()`` and ``close()``.
    """
    log = NVLog(nvmm, policy, format=False)
    stats = RecoveryStats()
    ptail = log.persistent_tail
    files: dict[str, object] = {}

    seen = 0
    for e in log.scan_committed(ptail, ptail + log.n):
        seen += 1
        if not log.verify_entry(e):
            stats.crc_failures += 1
            continue
        path = log.fd_table_get(e.fdid)
        if path is None:
            continue  # orphan entry: its file slot was already retired
        f = files.get(path)
        if f is None:
            f = open_backend(path)
            files[path] = f
        f.pwrite(bytes(e.data), e.off)
        stats.entries_replayed += 1
        stats.bytes_replayed += e.length
    stats.holes_skipped = log.n - seen if seen <= log.n else 0

    for f in files.values():
        f.fsync()
        f.close()
    stats.files = len(files)

    # paper: "empties the log" — reformat the region for the next run
    NVLog(nvmm, policy, format=True)
    return stats
