"""Recovery procedure (paper §III "Recovery procedure"), sharded.

On restart after a crash: re-open the files listed in the NVMM fd-path
table, scan *each shard* independently for committed entry groups starting
at that shard's persistent tail (uncommitted holes are skipped — possible
because entries are fixed-size, paper §II-D), then **merge the groups of
all shards by their global commit sequence number** and replay them in that
order, ``sync`` the backends, empty the log and clear the table.

The seq-merge is what preserves durable linearizability across shards: any
two overlapping writes were routed to the same shard (so their seqs are
ordered by that shard's log), and replaying the union in ascending seq
therefore applies every file location's writes in commit order.  Adaptive
routing (:mod:`repro.core.router`) changes nothing here: a migration drains
the old shard before the new epoch takes effect, so the union of committed
groups is still totally ordered per file location by ``seq`` — the merge
replays correctly across a mid-epoch crash, whichever epoch the persisted
route record shows (``RecoveryStats.route_epoch`` reports it).

Failure semantics of the replay itself:

* **Torn groups are dropped whole.**  A multi-entry ``pwrite`` is one
  commit group; if ANY entry of a group fails its CRC (or a committed head
  is missing followers), replaying the surviving entries would surface a
  partially applied write — exactly the tearing the commit protocol exists
  to rule out.  The whole group is skipped and counted in
  ``RecoveryStats.groups_dropped``.
* **A failing backend never leaks handles or half-promises durability.**
  If ``open_backend``/``pwrite`` raises mid-replay, every opened handle is
  closed, only files whose groups ALL replayed are fsynced, the log is NOT
  reformatted (the exception propagates and ``recover`` can be retried —
  replay is idempotent), and the original exception is re-raised.
* **Namespace records replay seq-merged with the data groups**
  (:mod:`repro.core.namespace`): a create/rename/unlink/ftruncate entry is
  applied to the backend namespace at its position in the global seq
  order, so data written before a rename is attributed to the renamed
  file, an unlinked file's bytes never resurrect (the op's drain barrier
  put every covered data entry below its seq), and a re-created path
  starts fresh.  Each replay is idempotent; a torn record is dropped whole
  like any torn group (the namespace is old-or-new, never torn).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.core.log import (CG_HEAD, META_FDID, META_NO_FDID, MOP_CREATE,
                            MOP_FTRUNCATE, MOP_RENAME, MOP_UNLINK, Entry,
                            NVLog, decode_meta)
from repro.core.nvmm import NVMM
from repro.core.policy import Policy
from repro.core.router import load_route_record


@dataclasses.dataclass
class RecoveryStats:
    entries_replayed: int = 0
    bytes_replayed: int = 0
    holes_skipped: int = 0
    crc_failures: int = 0
    groups_dropped: int = 0      # torn groups skipped in full (never partial)
    files: int = 0
    shards: int = 1
    groups_merged: int = 0
    route_epoch: int = 0         # routing epoch persisted at crash time
    meta_ops: int = 0            # namespace records replayed (seq-merged)
    meta_skipped: int = 0        # records at/below the backend's applied
    #                              watermark (already reflected in it)
    unlinked_dropped: int = 0    # data groups of an unlinked fdid committed
    #                              after its unlink (POSIX: they died with
    #                              the name — replaying them would re-create
    #                              the dead path around a racing writer)
    frames_seen: int = 0         # mapped paged-region frames found (v4)
    frames_replayed: int = 0     # frames whose image reached the backend
    frames_dropped: int = 0      # frames failing CRC (dropped whole)
    # forensic timeline (v5): the flight-recorder events that survived the
    # crash, ordered by event seq (repro.obs.flight.FlightEvent), plus the
    # count of torn records the decoder dropped.  Decoded before replay —
    # the closing reformat wipes the ring.
    flight_events: List = dataclasses.field(default_factory=list)
    flight_torn_dropped: int = 0


def recover(nvmm: NVMM, policy: Policy,
            backend) -> RecoveryStats:
    """Replay the log into the slow tier and reset the region.

    ``backend`` is either a tier-like object (``open(path)`` plus the
    namespace surface ``exists``/``unlink``/``rename`` used to replay
    metadata records) or a bare ``open_backend(path)`` callable — the
    historic signature, still accepted; a bound ``Tier.open`` exposes its
    tier through ``__self__``, and a region with no namespace records
    never needs more than ``open``.
    """
    if hasattr(backend, "open"):
        tier, open_backend = backend, backend.open
    else:
        open_backend = backend
        owner = getattr(backend, "__self__", None)
        tier = owner if hasattr(owner, "unlink") else None
    log = NVLog(nvmm, policy, format=False, adopt=False)
    stats = RecoveryStats(shards=policy.shards)
    stats.route_epoch, _, _ = load_route_record(nvmm, policy)

    # phase 0 (layout v5): decode the flight-recorder ring FIRST — the
    # closing reformat zeroes everything below entries_base, ring
    # included.  The surviving timeline is pure forensics (never consulted
    # by the replay): what the engine was doing when the power died.
    if policy.flight_records:
        from repro.obs.flight import decode_ring
        stats.flight_events, stats.flight_torn_dropped = \
            decode_ring(nvmm, policy)

    # phase 1: scan each shard independently, collecting committed groups
    # (head entry + its committed followers) in shard-log order.
    groups: List[tuple[int, int, List[Entry]]] = []   # (seq, sid, entries)
    seen = 0
    for sh in log.shards:
        ptail = sh.persistent_tail
        cur: List[Entry] | None = None
        for e in sh.scan_committed(ptail, ptail + sh.n):
            seen += 1
            if e.cg == CG_HEAD:
                cur = [e]
                groups.append((e.seq, sh.sid, cur))
            elif cur is not None:
                cur.append(e)
    total = log.n * policy.shards
    stats.holes_skipped = total - seen if seen <= total else 0

    # phase 1b (layout v4): fold each mapped paged-region frame into the
    # merge as a synthetic one-entry group at the frame's commit seq.  The
    # frame protocol (core/pager.py) guarantees the active slot is a whole
    # committed page image, so it flows through the same machinery as a
    # log group: CRC validation, the dead-fdid barrier, the orphan drop
    # for retired fd-table slots, and seq ordering against metadata ops —
    # a frame overwritten before a journaled ftruncate replays before the
    # cut, one committed after it replays after.  ``sid=policy.shards``
    # (one past the last real shard) keeps the sort key well-defined.
    if policy.page_frames:
        from repro.core.pager import scan_frames
        ps = policy.page_size
        for fr in scan_frames(nvmm, policy):
            stats.frames_seen += 1
            groups.append((fr.seq, policy.shards,
                           [Entry(policy.shards, fr.idx, CG_HEAD, fr.seq,
                                  fr.page_no * ps, fr.fdid, fr.length, 0,
                                  fr.crc, fr.data)]))

    # phase 2: merge by global commit sequence; validate whole groups.  A
    # group is all-or-nothing: one bad CRC (or a missing follower) drops the
    # entire group, never just the failing entry — a multi-entry pwrite must
    # not resurface partially applied.
    groups.sort(key=lambda g: (g[0], g[1]))
    stats.groups_merged = len(groups)
    valid: List[tuple[int, int, List[Entry]]] = []
    for seq, sid, entries in groups:
        bad = sum(1 for e in entries if not log.verify_entry(e))
        stats.crc_failures += bad
        if bad or len(entries) != 1 + entries[0].nfollow:
            stats.groups_dropped += 1
            if sid == policy.shards:
                stats.frames_dropped += 1
            continue
        if entries[0].fdid == META_FDID:
            try:   # a namespace record must also parse; torn == dropped whole
                decode_meta(b"".join(bytes(e.data) for e in entries))
            except ValueError:
                stats.groups_dropped += 1
                continue
        valid.append((seq, sid, entries))

    # phase 3: replay in merge order.  Namespace records replay seq-merged
    # with the data groups — the merge is what rebuilds the namespace
    # old-or-new: data written before a rename lands under the old binding
    # that the rename then moves, an unlink deletes everything below its
    # seq, and a later re-create starts the path fresh.  Every namespace
    # replay is idempotent (the op may have been applied just before the
    # crash, or by an earlier recover() attempt that failed midway).
    # ``last_group`` lets the failure path tell which files had already
    # fully replayed when a backend call threw.
    files: Dict[str, object] = {}
    last_group: Dict[str, int] = {}
    for gi, (_seq, _sid, entries) in enumerate(valid):
        if entries[0].fdid == META_FDID:
            _op, _f, _aux, a, b = decode_meta(
                b"".join(bytes(e.data) for e in entries))
            last_group[a] = gi
            if b:
                last_group[b] = gi
            continue
        path = log.fd_table_get(entries[0].fdid)
        if path is not None:
            last_group[path] = gi
    # the backend's applied watermark: the seq of the last namespace op it
    # already reflects (a journaling backend records it as part of the op).
    # Replaying an op at/below it is NOT idempotent — the backend state has
    # moved past it (its covered data drained, its paths re-created) and a
    # second rename/unlink would tear exactly what the first one built.
    ns_seq = getattr(tier, "ns_seq", 0)
    # dead-fdid barrier: once an unlink of fdid F is processed (replayed OR
    # already applied), any LATER data group still carrying F belongs to
    # the anonymous (unlinked-while-open) file and died with the name — a
    # writer racing the unlink's fd-table clear could otherwise resurrect
    # the path holding only its own bytes.  A later MOP_CREATE re-binding F
    # lifts the barrier (fdid reuse after the old file drained; the create
    # is in the same shard as the unlink, so it can never be consumed while
    # the unlink survives in the log).
    dead: Dict[int, str] = {}
    done_groups = 0
    try:
        for gi, (seq, gsid, entries) in enumerate(valid):
            if entries[0].fdid == META_FDID:
                op, mfdid, _aux, a, _b = decode_meta(
                    b"".join(bytes(e.data) for e in entries))
                if op == MOP_UNLINK and mfdid != META_NO_FDID:
                    dead[mfdid] = a
                elif op == MOP_CREATE:
                    dead.pop(mfdid, None)
                if seq <= ns_seq:
                    stats.meta_skipped += 1
                else:
                    _replay_meta(entries, tier, open_backend, files)
                    stats.meta_ops += 1
                    if tier is not None:
                        tier.ns_seq = seq      # the backend now reflects it
                done_groups = gi + 1
                continue
            path = log.fd_table_get(entries[0].fdid)
            if path is None:
                continue  # orphan group: its file slot was already retired
            if dead.get(entries[0].fdid) == path:
                # fdid unlinked at a lower seq and not re-bound since (a
                # different live binding would show a different slot path)
                stats.unlinked_dropped += 1
                done_groups = gi + 1
                continue
            f = files.get(path)
            if f is None:
                f = open_backend(path)
                files[path] = f
            for e in entries:
                f.pwrite(bytes(e.data), e.off)
                stats.entries_replayed += 1
                stats.bytes_replayed += e.length
            if gsid == policy.shards:
                stats.frames_replayed += 1
            done_groups = gi + 1
    except BaseException:
        # a raising open_backend/pwrite must not leak the already-opened
        # handles or fsync files whose replay never finished; the log stays
        # intact so the caller can retry (replay is idempotent).  Cleanup
        # errors must not mask the original exception.
        _finish(files, last_group, done_groups, suppress=True)
        raise
    _finish(files, last_group, done_groups)
    stats.files = len(files)

    # paper: "empties the log" — reformat the region for the next run
    # (reached only on success; the reformat also clears the route record)
    NVLog(nvmm, policy, format=True)
    return stats


def _replay_meta(entries: List[Entry], tier, open_backend,
                 files: Dict[str, object]) -> None:
    """Apply one namespace record to the backend (idempotently — the op may
    already have been applied pre-crash, or by a failed earlier recover()
    attempt).  ``files`` is the replay's open-handle cache: unlink/rename
    must invalidate (or re-key) its entries, or later data groups for a
    re-created path would write through a handle the tier no longer owns."""
    op, _fdid, aux, a, b = decode_meta(
        b"".join(bytes(e.data) for e in entries))
    if op == MOP_CREATE:
        open_backend(a).close()       # ensure the path exists
    elif op == MOP_FTRUNCATE:
        f = files.get(a)
        if f is None:
            f = files[a] = open_backend(a)
        f.truncate(aux)
    elif op == MOP_UNLINK:
        if tier is None:
            raise RuntimeError("unlink record needs a tier-like backend "
                               "(pass the tier to recover())")
        h = files.pop(a, None)
        if h is not None:
            h.close()
        tier.unlink(a)                # idempotent: a no-op when already gone
    elif op == MOP_RENAME:
        if tier is None:
            raise RuntimeError("rename record needs a tier-like backend "
                               "(pass the tier to recover())")
        hb = files.pop(b, None)
        if hb is not None:
            hb.close()                # destination is replaced
        ha = files.pop(a, None)
        if tier.exists(a):
            tier.rename(a, b)
            if ha is not None:
                files[b] = ha         # same backend object, re-keyed
        else:
            if ha is not None:
                ha.close()
            if not tier.exists(b):    # both lost: restore the destination
                open_backend(b).close()
    else:
        raise ValueError(f"unknown namespace op {op}")


def _finish(files: Dict[str, object], last_group: Dict[str, int],
            done_groups: int, *, suppress: bool = False) -> None:
    """Fsync every file whose groups all replayed, then close ALL handles
    (even on fsync failure — the first error propagates after the closes,
    unless ``suppress`` because a replay exception is already in flight)."""
    first_err: BaseException | None = None
    for path, f in files.items():
        try:
            if last_group.get(path, -1) < done_groups:
                f.fsync()
        except BaseException as exc:
            if first_err is None:
                first_err = exc
        finally:
            try:
                f.close()
            except BaseException as exc:
                if first_err is None:
                    first_err = exc
    if first_err is not None and not suppress:
        raise first_err
