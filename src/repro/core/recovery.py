"""Recovery procedure (paper §III "Recovery procedure"), sharded.

On restart after a crash: re-open the files listed in the NVMM fd-path
table, scan *each shard* independently for committed entry groups starting
at that shard's persistent tail (uncommitted holes are skipped — possible
because entries are fixed-size, paper §II-D), then **merge the groups of
all shards by their global commit sequence number** and replay them in that
order, ``sync`` the backends, empty the log and clear the table.

The seq-merge is what preserves durable linearizability across shards: any
two overlapping writes were routed to the same shard (so their seqs are
ordered by that shard's log), and replaying the union in ascending seq
therefore applies every file location's writes in commit order.  Adaptive
routing (:mod:`repro.core.router`) changes nothing here: a migration drains
the old shard before the new epoch takes effect, so the union of committed
groups is still totally ordered per file location by ``seq`` — the merge
replays correctly across a mid-epoch crash, whichever epoch the persisted
route record shows (``RecoveryStats.route_epoch`` reports it).

Failure semantics of the replay itself:

* **Torn groups are dropped whole.**  A multi-entry ``pwrite`` is one
  commit group; if ANY entry of a group fails its CRC (or a committed head
  is missing followers), replaying the surviving entries would surface a
  partially applied write — exactly the tearing the commit protocol exists
  to rule out.  The whole group is skipped and counted in
  ``RecoveryStats.groups_dropped``.
* **A failing backend never leaks handles or half-promises durability.**
  If ``open_backend``/``pwrite`` raises mid-replay, every opened handle is
  closed, only files whose groups ALL replayed are fsynced, the log is NOT
  reformatted (the exception propagates and ``recover`` can be retried —
  replay is idempotent), and the original exception is re-raised.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

from repro.core.log import CG_HEAD, Entry, NVLog
from repro.core.nvmm import NVMM
from repro.core.policy import Policy
from repro.core.router import load_route_record


@dataclasses.dataclass
class RecoveryStats:
    entries_replayed: int = 0
    bytes_replayed: int = 0
    holes_skipped: int = 0
    crc_failures: int = 0
    groups_dropped: int = 0      # torn groups skipped in full (never partial)
    files: int = 0
    shards: int = 1
    groups_merged: int = 0
    route_epoch: int = 0         # routing epoch persisted at crash time


def recover(nvmm: NVMM, policy: Policy,
            open_backend: Callable[[str], object]) -> RecoveryStats:
    """Replay the log into the slow tier and reset the region.

    ``open_backend(path)`` must return a backend file object with
    ``pwrite(data, off)``, ``fsync()`` and ``close()``.
    """
    log = NVLog(nvmm, policy, format=False, adopt=False)
    stats = RecoveryStats(shards=policy.shards)
    stats.route_epoch, _ = load_route_record(nvmm, policy)

    # phase 1: scan each shard independently, collecting committed groups
    # (head entry + its committed followers) in shard-log order.
    groups: List[tuple[int, int, List[Entry]]] = []   # (seq, sid, entries)
    seen = 0
    for sh in log.shards:
        ptail = sh.persistent_tail
        cur: List[Entry] | None = None
        for e in sh.scan_committed(ptail, ptail + sh.n):
            seen += 1
            if e.cg == CG_HEAD:
                cur = [e]
                groups.append((e.seq, sh.sid, cur))
            elif cur is not None:
                cur.append(e)
    total = log.n * policy.shards
    stats.holes_skipped = total - seen if seen <= total else 0

    # phase 2: merge by global commit sequence; validate whole groups.  A
    # group is all-or-nothing: one bad CRC (or a missing follower) drops the
    # entire group, never just the failing entry — a multi-entry pwrite must
    # not resurface partially applied.
    groups.sort(key=lambda g: (g[0], g[1]))
    stats.groups_merged = len(groups)
    valid: List[tuple[int, int, List[Entry]]] = []
    for seq, sid, entries in groups:
        bad = sum(1 for e in entries if not log.verify_entry(e))
        stats.crc_failures += bad
        if bad or len(entries) != 1 + entries[0].nfollow:
            stats.groups_dropped += 1
            continue
        valid.append((seq, sid, entries))

    # phase 3: replay in merge order.  ``last_group`` lets the failure path
    # tell which files had already fully replayed when a backend call threw.
    files: Dict[str, object] = {}
    last_group: Dict[str, int] = {}
    for gi, (_seq, _sid, entries) in enumerate(valid):
        path = log.fd_table_get(entries[0].fdid)
        if path is not None:
            last_group[path] = gi
    done_groups = 0
    try:
        for gi, (_seq, _sid, entries) in enumerate(valid):
            path = log.fd_table_get(entries[0].fdid)
            if path is None:
                continue  # orphan group: its file slot was already retired
            f = files.get(path)
            if f is None:
                f = open_backend(path)
                files[path] = f
            for e in entries:
                f.pwrite(bytes(e.data), e.off)
                stats.entries_replayed += 1
                stats.bytes_replayed += e.length
            done_groups = gi + 1
    except BaseException:
        # a raising open_backend/pwrite must not leak the already-opened
        # handles or fsync files whose replay never finished; the log stays
        # intact so the caller can retry (replay is idempotent).  Cleanup
        # errors must not mask the original exception.
        _finish(files, last_group, done_groups, suppress=True)
        raise
    _finish(files, last_group, done_groups)
    stats.files = len(files)

    # paper: "empties the log" — reformat the region for the next run
    # (reached only on success; the reformat also clears the route record)
    NVLog(nvmm, policy, format=True)
    return stats


def _finish(files: Dict[str, object], last_group: Dict[str, int],
            done_groups: int, *, suppress: bool = False) -> None:
    """Fsync every file whose groups all replayed, then close ALL handles
    (even on fsync failure — the first error propagates after the closes,
    unless ``suppress`` because a replay exception is already in flight)."""
    first_err: BaseException | None = None
    for path, f in files.items():
        try:
            if last_group.get(path, -1) < done_groups:
                f.fsync()
        except BaseException as exc:
            if first_err is None:
                first_err = exc
        finally:
            try:
                f.close()
            except BaseException as exc:
                if first_err is None:
                    first_err = exc
    if first_err is not None and not suppress:
        raise first_err
