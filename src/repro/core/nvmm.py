"""Simulated byte-addressable NVMM with an explicit crash model.

The paper's prototype runs on Optane NVDIMMs and orders durability with
three primitives (§III):

  * ``pwb(addr)``  — enqueue the cacheline holding ``addr`` for flushing
                     (``clwb`` on x86),
  * ``pfence()``   — order: every ``pwb`` issued before the fence completes
                     before any store issued after it (``sfence``),
  * ``psync()``    — like ``pfence`` but additionally guarantees the lines
                     have reached the persistence domain.

This container has no NVMM, so we simulate the *semantics*: a volatile
"CPU cache" view (what loads observe) plus a durable shadow (what survives
``crash()``).  The shadow is tracked at cacheline granularity which makes
the log's commit protocol *testable*: hypothesis can crash at any point and
choose which un-flushed dirty lines happened to be evicted to media, so a
missing ``pwb``/``pfence`` in the protocol becomes a failing property test.

Crash model (standard persistent-memory testing model, e.g. Yat):
  * a store makes its line *dirty*;
  * ``pwb`` marks the line *flush-requested*;
  * ``pfence``/``psync`` drain every flush-requested line to the durable
    shadow (guaranteed durable from then on);
  * at ``crash()``, every remaining dirty line independently may or may not
    have been evicted to media (the test chooses adversarially); we expose
    the choice via a callback.

``track=False`` disables the shadow entirely (used by benchmarks where only
the volatile view matters for throughput).

Region map (layout VERSION 5, offsets computed by
:class:`repro.core.policy.Policy`)::

    0             superblock (magic/version/geometry) + per-shard
                  persistent tails (one cacheline each, from SHARD_TAILS)
    SUPERBLOCK    fd-path table (fd_max slots of path_max bytes)
    route_base    persisted route record (epoch + overrides + stripe-width
                  tuning entries, CRC'd header)
    flight_base   flight-recorder ring (VERSION 5): flight_records 64-byte
                  CRC'd event records, round-robin, store+pwb only (no
                  fence — lines ride the engine's next psync) — see
                  :mod:`repro.obs.flight`
    page_base     paged region (VERSION 4): page_frames in-place frames,
                  each [header cacheline | 2 ping-pong page slots] — see
                  :mod:`repro.core.pager`
    entries_base  K shard logs of entries_per_shard fixed-size entries

VERSION 4 is the same map minus the ``flight_base`` row (and with an
8-field superblock): a VERSION-4 image with ``flight_records=0`` decodes
identically under VERSION 5 offsets.

Two persistence modes share the region: log shards (append + drain) and
paged frames (in-place overwrite + writeback).  They are seq-fenced
against each other — both draw commit seqs from one global counter, and
recovery replays their union in ascending seq — and a given (file, page)
is owned by exactly one mode at a time (see :mod:`repro.core.log`).
"""
from __future__ import annotations

import struct
from typing import Callable, Iterable, Optional

from repro.core.policy import CACHELINE

_U64 = struct.Struct("<Q")


class NVMM:
    """One simulated NVMM region (a DAX device or DAX file in the paper)."""

    def __init__(self, size: int, *, track: bool = False):
        self.size = size
        self.track = track
        self._buf = bytearray(size)          # CPU-visible content
        self._durable: Optional[bytearray] = bytearray(size) if track else None
        self._dirty: set[int] = set()        # dirty line indices
        self._requested: set[int] = set()    # pwb'd but not yet fenced
        self.stats_pwb = 0
        self.stats_pwb_lines = 0             # cachelines covered by pwb calls
        self.stats_fence = 0
        self.stats_psync = 0
        self.stats_stored_bytes = 0

    # -- volatile (CPU cache) accessors ------------------------------------
    def store(self, off: int, data: bytes | bytearray | memoryview) -> None:
        n = len(data)
        self._buf[off:off + n] = data
        self.stats_stored_bytes += n
        if self.track:
            self._dirty.update(range(off // CACHELINE, (off + n - 1) // CACHELINE + 1))

    def load(self, off: int, n: int) -> memoryview:
        return memoryview(self._buf)[off:off + n]

    def store_u64(self, off: int, val: int) -> None:
        self.store(off, _U64.pack(val))

    def load_u64(self, off: int) -> int:
        return _U64.unpack_from(self._buf, off)[0]

    # -- persistence primitives (paper §III) --------------------------------
    def pwb(self, off: int, n: int = CACHELINE) -> None:
        """Request flush of the cachelines covering ``[off, off+n)``."""
        self.stats_pwb += 1
        self.stats_pwb_lines += \
            (off + max(n, 1) - 1) // CACHELINE - off // CACHELINE + 1
        if self.track:
            lines = range(off // CACHELINE, (off + n - 1) // CACHELINE + 1)
            self._requested.update(l for l in lines if l in self._dirty)

    def pfence(self) -> None:
        """Drain flush-requested lines; order them before subsequent stores."""
        self.stats_fence += 1
        self._drain_requested()

    def psync(self) -> None:
        """Like ``pfence`` but guarantees arrival in the persistence domain."""
        self.stats_psync += 1
        self._drain_requested()

    def _drain_requested(self) -> None:
        if not self.track:
            return
        # pop-drain rather than iterate: concurrent pwb() calls (writer vs
        # cleanup threads share the region) mutate the set mid-fence, and
        # iterating a set while another thread updates it raises.  Draining
        # a line requested *during* the fence is benign — fences guarantee
        # at-least the lines requested before them.
        while self._requested:
            try:
                line = self._requested.pop()
            except KeyError:
                break
            b = line * CACHELINE
            e = min(b + CACHELINE, self.size)
            self._durable[b:e] = self._buf[b:e]
            self._dirty.discard(line)

    # -- crash simulation ----------------------------------------------------
    def crash(self, choose_evicted: Optional[Callable[[Iterable[int]], Iterable[int]]] = None) -> None:
        """Simulate power loss.

        ``choose_evicted`` receives the sorted dirty-line indices and returns
        the subset that happened to reach media before the crash (hardware may
        evict any dirty line at any time).  Default: none of them made it —
        the most common adversarial case for a write-ahead protocol.
        After the call, the volatile view equals the durable state.
        """
        if not self.track:
            raise RuntimeError("crash() requires track=True")
        pending = sorted(self._dirty | self._requested)
        evicted = set(choose_evicted(pending)) if choose_evicted else set()
        for line in evicted:
            b = line * CACHELINE
            e = min(b + CACHELINE, self.size)
            self._durable[b:e] = self._buf[b:e]
        self._buf[:] = self._durable
        self._dirty.clear()
        self._requested.clear()

    # convenience for protocol code: store+flush in one call (NOT one atomic
    # op — still two steps, kept separate in the log protocol where ordering
    # matters).
    def store_flush(self, off: int, data: bytes) -> None:
        self.store(off, data)
        self.pwb(off, len(data))
