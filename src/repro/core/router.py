"""Epoch-based adaptive shard router (load-aware rebalancing on top of the
static routes of :mod:`repro.core.policy`).

The static routes are the PR-1 contract: ``fdid % K`` or per-stripe
``(fdid + off // stripe_bytes) % K``.  Both can collapse under skew — two
hot SQLite/RocksDB files whose fdids collide modulo K serialize on one
shard's fetch-and-add and drain thread, which is exactly the per-core-log
contention problem "NVMM cache design: Logging vs. Paging" identifies.
This module makes the route *adaptive* without giving up the invariant the
whole sharded design rests on.

Routing model
-------------
A **route key** is the unit of migration: the fdid in ``"fdid"`` mode, the
``(fdid, stripe)`` pair in ``"stripe"`` mode (packed into one u64).  The
router holds an immutable override table ``{key: sid}`` plus a monotonically
increasing **epoch**; a key without an override routes by the static
formula, so an empty table is bit-identical to the static router.  Installing
a new epoch swaps the whole table atomically (one reference store), so a
writer observes either the old or the new route, never a mix.

Why migration requires the drain barrier (the ordering proof)
-------------------------------------------------------------
Correctness of sharding rests on one invariant: **any two overlapping
writes append to the same shard log**.  Within one shard, allocation order
equals global-``seq`` order (the seq is drawn inside the shard's allocation
lock), so the drain applies same-page writes in commit order and a
dirty-miss replay sees them in ``seq`` order; across shards nothing orders
two drain threads.  A migration of key X from shard *a* to shard *b*
threatens the invariant in exactly one way: an old write W1 to X still
*live in shard a* (committed but not yet drained) while a new write W2 to
the same location appends to shard b.  Then shard a's and shard b's drain
threads race, and the backend can end up with W1's stale bytes over W2's.

The migration protocol therefore is, per key:

1. **freeze** the owning file's route gate — new writes to the file block,
   and the rebalancer waits until in-flight writes (which pinned the old
   epoch when they looked up their route) have committed;
2. run the per-file **drain barrier** (``api._drain_barrier``, the same
   barrier close/flock/O_TRUNC use): every committed entry of the file is
   written to the backend, fsynced, and retired from the log;
3. **install** the new epoch (override X -> b) and persist it;
4. unfreeze — blocked writers re-run their route lookup under the new
   table.

After step 2 the old shard holds *no* live entry for the file, so when the
first post-migration write appends to shard b there is nothing left in
shard a it could overlap with: every pair of overlapping live writes is
again same-shard, and the invariant holds in every epoch.  Recovery needs
no extra machinery — its cross-shard merge replays committed groups in
ascending global ``seq``, which is a superset of the per-shard ordering the
invariant guarantees, so a crash *between* any two protocol steps replays
in commit order regardless of which epoch the table shows.  The epoch
record is still persisted next to the superblock (CRC-guarded, written
payload-then-header with pwb/pfence/psync) so an attach after a mid-epoch
crash — e.g. ``NVLog(format=False)`` on a region with live entries — routes
new writes exactly as the pre-crash instance did, instead of silently
falling back to the static route while old-epoch entries are still live.

Load model
----------
``EpochRouter.note_append`` counts entries appended per route key;
:class:`repro.core.cleanup.CleanupPool`'s rebalance thread closes an epoch
every ``Policy.rebalance_epoch_ms``, samples per-shard load —
entries appended (from the key counters), drain queue depth and allocation
wait time (:meth:`repro.core.log.LogShard.load_sample`) — and asks
:meth:`EpochRouter.plan` for migrations.  The planner is greedy with
hysteresis: within each placement group it moves the hottest movable keys
from the most- to the least-loaded shard, only while the imbalance ratio
exceeds ``MIN_RATIO`` and each move strictly improves the spread, and never
more than ``MAX_MIGRATIONS_PER_EPOCH`` per group per epoch (each migration
costs a per-file drain barrier, so convergence is rate-limited by design).

Stripe-width auto-tuning (the rebalancing follow-up): a fdid the planner
wants to migrate ``Policy.stripe_tune_streak`` epochs in a row is
*persistently* hot — per-stripe moves are chasing it without converging.
Instead of another migration, :meth:`EpochRouter.plan` emits a width
change (``Migration.new_shift``): the fdid's stripe is halved, doubling
its fan-out across shards via the static formula, and every per-stripe
override it owned is dropped in the same epoch.  The install rides the
same freeze + drain-barrier protocol, so the re-keying can never strand a
live entry, and the per-fdid shifts are persisted in the route record
(flag-tagged entries) so an attach routes with the tuned width.
"""
from __future__ import annotations

import struct
import threading
import zlib
from typing import Dict, List, Optional, Tuple

from repro.core import locking
from repro.core.nvmm import NVMM
from repro.core.policy import Policy, ROUTE_ENT, ROUTE_HDR

_RT_HDR = struct.Struct("<QII")    # epoch, count, crc(payload || epoch || count)
_RT_ENT = struct.Struct("<QI")     # key, sid
assert _RT_HDR.size == ROUTE_HDR
assert _RT_ENT.size == ROUTE_ENT

# stripe-mode keys pack (fdid, stripe) into one u64; stripes beyond the
# 40-bit field (≈ petabyte offsets at default stripe width) stay static
_STRIPE_BITS = 40
_STRIPE_MASK = (1 << _STRIPE_BITS) - 1

# persisted stripe-width tuning entries share the route record: a record
# entry whose key has this flag set maps fdid -> stripe shift, not key ->
# sid.  Real route keys never reach bit 63 (fdid < fd_max << 40).
_WIDTH_FLAG = 1 << 63

MIN_RATIO = 1.5                # hot/cold load ratio needed before migrating
MIN_EPOCH_ENTRIES = 16         # ignore epochs with almost no traffic
MAX_MIGRATIONS_PER_EPOCH = 2   # per placement group (each costs a barrier)
QUEUE_WEIGHT = 0.5             # drain-backlog penalty when picking a TARGET
#                                shard (a backlogged shard is a bad home for
#                                a hot key).  The migrate/stay decision uses
#                                appended entries only: backlog is backward-
#                                looking and largely belongs to the very key
#                                being considered, so counting it would
#                                justify moves that merely relocate the hot
#                                spot and ping-pong every epoch.
MIN_IMPROVEMENT = 0.05         # a move must lower the hot shard's load by
#                                >= 5% (max(hot-n, cold+n) <= 0.95*hot) —
#                                otherwise it merely relocates the hot spot
#                                (and a noise key is not worth a barrier)
BARRIER_HORIZON_EPOCHS = 32    # migration cost model: a move's drain
#                                barrier stalls the frozen file until its
#                                pending entries land — estimated as the
#                                hot shard's queue depth scaled by the
#                                key's share of the shard's load (the
#                                barrier waits on the FILE's entries, not
#                                the whole shard).  The move pays off if
#                                the per-epoch load reduction, recouped
#                                over this many epochs (~a second of
#                                steady traffic — hysteresis already
#                                stops churn), exceeds that one-time
#                                cost; a key whose backlog (in entries ≈
#                                bytes / entry_size) outweighs it is
#                                skipped and counted in
#                                ``stats_skipped_uneconomic``.


class Migration:
    """One planned route change: move ``key`` (owned by ``fdid``) from
    shard ``old_sid`` to ``new_sid``."""

    __slots__ = ("key", "fdid", "old_sid", "new_sid", "load", "new_shift")

    def __init__(self, key: int, fdid: int, old_sid: int, new_sid: int,
                 load: int, new_shift: Optional[int] = None):
        self.key = key
        self.fdid = fdid
        self.old_sid = old_sid
        self.new_sid = new_sid
        self.load = load
        # stripe-width tuning: when set, this "migration" narrows the
        # fdid's stripe to stripe_bytes >> new_shift (widening its fan-out
        # across shards) instead of moving one key — same freeze + drain
        # barrier, different install
        self.new_shift = new_shift

    def __repr__(self) -> str:
        if self.new_shift is not None:
            return (f"Migration(fdid={self.fdid}, widen->shift="
                    f"{self.new_shift}, load={self.load})")
        return (f"Migration(key={self.key:#x}, fdid={self.fdid}, "
                f"{self.old_sid}->{self.new_sid}, load={self.load})")


class EpochRouter:
    """The adaptive route table: static formula + epoch-versioned overrides.

    Thread model: ``route`` is lock-free (it reads one immutable dict
    reference — writers may call it concurrently with an install and see
    either epoch, which the freeze/barrier protocol makes safe);
    ``note_append`` takes a short counter lock; ``install``/``plan`` are
    serialized by the rebalance thread (plus ``_lock`` for safety).
    """

    GUARDED_BY = {
        # immutable-swap tables: installs rebind a fresh dict under _lock,
        # lookups read the reference lock-free and see one epoch or the next
        "epoch": "write:_lock", "table": "write:_lock",
        "stripe_shift": "write:_lock",
        "_key_load": "_lock", "_key_fdid": "_lock", "_streak": "_lock",
        "stats_migrations": "_lock", "stats_epochs": "_lock",
        "stats_installs": "_lock", "stats_skew_ratio": "_lock",
        "stats_skipped_uneconomic": "_lock", "stats_stripe_widenings": "_lock",
    }

    def __init__(self, nvmm: NVMM, policy: Policy, *, sampling: bool = True):
        """``sampling=False`` builds a route-only router (used by
        ``NVLog``'s attach auto-adoption, where no rebalance thread exists
        to drain the per-key counters): lookups honor the persisted table
        but ``note_append`` is a no-op, so the counters cannot leak."""
        self.nvmm = nvmm
        self.policy = policy
        self.sampling = sampling
        self._lock = locking.make_lock("leaf:router")  # installs + counters
        self.epoch = 0                         # guarded-by: write:_lock
        self.table: Dict[int, int] = {}        # key -> sid (immutable; swapped)
        #                                        guarded-by: write:_lock
        self._key_load: Dict[int, int] = {}    # entries appended this epoch
        self._key_fdid: Dict[int, int] = {}    # key -> owning fdid
        #                                        (both guarded-by: _lock)
        # guarded-by: _lock — planner/installer counters; api.stats()
        # reads them through snapshot_stats()
        self.stats_migrations = 0
        self.stats_epochs = 0                  # rebalance ticks evaluated
        self.stats_installs = 0                # epochs actually installed
        self.stats_skew_ratio = 0.0            # last epoch's hot/cold ratio
        self.stats_skipped_uneconomic = 0      # moves rejected by the cost
        #                                        model (barrier > gain)
        self.stats_stripe_widenings = 0        # width-tuning installs
        self._streak: Dict[int, int] = {}      # fdid -> consecutive epochs
        #                                        the planner wanted to move
        #                                        it; guarded-by: _lock
        #                                        (drop_fdid pops from api
        #                                        threads while the planner
        #                                        rebinds it)
        epoch, table, shifts = load_route_record(nvmm, policy)
        self.epoch = epoch
        self.table = table
        self.stripe_shift: Dict[int, int] = shifts  # fdid -> width shift
        #   (immutable like ``table``: installs swap a fresh dict;
        #   guarded-by: write:_lock)

    # ---------------------------------------------------------------- route
    def stripe_bytes_of(self, fdid: int) -> int:
        """Effective stripe width of ``fdid`` (auto-tuning may have narrowed
        it below ``policy.stripe_bytes`` to widen the file's shard fan-out)."""
        return self.policy.stripe_bytes >> self.stripe_shift.get(fdid, 0)

    def key_of(self, fdid: int, off: int) -> Optional[int]:
        if self.policy.shard_route == "fdid":
            return fdid
        stripe = off // self.stripe_bytes_of(fdid)
        if stripe > _STRIPE_MASK:
            return None
        return (fdid << _STRIPE_BITS) | stripe

    @staticmethod
    def key_fdid(key: int, policy: Policy) -> int:
        return key if policy.shard_route == "fdid" else key >> _STRIPE_BITS

    def key_off(self, key: int) -> int:
        """A file offset inside the key's stripe (0 in fdid mode) —
        enough to reconstruct the static route of the key."""
        if self.policy.shard_route == "stripe":
            fdid = key >> _STRIPE_BITS
            return (key & _STRIPE_MASK) * self.stripe_bytes_of(fdid)
        return 0

    def static_route(self, fdid: int, off: int) -> int:
        sh = self.stripe_shift.get(fdid)
        if sh and self.policy.shard_route == "stripe" \
                and self.policy.shards > 1:
            return (fdid + off // (self.policy.stripe_bytes >> sh)) \
                % self.policy.shards
        return self.policy.static_shard(fdid, off)

    def static_sid_of_key(self, key: int) -> int:
        return self.static_route(self.key_fdid(key, self.policy),
                                 self.key_off(key))

    def current_sid(self, key: int) -> int:
        sid = self.table.get(key)
        return sid if sid is not None else self.static_sid_of_key(key)

    def route(self, fdid: int, off: int) -> int:
        key = self.key_of(fdid, off)
        if key is not None:
            sid = self.table.get(key)          # immutable dict: atomic read
            if sid is not None:
                return sid
        return self.static_route(fdid, off)

    # ------------------------------------------------------------- sampling
    def note_append(self, fdid: int, off: int, k_entries: int) -> None:
        if not self.sampling:
            return                             # route-only router: nobody
            #                                    ever drains the counters
        key = self.key_of(fdid, off)
        if key is None:
            return
        with self._lock:
            self._key_load[key] = self._key_load.get(key, 0) + k_entries
            self._key_fdid[key] = fdid

    def shard_loads(self, key_load: Dict[int, int]) -> List[float]:
        """Per-shard load of one epoch: entries appended, by current route."""
        loads = [0.0] * self.policy.shards
        for key, n in key_load.items():
            loads[self.current_sid(key)] += n
        return loads

    # ------------------------------------------------------------- planning
    def plan(self, queue_depths: Optional[List[int]] = None,
             wait_deltas: Optional[List[float]] = None) -> List[Migration]:
        """Close the current sampling epoch and return the migrations to
        perform (possibly empty).  The caller executes each migration under
        the freeze + drain-barrier protocol and then calls :meth:`install`.

        Decision inputs: per-key appended entries drive the hot/cold
        split; ``queue_depths`` (drain backlog) penalizes target shards;
        ``wait_deltas`` (alloc-wait seconds this epoch) breaks ties for
        the hot shard — of two equally-loaded shards, the one writers
        actually stalled on is the one worth relieving.

        Holds ``_lock`` end to end: the planner mutates the epoch counters
        and the migration streaks, which ``drop_fdid`` (api threads) also
        touches.  Pure CPU, once per epoch — writers only contend on their
        short ``note_append`` during the planning instant.
        """
        with self._lock:
            return self._plan_locked(queue_depths, wait_deltas)

    def _plan_locked(self, queue_depths: Optional[List[int]],
                     wait_deltas: Optional[List[float]]) -> List[Migration]:
        key_load = self._key_load
        key_fdid = self._key_fdid
        self._key_load = {}
        self._key_fdid = {}
        self.stats_epochs += 1
        k = self.policy.shards
        if k == 1 or sum(key_load.values()) < MIN_EPOCH_ENTRIES:
            return []
        loads = self.shard_loads(key_load)
        queues = queue_depths if queue_depths is not None else [0] * k
        waits = wait_deltas if wait_deltas is not None else [0.0] * k
        key_sid = {key: self.current_sid(key) for key in key_load}
        # migrations that will need a NEW table slot must fit: planning a
        # move install() will refuse just burns a freeze + drain barrier
        # on the hot file, every epoch, forever
        free_slots = self.policy.route_table_max - len(self.table) \
            - len(self.stripe_shift)
        out: List[Migration] = []
        for g in range(self.policy.placement_groups):
            group = [s for s in range(k)
                     if self.policy.placement_group(s) == g]
            if len(group) < 2:
                continue
            for _ in range(MAX_MIGRATIONS_PER_EPOCH):
                hot = max(group, key=lambda s: (loads[s], waits[s]))
                # target choice penalizes drain backlog: a shard still
                # churning through old entries is a bad home for a hot key
                cold = min(group,
                           key=lambda s: loads[s] + QUEUE_WEIGHT * queues[s])
                self.stats_skew_ratio = loads[hot] / max(1.0, loads[cold])
                if hot == cold or loads[hot] < MIN_RATIO * max(1.0, loads[cold]):
                    break
                # hottest key on the hot shard whose move meaningfully
                # lowers the group's maximum (not merely relocates it),
                # preferring the largest such key.  The cost model then
                # vetoes moves whose drain barrier — flushing the hot
                # shard's whole backlog before the epoch can flip — costs
                # more entries than the move recoups over the horizon.
                cap = (1.0 - MIN_IMPROVEMENT) * loads[hot]
                best = best_any = None
                for key, n in key_load.items():
                    if key_sid[key] != hot or n <= 0:
                        continue
                    if (key not in self.table and free_slots <= 0
                            and cold != self.static_sid_of_key(key)):
                        continue               # would not fit the table
                    if max(loads[hot] - n, loads[cold] + n) <= cap:
                        if best_any is None or n > key_load[best_any]:
                            best_any = key
                        gain = loads[hot] - max(loads[hot] - n,
                                                loads[cold] + n)
                        barrier_cost = queues[hot] * n / max(1.0, loads[hot])
                        if barrier_cost > BARRIER_HORIZON_EPOCHS * gain:
                            continue           # barrier outweighs the gain
                        if best is None or n > key_load[best]:
                            best = key
                if best is None:
                    if best_any is not None:
                        # a move was justified by imbalance but vetoed by
                        # the cost model: surface it, don't pay the barrier
                        self.stats_skipped_uneconomic += 1
                    break
                if best not in self.table \
                        and cold != self.static_sid_of_key(best):
                    free_slots -= 1
                out.append(Migration(best, key_fdid[best], hot, cold,
                                     key_load[best]))
                loads[hot] -= key_load[best]
                loads[cold] += key_load[best]
                key_sid[best] = cold
        return self._tune_widths_locked(out)

    def _tune_widths_locked(self, out: List[Migration]) -> List[Migration]:
        """Stripe-width auto-tuning: a fdid the planner keeps wanting to
        migrate — ``stripe_tune_streak`` consecutive epochs — is hot enough
        that chasing individual stripes (at most ``MAX_MIGRATIONS_PER_EPOCH``
        per epoch, a drain barrier each) never converges.  Replace its
        per-key moves with ONE width change: halving the fdid's stripe
        doubles its shard fan-out, spreading the load by the static formula
        with no per-stripe overrides at all."""
        pol = self.policy
        if pol.shard_route != "stripe" or pol.stripe_tune_streak <= 0 \
                or pol.shards == 1:
            return out
        moved = {m.fdid for m in out}
        # a miss resets the streak: "persistently hot" means consecutive
        self._streak = {f: self._streak.get(f, 0) + 1 for f in moved}
        widened = set()
        tuned: List[Migration] = []
        for fdid in sorted(moved):
            shift = self.stripe_shift.get(fdid, 0)
            if (self._streak.get(fdid, 0) < pol.stripe_tune_streak
                    or shift >= pol.stripe_tune_max_shift
                    or pol.stripe_bytes >> (shift + 1) < pol.page_size
                    # the narrowed stripe must stay page-aligned: a page
                    # spanning two stripes would break the overlap
                    # invariant (and the paged mode's per-page fallback)
                    or (pol.stripe_bytes >> (shift + 1)) % pol.page_size):
                continue
            load = sum(m.load for m in out if m.fdid == fdid)
            tuned.append(Migration(0, fdid, -1, -1, load,
                                   new_shift=shift + 1))
            widened.add(fdid)
            self._streak.pop(fdid, None)
        if not widened:
            return out
        return [m for m in out if m.fdid not in widened] + tuned

    # -------------------------------------------------------------- install
    def install(self, key: int, sid: int) -> bool:
        """Publish a new routing epoch with ``key -> sid`` and persist it.
        Returns False (no epoch change) when the persisted table is full
        even after dropping no-op overrides."""
        with self._lock:
            table = dict(self.table)
            if self.static_sid_of_key(key) == sid:
                table.pop(key, None)           # back to static: drop override
            else:
                table[key] = sid
            cap = self.policy.route_table_max - len(self.stripe_shift)
            if len(table) > cap:
                # drop overrides that merely restate the static route
                for ikey in list(table):
                    if table[ikey] == self.static_sid_of_key(ikey):
                        del table[ikey]
                if len(table) > cap:
                    return False
            self.epoch += 1
            self.table = table                 # atomic publish
            self._persist_locked()
            self.stats_installs += 1
            return True

    def install_width(self, fdid: int, shift: int) -> bool:
        """Publish a stripe-width change for ``fdid`` and persist it.  The
        fdid's per-key overrides are dropped in the same epoch — their
        stripe indices are in old-width units.  The caller holds the file's
        freeze + drain barrier, so no shard holds a live entry routed under
        the old width: the first post-install write can't overlap anything
        the old routing placed elsewhere (same argument as a migration)."""
        pol = self.policy
        if pol.shard_route != "stripe":
            return False
        with self._lock:
            table = {k: s for k, s in self.table.items()
                     if self.key_fdid(k, pol) != fdid}
            shifts = dict(self.stripe_shift)
            if shift <= 0:
                shifts.pop(fdid, None)
            else:
                shifts[fdid] = shift
            if len(table) + len(shifts) > pol.route_table_max:
                return False
            self.epoch += 1
            self.table = table                 # atomic publish (route first:
            self.stripe_shift = shifts         # stale key lookups just miss)
            self._persist_locked()
            self.stats_installs += 1
            self.stats_stripe_widenings += 1
            return True

    def drop_fdid(self, fdid: int) -> bool:
        """Remove (and persist) every override owned by ``fdid`` — called
        when the file table retires the fdid.  The file is fully drained at
        that point (retire requires pending <= 0), so reverting its keys to
        the static route cannot strand live entries; NOT dropping them
        would let dead overrides accumulate until the persisted table hits
        ``route_table_max`` and every future migration fails after paying
        its drain barrier.  Also keeps a reused fdid from inheriting the
        dead file's routing."""
        with self._lock:
            table = {k: s for k, s in self.table.items()
                     if self.key_fdid(k, self.policy) != fdid}
            shifts = {f: s for f, s in self.stripe_shift.items()
                      if f != fdid}
            if len(table) == len(self.table) \
                    and len(shifts) == len(self.stripe_shift):
                return False
            self.epoch += 1
            self.table = table
            self.stripe_shift = shifts
            self._streak.pop(fdid, None)
            self._persist_locked()
            return True

    def _persist_locked(self) -> None:
        """Durably record (epoch, overrides): payload first, pwb+pfence,
        then the CRC'd header, pwb+psync — a crash mid-install leaves either
        the old record or the new one, never a half-record that parses (the
        CRC covers payload + epoch + count)."""
        pol = self.policy
        entries = sorted(self.table.items())
        entries += [(_WIDTH_FLAG | fdid, shift)
                    for fdid, shift in sorted(self.stripe_shift.items())]
        payload = b"".join(_RT_ENT.pack(key, val) for key, val in entries)
        base = pol.route_base
        if payload:
            self.nvmm.store(base + ROUTE_HDR, payload)
            self.nvmm.pwb(base + ROUTE_HDR, len(payload))
            self.nvmm.pfence()
        crc = zlib.crc32(payload + struct.pack("<QI", self.epoch,
                                               len(entries)))
        self.nvmm.store(base, _RT_HDR.pack(self.epoch, len(entries), crc))
        self.nvmm.pwb(base, ROUTE_HDR)
        self.nvmm.psync()

    def snapshot_stats(self) -> Dict[str, float]:
        """Coherent copy of the planner/installer counters for api.stats()
        (they are mutated under ``_lock`` by the rebalance thread)."""
        with self._lock:
            return {
                "epoch": self.epoch,
                "overrides": len(self.table),
                "epochs": self.stats_epochs,
                "installs": self.stats_installs,
                "skew_ratio": self.stats_skew_ratio,
                "skipped_uneconomic": self.stats_skipped_uneconomic,
                "stripe_widenings": self.stats_stripe_widenings,
                "stripe_shifts": len(self.stripe_shift),
            }


def load_route_record(nvmm: NVMM, policy: Policy
                      ) -> Tuple[int, Dict[int, int], Dict[int, int]]:
    """Read the persisted route record as ``(epoch, table, stripe_shifts)``;
    ``(0, {}, {})`` when absent or torn (CRC mismatch — e.g. a crash
    mid-install before the header landed).  Recovery also calls this to
    report the epoch it recovered across."""
    base = policy.route_base
    epoch, count, crc = _RT_HDR.unpack_from(nvmm.load(base, ROUTE_HDR))
    if epoch == 0 and count == 0 and crc == 0:
        return 0, {}, {}
    if count > policy.route_table_max:
        return 0, {}, {}
    payload = bytes(nvmm.load(base + ROUTE_HDR, count * ROUTE_ENT))
    if zlib.crc32(payload + struct.pack("<QI", epoch, count)) != crc:
        return 0, {}, {}
    table: Dict[int, int] = {}
    shifts: Dict[int, int] = {}
    for i in range(count):
        key, val = _RT_ENT.unpack_from(payload, i * ROUTE_ENT)
        if key & _WIDTH_FLAG:
            shifts[key & ~_WIDTH_FLAG] = val
        elif val < policy.shards:
            table[key] = val
    return epoch, table, shifts
