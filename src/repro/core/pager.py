"""The paged NVMM region (layout VERSION 4): in-place dual persistence.

The log (:mod:`repro.core.log`) makes every committed byte pay a double
copy — once into the NVMM log, once again when the drain propagates it to
the backend.  For small synchronous writes that is the right trade (one
fetch-and-add, one flush); for large sequential streams and rewrite-heavy
files it is pure overhead, and the same data keeps transiting the log and
the backend over and over.  The paged region is the second mode (cf. "NVMM
cache design: Logging vs. Paging" and Libnvmmio's per-file mmap idiom): a
pool of ``policy.page_frames`` fixed *frames*, each binding one
(fdid, page) to NVMM-resident bytes that are updated **in place** — an
overwrite replaces the frame's image and appends nothing anywhere.  The
frame then flushes to the backend at most once, lazily (writeback), no
matter how many times it was rewritten.

Frame layout (``policy.frame_size`` bytes each, at ``policy.frame_base(i)``)::

    [header: 1 cacheline | data slot 0: page_size | data slot 1: page_size]

    header = state u32 (0 free / 1 mapped), slot u32 (active data slot),
             page_no u64, seq u64, fdid u32, length u32, crc u32

Commit protocol (ping-pong undo, pwb/pfence/psync-ordered): the new page
image is built in the *inactive* slot, flushed, fenced, and then the
header — which fits one cacheline, so its store is atomic under the crash
model — is rewritten to point at it::

    store(inactive slot, image) -> pwb -> pfence
    -> store(header{slot=inactive, seq, length, crc}) -> pwb -> psync

A crash anywhere leaves either the old header (old image intact in the
still-untouched old slot) or the new header (new image fenced durable
before the flip) — per-page old-or-new, never torn.  ``seq`` is drawn from
the same global counter as log groups (``NVLog.next_seq``), which is the
whole recovery story: :mod:`repro.core.recovery` folds each mapped frame
into the log's cross-shard merge as a one-entry group and replays strictly
by ascending seq, so frames order correctly against log writes, metadata
ops (truncate/unlink/rename) and each other.

Volatile state (rebuilt by :meth:`PagedRegion.attach`, irrelevant after a
crash because recovery replays frames to the backend and reformats): the
free list, the dirty set (frames whose image is newer than the backend),
and the owner map for writeback.  Frame *reuse* is the one place a durable
invalidate matters: a freed frame's header must be durably zeroed before
the frame can be re-allocated, otherwise a crash between the new owner's
slot fill and its header flip could resurrect the old header over the new
owner's bytes.  :meth:`invalidate` batches exactly that
(store+pwb per frame, one psync) before returning frames to the free list.
"""
from __future__ import annotations

import struct
import threading
import zlib
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core import locking
from repro.core.nvmm import NVMM
from repro.core.policy import FRAME_HDR, Policy

_FR = struct.Struct("<IIQQIII")  # state, slot, page_no, seq, fdid, length, crc
assert _FR.size <= FRAME_HDR

FR_FREE = 0
FR_MAPPED = 1


class FrameRec:
    """Decoded view of one mapped frame (recovery / attach scan)."""

    __slots__ = ("idx", "slot", "page_no", "seq", "fdid", "length", "crc",
                 "data")

    def __init__(self, idx, slot, page_no, seq, fdid, length, crc, data):
        self.idx = idx
        self.slot = slot
        self.page_no = page_no
        self.seq = seq
        self.fdid = fdid
        self.length = length
        self.crc = crc
        self.data = data  # memoryview of the active slot's length bytes


def scan_frames(nvmm: NVMM, policy: Policy) -> Iterator[FrameRec]:
    """Yield every mapped frame's header + active image.  Pure read — used
    by recovery's merge and by :meth:`PagedRegion.attach`."""
    ps = policy.page_size
    for i in range(policy.page_frames):
        base = policy.frame_base(i)
        state, slot, page_no, seq, fdid, length, crc = _FR.unpack_from(
            nvmm.load(base, _FR.size))
        if state != FR_MAPPED or slot > 1 or length > ps:
            continue
        data = nvmm.load(base + FRAME_HDR + slot * ps, length)
        yield FrameRec(i, slot, page_no, seq, fdid, length, crc, data)


def max_frame_seq(nvmm: NVMM, policy: Policy) -> int:
    return max((fr.seq for fr in scan_frames(nvmm, policy)), default=0)


class PagedRegion:
    """Frame pool manager.  Thread safety: pool state (free list, dirty
    set, owner map) is guarded by ``self.lock``; the *content* of a frame
    is guarded by its page's ``PageDesc.atomic_lock``, which every caller
    (write path, read miss, writeback, invalidate) already holds — so one
    frame is never written and read concurrently."""

    GUARDED_BY = {
        "free": "lock", "dirty": "lock", "owner": "lock", "_tick": "lock",
        "stats_frame_writes": "lock", "stats_frame_bytes": "lock",
        "stats_cow_bytes": "lock", "stats_writebacks": "lock",
        "stats_invalidated": "lock", "stats_alloc_fail": "lock",
    }

    def __init__(self, nvmm: NVMM, policy: Policy, seq_source):
        self.nvmm = nvmm
        self.policy = policy
        self.page_size = policy.page_size
        self.seq_source = seq_source          # NVLog.next_seq
        self.lock = locking.make_lock("pager_free")
        # guarded-by: lock — the whole pool state (free list, dirty set,
        # owner map, tick) and every stats counter below
        self.free: List[int] = list(range(policy.page_frames - 1, -1, -1))
        self.dirty: Dict[int, int] = {}       # idx -> dirty tick (FIFO age)
        self.owner: Dict[int, Tuple[int, int]] = {}  # idx -> (fdid, page_no)
        self._tick = 0
        self.pressure = threading.Event()     # wakes the writeback thread
        self.stats_frame_writes = 0
        self.stats_frame_bytes = 0            # committed bytes absorbed
        self.stats_cow_bytes = 0              # old bytes re-copied (partial
        #                                       overwrites pay the ping-pong)
        self.stats_writebacks = 0             # frames flushed to the backend
        self.stats_invalidated = 0
        self.stats_alloc_fail = 0             # pool-exhausted log fallbacks

    def attach(self) -> Dict[int, Dict[int, int]]:
        """Rebuild pool state from the region; returns per-fdid frame maps
        ``{fdid: {page_no: idx}}`` for the owner to hand to its files.  All
        surviving frames are conservatively marked dirty (the backend may
        or may not have their bytes — rewriting is idempotent)."""
        mapped: Dict[int, Dict[int, int]] = {}
        with self.lock:
            self.free = []
            self.dirty.clear()
            self.owner.clear()
            for fr in scan_frames(self.nvmm, self.policy):
                mapped.setdefault(fr.fdid, {})[fr.page_no] = fr.idx
                self.owner[fr.idx] = (fr.fdid, fr.page_no)
                self._tick += 1
                self.dirty[fr.idx] = self._tick
            used = set(self.owner)
            self.free = [i for i in range(self.policy.page_frames - 1, -1, -1)
                         if i not in used]
        return mapped

    # ------------------------------------------------------------------ pool
    def alloc(self, fdid: int, page_no: int) -> Optional[int]:
        """Reserve a frame for (fdid, page_no); None when the pool is empty
        (the caller falls back to the log and the writeback path reclaims).
        Non-blocking by design: a writer holds page atomic locks here, and
        the writeback thread needs those same locks to free frames."""
        with self.lock:
            if not self.free:
                self.stats_alloc_fail += 1
                self.pressure.set()
                return None
            idx = self.free.pop()
            self.owner[idx] = (fdid, page_no)
            if len(self.free) < (1.0 - self.policy.page_wb_watermark) * \
                    self.policy.page_frames:
                self.pressure.set()
            return idx

    def invalidate(self, idxs) -> None:
        """Durably free frames: zero each header (store+pwb), one psync,
        then return them to the free list.  See the module docstring for
        why the psync must precede reuse.  Caller holds the pages' atomic
        locks and has already removed the File-side mappings."""
        idxs = list(idxs)
        if not idxs:
            return
        for idx in idxs:
            base = self.policy.frame_base(idx)
            self.nvmm.store(base, b"\x00" * _FR.size)
            self.nvmm.pwb(base, _FR.size)
        self.nvmm.psync()
        with self.lock:
            for idx in idxs:
                self.owner.pop(idx, None)
                self.dirty.pop(idx, None)
                self.free.append(idx)
                self.stats_invalidated += 1

    # ----------------------------------------------------------------- write
    def frame_write(self, idx: int, fdid: int, page_no: int, s: int, e: int,
                    data, base_image: Optional[bytes], valid: int) -> None:
        """Commit one write of ``data`` into page range ``[s, e)`` of frame
        ``idx`` — the in-place overwrite protocol (module docstring).

        ``base_image``/``valid`` seed a *fresh* frame: the page's committed
        bytes (None == the frame already holds them in its active slot) and
        how many of them are meaningful.  Caller holds the page's
        atomic_lock.
        """
        ps = self.page_size
        fb = self.policy.frame_base(idx)
        state, slot, pno, _seq, _fdid, length, _crc = _FR.unpack_from(
            self.nvmm.load(fb, _FR.size))
        if state == FR_MAPPED:
            if pno != page_no:
                raise RuntimeError("frame/page mismatch (stale mapping)")
            new_slot = 1 - slot
            old = self.nvmm.load(fb + FRAME_HDR + slot * ps, length)
        else:
            new_slot, length = 0, min(valid, ps)
            old = (base_image or b"")[:length]
        img = bytearray(max(length, e))
        img[:len(old)] = old
        img[s:e] = data
        new_len = len(img)
        cow = max(0, len(old) - (e - s))
        crc = zlib.crc32(bytes(img)) if self.policy.verify_crc else 0
        seq = self.seq_source()
        doff = fb + FRAME_HDR + new_slot * ps
        self.nvmm.store(doff, bytes(img))
        self.nvmm.pwb(doff, new_len)
        self.nvmm.pfence()
        self.nvmm.store(fb, _FR.pack(FR_MAPPED, new_slot, page_no, seq,
                                     fdid, new_len, crc))
        self.nvmm.pwb(fb, _FR.size)
        self.nvmm.psync()
        with self.lock:
            self._tick += 1
            self.dirty.setdefault(idx, self._tick)
            self.stats_frame_writes += 1
            self.stats_frame_bytes += e - s
            # counted here, not at the unlocked computation site: two
            # writers in frame_write for different pages race otherwise
            self.stats_cow_bytes += cow

    def truncate_frame(self, idx: int, new_len: int) -> None:
        """Durably clip a frame's valid length (file shrank mid-page): a
        header-only rewrite — the active image is untouched."""
        ps = self.page_size
        fb = self.policy.frame_base(idx)
        state, slot, pno, _seq, fdid, length, _crc = _FR.unpack_from(
            self.nvmm.load(fb, _FR.size))
        if state != FR_MAPPED or new_len >= length:
            return
        img = self.nvmm.load(fb + FRAME_HDR + slot * ps, new_len)
        crc = zlib.crc32(bytes(img)) if self.policy.verify_crc else 0
        seq = self.seq_source()
        self.nvmm.store(fb, _FR.pack(FR_MAPPED, slot, pno, seq, fdid,
                                     new_len, crc))
        self.nvmm.pwb(fb, _FR.size)
        self.nvmm.psync()
        with self.lock:
            self._tick += 1
            self.dirty.setdefault(idx, self._tick)

    # ------------------------------------------------------------------ read
    def read(self, idx: int) -> Tuple[memoryview, int]:
        """Active image of a mapped frame as ``(view, length)``.  Caller
        holds the page's atomic_lock (no concurrent flip)."""
        ps = self.page_size
        fb = self.policy.frame_base(idx)
        state, slot, _pno, _seq, _fdid, length, _crc = _FR.unpack_from(
            self.nvmm.load(fb, _FR.size))
        if state != FR_MAPPED:
            raise RuntimeError(f"read of unmapped frame {idx}")
        return self.nvmm.load(fb + FRAME_HDR + slot * ps, length), length

    # ------------------------------------------------------------- writeback
    def mark_clean(self, idx: int) -> None:
        with self.lock:
            self.dirty.pop(idx, None)
            self.stats_writebacks += 1

    def dirty_victims(self, limit: int) -> Dict[int, List[int]]:
        """Oldest-first dirty frames grouped by owning fdid (for the
        background writeback path), at most ``limit`` frames."""
        with self.lock:
            oldest = sorted(self.dirty, key=self.dirty.__getitem__)[:limit]
            out: Dict[int, List[int]] = {}
            for idx in oldest:
                own = self.owner.get(idx)
                if own is not None:
                    out.setdefault(own[0], []).append(idx)
            return out

    def over_watermark(self) -> bool:
        with self.lock:
            n = self.policy.page_frames
            return n > 0 and len(self.dirty) >= self.policy.page_wb_watermark * n

    @property
    def frames_used(self) -> int:
        with self.lock:
            return self.policy.page_frames - len(self.free)

    @property
    def frames_dirty(self) -> int:
        with self.lock:
            return len(self.dirty)

    def snapshot_stats(self) -> Dict[str, int]:
        """Coherent point-in-time copy of the pool counters (api.stats()
        reads this instead of the live fields — the writeback thread and
        concurrent writers mutate them under ``lock``)."""
        with self.lock:
            return {
                "frame_writes": self.stats_frame_writes,
                "frame_bytes": self.stats_frame_bytes,
                "cow_bytes": self.stats_cow_bytes,
                "writebacks": self.stats_writebacks,
                "invalidated": self.stats_invalidated,
                "alloc_fail": self.stats_alloc_fail,
                "frames_used": self.policy.page_frames - len(self.free),
                "frames_dirty": len(self.dirty),
            }
