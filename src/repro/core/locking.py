"""Global lock hierarchy and the registered-lock factories.

Every ``threading.Lock``/``RLock``/``Condition`` constructed inside
``repro.core`` MUST come from :func:`make_lock` / :func:`make_rlock` /
:func:`make_condition` with a class name drawn from the table below —
``repro.analysis.lint`` enforces this statically, and
``repro.analysis.lockcheck`` uses the registration to trace acquisitions
at runtime under ``pytest --sanitize``.

LOCK HIERARCHY (parsed by repro.analysis.lint — keep the column format):

    level  class          multi  owner
    -----  -------------  -----  -----------------------------------------
    10     meta                  Namespace.lock — the "_meta" file-table
                                 lock (api.NVCache._meta aliases it)
    20     route_gate            File._route_cv — per-file route freeze
                                 gate (enter/exit/freeze protocol)
    30     page_atomic    multi  PageDesc.atomic_lock, ascending page_no
    40     page_cleanup   multi  PageDesc.cleanup_lock, ascending page_no
    50     shard                 LogShard._lock (+ the _space/_committed
                                 conditions sharing it)
    60     pager_free            PagedRegion.lock — paged-frame free list
    90     leaf:seq              NVLog._seq_lock
    90     leaf:ref              PageDesc.ref_lock
    90     leaf:size             File.size_lock
    90     leaf:drained          File._drained condition
    90     leaf:cursor           OpenFile.cursor_lock
    90     leaf:lru              LRUCache._lock
    90     leaf:radix            RadixTree._insert_lock
    90     leaf:router           EpochRouter._lock
    90     leaf:ns_unapplied     Namespace._ua_lock (+ _consumed)
    90     leaf:ns_apply         Namespace._apply_lock
    90     leaf:drain_gate       CleanupThread._drain_lock
    90     leaf:fsync_sched      FsyncEpochScheduler._lock
    90     leaf:fsync_epoch      drain._SyncState.cond
    90     leaf:atomic_int       AtomicInt._lock
    90     leaf:obs              obs.metrics cell-list/registry locks
                                 (cold paths only: first touch per
                                 thread, snapshot on read)
    90     leaf:flight           obs.flight.FlightRecorder._lock — flight
                                 ring slot allocation

Rules (checked by repro.analysis.lockcheck at runtime):

* A thread may only *block* on an ordered lock (level < 90) whose level is
  strictly greater than the highest ordered level it already holds.
* ``multi`` classes may stack same-class acquisitions when the order keys
  are strictly increasing (page locks are taken in ascending page order).
* ``leaf:`` locks (level 90) are terminal by convention — they protect
  short critical sections and never *block* on an ordered lock while
  held.  The checker does not enforce levels for them but still records
  their edges in the global acquisition graph, so a cycle through a leaf
  is reported.
* Non-blocking (try-lock) acquisitions are exempt from level checks —
  they cannot deadlock — but successful ones still count as held
  (``NVCache._reap_file``'s try-lock of ``meta`` and the LRU's try-lock
  eviction rely on this).

Why ``shard`` ranks *after* the page locks (the paper's Alg. 1 narrative
reads log-then-page): the write path (`api.NVCache._pwrite_op`) holds the
touched pages' ``page_atomic`` locks across the whole group commit — the
``on_alloc`` ref registration and the loaded-page patch must be atomic
with the append — so ``LogShard._lock`` is acquired (inside ``alloc`` and
the commit notify) while page locks are held, never the reverse.
Likewise the dirty-miss replay holds ``page_cleanup`` while reading shard
state.  The hierarchy records the code's true order; the commit
*protocol* ordering (entries before head flag before psync) is pmcheck's
job, not this table's.

GUARDED-BY CONTRACT (the second source-of-truth table)
------------------------------------------------------

Alongside the hierarchy, every core class with cross-thread mutable
state declares *which lock guards which field* in a class-level
``GUARDED_BY`` dict, with a ``# guarded-by:`` comment at the field's
definition site.  The declarations are enforced two ways: statically by
``repro.analysis.lint`` (L004 — guarded field accessed outside a
``with <its guard>`` block; L005 — public mutable attribute of a
lock-owning class with no declaration) and at runtime by
``repro.analysis.racecheck`` (RC003 — guarded field touched without the
guard held, plus the RC001/RC002 lockset+vector-clock race analysis).

Spec grammar — ``GUARDED_BY = {"field": spec, ...}`` where spec is:

* ``"attr"``           — the lock at ``self.attr`` must be held for
                         every read and write (once the field is shared
                         between threads);
* ``("a", "b", ...)``  — any-of: condition variables sharing one lock
                         (e.g. a shard's ``_lock``/``_space``/
                         ``_committed``) — holding any satisfies;
* ``"write:attr"``     — writes require the lock; reads are lock-free
                         by design (immutable-swap tables: the router's
                         epoch table, the radix tree) and excluded from
                         the read-write race analysis;
* ``None``             — no lock: ordering comes from happens-before
                         edges only (thread-confined state published at
                         start/join/Event handoffs, e.g. the drain
                         thread's span carry).  racecheck still applies
                         the epoch analysis, but not RC003;
* ``VOLATILE``         — racy by design (approximate counters,
                         opportunistic hints).  Excluded from every
                         check; keep rare and justified in the
                         ``# guarded-by:`` comment.

Subclasses inherit and may extend the parent's table; use
:func:`guards` to read the merged view.
"""
from __future__ import annotations

import re
import threading
from typing import Dict, Optional

LEAF_LEVEL = 90

_ROW = re.compile(r"^\s+(\d+)\s+((?:leaf:)?[a-z_]+)(\s+multi)?(?:\s|$)")


def parse_hierarchy(doc: Optional[str] = None) -> Dict[str, dict]:
    """Parse the LOCK HIERARCHY table out of this module's docstring (the
    single source of truth — lint.py calls this too).  Returns
    ``{class_name: {"level": int, "multi": bool}}``."""
    table: Dict[str, dict] = {}
    in_table = False
    for line in (doc or __doc__).splitlines():
        if "LOCK HIERARCHY" in line:
            in_table = True
            continue
        if not in_table:
            continue
        if line.strip().startswith(("level", "-----")):
            continue
        m = _ROW.match(line)
        if m:
            lvl, name, multi = int(m.group(1)), m.group(2), bool(m.group(3))
            table[name] = {"level": lvl, "multi": multi}
        elif line.strip() == "" and table:
            break  # blank line ends the table
    return table


HIERARCHY: Dict[str, dict] = parse_hierarchy()

#: guarded-by spec for fields that are racy by design (see the
#: GUARDED-BY CONTRACT section of the module docstring)
VOLATILE = "volatile"


def guards(cls: type) -> Dict[str, object]:
    """Merged ``GUARDED_BY`` view of ``cls`` across its MRO (subclasses
    inherit the parent's declarations and may extend/override them).
    Returns ``{}`` for classes with no declarations."""
    merged: Dict[str, object] = {}
    for c in reversed(cls.__mro__):
        own = c.__dict__.get("GUARDED_BY")
        if own:
            merged.update(own)
    return merged

# Installed by repro.analysis.sanitize before any stack is constructed;
# when None the factories return raw threading primitives (zero overhead).
_tracer = None


def set_tracer(tracer) -> None:
    global _tracer
    _tracer = tracer


def _check_name(name: str) -> dict:
    info = HIERARCHY.get(name)
    if info is None:
        raise ValueError(f"lock class {name!r} not in the hierarchy table "
                         f"(core/locking.py docstring)")
    return info


def make_lock(name: str, order_key=None, group=None):
    """A ``threading.Lock`` registered under hierarchy class ``name``.

    ``order_key`` orders same-class acquisitions of ``multi`` classes
    (e.g. ``page_no``); ``group`` scopes that comparison (e.g. the owning
    file) so unrelated key spaces are not compared."""
    info = _check_name(name)
    if _tracer is None:
        return threading.Lock()
    return _tracer.traced_lock(name, info, order_key=order_key, group=group)


def make_rlock(name: str):
    """A ``threading.RLock`` registered under hierarchy class ``name``."""
    info = _check_name(name)
    if _tracer is None:
        return threading.RLock()
    return _tracer.traced_lock(name, info, rlock=True)


def make_condition(name: str, lock=None):
    """A ``threading.Condition`` registered under hierarchy class ``name``.

    With ``lock`` given (already a registered lock) the condition shares
    it — acquisitions through the condition are traced via the shared
    lock.  Without one, a fresh registered RLock backs it (``Condition()``
    semantics)."""
    if lock is None:
        lock = make_rlock(name)
    else:
        _check_name(name)
    return threading.Condition(lock)
