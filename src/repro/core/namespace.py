"""Durable namespace subsystem: logged metadata operations with
crash-consistent create/rename/unlink/ftruncate.

Why this exists (paper §IV, §II)
--------------------------------
The paper's headline experiments run *unmodified legacy applications* —
SQLite and RocksDB — over NVCache.  Both derive their crash consistency
from **metadata** protocols, not data writes: SQLite's rollback-journal
commit point is the *unlink* of the journal (and WAL mode resets the WAL
with a truncate), while RocksDB installs a new MANIFEST by *renaming* it
into place.  A data-plane-only cache (paper §II: the write log holds file
bytes) lets a crash lose a create/rename/unlink the application already
observed as durable, silently breaking those protocols.  NVLog
(arXiv:2408.02911) journals exactly these operations in NVM for the same
reason.

Design
------
The namespace owns the path→fdid map (the paper's §III "file table",
previously inline in :class:`repro.core.api.NVCache`) and persists every
namespace mutation as a first-class NVMM log entry
(:data:`repro.core.log.META_FDID`, ops ``MOP_CREATE``/``MOP_RENAME``/
``MOP_UNLINK``/``MOP_FTRUNCATE``) committed through the **same per-shard
alloc/fill/commit protocol as data writes** (paper §II-D).  Because the
global commit ``seq`` is drawn inside the shard allocation lock, the
cross-shard seq-merge that recovery already performs totally orders every
metadata op against every data group, and replaying the union in ascending
seq rebuilds the namespace exactly as the application observed it.

The per-op commit protocol maps onto the paper's §II guarantees:

* **Synchronous durability** (§II, Table III): the metadata record is
  committed in the NVMM log — followers, pwb, head commit flag, psync —
  *before* the backend (slow-tier) namespace is touched and before the
  call returns.  An acknowledged rename/unlink survives any crash.
* **Durable linearizability** (§III): the caller first quiesces the file
  behind the shared drain barrier (the one close/O_TRUNC/route-migration
  already use), so every covered data entry has a smaller ``seq`` and has
  already drained; writes after the op observe the new namespace.  The
  recovery merge therefore can never attribute renamed data to the old
  name or resurrect an unlinked file's bytes.
* **Old-or-new, never torn**: the record commits atomically through the
  entry group's head commit flag (one 8-byte store), and recovery drops a
  torn group *whole* (the PR-4 rule).  A crash at any point leaves the
  namespace in the pre-op or post-op state — exactly the atomicity the
  legacy protocols assume of the kernel.

Drain coordination
------------------
Between "record committed in the log" and "backend effect applied" the
entry must not be retired — a crash in that window must still replay the
op.  The namespace registers a **not-yet-applied marker** for the entry in
:meth:`Namespace.journal_locked`'s pre-commit ``on_alloc`` hook (the same trick
the dirty-page index uses, so the drain can never observe the entry
without its marker) and clears it in :meth:`Namespace.mark_applied` once
the backend namespace mutation is done.  The drain
(:meth:`repro.core.cleanup.CleanupThread._consume_batch`) stops a batch
short of the first still-marked metadata entry and retries — deletes and
backend renames are thus consumed only after they are both *covered*
(barrier) and *applied*.  Recovery replays a still-logged op idempotently.

Deferred backend apply
----------------------
``rename`` used to apply its backend effect synchronously under the
file-table lock, stalling every racing namespace op behind a slow-tier
directory update.  It now journals under the lock and enqueues the apply
(:meth:`Namespace.queue_apply`); the queue is drained FIFO by
:meth:`Namespace.apply_deferred` — called by the renaming thread itself
right after releasing the lock (so the backend is current by the time the
call returns), by any namespace op that is about to consult backend state,
and by the drain threads whenever an unapplied record blocks a batch (the
drain's meta-apply path: progress never depends on the original caller).
Applying is idempotent to re-entry because the queue pops under its own
lock and each apply runs exactly once.
"""
from __future__ import annotations

import collections
import threading
from typing import Dict, List, Optional, Set, Tuple

from repro.core import locking
from repro.core.log import (MOP_CREATE, MOP_FTRUNCATE, MOP_RENAME,
                            MOP_UNLINK, NVLog, encode_meta)

__all__ = ["Namespace", "MOP_CREATE", "MOP_RENAME", "MOP_UNLINK",
           "MOP_FTRUNCATE"]

#: generous bound for metadata appends: a namespace op behind a full log
#: waits for the drain like any writer, but must not hang forever
META_APPEND_TIMEOUT = 30.0


class Namespace:
    """The path→fdid map plus the metadata journaling protocol.

    ``lock`` is the file-table lock (what :class:`~repro.core.api.NVCache`
    historically called ``_meta``); the owner takes it around every
    file-table mutation, including the journal+apply step of a namespace
    op, so a concurrent ``open`` can never slip between an unlink's
    journal record and its backend effect.
    """

    GUARDED_BY = {
        # mutated only under ``lock`` (the *_locked helpers); read lock-free
        # by the drain's resolve and by existence probes — safe because a
        # file with pending entries is never unbound, so any binding the
        # drain observes is stable
        "files": "write:lock", "by_fdid": "write:lock",
        "fdid_free": "lock",
        # journaled-record markers: mutated under _ua_lock (the _consumed
        # condition shares it); has_unapplied's lock-free read is a cheap
        # maybe-stale pre-check by design, hence write-only
        "_unapplied": "write:_ua_lock",
        "_live": ("_ua_lock", "_consumed"),
        # append under the caller-held meta lock, popleft under _apply_lock;
        # deque ops are individually atomic and FIFO order is preserved
        "_deferred": locking.VOLATILE,
        "stats_meta_ops": "lock", "stats_meta_entries": "lock",
        "stats_deferred_applies": "_apply_lock",
    }

    def __init__(self, log: NVLog, tier, fd_max: int):
        self.log = log
        self.tier = tier
        self.lock = locking.make_lock("meta")
        # guarded-by: write:lock — see GUARDED_BY for the read-side story
        self.files: Dict[str, object] = {}       # path -> api.File
        self.by_fdid: Dict[int, object] = {}
        self.fdid_free: List[int] = list(range(fd_max - 1, -1, -1))
        #                                          guarded-by: lock
        self._unapplied: Set[Tuple[int, int]] = set()  # {(sid, idx)}
        #                                guarded-by: write:_ua_lock
        self._live: Set[Tuple[int, int]] = set()       # journaled, not yet
        #                                                consumed by the
        #                                                drain; guarded-by:
        #                                                _ua_lock/_consumed
        self._ua_lock = locking.make_lock("leaf:ns_unapplied")
        self._consumed = locking.make_condition("leaf:ns_unapplied", self._ua_lock)
        self._deferred = collections.deque()      # (seq, fn, marks) FIFO
        #                                           guarded-by: volatile
        self._apply_lock = locking.make_lock("leaf:ns_apply")  # serializes appliers
        self.stats_meta_ops = {"create": 0, "rename": 0, "unlink": 0,
                               "ftruncate": 0}    # guarded-by: lock
        self.stats_meta_entries = 0               # log entries appended
        #                                           guarded-by: lock
        self.stats_deferred_applies = 0           # queued backend applies
        #                                           run; guarded-by:
        #                                           _apply_lock

    # ------------------------------------------------------------ journal
    def journal_locked(self, op: int, fdid: int, aux: int, a: str,
                       b: str = "") -> Tuple[List[Tuple[int, int]], int]:
        """Durably commit one metadata record; returns ``(marks, seq)``.
        Caller holds :attr:`lock` (every namespace op journals inside its
        file-table critical section — that is what keeps a concurrent open
        from slipping between journal record and backend effect).
        The caller applies the backend effect, then calls
        :meth:`note_backend_applied` with ``seq`` and (in a ``finally``)
        :meth:`mark_applied` with ``marks``.  The markers are registered
        pre-commit, so there is no window in which the drain could retire
        the record before the effect lands."""
        payload = encode_meta(op, fdid, aux, a, b)
        marks: List[Tuple[int, int]] = []

        def on_alloc(sid: int, head: int, k: int, seq: int) -> None:
            with self._ua_lock:
                for j in range(k):
                    self._unapplied.add((sid, head + j))
                    self._live.add((sid, head + j))
                    marks.append((sid, head + j))

        _sid, _head, k, seq = self.log.append_meta(
            payload, route_key=a, timeout=META_APPEND_TIMEOUT,
            on_alloc=on_alloc)
        self.stats_meta_entries += k
        name = {MOP_CREATE: "create", MOP_RENAME: "rename",
                MOP_UNLINK: "unlink", MOP_FTRUNCATE: "ftruncate"}[op]
        self.stats_meta_ops[name] += 1
        return marks, seq

    def snapshot_stats(self) -> dict:
        """Coherent copy of the metadata counters for api.stats(): each
        counter is read under its own guard, never bare."""
        with self.lock:
            ops = dict(self.stats_meta_ops)
            entries = self.stats_meta_entries
        with self._apply_lock:
            deferred = self.stats_deferred_applies
        return {"meta_ops": ops, "meta_entries": entries,
                "deferred_applies": deferred}

    def note_backend_applied(self, seq: int) -> None:
        """Advance the backend's **applied watermark**: the tier records
        (durably, as part of applying — a journaling filesystem's dir
        update) the seq of the last namespace op reflected in it.  Recovery
        replays only ops ABOVE the surviving watermark: replaying an
        already-applied rename/unlink against a backend whose state has
        moved past it is not idempotent (a re-created source would be
        dragged over the destination, a re-created path unlinked again) —
        the watermark is what makes namespace replay old-or-new instead.

        Monotone under the lock: two ops whose applies interleave (an
        ftruncate racing an unlink of another file) must never let the
        lower seq overwrite the higher one — a regressed watermark would
        make recovery re-apply an op the backend already moved past."""
        with self._ua_lock:
            if seq > getattr(self.tier, "ns_seq", 0):
                self.tier.ns_seq = seq

    def mark_applied(self, marks: List[Tuple[int, int]]) -> None:
        """The backend namespace effect of a journaled op is applied (and,
        in the device model, durable): the drain may now consume it."""
        with self._ua_lock:
            self._unapplied.difference_update(marks)

    # ------------------------------------------------------ deferred apply
    def queue_apply(self, seq: int, fn, marks: List[Tuple[int, int]]) -> None:
        """Enqueue a journaled op's backend effect (see the module
        docstring).  The record's unapplied markers stay set until the
        apply runs, so the drain cannot retire it early."""
        self._deferred.append((seq, fn, marks))

    def apply_deferred(self) -> int:
        """Run every queued backend apply, FIFO; returns how many ran.
        Safe from any thread (appliers serialize on ``_apply_lock``); an
        apply that raises still advances the watermark and clears its
        markers — the journaled record was consumed conceptually, and
        leaving the markers set would wedge the drain forever — then the
        error propagates to whichever applier happened to pop it."""
        ran = 0
        with self._apply_lock:
            while True:
                try:
                    seq, fn, marks = self._deferred.popleft()
                except IndexError:
                    return ran
                try:
                    fn()
                finally:
                    self.note_backend_applied(seq)
                    self.mark_applied(marks)
                    self.stats_deferred_applies += 1
                    ran += 1

    # ---------------------------------------------------------- drain gate
    def has_unapplied(self) -> bool:
        """Cheap pre-check for the drain: almost always False, so batches
        skip the per-entry scan entirely."""
        return bool(self._unapplied)

    def meta_blocked(self, sid: int, idx: int) -> bool:
        """True while the entry's backend effect has not been applied —
        the drain must not consume past it."""
        with self._ua_lock:
            return (sid, idx) in self._unapplied

    def note_consumed(self, sid: int, start: int, count: int) -> None:
        """The drain durably retired ``[start, start+count)`` of shard
        ``sid``: drop any namespace records in that range and wake
        :meth:`wait_consumed` waiters."""
        with self._consumed:
            if not self._live:
                return
            dead = [m for m in self._live
                    if m[0] == sid and start <= m[1] < start + count]
            if dead:
                self._live.difference_update(dead)
                self._consumed.notify_all()

    def wait_consumed(self, timeout: Optional[float] = None) -> bool:
        """Block until every journaled record has been retired from the
        log — the namespace half of the ``flush()`` barrier (a File's
        ``pending`` counter covers only data entries)."""
        with self._consumed:
            return self._consumed.wait_for(lambda: not self._live,
                                           timeout=timeout)

    # ------------------------------------------------------------ fd slots
    def alloc_fdid_locked(self) -> int:
        """Caller holds :attr:`lock`."""
        if not self.fdid_free:
            raise OSError("fd table full")
        return self.fdid_free.pop()

    def free_fdid_locked(self, fdid: int) -> None:
        """Caller holds :attr:`lock`; the fdid's entries must be drained."""
        self.fdid_free.append(fdid)

    def bind_locked(self, path: str, f: object) -> None:
        """Caller holds :attr:`lock`."""
        self.files[path] = f
        self.by_fdid[f.fdid] = f

    def unbind_locked(self, f: object) -> None:
        """Caller holds :attr:`lock`."""
        self.files.pop(f.path, None)
        self.by_fdid.pop(f.fdid, None)

    def lookup(self, path: str) -> Optional[object]:
        return self.files.get(path)

    def resolve(self, fdid: int) -> Optional[object]:
        return self.by_fdid.get(fdid)
