"""Page-coalescing drain engine: a two-phase **plan/apply** propagation path.

The paper's cleanup thread (§II-A step 6) forwards log entries to the slow
tier one ``pwrite`` at a time and leans on the kernel page cache to
write-combine them before they hit the device (§IV-C: batching works
*because* the kernel merges the small writes).  This module makes that
write-combining explicit and moves it above the syscall boundary, the way
dm-writeboost submits one bio for hundreds of data+metadata blocks:

* **Phase 1 — plan** (:func:`build_plan`): walk the batch's committed
  entries in shard-log order and group them by (file, page).  Overlapping
  and adjacent entries are merged into *materialized page images* (the
  paper's "the kernel combines the writes", §IV-C, done eagerly in user
  space), and runs of contiguous pages are coalesced into *extents*, so
  each dirty backend page is written at most once per batch no matter how
  many small log entries touched it.
* **Phase 2 — apply** (:func:`apply_plan`): take the cleanup locks of the
  affected pages (the reader/cleanup exclusion of §II-D), issue the extents
  as vectored ``pwritev`` calls (one syscall per file per batch instead of
  one per entry), and retire each page's entry refs from the dirty-page
  index (:class:`~repro.core.readcache.PageDesc`) — the accounting that
  step 6 of §II-A does per entry, done per page here.

Durability ordering is unchanged from the paper: nothing in the log is
retired (:meth:`~repro.core.log.LogShard.consume`) until the extents are
written *and* fsynced, so a power loss at any plan/apply point replays the
whole batch from the log — extent writes are idempotent prefixes of that
replay.  Refs are retired only after the covering extent reached the
backend, so a dirty-miss read that interleaves with apply always finds
either the ref (and replays from NVMM) or the bytes (in the backend).

:class:`FsyncEpochScheduler` is the cross-shard half of the story
(§IV-C's one-fsync-per-batch, generalized to K drain threads): concurrent
per-shard fsyncs against the same backend file are merged into epochs —
callers that arrive while an fsync is in flight share the single next one.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional

from repro.core import locking
from repro.core.log import CG_HEAD, META_FDID, LogShard
from repro.core.policy import Policy

# fault-injection / power-loss checkpoint tags, in batch order
PLAN_ENTRY = "plan:entry"
APPLY_FILE = "apply:file"
APPLY_EXTENT = "apply:extent"
APPLY_RETIRE = "apply:retire"
FSYNC = "fsync"
CONSUME = "consume"

AbortFn = Callable[[str], bool]


class Extent:
    """One contiguous backend write: merged bytes plus, per covered page,
    the entry indices whose refs it retires once written."""

    __slots__ = ("off", "data", "pages", "retire")

    def __init__(self, off: int, data: bytearray,
                 pages: List[int], retire: Dict[int, List[int]]):
        self.off = off
        self.data = data
        self.pages = pages            # covered page numbers, ascending
        self.retire = retire          # page_no -> [entry idx] to retire

    def __len__(self) -> int:
        return len(self.data)


class FilePlan:
    __slots__ = ("file", "extents", "entries", "nbytes")

    def __init__(self, file):
        self.file = file
        self.extents: List[Extent] = []
        self.entries = 0              # log entries drained for this file
        self.nbytes = 0


class DrainPlan:
    """Phase-1 output: per-file extent lists for one batch of one shard."""

    __slots__ = ("sid", "start", "run", "files", "orphans", "meta_entries")

    def __init__(self, sid: int, start: int, run: int):
        self.sid = sid
        self.start = start
        self.run = run
        self.files: List[FilePlan] = []
        self.orphans = 0              # entries whose file is gone (dropped)
        self.meta_entries = 0         # namespace records in the batch (their
        #                               backend effect is already applied —
        #                               the caller's gate guarantees it — so
        #                               the drain only retires them)


class _PageImage:
    """A page being materialized: merged byte ranges + contributing entries."""

    __slots__ = ("buf", "ranges", "spans")

    def __init__(self, page_size: int):
        self.buf = bytearray(page_size)
        self.ranges: List[tuple] = []   # merged covered [s, e), page-relative
        self.spans: List[tuple] = []    # (idx, s, e) per contributing entry

    def add(self, s: int, e: int, data, idx: int) -> None:
        self.buf[s:e] = data
        self.spans.append((idx, s, e))
        ns, ne = s, e
        out = []
        for a, b in self.ranges:
            if b < ns or a > ne:        # disjoint and not adjacent
                out.append((a, b))
            else:                       # overlap or touch: absorb
                ns, ne = min(a, ns), max(b, ne)
        out.append((ns, ne))
        out.sort()
        self.ranges = out


class _FileAcc:
    __slots__ = ("file", "pages", "raw", "entries", "nbytes")

    def __init__(self, file):
        self.file = file
        self.pages: Dict[int, _PageImage] = {}
        self.raw: List[tuple] = []      # legacy mode: (off, bytes, idx)
        self.entries = 0
        self.nbytes = 0


def choose_deferred_suffix(shard: LogShard, start: int, run: int,
                           policy: Policy) -> int:
    """Batch-spanning coalescing, phase 0: how many log-order tail entries
    of this batch to leave *unconsumed* so the next batch's contiguous
    entries merge into the same backend write (the way NVLog keeps its tail
    extent open across syncs).

    The carried suffix is the maximal run of whole committed groups,
    walking back from the batch tail, that (a) belong to one file, (b)
    union into a single contiguous byte interval — the open tail extent —
    and (c) lie inside ONE page-aligned page: the open tail *page*.  The
    page boundary is the natural cut because a page whose bytes are all
    present can never be improved by further coalescing (it is written once
    either way), while the still-filling tail page is exactly what a small
    trailing batch would otherwise rewrite per batch; the one-page cap also
    keeps the carry negligible for big saturated batches (no latency
    hiccups).  Deferring is merely *not draining yet*: the entries stay
    committed in the log, their dirty-page-index refs stay live, reads
    replay them and recovery replays them — every durability invariant
    holds by construction, and the next batch's plan re-materializes them
    together with the new entries (write-combined across the batch
    boundary).  The caller enforces the deadline / drain-barrier / space
    conditions and never defers past them.
    """
    if run <= 0:
        return 0
    # only the tail can be carried, so only the tail needs scanning: a
    # 1-page suffix spans at most ceil(ps/entry_data) entries per group and
    # a handful of groups — scanning the whole batch here would duplicate
    # build_plan's O(run) scan for a decision about the last page.  A scan
    # landing mid-group sees that group's followers as holes and skips
    # them, so `groups` holds only whole groups, never a truncated one.
    window = min(run, 4 * (-(-policy.page_size // policy.entry_data)) + 8)
    lo_idx = start + run - window
    # whole committed groups of the window: [nentries, fdid, lo, hi)
    groups: List[list] = []
    for e in shard.scan_committed(lo_idx, start + run):
        if e.cg == CG_HEAD:
            groups.append([1 + e.nfollow, e.fdid, e.off, e.off + e.length])
        elif groups:
            g = groups[-1]
            g[2] = min(g[2], e.off)
            g[3] = max(g[3], e.off + e.length)
    ps = policy.page_size
    defer = 0
    lo = hi = fdid = None
    for cnt, fid, glo, ghi in reversed(groups):
        if fid == META_FDID:
            break                       # namespace record: never carried (it
            #                             is not file bytes, and holding it
            #                             back would delay its retirement)
        if ghi <= glo:
            break                       # empty group: nothing to carry
        if lo is None:
            nlo, nhi = glo, ghi
        elif fid != fdid or ghi < lo or glo > hi:
            break                       # different file / not contiguous
        else:
            nlo, nhi = min(lo, glo), max(hi, ghi)
        if nlo // ps != (nhi - 1) // ps:
            break                       # crosses the open page: close it
        lo, hi, fdid = nlo, nhi, fid
        defer += cnt
    return defer


def build_plan(shard: LogShard, start: int, run: int,
               resolve_file: Callable[[int], Optional[object]],
               policy: Policy, *, abort: Optional[AbortFn] = None
               ) -> Optional[DrainPlan]:
    """Phase 1: group the batch's committed entries by (file, page), merge
    them into page images, and coalesce page runs into extents.

    Returns ``None`` if ``abort`` fired (power loss / fault injection):
    nothing has been written or retired, the log replays the batch.
    """
    ps = policy.page_size
    plan = DrainPlan(shard.sid, start, run)
    accs: Dict[int, _FileAcc] = {}      # id(file) -> accumulator
    order: List[_FileAcc] = []
    for e in shard.scan_committed(start, start + run):
        if abort is not None and abort(PLAN_ENTRY):
            return None
        if e.fdid == META_FDID:
            plan.meta_entries += 1    # applied namespace record: retire only
            continue
        f = resolve_file(e.fdid)
        if f is None:                   # orphan (file force-closed): drop
            plan.orphans += 1
            continue
        acc = accs.get(id(f))
        if acc is None:
            acc = accs[id(f)] = _FileAcc(f)
            order.append(acc)
        acc.entries += 1
        acc.nbytes += e.length
        if e.length == 0:
            continue
        if not policy.drain_coalesce:
            acc.raw.append((e.off, bytes(e.data), e.idx))
            continue
        p0, p1 = e.off // ps, (e.off + e.length - 1) // ps
        for p in range(p0, p1 + 1):
            img = acc.pages.get(p)
            if img is None:
                img = acc.pages[p] = _PageImage(ps)
            base = p * ps
            s, t = max(e.off, base), min(e.off + e.length, base + ps)
            img.add(s - base, t - base, e.data[s - e.off:t - e.off], e.idx)

    for acc in order:
        fp = FilePlan(acc.file)
        fp.entries = acc.entries
        fp.nbytes = acc.nbytes
        fp.extents = (_coalesced_extents(acc, ps, policy.coalesce_max_extent)
                      if policy.drain_coalesce else _raw_extents(acc, ps))
        plan.files.append(fp)
    return plan


def _raw_extents(acc: _FileAcc, ps: int) -> List[Extent]:
    """Entry-at-a-time degenerate plan (``drain_coalesce=False``): one
    extent per log entry, exactly the paper's per-entry forwarding — kept
    as the measurable baseline for the coalescing win."""
    out = []
    for off, data, idx in acc.raw:
        pages = list(range(off // ps, (off + max(len(data), 1) - 1) // ps + 1))
        out.append(Extent(off, bytearray(data), pages,
                          {p: [idx] for p in pages}))
    return out


def _coalesced_extents(acc: _FileAcc, ps: int, max_extent: int) -> List[Extent]:
    """Flatten materialized page images into maximal contiguous extents."""
    out: List[Extent] = []
    cur_off = cur_end = 0
    cur_data: Optional[bytearray] = None
    cur_pages: List[int] = []
    cur_retire: Dict[int, List[int]] = {}

    def flush():
        nonlocal cur_data
        if cur_data is not None:
            out.append(Extent(cur_off, cur_data, cur_pages, cur_retire))
            cur_data = None

    for p in sorted(acc.pages):
        img = acc.pages[p]
        base = p * ps
        for s, e in img.ranges:
            abs_s, abs_e = base + s, base + e
            # every contributing entry's bytes on this page are contiguous,
            # so each span lies inside exactly one merged range
            idxs = [idx for idx, a, b in img.spans if s <= a and b <= e]
            if (cur_data is not None and abs_s == cur_end
                    and len(cur_data) + (abs_e - abs_s) <= max_extent):
                cur_data += img.buf[s:e]
                cur_end = abs_e
                if not cur_pages or cur_pages[-1] != p:
                    cur_pages.append(p)
                cur_retire.setdefault(p, []).extend(idxs)
            else:
                flush()
                cur_off, cur_end = abs_s, abs_e
                cur_data = bytearray(img.buf[s:e])
                cur_pages = [p]
                cur_retire = {p: list(idxs)}
    flush()
    return out


def apply_plan(plan: DrainPlan, policy: Policy, *,
               abort: Optional[AbortFn] = None,
               stats=None) -> Optional[Dict[object, int]]:
    """Phase 2: issue the extent writes and retire the dirty-page index.

    Per file: take the cleanup locks of every covered page (ascending — the
    same total order the write path uses, and drain threads of different
    shards never share a page, so there is no cycle), issue one vectored
    ``pwritev`` when the backend supports it (else per-extent ``pwrite``),
    then drop the batch's refs from each covered page.  Returns
    ``{file: entries_drained}``, or ``None`` on abort — in which case the
    log is *not* consumed and recovery replays everything (idempotent).
    """
    drained: Dict[object, int] = {}
    for fp in plan.files:
        if abort is not None and abort(APPLY_FILE):
            return None
        f = fp.file
        pwritev = getattr(f.backend, "pwritev", None)
        if policy.drain_coalesce and pwritev is not None:
            ok = _apply_vectored(plan, fp, pwritev, abort, stats)
        else:
            ok = _apply_serial(plan, fp, abort, stats)
        if not ok:
            return None
        drained[f] = fp.entries
    return drained


def _lock_descs(f, pages: List[int]):
    """Cleanup locks for ``pages``, ascending; returns [(page, desc)]."""
    if f.radix is None:
        return []
    descs = []
    for p in pages:
        d = f.radix.get_or_create(p)
        d.cleanup_lock.acquire()
        descs.append((p, d))
    return descs


# extents per pwritev call / per cleanup-lock hold: big enough that the
# syscall amortization is intact (64 segments per call), small enough that
# a huge batch against one file does not hold thousands of page locks
# across a device write and starve dirty-miss readers for the whole batch
VEC_CHUNK = 64


def _apply_vectored(plan, fp, pwritev, abort, stats) -> bool:
    """A file's extents in chunks: one lock hold + one pwritev per chunk."""
    obs = getattr(stats, "obs", None)
    lv2 = obs is not None and obs.prof.lv2
    for i in range(0, len(fp.extents), VEC_CHUNK):
        chunk = fp.extents[i:i + VEC_CHUNK]
        if abort is not None and abort(APPLY_EXTENT):
            return False
        pages = sorted({p for ext in chunk for p in ext.pages})
        descs = _lock_descs(fp.file, pages)
        dmap = dict(descs)
        try:
            t0 = time.perf_counter_ns() if lv2 else 0
            pwritev([(ext.data, ext.off) for ext in chunk])
            if lv2:
                obs.prof.h_drain_pwritev.record_ns(
                    time.perf_counter_ns() - t0)
            if stats is not None:
                stats.stats_pwritevs += 1
                stats.stats_extents += len(chunk)
            if abort is not None and abort(APPLY_RETIRE):
                return False
            for ext in chunk:
                for p, idxs in ext.retire.items():
                    d = dmap.get(p)
                    if d is not None:
                        d.retire_refs(plan.sid, set(idxs))
        finally:
            for _p, d in reversed(descs):
                d.cleanup_lock.release()
    return True


def _apply_serial(plan, fp, abort, stats) -> bool:
    """Per-extent pwrite + retire (legacy mode, or backend without pwritev)."""
    obs = getattr(stats, "obs", None)
    lv2 = obs is not None and obs.prof.lv2
    for ext in fp.extents:
        if abort is not None and abort(APPLY_EXTENT):
            return False
        descs = _lock_descs(fp.file, ext.pages)
        try:
            t0 = time.perf_counter_ns() if lv2 else 0
            fp.file.backend.pwrite(bytes(ext.data), ext.off)
            if lv2:
                obs.prof.h_drain_pwritev.record_ns(
                    time.perf_counter_ns() - t0)
            if stats is not None:
                stats.stats_extents += 1
            if abort is not None and abort(APPLY_RETIRE):
                return False
            for p, d in descs:
                idxs = ext.retire.get(p)
                if idxs:
                    d.retire_refs(plan.sid, set(idxs))
        finally:
            for _p, d in reversed(descs):
                d.cleanup_lock.release()
    return True


# --------------------------------------------------------------------------
class _SyncState:
    __slots__ = ("cond", "running", "started", "done", "waiters", "errors",
                 "__weakref__")

    GUARDED_BY = {
        "running": "cond", "started": "cond", "done": "cond",
        "errors": "cond",
        # guarded by the OWNING SCHEDULER's _lock (not expressible as a
        # self attribute): every touch happens inside the scheduler's
        # registration/teardown sections, whose lock edges order them
        "waiters": None,
    }

    def __init__(self):
        self.cond = locking.make_condition("leaf:fsync_epoch")
        self.running = False          # guarded-by: cond
        self.started = 0              # epochs started; guarded-by: cond
        self.done = 0                 # epochs completed (success OR
        #                               failure); guarded-by: cond
        self.waiters = 0              # guarded-by: scheduler._lock
        self.errors: Dict[int, BaseException] = {}   # epoch -> fsync error
        #                                              guarded-by: cond


class FsyncEpochScheduler:
    """Merges concurrent fsyncs of the same backend file into epochs.

    A caller's pwrites finished before it asked to fsync, so any fsync that
    *starts* afterwards covers them — but one already in flight may not.
    Each caller therefore waits for epoch ``started + 1`` (as observed at
    arrival): if no fsync is running it leads that epoch immediately; if
    one is running, every caller that arrives meanwhile shares the single
    next epoch — K shard drain threads fsyncing one backend file collapse
    to at most two device fsyncs instead of K.
    """

    GUARDED_BY = {
        "_state": "_lock",
        "stats_requests": "_lock", "stats_issued": "_lock",
    }

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = locking.make_lock("leaf:fsync_sched")
        self._state: Dict[int, _SyncState] = {}   # id(backend) -> state
        #                                           guarded-by: _lock
        self.stats_requests = 0                   # guarded-by: _lock
        self.stats_issued = 0                     # guarded-by: _lock

    @property
    def stats_merged(self) -> int:
        with self._lock:
            return self.stats_requests - self.stats_issued

    @property
    def stats_issued_snapshot(self) -> int:
        """Locked read of ``stats_issued`` for cross-thread reporting."""
        with self._lock:
            return self.stats_issued

    def fsync(self, backend) -> None:
        if not self.enabled:
            with self._lock:
                self.stats_requests += 1
                self.stats_issued += 1
            backend.fsync()
            return
        key = id(backend)
        with self._lock:
            self.stats_requests += 1
            st = self._state.get(key)
            if st is None:
                st = self._state[key] = _SyncState()
            st.waiters += 1
        try:
            with st.cond:
                need = st.started + 1
                while st.done < need:
                    if not st.running:
                        st.running = True
                        st.started += 1
                        epoch = st.started
                        st.cond.release()
                        exc: Optional[BaseException] = None
                        try:
                            backend.fsync()
                        except BaseException as e:
                            exc = e
                        finally:
                            st.cond.acquire()
                            st.running = False
                            st.done = epoch
                            if exc is not None:
                                st.errors[epoch] = exc
                            st.cond.notify_all()
                        with self._lock:
                            self.stats_issued += 1
                    else:
                        st.cond.wait()
                # epochs complete in order, so epoch `need` is the one that
                # covered this caller's writes: a failure there must reach
                # EVERY waiter that shared it, not just the leader —
                # otherwise a merged drain thread would retire log entries
                # whose data never became durable
                err = st.errors.get(need)
                if err is not None:
                    raise err
        finally:
            with self._lock:
                st.waiters -= 1
                if st.waiters == 0 and not st.running:
                    self._state.pop(key, None)
