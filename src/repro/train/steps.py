"""Step builders: train_step / prefill_step / serve_step, with the sharding
trees needed to jit them on the production mesh."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.optim import grad_compress
from repro.optim.adamw import AdamW, apply_updates
from repro.parallel import context as pctx
from repro.parallel import sharding as shd


def bind_mesh(fn, mesh):
    """Make ``mesh`` visible to mesh-aware model code (shard_map EP MoE)
    while ``fn`` is being traced."""
    if mesh is None:
        return fn

    def wrapped(*args, **kwargs):
        with pctx.with_mesh(mesh):
            return fn(*args, **kwargs)

    return wrapped


def init_train_state(model: Model, optimizer: AdamW, key):
    params = model.init(key)
    return {"params": params, "opt": optimizer.init(params)}


def abstract_train_state(model: Model, optimizer: AdamW):
    return jax.eval_shape(lambda: init_train_state(
        model, optimizer, jax.random.PRNGKey(0)))


def make_train_step(model: Model, optimizer: AdamW, *, compress: bool = False):
    def step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state["params"], batch)
        if compress:
            grads = grad_compress.compress_tree(grads)
        updates, opt, om = optimizer.update(grads, state["opt"], state["params"])
        params = apply_updates(state["params"], updates)
        metrics = dict(metrics, loss=loss, **om)
        return {"params": params, "opt": opt}, metrics

    return step


def make_prefill_step(model: Model, max_len: int):
    def step(params, batch):
        return model.prefill(params, batch, max_len)
    return step


def make_serve_step(model: Model):
    def step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return step


# ---------------------------------------------------------------- shardings

def train_shardings(model: Model, optimizer: AdamW, mesh, batch_spec_like,
                    *, fsdp: bool = True):
    """(in_shardings, out_shardings) for ``make_train_step``'s jit."""
    state = abstract_train_state(model, optimizer)
    pspec = shd.param_specs(state["params"], mesh, fsdp=fsdp)
    mspec = shd.param_specs(state["opt"]["m"], mesh, fsdp=fsdp)
    state_spec = {"params": pspec,
                  "opt": {"m": mspec, "v": mspec, "step": shd.P()}}
    bspec = shd.batch_specs(batch_spec_like, mesh)
    metrics_spec = None     # replicated scalars
    return (shd.named(mesh, state_spec), shd.named(mesh, bspec)), \
        (shd.named(mesh, state_spec), metrics_spec), state


def serve_shardings(model: Model, mesh, cache_like, batch_like=None,
                    *, fsdp: bool = False):
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspec = shd.param_specs(params, mesh, fsdp=fsdp)
    cspec = shd.cache_specs(cache_like, mesh)
    out = {"params": shd.named(mesh, pspec), "cache": shd.named(mesh, cspec)}
    if batch_like is not None:
        out["batch"] = shd.named(mesh, shd.batch_specs(batch_like, mesh))
    return out, params
