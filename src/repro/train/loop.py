"""Training loop with NVCache-backed persistence.

Every durable artifact — checkpoints, data-pipeline state, metrics JSONL —
goes through the plain file API; when that FS is NVCache-backed, a step's
checkpoint is synchronously durable at fast-tier speed and drains to the
blob tier in the background (the paper's cleanup thread IS the
compute/IO overlap).  On restart the loop recovers: NVCache log replay ->
manifest -> restore -> resume the data pipeline at the exact step.
"""
from __future__ import annotations

import json
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.models.registry import Model
from repro.optim.adamw import AdamW
from repro.train import steps as tsteps


class MetricsLog:
    """JSONL metrics through the FS (another 'legacy' NVCache consumer)."""

    def __init__(self, fs, path: str = "/metrics.jsonl"):
        self.fs = fs
        self.fd = fs.open(path)
        self.off = fs.size(self.fd)

    def log(self, step: int, metrics: dict) -> None:
        rec = {"step": step}
        for k, v in metrics.items():
            try:
                rec[k] = float(v)
            except (TypeError, ValueError):
                pass
        line = (json.dumps(rec) + "\n").encode()
        self.fs.pwrite(self.fd, line, self.off)
        self.off += len(line)


def train(model: Model, optimizer: AdamW, pipeline, fs, *,
          total_steps: int, ckpt_every: int = 50, keep: int = 2,
          mesh=None, fsdp: bool = True, seed: int = 0,
          heartbeat: Optional[Callable[[int], None]] = None,
          compress_grads: bool = False):
    """Returns (final_state, history list of metric dicts)."""
    mgr = CheckpointManager(fs, keep=keep)
    metrics_log = MetricsLog(fs)
    step_fn = tsteps.make_train_step(model, optimizer, compress=compress_grads)

    if mesh is not None:
        spec_like = jax.eval_shape(lambda: pipeline.next())
        (in_sh, b_sh), (out_sh, _), _ = tsteps.train_shardings(
            model, optimizer, mesh, spec_like, fsdp=fsdp)
        step_fn = jax.jit(tsteps.bind_mesh(step_fn, mesh),
                          in_shardings=(in_sh, b_sh),
                          out_shardings=(out_sh, None), donate_argnums=(0,))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    # ---- restore or init ---------------------------------------------------
    state = tsteps.init_train_state(model, optimizer, jax.random.PRNGKey(seed))
    start = 0
    latest = mgr.latest_step()
    if latest is not None:
        abstract = jax.tree.map(np.asarray, state)
        state = jax.tree.map(
            lambda like, a: a.astype(like.dtype),
            abstract, mgr.restore(abstract, step=latest))
        state = jax.tree.map(jax.numpy.asarray, state)
        pipeline.restore_state(fs)
        start = latest
    history = []

    for step in range(start, total_steps):
        batch = pipeline.next()
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        metrics = dict(metrics, step_time=time.perf_counter() - t0)
        metrics_log.log(step, metrics)
        history.append({k: float(v) for k, v in metrics.items()})
        if heartbeat:
            heartbeat(step)
        if (step + 1) % ckpt_every == 0 or step + 1 == total_steps:
            host_state = jax.tree.map(np.asarray, state)
            mgr.save(step + 1, host_state)
            pipeline.save_state(fs)
    return state, history
