"""Mixture-of-Experts layer with capacity-factor gather/scatter dispatch.

Design notes (expert parallelism on the ``model`` mesh axis):
  * tokens are reshaped to (groups, group_len, d) with groups sharded over
    the data axes — dispatch indices are computed per group;
  * dispatch/combine are pure data movement (scatter/gather), NOT the GShard
    dense one-hot einsum, whose mask matmul FLOPs would dwarf the expert
    FLOPs at 128 experts and poison the roofline's useful-FLOPs ratio;
  * expert weights (E, d, f) are sharded on E over ``model``; XLA SPMD
    inserts the all-to-alls between the token-sharded and expert-sharded
    views (inspected in the dry-run HLO);
  * over-capacity tokens are dropped (capacity_factor, GShard-style) — the
    standard trade for static shapes.

Returns the layer output plus the load-balancing auxiliary loss
(Switch-style: E · Σ_e f_e · p_e).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import dense_init


def moe_init(cfg: ModelConfig, key):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), d, jnp.float32),
        "wg": dense_init(ks[1], (E, d, f), d, cfg.pdt),
        "wu": dense_init(ks[2], (E, d, f), d, cfg.pdt),
        "wd": dense_init(ks[3], (E, f, d), f, cfg.pdt),
    }


def moe_capacity(cfg: ModelConfig, group_len: int) -> int:
    c = int(group_len * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(4, -(-c // 4) * 4)          # round up to a multiple of 4


def moe_forward(cfg: ModelConfig, p, x):
    """x: (B, S, d) -> (out: (B, S, d), aux_loss: scalar).

    Dispatches to the explicit shard_map EP implementation when a mesh with
    a compatible ``model`` axis is in scope (see EXPERIMENTS.md §Perf
    hillclimb 3); otherwise the pjit-auto gather implementation below."""
    from repro.parallel import context
    mesh = context.current_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        M = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
        B, S, _ = x.shape
        if M > 1 and cfg.n_experts % M == 0 and S % M == 0 and \
                (B * S) // M >= cfg.top_k:
            return _moe_shard_map(cfg, p, x, mesh, M)
    return _moe_gather(cfg, p, x)


def _route(cfg: ModelConfig, router, xt):
    """Shared routing: top-k weights/ids + Switch aux loss.  xt: (T, d)."""
    E, K = cfg.n_experts, cfg.top_k
    logits = (xt @ router.astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, K)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    f_e = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(1), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e / K * p_e)
    return w, idx, aux


def _slots(idx_f, E, C):
    """Slot of each (token,k) in its expert's capacity-C queue."""
    onehot = jax.nn.one_hot(idx_f, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.take_along_axis(pos, idx_f[:, None], axis=-1)[:, 0]
    return jnp.minimum(slot, C - 1), (slot < C)


def _moe_shard_map(cfg: ModelConfig, p, x, mesh, M):
    """Expert parallelism with explicit all-to-alls.

    Tokens enter sharded (batch over the DP axes, sequence over ``model``);
    each shard routes its own tokens, builds per-expert send buffers, and
    two ``all_to_all``s over the model axis move tokens to their experts
    and back.  Wire bytes per device ≈ 2·T_loc·k·cf·d — two orders of
    magnitude below what the auto-partitioned scatter/gather produced for
    arctic-480b (the baseline's dominant roofline term)."""
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:      # jax<0.7 spelling
        from jax.experimental.shard_map import shard_map

    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    E_loc = E // M
    dp = tuple(a for a in mesh.axis_names if a != "model")
    all_axes = tuple(mesh.axis_names)

    def local(xl, router, wg, wu, wd):
        # xl: (B_loc, S/M, d); wg/wu/wd: (E_loc, d, f)
        Bl, Sl, _ = xl.shape
        T = Bl * Sl
        xt = xl.reshape(T, d)
        C = moe_capacity(cfg, T)
        w, idx, aux = _route(cfg, router, xt)
        idx_f = idx.reshape(T * K)
        slot, keep = _slots(idx_f, E, C)
        keep = keep.astype(xl.dtype)
        token_of = jnp.arange(T * K) // K
        buf = jnp.zeros((E, C, d), xl.dtype).at[idx_f, slot].add(
            xt[token_of] * keep[:, None])                    # (E, C, d)
        # ship tokens to their expert's shard
        buf = buf.reshape(M, E_loc, C, d)
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0,
                                 tiled=False)                # (M, E_loc, C, d)
        h = buf.transpose(1, 0, 2, 3).reshape(E_loc, M * C, d)
        a = jax.nn.silu(jnp.einsum("emd,edf->emf", h, wg.astype(xl.dtype)))
        a = a * jnp.einsum("emd,edf->emf", h, wu.astype(xl.dtype))
        o = jnp.einsum("emf,efd->emd", a, wd.astype(xl.dtype))
        o = o.reshape(E_loc, M, C, d).transpose(1, 0, 2, 3)
        o = jax.lax.all_to_all(o, "model", split_axis=0, concat_axis=0,
                               tiled=False)                  # back home
        o = o.reshape(E, C, d)
        y = o[idx_f, slot] * keep[:, None]                   # (T*K, d)
        y = (y.reshape(T, K, d) * w[..., None].astype(y.dtype)).sum(1)
        aux = jax.lax.pmean(aux, all_axes)
        return y.reshape(Bl, Sl, d), aux

    xspec = P(dp if B % max(1, _prod(mesh, dp)) == 0 else None, "model", None)
    kwargs = dict(mesh=mesh,
                  in_specs=(xspec, P(), P("model", None, None),
                            P("model", None, None), P("model", None, None)),
                  out_specs=(xspec, P()))
    try:
        f = shard_map(local, check_vma=False, **kwargs)
    except TypeError:
        f = shard_map(local, check_rep=False, **kwargs)
    out, aux = f(x, p["router"], p["wg"], p["wu"], p["wd"])
    return out, aux


def _prod(mesh, axes):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    t = 1
    for a in axes:
        t *= sizes[a]
    return t


def _moe_gather(cfg: ModelConfig, p, x):
    """pjit-auto gather/scatter implementation (portable baseline)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    Tg = min(cfg.moe_group, B * S)
    T = B * S
    pad = (-T) % Tg
    xt = x.reshape(T, d)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    G = xt.shape[0] // Tg
    xg = xt.reshape(G, Tg, d)
    C = moe_capacity(cfg, Tg)

    logits = (xg @ p["router"].astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (G,Tg,E)
    w, idx = jax.lax.top_k(probs, K)                         # (G,Tg,K)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch): fraction routed vs mean prob
    f_e = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(2), axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f_e / K * p_e)

    # slot assignment: position of each (token,k) within its expert's queue
    idx_f = idx.reshape(G, Tg * K)                           # token-major order
    onehot = jax.nn.one_hot(idx_f, E, dtype=jnp.int32)       # (G,TK,E)
    pos = jnp.cumsum(onehot, axis=1) - onehot                # slots before this one
    slot = jnp.take_along_axis(pos, idx_f[..., None], axis=-1)[..., 0]  # (G,TK)
    keep = (slot < C).astype(xg.dtype)

    token_of = jnp.arange(Tg * K) // K                       # (TK,)
    slot_c = jnp.minimum(slot, C - 1)

    def dispatch(xg_g, e_g, slot_g, keep_g):
        vals = xg_g[token_of] * keep_g[:, None]              # (TK, d)
        return jnp.zeros((E, C, d), xg.dtype).at[e_g, slot_g].add(vals)

    buf = jax.vmap(dispatch)(xg, idx_f, slot_c, keep)        # (G,E,C,d)

    # expert FFN (SwiGLU), E sharded on the model axis
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["wg"].astype(xg.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["wu"].astype(xg.dtype))
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["wd"].astype(xg.dtype))

    def combine(out_g, e_g, slot_g, keep_g):
        return out_g[e_g, slot_g] * keep_g[:, None]          # (TK, d)

    y = jax.vmap(combine)(out_buf, idx_f, slot_c, keep)      # (G,TK,d)
    y = (y.reshape(G, Tg, K, d) * w[..., None].astype(y.dtype)).sum(2)
    y = y.reshape(G * Tg, d)[:T].reshape(B, S, d)
    return y, aux
