"""Mamba-2 (SSD) block: in-proj -> causal depthwise conv -> SSD scan ->
gated RMSNorm -> out-proj.  Train/prefill use the chunked SSD algorithm
(`repro.kernels` — Pallas on TPU, jnp oracle elsewhere); decode is the
O(1)-state recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import dense_init, rmsnorm


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    g, n = 1, cfg.ssm_state
    h = cfg.ssm_heads
    conv_dim = di + 2 * g * n
    return di, g, n, h, conv_dim


def ssm_init(cfg: ModelConfig, key):
    di, g, n, h, conv_dim = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * g * n + h), d, cfg.pdt),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_dim), cfg.ssm_conv, cfg.pdt),
        "conv_b": jnp.zeros((conv_dim,), cfg.pdt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(cfg.pdt),
        "D": jnp.ones((h,), cfg.pdt),
        "dt_bias": jnp.zeros((h,), cfg.pdt),
        "gnorm": jnp.ones((di,), cfg.pdt),
        "out_proj": dense_init(ks[2], (di, d), di, cfg.pdt),
    }


def _split(cfg, zxbcdt):
    di, g, n, h, _ = _dims(cfg)
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n:]
    return z, xBC, dt


def causal_conv(xBC, w, b):
    """Depthwise causal conv along sequence. xBC: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i][None, None, :] for i in range(K))
    return jax.nn.silu(out + b[None, None, :])


def ssm_forward(cfg: ModelConfig, p, x, *, return_state=False, ssd_fn=None):
    """Full-sequence path.  x: (B, S, d_model)."""
    from repro.kernels import ops as kops
    ssd_fn = ssd_fn or kops.ssd
    di, g, n, h, conv_dim = _dims(cfg)
    B_, S, _ = x.shape
    P = cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xBC, dt = _split(cfg, zxbcdt)
    xBC = causal_conv(xBC, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    from repro.parallel import context as pctx
    xs = xBC[..., :di].reshape(B_, S, h, P)
    Bs = xBC[..., di:di + g * n].reshape(B_, S, g, n)
    Cs = xBC[..., di + g * n:].reshape(B_, S, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xs_res = xs                          # un-padded, for the D skip term
    # pad heads so the SSD shards over the model axis (hillclimb 2), then
    # pin head axes — otherwise the partitioner replicates the whole scan
    hp = h
    if cfg.ssm_pad_heads_to and h % cfg.ssm_pad_heads_to:
        hp = -(-h // cfg.ssm_pad_heads_to) * cfg.ssm_pad_heads_to
        xs = jnp.pad(xs, ((0, 0), (0, 0), (0, hp - h), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, hp - h)))
        A = jnp.pad(A, (0, hp - h), constant_values=-1.0)
    xs = pctx.constrain(xs, ("__dp__", None, "model", None))
    dt = pctx.constrain(dt, ("__dp__", None, "model"))
    # pad sequence to a chunk multiple
    chunk = min(cfg.ssm_chunk, S)
    pad = (-S) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bs = jnp.pad(Bs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cs = jnp.pad(Cs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y, state = ssd_fn(xs, dt, A, Bs, Cs, chunk=chunk)
    y = y[:, :S, :h]
    state = state[:, :h]
    y = y + xs_res * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B_, S, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["gnorm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    if return_state:
        # conv state: last (K-1) raw (pre-conv) channels for decode continuation
        K = cfg.ssm_conv
        tail = x[:, -(K - 1):, :] if S >= K - 1 else x
        pre = tail @ p["in_proj"].astype(x.dtype)
        _, xBC_raw, _ = _split(cfg, pre)
        if S < K - 1:
            xBC_raw = jnp.pad(xBC_raw, ((0, 0), (K - 1 - S, 0), (0, 0)))
        return out, (state, xBC_raw)
    return out


def ssm_decode(cfg: ModelConfig, p, x, state, conv_state):
    """One-token step.  x: (B, 1, d); state: (B,h,P,n);
    conv_state: (B, K-1, conv_dim) raw (pre-activation) conv inputs."""
    from repro.kernels.ref import ssd_decode_ref
    di, g, n, h, conv_dim = _dims(cfg)
    P = cfg.ssm_head_dim
    B_ = x.shape[0]
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xBC_new, dt = _split(cfg, zxbcdt)            # (B,1,·)
    window = jnp.concatenate([conv_state, xBC_new], axis=1)   # (B,K,conv)
    w = p["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(x.dtype)
    xBC = jax.nn.silu(conv_out)                     # (B, conv_dim)
    xs = xBC[..., :di].reshape(B_, h, P)
    Bs = xBC[..., di:di + g * n].reshape(B_, g, n)
    Cs = xBC[..., di + g * n:].reshape(B_, g, n)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, state = ssd_decode_ref(xs, dtv, A, Bs, Cs, state)
    y = y + xs * p["D"].astype(y.dtype)[None, :, None]
    y = y.reshape(B_, 1, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["gnorm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    conv_state = window[:, 1:, :]
    return out, state, conv_state


def ssm_init_cache(cfg: ModelConfig, batch, dtype):
    di, g, n, h, conv_dim = _dims(cfg)
    return (jnp.zeros((batch, h, cfg.ssm_head_dim, n), jnp.float32),
            jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype))
