"""Shared layer primitives: norms, RoPE / M-RoPE, SwiGLU MLP, blocked
(flash-style) attention in pure ``jax.lax`` — the portable path; the Pallas
kernel in ``repro.kernels.flash_attention`` is the TPU fast path with the
same semantics (validated against each other in tests)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------- init

def dense_init(key, shape, in_dim, dtype):
    return (jax.random.normal(key, shape) / jnp.sqrt(in_dim)).astype(dtype)


# --------------------------------------------------------------------- norms

def rmsnorm(x, w, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(x, w, b, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------- RoPE

def rope_cos_sin(positions, dim, theta):
    """positions: (..., S) int -> cos/sin (..., S, dim//2) float32."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions3, dim, theta, sections):
    """Qwen2-VL M-RoPE: positions3 (3, B, S) for (t, h, w); ``sections``
    partitions the dim//2 frequency slots among the three streams."""
    assert sum(sections) == dim // 2
    cos_t, sin_t = rope_cos_sin(positions3, dim, theta)   # (3, B, S, dim//2)
    parts_c, parts_s = [], []
    start = 0
    for i, sec in enumerate(sections):
        parts_c.append(cos_t[i, ..., start:start + sec])
        parts_s.append(sin_t[i, ..., start:start + sec])
        start += sec
    return jnp.concatenate(parts_c, -1), jnp.concatenate(parts_s, -1)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (B, S, D//2) — half-rotation (NeoX)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ----------------------------------------------------------------------- MLP

def swiglu(x, wg, wu, wd):
    h = jax.nn.silu(x @ wg) * (x @ wu)
    return h @ wd


# ----------------------------------------------- blocked (flash-style) attn

def blocked_attention(q, k, v, *, causal: bool, window=None,
                      block: int = 1024, q_offset=0,
                      kv_len: Optional[jax.Array] = None,
                      scale: Optional[float] = None):
    """Online-softmax attention over KV blocks (memory O(S·block)).

    q: (B, Sq, H, D); k, v: (B, Skv, KV, D) with H % KV == 0 (GQA).
    ``q_offset``: global position of q[0] (prefill continuation / decode).
    ``window`` > 0: sliding-window attention (key j visible to query i iff
    i - window < j <= i).  ``kv_len``: valid prefix length of k/v (padding).
    Returns (B, Sq, H, D) in q.dtype.
    """
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    Dv = v.shape[-1]
    G = H // KV
    nblk = -(-Skv // block)
    pad = nblk * block - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, block, KV, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block, KV, Dv).transpose(1, 0, 2, 3, 4)

    qg = q.reshape(B, Sq, KV, G, D).astype(jnp.float32)
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    iq = q_offset + jnp.arange(Sq)

    def step(carry, xs):
        m, l, acc = carry
        kblk, vblk, bi = xs
        jk = bi * block + jnp.arange(block)
        s = jnp.einsum("bqkgd,bjkd->bkgqj", qg, kblk.astype(jnp.float32)) * scale
        mask = jnp.ones((Sq, block), dtype=bool)
        if causal:
            mask &= jk[None, :] <= iq[:, None]
        if window is not None:          # static int or traced scalar; >0
            mask &= jk[None, :] > iq[:, None] - window
        if kv_len is not None:
            mask &= (jk < kv_len)[None, :]
        else:
            mask &= (jk < Skv)[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqj,bjkd->bkgqd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nblk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, pos, window=None,
                     scale: Optional[float] = None):
    """Single-step attention against a cache.

    q: (B, 1, H, D); caches: (B, Smax, KV, D); ``pos``: (B,) or scalar —
    number of valid cache entries (the new token's kv must already be
    written at pos-? caller convention: caches hold pos+1 valid entries,
    i.e. index ``pos`` is the current token).
    """
    B, _, H, D = q.shape
    _, Smax, KV, _ = k_cache.shape
    Dv = v_cache.shape[-1]
    G = H // KV
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bjkd->bkgj", qg, k_cache.astype(jnp.float32))
    s *= scale if scale is not None else 1.0 / (D ** 0.5)
    j = jnp.arange(Smax)
    cur = jnp.broadcast_to(jnp.asarray(pos), (B,))
    mask = j[None, :] <= cur[:, None]
    if window is not None:
        mask &= j[None, :] > (cur[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgj,bjkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, Dv).astype(q.dtype)
