"""Attention modules: GQA/MQA (with optional sliding window and M-RoPE)
and MLA (multi-head latent attention, MiniCPM3/DeepSeek-V2 style, with the
absorbed decode path serving directly from the compressed latent cache)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import (apply_rope, blocked_attention, decode_attention,
                                 dense_init)


# =============================================================== GQA / MQA

def gqa_init(cfg: ModelConfig, key):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, H * hd), d, cfg.pdt),
        "wk": dense_init(k2, (d, KV * hd), d, cfg.pdt),
        "wv": dense_init(k3, (d, KV * hd), d, cfg.pdt),
        "wo": dense_init(k4, (H * hd, d), H * hd, cfg.pdt),
    }


def _qkv(cfg, p, x):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, KV, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, KV, hd)
    return q, k, v


def gqa_forward(cfg: ModelConfig, p, x, rope=None, *, causal=True, window=None,
                return_kv=False):
    """Full-sequence path (train / prefill).  ``rope``: (cos, sin) or None."""
    q, k, v = _qkv(cfg, p, x)
    if rope is not None:
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    kv_out = (k, v)                      # caches keep the compact KV heads
    H, KV = cfg.n_heads, cfg.n_kv_heads
    ka, va, qa = k, v, q
    # TP-friendliness: with KV < model-axis the (KV, G) split replicates the
    # whole attention per shard; repeating KV to H restores head sharding
    # (transient, bf16 — see EXPERIMENTS.md §Perf hillclimb 1).
    if cfg.tp_repeat_kv and H > KV:
        ka = jnp.repeat(k, H // KV, axis=2)
        va = jnp.repeat(v, H // KV, axis=2)
    if cfg.pad_heads_to and ka.shape[2] == qa.shape[2] and H % cfg.pad_heads_to:
        Hp = -(-H // cfg.pad_heads_to) * cfg.pad_heads_to
        pad = ((0, 0), (0, 0), (0, Hp - H), (0, 0))
        qa, ka, va = jnp.pad(qa, pad), jnp.pad(ka, pad), jnp.pad(va, pad)
    # pin the head axis to the model mesh axis — without the constraint the
    # partitioner replicates the whole attention when it cannot propagate
    # sharding through the repeat/reshape (hillclimb 1, iteration 2)
    from repro.parallel import context as pctx
    qa = pctx.constrain(qa, ("__dp__", None, "model", None))
    ka = pctx.constrain(ka, ("__dp__", None, "model", None))
    va = pctx.constrain(va, ("__dp__", None, "model", None))
    o = blocked_attention(qa, ka, va, causal=causal, window=window,
                          block=cfg.attn_block,
                          scale=1.0 / (cfg.head_dim ** 0.5))
    o = o[:, :, :H, :]
    out = o.reshape(x.shape[0], x.shape[1], -1) @ p["wo"].astype(x.dtype)
    if return_kv:
        return out, kv_out
    return out


def gqa_decode(cfg: ModelConfig, p, x, kc, vc, pos, rope=None, *, window=None):
    """One-token step.  kc/vc: (B, Smax, KV, hd); pos: scalar index of the
    new token.  Returns (out, kc, vc) with the caches updated at ``pos``."""
    q, k, v = _qkv(cfg, p, x)
    if rope is not None:
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, axis=1)
    o = decode_attention(q, kc, vc, pos=pos, window=window)
    out = o.reshape(x.shape[0], 1, -1) @ p["wo"].astype(x.dtype)
    return out, kc, vc


# ===================================================================== MLA

def mla_init(cfg: ModelConfig, key):
    d, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (d, qr), d, cfg.pdt),
        "q_norm": jnp.ones((qr,), cfg.pdt),
        "wq_b": dense_init(ks[1], (qr, H * (nd + rd)), qr, cfg.pdt),
        "wkv_a": dense_init(ks[2], (d, kvr + rd), d, cfg.pdt),
        "kv_norm": jnp.ones((kvr,), cfg.pdt),
        "wkv_b": dense_init(ks[3], (kvr, H * (nd + vd)), kvr, cfg.pdt),
        "wo": dense_init(ks[4], (H * vd, d), H * vd, cfg.pdt),
    }


def _mla_q(cfg, p, x, rope):
    from repro.models.layers import rmsnorm
    B, S, _ = x.shape
    H, nd, rd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    ql = rmsnorm(x @ p["wq_a"].astype(x.dtype), p["q_norm"], cfg.norm_eps)
    q = (ql @ p["wq_b"].astype(x.dtype)).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    if rope is not None:
        cos, sin = rope
        q_rope = apply_rope(q_rope, cos[..., :rd // 2], sin[..., :rd // 2])
    return q_nope, q_rope


def _mla_latent(cfg, p, x, rope):
    from repro.models.layers import rmsnorm
    rd = cfg.qk_rope_dim
    kv = x @ p["wkv_a"].astype(x.dtype)
    ckv = rmsnorm(kv[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., cfg.kv_lora_rank:][:, :, None, :]     # one shared head
    if rope is not None:
        cos, sin = rope
        k_rope = apply_rope(k_rope, cos[..., :rd // 2], sin[..., :rd // 2])
    return ckv, k_rope[:, :, 0, :]


def mla_forward(cfg: ModelConfig, p, x, rope=None, *, causal=True,
                return_kv=False):
    """Train/prefill: expand latent to per-head K/V (standard MLA math)."""
    B, S, _ = x.shape
    H, nd, rd, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(cfg, p, x, rope)
    ckv, k_rope = _mla_latent(cfg, p, x, rope)
    kvb = (ckv @ p["wkv_b"].astype(x.dtype)).reshape(B, S, H, nd + vd)
    k_nope, v = kvb[..., :nd], kvb[..., nd:]
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                  (B, S, H, rd))], -1)
    # TP-friendliness (same reasoning as gqa_forward): pad the head axis to
    # the model-axis multiple and pin it, else MLA attention replicates
    from repro.parallel import context as pctx
    if cfg.pad_heads_to and H % cfg.pad_heads_to:
        Hp = -(-H // cfg.pad_heads_to) * cfg.pad_heads_to
        pad = ((0, 0), (0, 0), (0, Hp - H), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    q = pctx.constrain(q, ("__dp__", None, "model", None))
    k = pctx.constrain(k, ("__dp__", None, "model", None))
    v = pctx.constrain(v, ("__dp__", None, "model", None))
    o = blocked_attention(q, k, v, causal=causal, block=cfg.attn_block,
                          scale=1.0 / ((nd + rd) ** 0.5))[:, :, :H, :]
    out = o.reshape(B, S, -1) @ p["wo"].astype(x.dtype)
    if return_kv:
        return out, (ckv, k_rope)
    return out


def mla_decode(cfg: ModelConfig, p, x, ckv_c, krope_c, pos, rope=None):
    """Absorbed decode: attention runs in the compressed latent space so the
    cache is (kv_lora + rope) per token instead of 2·H·head_dim — the MLA
    serving advantage.  ckv_c: (B, Smax, kvr); krope_c: (B, Smax, rd)."""
    B = x.shape[0]
    H, nd, rd, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(cfg, p, x, rope)               # (B,1,H,nd/rd)
    ckv, k_rope = _mla_latent(cfg, p, x, rope)             # (B,1,kvr), (B,1,rd)
    ckv_c = jax.lax.dynamic_update_slice_in_dim(ckv_c, ckv.astype(ckv_c.dtype), pos, 1)
    krope_c = jax.lax.dynamic_update_slice_in_dim(krope_c, k_rope.astype(krope_c.dtype), pos, 1)
    # absorb W^{kv_b} K-half into the query
    wkvb = p["wkv_b"].astype(x.dtype).reshape(kvr, H, nd + vd)
    wk = wkvb[..., :nd]                                    # (kvr, H, nd)
    q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope, wk)       # (B,1,H,kvr)
    q_eff = jnp.concatenate([q_abs, q_rope], -1)           # (B,1,H,kvr+rd)
    k_eff = jnp.concatenate([ckv_c, krope_c], -1)[:, :, None, :]  # 1 kv head
    o_lat = decode_attention(q_eff, k_eff, ckv_c[:, :, None, :], pos=pos,
                             scale=1.0 / ((nd + rd) ** 0.5))  # (B,1,H,kvr)
    wv = wkvb[..., nd:]                                    # (kvr, H, vd)
    o = jnp.einsum("bqhr,rhv->bqhv", o_lat, wv)
    out = o.reshape(B, 1, -1) @ p["wo"].astype(x.dtype)
    return out, ckv_c, krope_c
