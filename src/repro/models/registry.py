"""Uniform model interface over all families."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

from repro.models import encdec as _encdec
from repro.models import lm as _lm
from repro.models.common import ModelConfig


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable              # key -> params
    forward: Callable           # (params, batch) -> (logits, aux)
    loss: Callable              # (params, batch) -> (loss, metrics)
    prefill: Callable           # (params, batch, max_len) -> (logits, cache)
    decode_step: Callable       # (params, cache, tokens) -> (logits, cache)
    init_cache: Callable        # (batch, max_len, **kw) -> cache pytree


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            init=lambda key: _encdec.init_encdec(cfg, key),
            forward=lambda p, b: _encdec.forward(cfg, p, b["frames"], b["dec_tokens"]),
            loss=lambda p, b: _encdec.loss_fn(cfg, p, b),
            prefill=lambda p, b, max_len: _encdec.prefill(
                cfg, p, b["frames"], b["dec_tokens"], max_len),
            decode_step=lambda p, c, t: _encdec.decode_step(cfg, p, c, t),
            init_cache=lambda batch, max_len, enc_len=1500: _encdec.init_cache(
                cfg, batch, max_len, enc_len),
        )
    return Model(
        cfg=cfg,
        init=lambda key: _lm.init_lm(cfg, key),
        forward=lambda p, b: _lm.forward(cfg, p, b["tokens"], b.get("positions")),
        loss=lambda p, b: _lm.loss_fn(cfg, p, b),
        prefill=lambda p, b, max_len: _lm.prefill(
            cfg, p, b["tokens"], max_len, b.get("positions")),
        decode_step=lambda p, c, t: _lm.decode_step(cfg, p, c, t),
        init_cache=lambda batch, max_len, **_kw: _lm.init_cache(cfg, batch, max_len),
    )
