"""Decoder-only LM assembly for the dense / moe / ssm / hybrid / vlm
families: scan-over-stacked-layers (one-layer HLO regardless of depth),
configurable remat, and three entry points — ``forward`` (train),
``prefill`` (build caches), ``decode_step`` (one token)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import ModelConfig
from repro.models.layers import dense_init, mrope_cos_sin, rmsnorm, rope_cos_sin, swiglu

NEG_WINDOW_OFF = 1 << 30   # "window" value that disables windowing


# ------------------------------------------------------------------- params

def _layer_init(cfg: ModelConfig, key):
    ks = jax.random.split(key, 8)
    p = {"norm1": jnp.ones((cfg.d_model,), cfg.pdt)}
    if cfg.family == "ssm":
        p["ssm"] = ssm_mod.ssm_init(cfg, ks[0])
        return p
    if cfg.attn_kind == "mla":
        p["attn"] = attn.mla_init(cfg, ks[0])
    else:
        p["attn"] = attn.gqa_init(cfg, ks[0])
    p["norm2"] = jnp.ones((cfg.d_model,), cfg.pdt)
    if cfg.family == "moe":
        p["moe"] = moe_mod.moe_init(cfg, ks[1])
        if cfg.dense_residual:
            p["mlp"] = _mlp_init(cfg, ks[2])
    else:
        p["mlp"] = _mlp_init(cfg, ks[2])
    if cfg.family == "hybrid":
        p["ssm"] = ssm_mod.ssm_init(cfg, ks[3])
        p["fuse_a"] = jnp.full((cfg.d_model,), 0.5, cfg.pdt)
        p["fuse_s"] = jnp.full((cfg.d_model,), 0.5, cfg.pdt)
    return p


def _mlp_init(cfg, key):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wg": dense_init(k1, (d, f), d, cfg.pdt),
            "wu": dense_init(k2, (d, f), d, cfg.pdt),
            "wd": dense_init(k3, (f, d), f, cfg.pdt)}


def init_lm(cfg: ModelConfig, key):
    k_emb, k_layers, k_un = jax.random.split(key, 3)
    params = {
        "embed": dense_init(k_emb, (cfg.vocab, cfg.d_model), cfg.d_model, cfg.pdt),
        "final_norm": jnp.ones((cfg.d_model,), cfg.pdt),
        "layers": jax.vmap(lambda k: _layer_init(cfg, k))(
            jax.random.split(k_layers, cfg.n_layers)),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(k_un, (cfg.d_model, cfg.vocab),
                                       cfg.d_model, cfg.pdt)
    if cfg.pos == "learned":
        params["pos_table"] = (0.02 * jax.random.normal(
            k_un, (cfg.max_positions, cfg.d_model))).astype(cfg.pdt)
    return params


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer attention window (NEG_WINDOW_OFF = full attention)."""
    if cfg.family == "hybrid" and cfg.swa_window:
        win = jnp.full((cfg.n_layers,), cfg.swa_window, jnp.int32)
        if cfg.global_layers:
            win = win.at[jnp.array(cfg.global_layers)].set(NEG_WINDOW_OFF)
        return win
    w = cfg.swa_window if cfg.swa_window else NEG_WINDOW_OFF
    return jnp.full((cfg.n_layers,), w, jnp.int32)


# -------------------------------------------------------------------- block

def _block(cfg: ModelConfig, pl, x, rope, window, *, return_kv=False):
    """One transformer block, full-sequence path.  Returns (x, aux, kv)."""
    aux = jnp.float32(0.0)
    kv = None
    if cfg.family == "ssm":
        out = ssm_mod.ssm_forward(cfg, pl["ssm"], rmsnorm(x, pl["norm1"], cfg.norm_eps),
                                  return_state=return_kv)
        if return_kv:
            out, kv = out
        return x + out, aux, kv

    h = rmsnorm(x, pl["norm1"], cfg.norm_eps)
    if cfg.attn_kind == "mla":
        a = attn.mla_forward(cfg, pl["attn"], h, rope, return_kv=return_kv)
    else:
        a = attn.gqa_forward(cfg, pl["attn"], h, rope, window=window,
                             return_kv=return_kv)
    if return_kv:
        a, kv = a
    if cfg.family == "hybrid":
        s_out = ssm_mod.ssm_forward(cfg, pl["ssm"], h, return_state=return_kv)
        if return_kv:
            s_out, sstate = s_out
            kv = (*kv, *sstate)
        x = x + pl["fuse_a"].astype(x.dtype) * a + pl["fuse_s"].astype(x.dtype) * s_out
    else:
        x = x + a

    h2 = rmsnorm(x, pl["norm2"], cfg.norm_eps)
    if cfg.family == "moe":
        m, aux = moe_mod.moe_forward(cfg, pl["moe"], h2)
        if cfg.dense_residual:
            m = m + swiglu(h2, pl["mlp"]["wg"].astype(x.dtype),
                           pl["mlp"]["wu"].astype(x.dtype),
                           pl["mlp"]["wd"].astype(x.dtype))
        x = x + m
    else:
        x = x + swiglu(h2, pl["mlp"]["wg"].astype(x.dtype),
                       pl["mlp"]["wu"].astype(x.dtype),
                       pl["mlp"]["wd"].astype(x.dtype))
    return x, aux, kv


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


# ----------------------------------------------------------------- forward

def _embed(cfg, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdt)
    return x


def _rope_for(cfg: ModelConfig, positions):
    """positions: (B,S) int32, or (3,B,S) for mrope; returns (cos, sin)."""
    if cfg.pos == "learned":
        return None
    dim = cfg.qk_rope_dim * 2 if cfg.attn_kind == "mla" else cfg.head_dim
    if cfg.pos == "mrope":
        return mrope_cos_sin(positions, dim, cfg.rope_theta, cfg.mrope_sections)
    return rope_cos_sin(positions, dim, cfg.rope_theta)


def forward(cfg: ModelConfig, params, tokens, positions=None):
    """Train-path logits.  tokens: (B,S) int32.  Returns (logits_f32, aux)."""
    B, S = tokens.shape[-2:] if tokens.ndim >= 2 else (1, tokens.shape[0])
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        if cfg.pos == "mrope":
            positions = jnp.broadcast_to(positions, (3, B, S))
    x = _embed(cfg, params, tokens)
    if cfg.pos == "learned":
        x = x + params["pos_table"][:S][None].astype(x.dtype)
    rope = _rope_for(cfg, positions)
    windows = layer_windows(cfg)

    def body(carry, xs):
        pl, win = xs
        y, aux, _ = _block(cfg, pl, carry, rope, win)
        return y, aux

    x, auxs = jax.lax.scan(_remat(cfg, body), x, (params["layers"], windows))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    un = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = (x @ un.astype(x.dtype)).astype(jnp.float32)
    return logits, jnp.sum(auxs)


def loss_fn(cfg: ModelConfig, params, batch, *, aux_weight=0.01):
    """Next-token cross-entropy.  batch: {tokens: (B,S)}."""
    tokens = batch["tokens"]
    logits, aux = forward(cfg, params, tokens, batch.get("positions"))
    tgt = tokens[:, 1:]
    lg = logits[:, :-1]
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# ------------------------------------------------------------------ serving

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Decode cache pytree, stacked over layers."""
    L = cfg.n_layers
    c = {"pos": jnp.zeros((), jnp.int32)}
    cdt = cfg.cdt
    if cfg.family != "ssm":
        if cfg.attn_kind == "mla":
            c["ckv"] = jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), cdt)
            c["krope"] = jnp.zeros((L, batch, max_len, cfg.qk_rope_dim), cdt)
        else:
            kvh, hd = cfg.n_kv_heads, cfg.head_dim
            c["k"] = jnp.zeros((L, batch, max_len, kvh, hd), cdt)
            c["v"] = jnp.zeros((L, batch, max_len, kvh, hd), cdt)
    if cfg.family in ("ssm", "hybrid"):
        st, cv = ssm_mod.ssm_init_cache(cfg, batch, cdt)
        c["ssm_state"] = jnp.broadcast_to(st[None], (L, *st.shape))
        c["conv_state"] = jnp.broadcast_to(cv[None], (L, *cv.shape))
    return c


def prefill(cfg: ModelConfig, params, tokens, max_len: int, positions=None):
    """Run the full prompt, return (last_logits, cache)."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        if cfg.pos == "mrope":
            positions = jnp.broadcast_to(positions, (3, B, S))
    x = _embed(cfg, params, tokens)
    if cfg.pos == "learned":
        x = x + params["pos_table"][:S][None].astype(x.dtype)
    rope = _rope_for(cfg, positions)
    windows = layer_windows(cfg)

    def body(carry, xs):
        pl, win = xs
        y, _aux, kv = _block(cfg, pl, carry, rope, win, return_kv=True)
        return y, kv

    x, kvs = jax.lax.scan(body, x, (params["layers"], windows))
    cache = init_cache(cfg, B, max_len)
    cache["pos"] = jnp.int32(S)
    if cfg.family == "ssm":
        cache["ssm_state"] = kvs[0]
        cache["conv_state"] = kvs[1]
    else:
        if cfg.attn_kind == "mla":
            ckv, krope = kvs[0], kvs[1]
            cache["ckv"] = jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), 0, axis=2)
            cache["krope"] = jax.lax.dynamic_update_slice_in_dim(
                cache["krope"], krope.astype(cache["krope"].dtype), 0, axis=2)
        else:
            k, v = kvs[0], kvs[1]
            cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=2)
            cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=2)
        if cfg.family == "hybrid":
            cache["ssm_state"] = kvs[2]
            cache["conv_state"] = kvs[3]
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    un = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = (x[:, -1:] @ un.astype(x.dtype)).astype(jnp.float32)
    return logits, cache


def _block_decode(cfg: ModelConfig, pl, x, rope, window, caches, pos):
    """One block, one token.  ``caches``: per-layer slice tuple."""
    new = []
    if cfg.family == "ssm":
        h = rmsnorm(x, pl["norm1"], cfg.norm_eps)
        out, st, cv = ssm_mod.ssm_decode(cfg, pl["ssm"], h, caches[0], caches[1])
        return x + out, (st, cv)

    h = rmsnorm(x, pl["norm1"], cfg.norm_eps)
    if cfg.attn_kind == "mla":
        a, ckv, krope = attn.mla_decode(cfg, pl["attn"], h, caches[0], caches[1],
                                        pos, rope)
        new += [ckv, krope]
    else:
        a, kc, vc = attn.gqa_decode(cfg, pl["attn"], h, caches[0], caches[1],
                                    pos, rope, window=window)
        new += [kc, vc]
    if cfg.family == "hybrid":
        s_out, st, cv = ssm_mod.ssm_decode(cfg, pl["ssm"], h, caches[2], caches[3])
        new += [st, cv]
        x = x + pl["fuse_a"].astype(x.dtype) * a + pl["fuse_s"].astype(x.dtype) * s_out
    else:
        x = x + a
    h2 = rmsnorm(x, pl["norm2"], cfg.norm_eps)
    if cfg.family == "moe":
        m, _ = moe_mod.moe_forward(cfg, pl["moe"], h2)
        if cfg.dense_residual:
            m = m + swiglu(h2, pl["mlp"]["wg"].astype(x.dtype),
                           pl["mlp"]["wu"].astype(x.dtype),
                           pl["mlp"]["wd"].astype(x.dtype))
        x = x + m
    else:
        x = x + swiglu(h2, pl["mlp"]["wg"].astype(x.dtype),
                       pl["mlp"]["wu"].astype(x.dtype),
                       pl["mlp"]["wd"].astype(x.dtype))
    return x, tuple(new)


def _cache_keys(cfg: ModelConfig):
    if cfg.family == "ssm":
        return ("ssm_state", "conv_state")
    keys = ("ckv", "krope") if cfg.attn_kind == "mla" else ("k", "v")
    if cfg.family == "hybrid":
        keys = (*keys, "ssm_state", "conv_state")
    return keys


def decode_step(cfg: ModelConfig, params, cache, tokens):
    """One serving step.  tokens: (B, 1) int32; returns (logits, cache)."""
    B = tokens.shape[0]
    pos = cache["pos"]
    if cfg.pos == "mrope":
        positions = jnp.broadcast_to(pos.astype(jnp.int32), (3, B, 1))
    else:
        positions = jnp.broadcast_to(pos.astype(jnp.int32), (B, 1))
    x = _embed(cfg, params, tokens)
    if cfg.pos == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(params["pos_table"], pos, 1)[None].astype(x.dtype)
    rope = _rope_for(cfg, positions)
    windows = layer_windows(cfg)
    keys = _cache_keys(cfg)

    def body(carry, xs):
        pl, win = xs[0], xs[1]
        caches = xs[2:]
        y, new = _block_decode(cfg, pl, carry, rope, win, caches, pos)
        return y, new

    x, new = jax.lax.scan(body, x, (params["layers"], windows,
                                    *[cache[k] for k in keys]))
    for k, v in zip(keys, new):
        cache[k] = v
    cache["pos"] = pos + 1
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    un = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = (x @ un.astype(x.dtype)).astype(jnp.float32)
    return logits, cache
