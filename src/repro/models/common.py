"""Model configuration shared by all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # -- attention ---------------------------------------------------------
    attn_kind: str = "gqa"           # gqa | mla | none
    pos: str = "rope"                # rope | learned | mrope
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()
    swa_window: int = 0              # 0 = full attention
    global_layers: Tuple[int, ...] = ()   # hybrid: layers with full attention
    attn_block: int = 1024           # kv-block for blocked (flash-style) attn
    # --- TP-friendliness (see EXPERIMENTS.md §Perf) ---------------------
    # repeat KV heads to full H in the train/prefill path so the attention
    # einsums shard over the model axis even when n_kv_heads < TP degree
    # (otherwise XLA replicates ALL attention compute/memory per shard).
    tp_repeat_kv: bool = True
    # pad the (repeated) head dim to a multiple of this so odd head counts
    # (25/28/40/56) shard over a 16-way model axis; 0 = off.
    pad_heads_to: int = 0

    # -- MLA (MiniCPM3 / DeepSeek style) ------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # -- MoE -----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0                # expert hidden (d_ff used for dense MLP)
    dense_residual: bool = False     # Arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25
    moe_group: int = 2048            # tokens per dispatch group

    # -- SSM (Mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_pad_heads_to: int = 0        # pad SSD heads so they shard over TP

    # -- encoder-decoder -------------------------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0
    max_positions: int = 0           # learned-position table size (0: unused)

    # -- numerics / misc --------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "dots"              # none | dots | full

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def pdt(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdt(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def d_inner(self) -> int:        # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline math)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di, n, h = self.d_inner, self.ssm_state, self.ssm_heads
            per = d * (2 * di + 2 * n + h) + di * (self.ssm_conv + 1) + 2 * h + di * d + d
            return emb + self.n_layers * per + d
        att = self._attn_params()
        mlp = 3 * d * self.d_ff if self.d_ff else 0
        per = att + mlp + 2 * d
        if self.family == "moe":
            per = att + 2 * d + d * self.n_experts + self.n_experts * 3 * d * self.d_expert
            if self.dense_residual:
                per += 3 * d * self.d_ff
        if self.family == "hybrid":
            di, n = self.d_inner, self.ssm_state
            ssm = d * (2 * di + 2 * n + self.ssm_heads) + di * (self.ssm_conv + 1) \
                + 2 * self.ssm_heads + di * d
            per = att + mlp + ssm + 3 * d
        layers = self.n_layers
        if self.family == "encdec":
            layers = self.enc_layers + self.dec_layers
            per += att + d          # cross-attention + extra norm (decoder avg.)
        return emb + layers * per + d

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        if self.attn_kind == "mla":
            q = d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
            kv = d * (self.kv_lora_rank + self.qk_rope_dim) \
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
            o = self.n_heads * self.v_head_dim * d
            return q + kv + o
        if self.attn_kind == "none":
            return 0
        return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k only) for 6·N_active·D."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        att = self._attn_params()
        per = att + 2 * d + d * self.n_experts + self.top_k * 3 * d * self.d_expert
        if self.dense_residual:
            per += 3 * d * self.d_ff
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return emb + self.n_layers * per + d
