"""Whisper-style encoder-decoder backbone.

Per the assignment spec the audio frontend (mel + conv downsampling) is a
STUB: ``input_specs`` provides precomputed frame embeddings (B, S_enc, d).
Encoder: bidirectional attention + sinusoidal positions.  Decoder: causal
self-attention + cross-attention to encoder states + learned positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import ModelConfig
from repro.models.layers import (blocked_attention, decode_attention,
                                 dense_init, layernorm, swiglu)


def sinusoid_pos(S, d):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _ln_init(cfg):
    return {"w": jnp.ones((cfg.d_model,), cfg.pdt),
            "b": jnp.zeros((cfg.d_model,), cfg.pdt)}


def _mlp_init(cfg, key):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key)
    return {"w1": dense_init(k1, (d, f), d, cfg.pdt),
            "b1": jnp.zeros((f,), cfg.pdt),
            "w2": dense_init(k2, (f, d), f, cfg.pdt),
            "b2": jnp.zeros((d,), cfg.pdt)}


def _enc_layer_init(cfg, key):
    k1, k2 = jax.random.split(key)
    return {"ln1": _ln_init(cfg), "attn": attn.gqa_init(cfg, k1),
            "ln2": _ln_init(cfg), "mlp": _mlp_init(cfg, k2)}


def _dec_layer_init(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": _ln_init(cfg), "self": attn.gqa_init(cfg, k1),
            "ln2": _ln_init(cfg), "cross": attn.gqa_init(cfg, k2),
            "ln3": _ln_init(cfg), "mlp": _mlp_init(cfg, k3)}


def init_encdec(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4)
    return {
        "embed": dense_init(ks[0], (cfg.vocab, cfg.d_model), cfg.d_model, cfg.pdt),
        "dec_pos": (0.02 * jax.random.normal(ks[1], (cfg.max_positions, cfg.d_model))).astype(cfg.pdt),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(cfg, k))(
            jax.random.split(ks[2], cfg.enc_layers)),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(cfg, k))(
            jax.random.split(ks[3], cfg.dec_layers)),
        "enc_ln": _ln_init(cfg), "dec_ln": _ln_init(cfg),
    }


def _mlp(pl, x):
    h = jax.nn.gelu(x @ pl["w1"].astype(x.dtype) + pl["b1"].astype(x.dtype))
    return h @ pl["w2"].astype(x.dtype) + pl["b2"].astype(x.dtype)


def _ln(pl, x, eps):
    return layernorm(x, pl["w"], pl["b"], eps)


def encode(cfg: ModelConfig, params, frames):
    """frames: (B, S_enc, d) stub embeddings -> encoder states."""
    B, S, d = frames.shape
    x = frames.astype(cfg.cdt) + sinusoid_pos(S, d)[None].astype(cfg.cdt)

    def body(carry, pl):
        h = _ln(pl["ln1"], carry, cfg.norm_eps)
        a = attn.gqa_forward(cfg, pl["attn"], h, None, causal=False)
        x = carry + a
        x = x + _mlp(pl["mlp"], _ln(pl["ln2"], x, cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return _ln(params["enc_ln"], x, cfg.norm_eps)


def _dec_block(cfg, pl, x, enc_kv, *, self_kv=None, return_kv=False):
    """Full-sequence decoder block.  ``enc_kv``: (k_e, v_e) precomputed."""
    h = _ln(pl["ln1"], x, cfg.norm_eps)
    a = attn.gqa_forward(cfg, pl["self"], h, None, causal=True,
                         return_kv=return_kv)
    kv = None
    if return_kv:
        a, kv = a
    x = x + a
    h = _ln(pl["ln2"], x, cfg.norm_eps)
    B, S, _ = h.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (h @ pl["cross"]["wq"].astype(h.dtype)).reshape(B, S, H, hd)
    o = blocked_attention(q, enc_kv[0], enc_kv[1], causal=False,
                          block=cfg.attn_block)
    x = x + o.reshape(B, S, -1) @ pl["cross"]["wo"].astype(h.dtype)
    x = x + _mlp(pl["mlp"], _ln(pl["ln3"], x, cfg.norm_eps))
    return x, kv


def cross_kv(cfg, pl_cross, enc):
    B, Se, _ = enc.shape
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    k = (enc @ pl_cross["wk"].astype(enc.dtype)).reshape(B, Se, KV, hd)
    v = (enc @ pl_cross["wv"].astype(enc.dtype)).reshape(B, Se, KV, hd)
    return k, v


def forward(cfg: ModelConfig, params, frames, dec_tokens):
    """Train path.  Returns (logits over decoder positions, aux=0)."""
    enc = encode(cfg, params, frames)
    B, Sd = dec_tokens.shape
    x = jnp.take(params["embed"], dec_tokens, axis=0).astype(cfg.cdt)
    x = x + params["dec_pos"][:Sd][None].astype(x.dtype)

    def body(carry, pl):
        ekv = cross_kv(cfg, pl["cross"], enc)
        y, _ = _dec_block(cfg, pl, carry, ekv)
        return y, None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = _ln(params["dec_ln"], x, cfg.norm_eps)
    logits = (x @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)
    return logits, jnp.float32(0.0)


def loss_fn(cfg: ModelConfig, params, batch, **_kw):
    logits, _ = forward(cfg, params, batch["frames"], batch["dec_tokens"])
    tgt = batch["dec_tokens"][:, 1:]
    lg = logits[:, :-1]
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    return loss, {"ce": loss, "aux": jnp.float32(0.0)}


# -------------------------------------------------------------- serving

def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    L = cfg.dec_layers
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    cdt = cfg.cdt
    return {
        "pos": jnp.zeros((), jnp.int32),
        "k": jnp.zeros((L, batch, max_len, KV, hd), cdt),
        "v": jnp.zeros((L, batch, max_len, KV, hd), cdt),
        "ek": jnp.zeros((L, batch, enc_len, KV, hd), cdt),
        "ev": jnp.zeros((L, batch, enc_len, KV, hd), cdt),
    }


def prefill(cfg: ModelConfig, params, frames, dec_tokens, max_len: int):
    """Encode + run the decoder prompt; returns (last_logits, cache)."""
    enc = encode(cfg, params, frames)
    B, Sd = dec_tokens.shape
    x = jnp.take(params["embed"], dec_tokens, axis=0).astype(cfg.cdt)
    x = x + params["dec_pos"][:Sd][None].astype(x.dtype)

    def body(carry, pl):
        ekv = cross_kv(cfg, pl["cross"], enc)
        y, kv = _dec_block(cfg, pl, carry, ekv, return_kv=True)
        return y, (kv[0], kv[1], ekv[0], ekv[1])

    x, (k, v, ek, ev) = jax.lax.scan(body, x, params["dec_layers"])
    cache = init_cache(cfg, B, max_len, enc.shape[1])
    cache["pos"] = jnp.int32(Sd)
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cfg.cdt), 0, axis=2)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cfg.cdt), 0, axis=2)
    cache["ek"], cache["ev"] = ek.astype(cfg.cdt), ev.astype(cfg.cdt)
    x = _ln(params["dec_ln"], x, cfg.norm_eps)
    logits = (x[:, -1:] @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, tokens):
    """One decoder token against self+cross caches."""
    B = tokens.shape[0]
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdt)
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1)[None].astype(x.dtype)
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    def body(carry, xs):
        pl, kc, vc, ek, ev = xs
        h = _ln(pl["ln1"], carry, cfg.norm_eps)
        a, kc, vc = attn.gqa_decode(cfg, pl["self"], h, kc, vc, pos, None)
        x = carry + a
        h = _ln(pl["ln2"], x, cfg.norm_eps)
        q = (h @ pl["cross"]["wq"].astype(h.dtype)).reshape(B, 1, H, hd)
        o = decode_attention(q, ek, ev, pos=ek.shape[1] - 1)
        x = x + o.reshape(B, 1, -1) @ pl["cross"]["wo"].astype(h.dtype)
        x = x + _mlp(pl["mlp"], _ln(pl["ln3"], x, cfg.norm_eps))
        return x, (kc, vc)

    x, (k, v) = jax.lax.scan(body, x, (params["dec_layers"], cache["k"],
                                       cache["v"], cache["ek"], cache["ev"]))
    cache["k"], cache["v"] = k, v
    cache["pos"] = pos + 1
    x = _ln(params["dec_ln"], x, cfg.norm_eps)
    logits = (x @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)
    return logits, cache
