"""AdamW with global-norm clipping and configurable moment dtype (bf16
moments are the memory posture for the largest assigned model)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: Optional[str] = None   # None: match param dtype
    schedule: Optional[object] = None    # callable step -> lr scale

    def _mdt(self, leaf):
        return jnp.dtype(self.moment_dtype) if self.moment_dtype else leaf.dtype

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, self._mdt(p))
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params):
        step = state["step"] + 1
        if self.clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        else:
            gn = jnp.float32(0.0)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self.lr * (self.schedule(step) if self.schedule else 1.0)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mh = m_new / c1
            vh = v_new / c2
            delta = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return (-lr * delta).astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return updates, {"m": m, "v": v, "step": step}, {"grad_norm": gn}


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))
