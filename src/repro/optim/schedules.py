"""LR schedules (as multiplicative factors on the base lr)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(warmup: int, total: int, floor: float = 0.1):
    def f(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(1.0, float(warmup))
        prog = (step - warmup) / jnp.maximum(1.0, float(total - warmup))
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * jnp.clip(prog, 0, 1)))
        return jnp.where(step < warmup, warm, cos)
    return f


def constant():
    return lambda step: 1.0
