"""Gradient compression (distributed-optimization trick).

Two pieces:
  * ``compress_tree`` — int8 group quantize/dequantize every gradient leaf;
    under pjit this bounds what the data-parallel all-reduce would carry
    (the quantization error is what training actually sees, so convergence
    impact is testable on CPU).
  * ``compressed_psum`` — explicit int8 all-reduce for shard_map code paths
    (pipeline parallelism): quantize, psum the int32 accumulators, dequant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def _leaf_compress(g, group):
    flat = g.reshape(-1)
    pad = (-flat.size) % group
    if pad:
        flat = jnp.pad(flat, (0, pad))
    q, s = kops.quantize(flat, group=group)
    deq = kops.dequantize(q, s, group=group, dtype=g.dtype)
    return deq[:g.size].reshape(g.shape)


def compress_tree(grads, *, group: int = 256):
    """Quantize->dequantize every leaf (simulates int8 gradient exchange)."""
    return jax.tree.map(lambda g: _leaf_compress(g, group), grads)


def compressed_psum(x, axis_name: str, *, group: int = 256):
    """int8-compressed all-reduce for use inside shard_map.

    Wire format is int8 payload + fp32 group scales (an all-gather-based
    all-reduce): ~4x fewer bytes on the link than an fp32 psum; the
    reduction itself happens locally after dequantization.
    """
    flat = x.reshape(-1)
    pad = (-flat.size) % group
    if pad:
        flat = jnp.pad(flat, (0, pad))
    q, s = kops.quantize(flat, group=group)
    qs = jax.lax.all_gather(q, axis_name)        # int8 on the wire
    ss = jax.lax.all_gather(s, axis_name)
    vals = jax.vmap(lambda qq, sc: kops.dequantize(qq, sc, group=group))(qs, ss)
    out = vals.sum(0)
    return out[:x.size].reshape(x.shape).astype(x.dtype)
