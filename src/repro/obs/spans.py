"""Write/read/drain pipeline span profiler, gated by ``Policy.obs_level``.

Span taxonomy (each is one latency :class:`~repro.obs.metrics.Histogram`
in the engine registry; see ``obs/README.md``):

====================  =====  ==============================================
name                  level  covers
====================  =====  ==============================================
``write.op_us``         1    one ``pwrite`` call end to end (split, alloc,
                             fill, group commit)
``write.fill_us``       2    NVMM memcpy of followers+head plus the
                             payload ``pwb``/``pfence`` (libnvram's
                             "persist cost" term)
``write.commit_us``     2    commit-flag store + ``pwb`` + sealing
                             ``psync`` + group-commit wake
``read.load_us``        2    one backend extent fetch (``preadv`` +
                             frame/page install) on a read miss
``read.replay_us``      2    one dirty-page log replay under the
                             cleanup lock
``drain.wait_us``       2    drain thread blocked in ``wait_committed``
``drain.plan_us``       2    ``build_plan`` (merge + coalesce)
``drain.apply_us``      2    ``apply_plan`` (includes pwritev + replays)
``drain.pwritev_us``    2    one backend ``pwritev`` inside apply
``drain.fsync_us``      2    the per-file fsync-epoch loop of one batch
``stall.barrier_us``    1    one ``_drain_barrier`` (fsync, migration,
                             unlink) from enter to drained
``log.alloc_wait_us``   always  backpressure wait in ``LogShard.alloc``
                             (kept by the shard, pooled on read)
====================  =====  ==============================================

Levels: 0 = off (the hot path pays one attribute load + branch — no
allocation, no clock read); 1 = op-level spans + flight commit events;
2 = full per-stage breakdown.  Instrumentation sites follow the

    t0 = time.perf_counter_ns() if obs.lv2 else 0
    ...
    if obs.lv2:
        obs.prof.h_fill.record_ns(time.perf_counter_ns() - t0)

pattern rather than a context manager: entering a ``with`` block
allocates, and the whole point of level 0 is that ``pwrite`` allocates
nothing on behalf of observability.  The :class:`Span` context manager
exists for the cold paths (drain stages, barriers) where clarity beats
the nanoseconds, and it nests: each thread keeps a span stack so a
report can attribute child time.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional

_LEVELS = {
    "write.op_us": 1,
    "stall.barrier_us": 1,
    "write.fill_us": 2,
    "write.commit_us": 2,
    "read.load_us": 2,
    "read.replay_us": 2,
    "drain.wait_us": 2,
    "drain.plan_us": 2,
    "drain.apply_us": 2,
    "drain.pwritev_us": 2,
    "drain.fsync_us": 2,
}

# Report rows are grouped by pipeline position, not alphabetically.
_REPORT_ORDER = [
    "write.op_us", "write.fill_us", "write.commit_us",
    "log.alloc_wait_us",
    "drain.wait_us", "drain.plan_us", "drain.apply_us",
    "drain.pwritev_us", "drain.fsync_us",
    "read.load_us", "read.replay_us",
    "stall.barrier_us",
]


class Span:
    """Nestable timed region.  Allocates — cold paths only."""

    __slots__ = ("_prof", "_hist", "_t0", "name")

    def __init__(self, prof: "SpanProfiler", name: str, hist):
        self._prof = prof
        self._hist = hist
        self.name = name
        self._t0 = 0

    def __enter__(self):
        self._prof._stack().append(self)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        ns = time.perf_counter_ns() - self._t0
        stack = self._prof._stack()
        if stack and stack[-1] is self:
            stack.pop()
        if self._hist is not None:
            self._hist.record_ns(ns)
        return False


class SpanProfiler:
    """The per-engine span surface.

    All fields are created once, before worker threads start, and read
    immutably after — publication rides the thread-start edge.  Hot
    paths read ``lv1``/``lv2`` (plain bools) and the pre-bound
    histogram attributes; nothing here takes a lock.
    """

    def __init__(self, registry, level: int):
        self.registry = registry
        self.level = int(level)
        self.lv1 = self.level >= 1
        self.lv2 = self.level >= 2
        self._tl = threading.local()
        # Histograms exist whenever their level is enabled; the
        # attribute is None otherwise so call sites can be gated on the
        # level bool alone.
        self.h_op = self._mk("write.op_us")
        self.h_fill = self._mk("write.fill_us")
        self.h_commit = self._mk("write.commit_us")
        self.h_read_load = self._mk("read.load_us")
        self.h_read_replay = self._mk("read.replay_us")
        self.h_drain_wait = self._mk("drain.wait_us")
        self.h_drain_plan = self._mk("drain.plan_us")
        self.h_drain_apply = self._mk("drain.apply_us")
        self.h_drain_pwritev = self._mk("drain.pwritev_us")
        self.h_drain_fsync = self._mk("drain.fsync_us")
        self.h_barrier = self._mk("stall.barrier_us")

    def _mk(self, name: str):
        if self.level < _LEVELS[name]:
            return None
        return self.registry.histogram(name)

    def _stack(self) -> List[Span]:
        try:
            return self._tl.stack
        except AttributeError:
            self._tl.stack = []
            return self._tl.stack

    def span(self, name: str) -> Span:
        """Cold-path context manager; a no-op span when the stage's
        level is disabled."""
        return Span(self, name, self.registry.get(name))

    def current(self) -> Optional[Span]:
        stack = self._stack()
        return stack[-1] if stack else None

    # ------------------------------------------------------------ report

    def report(self, extra_hists=()) -> str:
        """The ``--profile`` text table: per-stage count and p50/p95/p99
        plus each stage's share of total recorded time."""
        snap = self.registry.snapshot()
        snaps = {}
        for name in _REPORT_ORDER:
            s = snap.get(name)
            if isinstance(s, dict) and "count" in s:
                snaps[name] = s
        for h in extra_hists:
            snaps[h.name] = h.snapshot()
        rows = [(n, s) for n, s in snaps.items() if s["count"]]
        if not rows:
            return "span profiler: no samples (obs_level=%d)" % self.level
        total_us = sum(s["sum_us"] for _, s in rows)
        out = [f"{'stage':<20}{'count':>9}{'p50_us':>10}{'p95_us':>10}"
               f"{'p99_us':>10}{'total_ms':>10}{'share':>8}"]
        for name, s in rows:
            out.append(
                f"{name:<20}{s['count']:>9}{s['p50_us']:>10.1f}"
                f"{s['p95_us']:>10.1f}{s['p99_us']:>10.1f}"
                f"{s['sum_us'] / 1e3:>10.2f}"
                f"{100.0 * s['sum_us'] / total_us:>7.1f}%")
        return "\n".join(out)
