"""Observability plane: metrics registry, span profiler, flight recorder.

Three layers, one bundle (:class:`ObsPlane`), wired into ``NVCache`` at
construction and threaded through the log shards and the drain pool:

* :mod:`repro.obs.metrics` — typed ``Counter``/``Gauge``/``Histogram``
  behind per-thread shards merged on read; no hot-path locks.
* :mod:`repro.obs.spans` — timed spans over the write pipeline, the
  read-miss path and the drain/barrier stalls, gated by
  ``Policy.obs_level`` so level 0 costs a branch per op.
* :mod:`repro.obs.flight` — a CRC'd ring of fixed-size event records
  carved into the NVMM layout (VERSION 5): the engine's black box,
  decoded into a forensic timeline by ``core/recovery.py`` after a
  crash (``python -m repro.obs.dump``).

See ``src/repro/obs/README.md`` for the metric naming grammar, the span
taxonomy and the flight-record format.
"""
from __future__ import annotations

from repro.obs.flight import FlightRecorder
from repro.obs.metrics import (BoundGauge, Counter, Gauge, Histogram,
                               Registry)
from repro.obs.spans import SpanProfiler


class ObsPlane:
    """Per-engine observability bundle: one registry, one span profiler,
    one flight recorder (when the layout carves a ring).

    Created once in ``NVCache.__init__`` before any worker thread starts
    and published read-only after that — every field here is set exactly
    once and never rebound, so cross-thread visibility rides on the
    thread-start happens-before edge.
    """

    def __init__(self, policy, nvmm=None):
        self.level = policy.obs_level
        self.registry = Registry()
        self.prof = SpanProfiler(self.registry, self.level)
        self.flight = None
        if nvmm is not None and policy.flight_records:
            self.flight = FlightRecorder(nvmm, policy,
                                         registry=self.registry)


__all__ = ["ObsPlane", "Registry", "Counter", "Gauge", "Histogram",
           "BoundGauge", "SpanProfiler", "FlightRecorder"]
