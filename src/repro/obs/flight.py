"""Persistent NVMM flight recorder — the engine's black box.

The VERSION-5 layout carves ``policy.flight_records`` fixed 64-byte
(one cacheline) record slots between the route table and the paged
region (``policy.flight_base``).  Writers append state-transition events
round-robin; after a crash, :func:`decode_ring` rebuilds the surviving
timeline so every torn state comes with the engine's last ~1k actions
(``RecoveryStats.flight_events``, ``python -m repro.obs.dump``).

Record format (``<IHHQQQQQQ``, 56 bytes used, zero-padded to 64)::

    u32 crc      crc32 over bytes [4:56] of the record
    u16 type     EV_* (below)
    u16 flags    reserved, 0
    u64 eseq     monotonic event sequence (never reused; orders the ring
                 across wraparound laps)
    u64 t_ns     time.monotonic_ns() at record time
    u64 a,b,c,d  event-specific payload (see EV_FIELDS)

Persistence protocol: slot store + ``pwb`` only — **no fence**.  The
engine fences constantly (every group commit ends in ``psync``), so
flight lines piggyback on the next engine fence instead of paying one
per event; the price is that the newest record(s) may be torn or lost
at a crash.  That is the right trade for a black box: the decoder
CRC-validates every slot, drops torn tails, and orders survivors by
``eseq`` (strictly increasing == seq-consistent).  The ring lives below
``page_base``, so ``repro.analysis.pmcheck`` can never mistake a flight
store for a log/frame/route commit point, and the missing fence is
invisible to PM001/PM002 because flight slots are never inside a commit
window's covered range.
"""
from __future__ import annotations

import struct
import time
import zlib
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core import locking

FLIGHT_REC = 64
_REC = struct.Struct("<IHHQQQQQQ")

_U64_MASK = (1 << 64) - 1


def _u64(v) -> int:
    return 0 if v is None else int(v) & _U64_MASK

EV_ATTACH = 1            # a=obs_level, b=shards, c=flight_records
EV_COMMIT = 2            # a=sid, b=group head seq, c=head entry idx, d=k
EV_BATCH = 3             # a=sid, b=start idx, c=entries drained
EV_BARRIER_ENTER = 4     # a=fdid, b=shards drained behind the barrier
EV_BARRIER_EXIT = 5      # a=fdid
EV_BACKPRESSURE = 6      # a=sid, b=wait_ns
EV_MODE_MIGRATE = 7      # a=fdid, b=1 to paged / 0 to log
EV_ROUTE_EPOCH = 8       # a=fdid, b=new sid, c=new stripe shift (0: move)
EV_META_OP = 9           # a=mop code, b=fdid, c=seq

EV_NAMES = {
    EV_ATTACH: "attach",
    EV_COMMIT: "commit",
    EV_BATCH: "drain_batch",
    EV_BARRIER_ENTER: "barrier_enter",
    EV_BARRIER_EXIT: "barrier_exit",
    EV_BACKPRESSURE: "backpressure",
    EV_MODE_MIGRATE: "mode_migrate",
    EV_ROUTE_EPOCH: "route_epoch",
    EV_META_OP: "meta_op",
}

EV_FIELDS = {
    EV_ATTACH: ("obs_level", "shards", "flight_records", ""),
    EV_COMMIT: ("sid", "seq", "head", "k"),
    EV_BATCH: ("sid", "start", "entries", ""),
    EV_BARRIER_ENTER: ("fdid", "shards", "", ""),
    EV_BARRIER_EXIT: ("fdid", "", "", ""),
    EV_BACKPRESSURE: ("sid", "wait_ns", "", ""),
    EV_MODE_MIGRATE: ("fdid", "to_paged", "", ""),
    EV_ROUTE_EPOCH: ("fdid", "new_sid", "new_shift", ""),
    EV_META_OP: ("op", "fdid", "seq", ""),
}


@dataclass
class FlightEvent:
    eseq: int
    t_ns: int
    type: int
    a: int
    b: int
    c: int
    d: int

    @property
    def name(self) -> str:
        return EV_NAMES.get(self.type, f"ev{self.type}")

    def format_line(self, t0_ns: Optional[int] = None) -> str:
        dt = "" if t0_ns is None else f" +{(self.t_ns - t0_ns) / 1e6:.3f}ms"
        fields = EV_FIELDS.get(self.type, ("a", "b", "c", "d"))
        kv = " ".join(f"{k}={v}" for k, v in
                      zip(fields, (self.a, self.b, self.c, self.d)) if k)
        return f"#{self.eseq:<6}{dt:>12}  {self.name:<14} {kv}"


class FlightRecorder:
    """Round-robin writer over the NVMM flight ring.

    One ``leaf:flight`` lock serializes slot allocation — events are
    rare relative to ops (state transitions, one commit record per
    *group*, not per write), so a plain lock beats a CAS loop here and
    keeps ``eseq`` dense.  Safe to call while holding any lock up to the
    leaf band (the flight lock is a level-90 leaf).
    """

    GUARDED_BY = {
        "_eseq": "_lock",
    }

    def __init__(self, nvmm, policy, registry=None):
        self.nvmm = nvmm
        self.base = policy.flight_base
        self.nrec = policy.flight_records
        self._lock = locking.make_lock("leaf:flight")
        # Continue after the highest surviving eseq so an adopt without
        # a reformat keeps the ring ordering monotonic.
        events, _ = decode_ring(nvmm, policy)
        self._eseq = events[-1].eseq if events else 0
        self.events_total = None
        if registry is not None:
            self.events_total = registry.counter("flight.event_total")

    def record(self, ev_type: int, a: int = 0, b: int = 0, c: int = 0,
               d: int = 0) -> None:
        if self.nrec <= 0:
            return
        t_ns = time.monotonic_ns()
        with self._lock:
            self._eseq += 1
            eseq = self._eseq
        # payloads are descriptive, not load-bearing: clamp None and
        # negative sentinels (e.g. a width migration's new_sid) into u64
        a, b, c, d = (_u64(a), _u64(b), _u64(c), _u64(d))
        body = _REC.pack(0, ev_type, 0, eseq, t_ns, a, b, c, d)
        crc = zlib.crc32(body[4:])
        rec = struct.pack("<I", crc) + body[4:]
        off = self.base + ((eseq - 1) % self.nrec) * FLIGHT_REC
        self.nvmm.store(off, rec)
        self.nvmm.pwb(off, FLIGHT_REC)
        if self.events_total is not None:
            self.events_total.inc()


def decode_ring(nvmm, policy,
                durable: bool = False) -> Tuple[List[FlightEvent], int]:
    """Decode surviving flight records, ordered by ``eseq``.

    Returns ``(events, dropped)`` where ``dropped`` counts non-empty
    slots that failed CRC (torn tail records, or half-written slots from
    a crash mid-store).  ``durable=True`` reads the durable NVMM shadow
    (what survived the crash) instead of the volatile buffer.
    """
    base, nrec = policy.flight_base, policy.flight_records
    events: List[FlightEvent] = []
    dropped = 0
    read = nvmm.load_durable if durable and hasattr(nvmm, "load_durable") \
        else nvmm.load
    for i in range(nrec):
        raw = bytes(read(base + i * FLIGHT_REC, FLIGHT_REC))
        if raw[:_REC.size].count(0) == _REC.size:
            continue                      # never-written slot
        crc, ev_type, _flags, eseq, t_ns, a, b, c, d = \
            _REC.unpack_from(raw)
        if eseq == 0 or zlib.crc32(raw[4:_REC.size]) != crc:
            dropped += 1
            continue
        events.append(FlightEvent(eseq, t_ns, ev_type, a, b, c, d))
    events.sort(key=lambda e: e.eseq)
    return events, dropped


def format_timeline(events: List[FlightEvent], dropped: int = 0) -> str:
    if not events:
        return (f"flight recorder: empty ring"
                f"{f' ({dropped} torn record(s) dropped)' if dropped else ''}")
    t0 = events[0].t_ns
    lines = [f"flight recorder: {len(events)} event(s), "
             f"eseq {events[0].eseq}..{events[-1].eseq}"
             + (f", {dropped} torn record(s) dropped" if dropped else "")]
    lines.extend(e.format_line(t0) for e in events)
    return "\n".join(lines)
