"""Typed metrics behind per-thread shards — no locks on the hot path.

Naming grammar (statically checked by lint L006, dynamically on
``register``): ``subsystem.noun_unit`` where ``subsystem`` and ``noun``
are snake_case and ``unit`` is one of ``total`` (monotonic count),
``count`` (instantaneous count), ``bytes``, ``us``, ``s``, ``ratio``.
Examples: ``nvmm.pwb_total``, ``log.alloc_wait_us``, ``route.skew_ratio``.

Concurrency design: each :class:`Counter`/:class:`Histogram` keeps one
private *cell* per touching thread (``threading.local``).  The hot path
mutates only the calling thread's own cell — plain ``+=`` on attributes
of an object no other thread writes, so there is no lock, no CAS and no
false sharing.  The cold paths (first touch from a new thread, and
``snapshot``/merge on read) take the metric's ``leaf:obs`` lock to
append to / walk the cell list.  Readers sum other threads' cells
without a lock: Python's GIL makes each individual load atomic and the
sums are statistically consistent snapshots, which is all a metrics
plane promises.  The cell objects themselves deliberately declare no
``GUARDED_BY`` table — they are single-writer by construction and the
racecheck shadow would cost exactly the hot-path overhead this design
exists to avoid.

Histograms use fixed log2 nanosecond buckets: bucket ``i`` holds values
``v`` with ``v.bit_length() == i``, i.e. ``[2^(i-1), 2^i)`` (bucket 0 is
the value 0).  Percentiles interpolate linearly inside the bucket and
clamp to the observed min/max, so ``p50/p95/p99/p999`` are exact to
bucket resolution and exact at the distribution edges.
"""
from __future__ import annotations

import re
import threading
from typing import Callable, Dict, Iterable, List, Optional

from repro.core import locking

_UNITS = ("total", "count", "bytes", "us", "s", "ratio")
NAME_RE = re.compile(
    r"^[a-z][a-z0-9]*\.[a-z][a-z0-9_]*_(?:%s)$" % "|".join(_UNITS))

_N_BUCKETS = 64                    # covers 0 .. 2^63-1 ns (~292 years)


def check_name(name: str) -> str:
    if not NAME_RE.match(name):
        raise ValueError(
            f"metric name {name!r} violates the subsystem.noun_unit "
            f"grammar (units: {', '.join(_UNITS)})")
    return name


def _scale_for(name: str) -> float:
    """ns -> reported-unit factor implied by the name's unit suffix."""
    if name.endswith("_us"):
        return 1e-3
    if name.endswith("_s"):
        return 1e-9
    return 1.0


class _CounterCell:
    __slots__ = ("n",)

    def __init__(self):
        self.n = 0


class _HistCell:
    __slots__ = ("buckets", "count", "sum", "vmin", "vmax")

    def __init__(self):
        self.buckets = [0] * _N_BUCKETS
        self.count = 0
        self.sum = 0
        self.vmin = None
        self.vmax = 0


class _Sharded:
    """Base for per-thread-cell metrics: cell discovery + registration."""

    GUARDED_BY = {
        # Appended on a thread's first touch, walked by snapshot readers;
        # the cells' *contents* are single-writer (see module docstring).
        "_cells": "_lock",
    }

    _CELL = _CounterCell

    def __init__(self, name: str):
        self.name = check_name(name)
        self._lock = locking.make_lock("leaf:obs")
        self._cells: List[object] = []
        self._tl = threading.local()

    def _cell(self):
        tl = self._tl
        try:
            return tl.cell
        except AttributeError:
            cell = self._CELL()
            with self._lock:
                self._cells.append(cell)
            tl.cell = cell
            return cell

    def _all_cells(self) -> List[object]:
        with self._lock:
            return list(self._cells)


class Counter(_Sharded):
    """Monotonic counter; ``inc`` is lock-free on the calling thread's
    private cell."""

    kind = "counter"

    def inc(self, n: int = 1) -> None:
        self._cell().n += n

    @property
    def value(self) -> int:
        return sum(c.n for c in self._all_cells())

    def read(self):
        return self.value


class Gauge:
    """Last-write-wins instantaneous value.  A single GIL-atomic slot —
    gauges are set from one place at a time (no read-modify-write), so a
    shard split buys nothing."""

    kind = "gauge"

    GUARDED_BY = {
        # Single plain slot: every set is one STORE_ATTR, every read one
        # LOAD_ATTR; last-write-wins is the gauge contract.
        "_value": locking.VOLATILE,
    }

    def __init__(self, name: str):
        self.name = check_name(name)
        self._value = 0.0

    def set(self, v) -> None:
        self._value = v

    @property
    def value(self):
        return self._value

    def read(self):
        return self._value


class BoundGauge:
    """Gauge computed on read from a callback — the adapter that lets
    pre-existing plain counters (``nvmm.stats_pwb`` et al.) surface in
    the registry without being rewritten."""

    kind = "bound"

    def __init__(self, name: str, fn: Callable[[], object]):
        self.name = check_name(name)
        self._fn = fn

    @property
    def value(self):
        return self._fn()

    def read(self):
        return self._fn()


class Histogram(_Sharded):
    """Fixed log2-ns-bucket latency histogram with per-thread cells.

    ``record_ns`` is the only hot-path entry point; everything else
    merges cells on read.  All derived statistics are zero-count safe
    (``mean``/``percentile`` return 0.0 on an empty histogram).
    """

    kind = "histogram"
    _CELL = _HistCell

    def record_ns(self, ns: int) -> None:
        if ns < 0:
            ns = 0
        c = self._cell()
        i = ns.bit_length()
        if i >= _N_BUCKETS:
            i = _N_BUCKETS - 1
        c.buckets[i] += 1
        c.count += 1
        c.sum += ns
        if c.vmin is None or ns < c.vmin:
            c.vmin = ns
        if ns > c.vmax:
            c.vmax = ns

    # ------------------------------------------------------------- reads

    def _merged(self):
        buckets = [0] * _N_BUCKETS
        count = 0
        total = 0
        vmin = None
        vmax = 0
        for c in self._all_cells():
            cb = c.buckets
            for i in range(_N_BUCKETS):
                buckets[i] += cb[i]
            count += c.count
            total += c.sum
            if c.vmin is not None and (vmin is None or c.vmin < vmin):
                vmin = c.vmin
            if c.vmax > vmax:
                vmax = c.vmax
        return buckets, count, total, (vmin or 0), vmax

    @property
    def count(self) -> int:
        return sum(c.count for c in self._all_cells())

    @property
    def sum_ns(self) -> int:
        return sum(c.sum for c in self._all_cells())

    @property
    def sum_s(self) -> float:
        return self.sum_ns * 1e-9

    def mean_ns(self) -> float:
        n = 0
        s = 0
        for c in self._all_cells():
            n += c.count
            s += c.sum
        return s / n if n else 0.0

    def percentile_ns(self, q: float) -> float:
        """q in [0, 1].  Linear interpolation inside the log2 bucket,
        clamped to observed min/max.  0.0 when empty."""
        buckets, count, _total, vmin, vmax = self._merged()
        return _percentile(buckets, count, vmin, vmax, q)

    def snapshot(self) -> Dict[str, object]:
        return _hist_snapshot(self.name, *self._merged())

    def read(self):
        return self.snapshot()

    @staticmethod
    def merged_snapshot(name: str,
                        hists: Iterable["Histogram"]) -> Dict[str, object]:
        """One snapshot over several histograms' pooled buckets (e.g. the
        per-shard alloc-wait histograms reported as one metric)."""
        buckets = [0] * _N_BUCKETS
        count = 0
        total = 0
        vmin = None
        vmax = 0
        for h in hists:
            b, n, s, lo, hi = h._merged()
            for i in range(_N_BUCKETS):
                buckets[i] += b[i]
            count += n
            total += s
            if n and (vmin is None or lo < vmin):
                vmin = lo
            if hi > vmax:
                vmax = hi
        return _hist_snapshot(name, buckets, count, total, (vmin or 0),
                              vmax)


def _percentile(buckets, count, vmin, vmax, q) -> float:
    if count <= 0:
        return 0.0
    target = q * count
    cum = 0.0
    for i, n in enumerate(buckets):
        if n == 0:
            continue
        if cum + n >= target:
            lo = 0 if i == 0 else 1 << (i - 1)
            hi = 1 if i == 0 else 1 << i
            frac = (target - cum) / n
            v = lo + (hi - lo) * frac
            return float(min(max(v, vmin), vmax))
        cum += n
    return float(vmax)


def _hist_snapshot(name, buckets, count, total, vmin, vmax):
    scale = _scale_for(name)
    unit = name.rsplit("_", 1)[-1]

    def cv(ns):
        return ns * scale

    return {
        "count": count,
        f"sum_{unit}": cv(total),
        f"mean_{unit}": cv(total / count) if count else 0.0,
        f"min_{unit}": cv(vmin if count else 0),
        f"max_{unit}": cv(vmax),
        f"p50_{unit}": cv(_percentile(buckets, count, vmin, vmax, 0.50)),
        f"p95_{unit}": cv(_percentile(buckets, count, vmin, vmax, 0.95)),
        f"p99_{unit}": cv(_percentile(buckets, count, vmin, vmax, 0.99)),
        f"p999_{unit}": cv(_percentile(buckets, count, vmin, vmax, 0.999)),
    }


class Registry:
    """Name -> metric table plus read-time bindings over legacy counters.

    Registration happens at engine construction (single-threaded); reads
    happen from ``api.stats()`` and the ``--profile`` report.  Both are
    cold, so one plain lock covers the table.
    """

    GUARDED_BY = {
        "_metrics": "_lock",
        "_groups": "_lock",
        "_summaries": "_lock",
    }

    def __init__(self):
        self._lock = locking.make_lock("leaf:obs")
        self._metrics: Dict[str, object] = {}
        self._groups: List[tuple] = []       # (name->key map, fn)
        self._summaries: List[tuple] = []    # (name, fn -> dict)

    def _adopt(self, metric):
        with self._lock:
            if metric.name in self._metrics:
                raise ValueError(f"metric {metric.name!r} already "
                                 f"registered")
            self._metrics[metric.name] = metric
        return metric

    def counter(self, name: str) -> Counter:
        return self._adopt(Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._adopt(Gauge(name))

    def histogram(self, name: str) -> Histogram:
        return self._adopt(Histogram(name))

    def bind(self, name: str, fn: Callable[[], object]) -> BoundGauge:
        return self._adopt(BoundGauge(name, fn))

    def bind_group(self, names: Dict[str, str],
                   fn: Callable[[], dict]) -> None:
        """One callback returning a dict, fanned out to several metric
        names (``{metric_name: dict_key}``) — preserves the coherence of
        subsystems that already snapshot under one lock."""
        for n in names:
            check_name(n)
        with self._lock:
            for n in names:
                if n in self._metrics:
                    raise ValueError(f"metric {n!r} already registered")
                self._metrics[n] = None      # reserve the name
            self._groups.append((dict(names), fn))

    def bind_summary(self, name: str, fn: Callable[[], dict]) -> None:
        """A callback producing a full histogram-style snapshot dict
        under one name (e.g. per-shard histograms pooled on read)."""
        check_name(name)
        with self._lock:
            if name in self._metrics:
                raise ValueError(f"metric {name!r} already registered")
            self._metrics[name] = None       # reserve the name
            self._summaries.append((name, fn))

    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            metrics = [m for m in self._metrics.values() if m is not None]
            groups = list(self._groups)
            summaries = list(self._summaries)
        out: Dict[str, object] = {}
        for m in metrics:
            out[m.name] = m.read()
        for names, fn in groups:
            d = fn()
            for name, key in names.items():
                out[name] = d.get(key, 0)
        for name, fn in summaries:
            out[name] = fn()
        return out
