"""Flight-recorder forensic dump — ``python -m repro.obs.dump``.

Two modes:

* ``python -m repro.obs.dump <image.bin> [--policy test|paper]`` —
  decode the flight ring out of a raw NVMM image (the byte dump of a
  region, e.g. ``bytes(nvmm.load(0, nvmm.size))`` written to a file)
  and print the surviving timeline.  The policy choice must match the
  image's geometry — the superblock is validated first and a mismatch
  is reported rather than mis-decoded.
* ``python -m repro.obs.dump --selftest`` — build a small engine,
  run writes/namespace ops, inject a power loss mid-workload, recover,
  and print the post-crash forensic timeline.  Exit 1 if the recovered
  timeline is empty or not seq-consistent — CI runs this as the flight
  smoke.
"""
from __future__ import annotations

import argparse
import sys

from repro.core.policy import PAPER_DEFAULT, TEST_SMALL
from repro.obs.flight import decode_ring, format_timeline

_POLICIES = {"test": TEST_SMALL, "paper": PAPER_DEFAULT}


class _ImageNVMM:
    """Read-only NVMM shim over a raw region byte dump."""

    def __init__(self, buf: bytes):
        self._buf = buf
        self.size = len(buf)

    def load(self, off: int, n: int) -> memoryview:
        return memoryview(self._buf)[off:off + n]

    def load_u64(self, off: int) -> int:
        import struct
        return struct.unpack_from("<Q", self._buf, off)[0]


def dump_image(path: str, policy) -> int:
    with open(path, "rb") as fh:
        buf = fh.read()
    if len(buf) < policy.nvmm_bytes:
        print(f"image is {len(buf)} bytes but the {policy!r} geometry "
              f"needs {policy.nvmm_bytes} — wrong --policy?",
              file=sys.stderr)
        return 1
    nvmm = _ImageNVMM(buf)
    from repro.core.log import MAGIC
    if nvmm.load_u64(0) != MAGIC:
        print("no NVCache superblock at offset 0 — not a region image?",
              file=sys.stderr)
        return 1
    events, dropped = decode_ring(nvmm, policy)
    print(format_timeline(events, dropped))
    return 0


def selftest(verbose: bool = True) -> int:
    """Crash-inject one small engine and dump the recovered timeline."""
    import dataclasses

    from repro.core import recovery
    from repro.core.api import NVCache
    from repro.core.nvmm import NVMM
    from repro.storage.tiers import Tier

    pol = dataclasses.replace(TEST_SMALL, obs_level=1)
    nvmm = NVMM(pol.nvmm_bytes, track=True)
    tier = Tier(scale=0.0)
    nv = NVCache(pol, tier, nvmm=nvmm, recover=False)
    fd = nv.open("/flight-selftest")
    for i in range(40):
        nv.pwrite(fd, bytes([i % 251]) * 64, i * 64)
    nv.close(fd)
    nv.rename("/flight-selftest", "/flight-renamed")
    fd = nv.open("/flight-renamed")
    for i in range(8):
        nv.pwrite(fd, b"\xab" * 64, i * 64)
    # power loss: drain threads die in place, volatile NVMM lines are lost
    nv._crashed = True
    nv.cleanup.power_loss()
    nvmm.crash()
    stats = recovery.recover(nvmm, pol, tier)
    events = stats.flight_events
    if verbose:
        print(format_timeline(events, stats.flight_torn_dropped))
        print(f"recovery: replayed={stats.entries_replayed} "
              f"meta={stats.meta_ops} "
              f"torn_flight_dropped={stats.flight_torn_dropped}")
    if not events:
        print("selftest FAILED: empty flight timeline after crash",
              file=sys.stderr)
        return 1
    seqs = [e.eseq for e in events]
    if any(b <= a for a, b in zip(seqs, seqs[1:])):
        print("selftest FAILED: flight timeline not seq-consistent",
              file=sys.stderr)
        return 1
    print(f"selftest OK: {len(events)} events, "
          f"eseq {seqs[0]}..{seqs[-1]}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.dump",
        description="decode an NVMM flight-recorder ring")
    ap.add_argument("image", nargs="?", help="raw NVMM region image file")
    ap.add_argument("--policy", choices=sorted(_POLICIES), default="test",
                    help="geometry of the image (default: test)")
    ap.add_argument("--selftest", action="store_true",
                    help="crash-inject a small engine and dump its ring")
    args = ap.parse_args(argv)
    if args.selftest:
        return selftest()
    if not args.image:
        ap.error("an image file (or --selftest) is required")
    return dump_image(args.image, _POLICIES[args.policy])


if __name__ == "__main__":
    sys.exit(main())
